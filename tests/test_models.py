"""Per-arch smoke tests + model-level consistency checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import TrainConfig
from repro.launch import steps
from repro.models import transformer as T
from repro.optim import adamw_init

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B, S, key=KEY):
    kw = {}
    if cfg.embeds_input:
        kw["embeds"] = jax.random.normal(key, (B, S, cfg.d_model),
                                         jnp.float32)
    else:
        kw["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.pos_type == "mrope":
        kw["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, None], (3, B, S))
    return kw


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_forward(arch):
    """One forward on the reduced config: shapes + finiteness."""
    cfg = configs.smoke(arch)
    params, axes = T.init(cfg, KEY)
    B, S = 2, 32
    kw = _inputs(cfg, B, S)
    logits, _ = T.forward(cfg, params, kw.get("tokens"),
                          embeds=kw.get("embeds"),
                          positions=kw.get("positions"), mode="train")
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_train_step(arch):
    """One optimizer step on CPU: loss finite, params move, no NaNs."""
    cfg = configs.smoke(arch)
    params, _ = T.init(cfg, KEY)
    opt = adamw_init(params)
    B, S = 2, 16
    batch = _inputs(cfg, B, S)
    batch["labels"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    step = steps.make_train_step(cfg, TrainConfig(warmup_steps=1))
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, new_params)
    assert max(jax.tree_util.tree_leaves(moved)) > 0
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ["llama3.2-1b", "recurrentgemma-2b",
                                  "xlstm-1.3b", "musicgen-medium"])
def test_decode_matches_full_forward(arch):
    """prefill+decode must reproduce the full-sequence forward logits."""
    cfg = dataclasses.replace(configs.smoke(arch), compute_dtype="float32")
    params, _ = T.init(cfg, KEY)
    B, S = 2, 24
    kw = _inputs(cfg, B, S + 1)
    full_logits, _ = T.forward(cfg, params, kw.get("tokens"),
                               embeds=kw.get("embeds"), mode="train")
    cache = T.init_cache(cfg, B, S + 1)
    if cfg.embeds_input:
        _, cache = T.prefill_step(cfg, params, embeds=kw["embeds"][:, :S],
                                  cache=cache)
        dec_logits, _ = T.decode_step(cfg, params,
                                      embeds=kw["embeds"][:, S:S + 1],
                                      cache=cache)
    else:
        _, cache = T.prefill_step(cfg, params, kw["tokens"][:, :S],
                                  cache=cache)
        dec_logits, _ = T.decode_step(cfg, params, kw["tokens"][:, S:S + 1],
                                      cache=cache)
    np.testing.assert_allclose(np.asarray(dec_logits[:, 0]),
                               np.asarray(full_logits[:, S]),
                               atol=2e-3, rtol=1e-3)


def test_moe_routing_mass_conservation():
    """Each surviving (token, k) dispatch slot carries its gate weight; the
    combine weights per token sum to ~1 when no drops occur."""
    from repro.models import moe as M
    cfg = dataclasses.replace(configs.smoke("phi3.5-moe-42b-a6.6b"),
                              compute_dtype="float32", capacity_factor=8.0)
    p_ann = M.init_moe_mlp(jax.random.PRNGKey(1), cfg)
    from repro.sharding import split_annotated
    p, _ = split_annotated(p_ann)
    x = 0.1 * jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.float32)
    y = M.moe_mlp(cfg, p, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    # zero input -> zero output (router gates scale expert outputs of 0)
    y0 = M.moe_mlp(cfg, p, jnp.zeros_like(x))
    np.testing.assert_allclose(np.asarray(y0), 0.0, atol=1e-5)


def test_rope_rotation_invariance():
    """RoPE preserves norms and relative-position inner products."""
    from repro.models.layers import apply_rope
    x = jax.random.normal(KEY, (1, 8, 2, 64), jnp.float32)
    pos = jnp.arange(8, dtype=jnp.int32)[None]
    r = apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(r, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> independent of p
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 64))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 64))
    def ip(p, d):
        rq = apply_rope(q, jnp.asarray([[p]]), 10000.0)
        rk = apply_rope(k, jnp.asarray([[p + d]]), 10000.0)
        return float(jnp.sum(rq * rk))
    np.testing.assert_allclose(ip(0, 3), ip(7, 3), rtol=1e-4)


def test_mrope_sections_match_rope_when_positions_equal():
    """With identical t/h/w position streams, M-RoPE == RoPE."""
    from repro.models.layers import apply_mrope, apply_rope
    x = jax.random.normal(KEY, (1, 8, 2, 64), jnp.float32)
    pos = jnp.arange(8, dtype=jnp.int32)[None]
    pos3 = jnp.broadcast_to(pos[None], (3, 1, 8))
    r1 = apply_rope(x, pos, 10000.0)
    r2 = apply_mrope(x, pos3, 10000.0, (16, 8, 8))
    np.testing.assert_allclose(np.asarray(r2), np.asarray(r1), atol=1e-5)


def test_scan_vs_unrolled_forward():
    """scan-over-layers must equal the unrolled python loop."""
    cfg = dataclasses.replace(configs.smoke("llama3.2-1b"), n_layers=4,
                              compute_dtype="float32")
    params, _ = T.init(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    l1, _ = T.forward(cfg, params, toks, mode="train")
    cfg2 = dataclasses.replace(cfg, scan_layers=False)
    l2, _ = T.forward(cfg2, params, toks, mode="train")
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-4)


def test_param_count_matches_init():
    for arch in configs.ARCHS:
        cfg = configs.smoke(arch)
        params, _ = T.init(cfg, KEY)
        actual = sum(int(np.prod(p.shape))
                     for p in jax.tree_util.tree_leaves(params))
        assert actual == cfg.param_count(), arch
