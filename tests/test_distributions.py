"""Property and invariant tests for the preemption probability models."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis installed")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import distributions as D

FAMILIES = {
    "constrained": lambda: D.Constrained(tau1=1.0, tau2=0.8, b=24.0, A=0.475),
    "diurnal_day": lambda: D.diurnal_for("n1-highcpu-16", launch_clock=20.0),
    "diurnal_night": lambda: D.diurnal_for("n1-highcpu-16", launch_clock=8.0),
    "exponential": lambda: D.Exponential(mttf=6.0),
    "weibull": lambda: D.Weibull(lam=0.15, k=0.8),
    "gompertz_makeham": lambda: D.GompertzMakeham(),
    "uniform": lambda: D.Uniform(),
}

params_strategy = st.fixed_dictionaries({
    "tau1": st.floats(0.3, 5.0),
    "tau2": st.floats(0.3, 2.0),
    "b": st.floats(20.0, 26.0),
    "A": st.floats(0.3, 0.5),
})


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_cdf_monotone_and_bounded(family):
    d = FAMILIES[family]()
    t = jnp.linspace(0.0, 24.0, 512)
    f = np.asarray(d.cdf(t))
    assert np.all(f >= -1e-6) and np.all(f <= 1 + 1e-6)
    assert np.all(np.diff(f) >= -1e-6), "CDF must be nondecreasing"


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_pdf_is_cdf_derivative(family):
    d = FAMILIES[family]()
    t = jnp.linspace(0.1, 23.9, 64)
    eps = 1e-3
    numeric = (d.cdf(t + eps) - d.cdf(t - eps)) / (2 * eps)
    np.testing.assert_allclose(np.asarray(d.pdf(t)), np.asarray(numeric),
                               rtol=2e-2, atol=2e-3)


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_partial_expectation_matches_quadrature(family):
    d = FAMILIES[family]()
    a, b = 2.0, 17.0
    closed = float(d.partial_expectation(a, b))
    numeric = float(D._gauss_legendre(lambda x: x * d.pdf(x), a, b))
    np.testing.assert_allclose(closed, numeric, rtol=1e-3, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(params_strategy)
def test_constrained_invariants(p):
    d = D.Constrained(**p)
    t = jnp.linspace(0.0, 24.0, 128)
    f = np.asarray(d.cdf(t))
    assert np.all(np.diff(f) >= -1e-5)
    assert np.all(np.asarray(d.pdf(t)) >= 0)
    # hazard >= 0 wherever survival is meaningfully positive
    surv = np.asarray(d.survival(t))
    lam = np.asarray(d.hazard(t))
    assert np.all(lam[surv > 1e-3] >= -1e-6)
    # partial expectations are additive
    ab = float(d.partial_expectation(0.0, 10.0))
    bc = float(d.partial_expectation(10.0, 24.0))
    ac = float(d.partial_expectation(0.0, 24.0))
    np.testing.assert_allclose(ab + bc, ac, rtol=1e-4, atol=1e-5)


def test_constrained_bathtub_shape():
    d = FAMILIES["constrained"]()
    lam = d.hazard
    early, mid, late = float(lam(0.2)), float(lam(12.0)), float(lam(23.8))
    assert early > 10 * mid, "early hazard must dominate the stable phase"
    assert late > 10 * mid, "deadline hazard must dominate the stable phase"


def test_sampling_matches_cdf():
    d = FAMILIES["constrained"]()
    s = d.sample(jax.random.PRNGKey(0), (40000,))
    assert float(s.min()) >= 0 and float(s.max()) <= 24.0
    for t in (1.0, 3.0, 12.0, 23.0):
        emp = float((s <= t).mean())
        np.testing.assert_allclose(emp, float(d.cdf(t)), atol=0.02)
    # mass at the hard cap equals the survivor probability
    np.testing.assert_allclose(float((s >= 23.999).mean()),
                               float(d.survival(24.0)), atol=0.02)


def test_expected_lifetime_closed_form_vs_mc():
    d = FAMILIES["constrained"]()
    s = np.asarray(d.sample(jax.random.PRNGKey(1), (60000,)))
    # Eq. 3 excludes the cap atom; E[min(T,L)] includes it
    np.testing.assert_allclose(float(d.mean_lifetime_capped()), s.mean(),
                               rtol=0.03)


def test_hazard_matches_paper_asymptotics():
    """Eq. 5: lambda(t) ~ r1 for 0 < t << b (the paper's limit check)."""
    d = D.Constrained(tau1=1.0, tau2=0.8, b=24.0, A=0.999999)
    # with A ~ 1 the small-t hazard approaches r1 = 1/tau1
    np.testing.assert_allclose(float(d.hazard(0.05)), 1.0, rtol=0.15)


def test_vm_type_ordering():
    """Obs. 4: larger VMs preempt faster (higher early CDF)."""
    f3 = [float(D.constrained_for(v).cdf(3.0))
          for v in ("n1-highcpu-2", "n1-highcpu-8", "n1-highcpu-32")]
    assert f3[0] < f3[1] < f3[2]


def test_empirical_cdf_roundtrip():
    d = FAMILIES["constrained"]()
    s = d.sample(jax.random.PRNGKey(2), (5000,))
    emp = D.Empirical.from_samples(s)
    t = jnp.linspace(0.5, 23.5, 32)
    np.testing.assert_allclose(np.asarray(emp.cdf(t)), np.asarray(d.cdf(t)),
                               atol=0.03)
