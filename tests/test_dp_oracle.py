"""Independent oracle for the checkpointing DP: a dense, loop-based numpy
re-implementation of the Eq. 11-15 recursion, cross-checked against the
vectorized JAX solver, plus fixed-point convergence checks."""
import numpy as np
import pytest

from repro.core import distributions as D
from repro.core.policies import checkpointing as C

GRID_DT = 0.25   # 15-min grid keeps the oracle's O(J^2 T) loops cheap


def _oracle_tables(dist, j_max, t_max, delta_steps, n_sweeps,
                   restart_overhead=0.0):
    """Plain-python mirror of the recursion (no vectorization tricks)."""
    dt = GRID_DT
    L = float(dist.L)
    tk = np.arange(t_max + 1) * dt
    F = np.clip(np.array(dist.cdf(tk)), 0.0, 1.0)
    atom = max(1.0 - F[-1], 0.0)
    F[-1] = 1.0
    H = np.array(dist.partial_expectation(np.zeros_like(tk), tk))
    H[-1] += atom * L
    eps = 1e-9

    V = np.tile((np.arange(j_max + 1) * dt)[:, None], (1, t_max + 1))
    for _ in range(n_sweeps):
        R = restart_overhead + V[:, 0].copy()
        V_new = np.zeros_like(V)
        for j in range(1, j_max + 1):
            for t in range(t_max + 1):
                if 1.0 - F[t] < 1e-6:
                    V_new[j, t] = R[j]
                    continue
                best = np.inf
                for i in range(1, j + 1):
                    w = i if i == j else i + delta_steps
                    e = min(t + w, t_max)
                    p_fail = min(max((F[e] - F[t]) / max(1 - F[t], eps),
                                     0.0), 1.0)
                    dF = max(F[e] - F[t], eps)
                    e_lost = (H[e] - H[t]) / dF - t * dt
                    e_lost = min(max(e_lost, 0.0), w * dt)
                    v_succ = w * dt + V_new[j - i, e]
                    v_fail = e_lost + R[j]
                    cost = (1 - p_fail) * v_succ + p_fail * v_fail
                    best = min(best, cost)
                V_new[j, t] = best
        V = V_new
    return V


def _dollar_oracle_tables(dist, prices, pdt, j_max, t_max, delta_steps,
                          n_sweeps, restart_overhead):
    """Plain-python mirror of the DOLLAR recursion: every segment is billed
    at the integrated price over its age window (ages beyond the price
    trace bill at the last cell), expected lost work is priced at the
    segment's mean rate, and the restart overhead is billed at the
    launch-cell price."""
    dt = GRID_DT
    L = float(dist.L)
    tk = np.arange(t_max + 1) * dt
    F = np.clip(np.array(dist.cdf(tk)), 0.0, 1.0)
    atom = max(1.0 - F[-1], 0.0)
    F[-1] = 1.0
    H = np.array(dist.partial_expectation(np.zeros_like(tk), tk))
    H[-1] += atom * L
    eps = 1e-9

    prices = np.asarray(prices, np.float64)
    TX = t_max + 1 + j_max + delta_steps

    def pcum(k):
        # cumulative dollars of the first k*dt hours of a VM's life
        tau = k * dt
        c = min(int(np.floor(tau / pdt)), len(prices) - 1)
        return float(np.sum(prices[:c]) * pdt + prices[c] * (tau - c * pdt))

    Pc = np.array([pcum(k) for k in range(TX)])
    ro_dollar = restart_overhead * prices[0]

    V = np.tile(Pc[: j_max + 1][:, None], (1, t_max + 1))
    for _ in range(n_sweeps):
        R = ro_dollar + V[:, 0].copy()
        V_new = np.zeros_like(V)
        for j in range(1, j_max + 1):
            for t in range(t_max + 1):
                if 1.0 - F[t] < 1e-6:
                    V_new[j, t] = R[j]
                    continue
                best = np.inf
                for i in range(1, j + 1):
                    w = i if i == j else i + delta_steps
                    e = min(t + w, t_max)
                    p_fail = min(max((F[e] - F[t]) / max(1 - F[t], eps),
                                     0.0), 1.0)
                    dF = max(F[e] - F[t], eps)
                    e_lost = (H[e] - H[t]) / dF - t * dt
                    e_lost = min(max(e_lost, 0.0), w * dt)
                    dP = Pc[t + w] - Pc[t]       # unclipped: tail billing
                    v_succ = dP + V_new[j - i, e]
                    v_fail = e_lost * (dP / (w * dt)) + R[j]
                    cost = (1 - p_fail) * v_succ + p_fail * v_fail
                    best = min(best, cost)
                V_new[j, t] = best
        V = V_new
    return V


@pytest.mark.parametrize("job_steps", [8, 16])
def test_jax_dp_matches_oracle(job_steps):
    dist = D.constrained_for()
    t_max = int(round(float(dist.L) / GRID_DT))
    tab = C.solve(dist, job_steps, grid_dt=GRID_DT, delta_steps=1,
                  n_sweeps=3)
    V_oracle = _oracle_tables(dist, job_steps, t_max, delta_steps=1,
                              n_sweeps=3)
    np.testing.assert_allclose(tab.V[: job_steps + 1], V_oracle,
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("job_steps", [8, 16])
def test_jax_dollar_dp_matches_oracle(job_steps):
    """Differential oracle for the dollar objective: a price spike mid-
    horizon plus a nonzero restart overhead exercises every dollar-specific
    term (tail billing, priced lost work, launch-priced restarts)."""
    from repro.core import market as M
    dist = D.constrained_for()
    t_max = int(round(float(dist.L) / GRID_DT))
    pdt = 1.0
    prices = np.full(12, 0.10)
    prices[3:6] = 0.48                         # crunch window, hours 3-6
    price = M.PriceGrid.from_prices(prices[None, :], pdt)
    tab = C.solve(dist, job_steps, grid_dt=GRID_DT, delta_steps=1,
                  n_sweeps=3, restart_overhead=0.3, objective="dollars",
                  price=price)
    assert tab.objective == "dollars"
    V_oracle = _dollar_oracle_tables(dist, prices, pdt, job_steps, t_max,
                                     delta_steps=1, n_sweeps=3,
                                     restart_overhead=0.3)
    np.testing.assert_allclose(tab.V[: job_steps + 1], V_oracle,
                               rtol=1e-4, atol=1e-4)


def test_dollar_dp_beats_any_fixed_interval():
    """Optimality in the new currency: V(J,0) <= expected dollars of every
    uniform schedule priced by the float64 policy evaluator."""
    from repro.core import market as M
    dist = D.constrained_for()
    J = 12
    prices = np.full(12, 0.10)
    prices[3:6] = 0.48
    price = M.PriceGrid.from_prices(prices[None, :], 1.0)
    tab = C.solve_batch([dist], J, grid_dt=GRID_DT, delta_steps=1,
                        n_sweeps=6, restart_overhead=0.3,
                        objective="dollars", price=price)
    v_dp = float(np.asarray(tab.V)[0, J, 0])
    for interval in (1, 2, 4, 8, 12):
        K = np.full_like(np.asarray(tab.K), interval)
        V_fix = C.evaluate_policy_dollars(
            K, [dist], price, grid_dt=GRID_DT, delta_steps=1, n_sweeps=6,
            restart_overhead=0.3)
        assert v_dp <= V_fix[0, J, 0] + 1e-3, interval


def test_fixed_point_converged():
    """The restart fixed point converges geometrically in P(fail): by 6
    sweeps further sweeps move V by < 3 minutes."""
    dist = D.constrained_for()
    t6 = C.solve(dist, 16, grid_dt=GRID_DT, delta_steps=1, n_sweeps=6)
    t9 = C.solve(dist, 16, grid_dt=GRID_DT, delta_steps=1, n_sweeps=9)
    assert np.max(np.abs(t6.V - t9.V)) < 0.05


def test_dp_beats_any_fixed_interval():
    """Optimality spot-check: V(J,0) <= expected makespan of every uniform
    schedule evaluated under the same recursion."""
    dist = D.constrained_for()
    J = 16
    t_max = int(round(float(dist.L) / GRID_DT))
    tab = C.solve(dist, J, grid_dt=GRID_DT, delta_steps=1, n_sweeps=6)

    def fixed_value(interval):
        # evaluate the fixed policy by the same backward recursion
        dt = GRID_DT
        tk = np.arange(t_max + 1) * dt
        F = np.clip(np.array(dist.cdf(tk)), 0.0, 1.0)
        atom = max(1.0 - F[-1], 0.0)
        F[-1] = 1.0
        H = np.array(dist.partial_expectation(np.zeros_like(tk), tk))
        H[-1] += atom * float(dist.L)
        eps = 1e-9
        V = np.tile((np.arange(J + 1) * dt)[:, None], (1, t_max + 1))
        for _ in range(6):
            R = V[:, 0].copy()
            V_new = np.zeros_like(V)
            for j in range(1, J + 1):
                i = min(interval, j)
                w = i if i == j else i + 1
                for t in range(t_max + 1):
                    if 1.0 - F[t] < 1e-6:
                        V_new[j, t] = R[j]
                        continue
                    e = min(t + w, t_max)
                    p_fail = min(max((F[e] - F[t]) / max(1 - F[t], eps),
                                     0.0), 1.0)
                    dF = max(F[e] - F[t], eps)
                    e_lost = min(max((H[e] - H[t]) / dF - t * dt, 0.0),
                                 w * dt)
                    V_new[j, t] = (1 - p_fail) * (w * dt + V_new[j - i, e]) \
                        + p_fail * (e_lost + R[j])
            V = V_new
        return V[J, 0]

    v_dp = tab.expected_makespan(J, 0)
    for interval in (1, 2, 4, 8, 16):
        assert v_dp <= fixed_value(interval) + 1e-3, interval
