"""Launch-layer tests: abstract specs, analytics, HLO collective parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analytics, configs
from repro.configs import SHAPES
from repro.configs.base import ShapeConfig, TrainConfig
from repro.launch import hlo_stats, steps


def test_abstract_init_no_allocation():
    """abstract_init on a 33B config must be instant (pure eval_shape)."""
    cfg = configs.get("deepseek-coder-33b")
    shapes, axes = steps.abstract_init(cfg)
    leaves = jax.tree_util.tree_leaves(shapes)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    total = sum(int(np.prod(l.shape)) for l in leaves)
    assert total == cfg.param_count()
    # axes tree mirrors params
    ax_leaves = jax.tree_util.tree_leaves(
        axes, is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    assert len(ax_leaves) == len(leaves)


@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_input_specs_cover_all_archs(shape_name):
    for arch in configs.ARCHS:
        cfg = configs.get(arch)
        spec = steps.input_specs(cfg, SHAPES[shape_name])
        assert "batch" in spec
        b = spec["batch"]
        if cfg.embeds_input:
            assert "embeds" in b and b["embeds"].shape[-1] == cfg.d_model
        else:
            assert "tokens" in b
        if cfg.pos_type == "mrope":
            assert b["positions"].shape[0] == 3
        if SHAPES[shape_name].kind != "train":
            assert "cache" in spec


def test_train_step_grad_accum_equivalence():
    """accum=2 must give (numerically) the same update as accum=1."""
    import dataclasses
    cfg = dataclasses.replace(configs.smoke("llama3.2-1b"),
                              compute_dtype="float32")
    from repro.models import transformer as T
    from repro.optim import adamw_init
    params, _ = T.init(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    B, S = 4, 16
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                     cfg.vocab_size),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    f1 = steps.make_train_step(cfg, TrainConfig(warmup_steps=1, grad_accum=1))
    f2 = steps.make_train_step(cfg, TrainConfig(warmup_steps=1, grad_accum=2))
    p1, _, m1 = jax.jit(f1)(params, opt, batch)
    p2, _, m2 = jax.jit(f2)(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_analytics_train_flops_scale():
    """Analytic FLOPs/chip x chips ~ 4 x forward; 6ND ratio sane."""
    cfg = configs.get("llama3.2-1b")
    shape = SHAPES["train_4k"]
    cost = analytics.cell_cost(cfg, shape, chips=256, rules="fsdp")
    roof = analytics.roofline(cost, chips=256)
    assert 0.05 < roof["model_flops_ratio"] <= 1.0
    assert roof["step_time_est"] > 0
    # total model flops across chips == 6*N*D
    total_useful = cost.model_flops * 256
    np.testing.assert_allclose(
        total_useful, 6 * cfg.active_param_count() * shape.global_batch
        * shape.seq_len, rtol=1e-6)


def test_analytics_decode_memory_bound():
    """32k-cache decode must be memory/collective bound, never compute."""
    cfg = configs.get("yi-34b")
    cost = analytics.cell_cost(cfg, SHAPES["decode_32k"], chips=256,
                               rules="fsdp")
    roof = analytics.roofline(cost, chips=256)
    assert roof["dominant"] in ("memory", "collective")


def test_hlo_collective_parser_on_real_module():
    """Parse a real partitioned module with a known all-reduce."""
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1,), ("x",))
    from jax.sharding import NamedSharding, PartitionSpec

    @jax.jit
    def f(x):
        return jax.lax.with_sharding_constraint(
            x.sum(0, keepdims=True), NamedSharding(mesh, PartitionSpec()))

    x = jax.ShapeDtypeStruct((8, 128), jnp.float32)
    hlo = jax.jit(lambda x: x @ x.T).lower(x).compile().as_text()
    stats = hlo_stats.collective_bytes(hlo)
    assert stats.total_bytes >= 0  # parser must not crash on any module


def test_hlo_parser_trip_counts():
    """Collectives inside a scanned body must be multiplied by trip count."""
    hlo = """
HloModule test

%cond.1 (p: (s32[], f32[16])) -> pred[] {
  %p = (s32[], f32[16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body.1 (p: (s32[], f32[16])) -> (s32[], f32[16]) {
  %p = (s32[], f32[16]) parameter(0)
  %x = f32[16] get-tuple-element(%p), index=1
  %ar = f32[16]{0} all-reduce(%x), replica_groups={}, to_apply=%sum
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[16]) tuple(%i, %ar)
}

ENTRY %main (a: f32[16]) -> f32[16] {
  %a = f32[16] parameter(0)
  %init = (s32[], f32[16]) tuple(s32[] constant(0), %a)
  %w = (s32[], f32[16]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[16] get-tuple-element(%w), index=1
}
"""
    stats = hlo_stats.collective_bytes(hlo)
    assert stats.count_by_kind.get("all-reduce") == 1
    # 16 floats * 4 bytes * 12 trips
    np.testing.assert_allclose(stats.bytes_by_kind["all-reduce"],
                               16 * 4 * 12)
