"""Checkpoint manager: roundtrip, torn writes, schedules."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_latest, \
    save_checkpoint
from repro.core import distributions as D


@pytest.fixture()
def tmpdir(tmp_path):
    return str(tmp_path / "ckpt")


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(6).reshape(2, 3),
                       "c": [jnp.ones(3), jnp.zeros(2)]}}


def test_roundtrip(tmpdir):
    tree = _tree()
    save_checkpoint(tmpdir, 7, tree, {"note": "x"})
    out = restore_latest(tmpdir, tree)
    assert out is not None
    restored, step, meta = out
    assert step == 7 and meta["note"] == "x"
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_wins_and_torn_write_skipped(tmpdir):
    t1, t2 = _tree(1), _tree(2)
    save_checkpoint(tmpdir, 10, t1)
    save_checkpoint(tmpdir, 20, t2)
    # corrupt the newest (simulate preemption mid-write)
    path = os.path.join(tmpdir, "step_0000000020", "arrays.npz")
    with open(path, "r+b") as f:
        f.seek(100)
        f.write(b"\x00" * 64)
    restored, step, _ = restore_latest(tmpdir, t1)
    assert step == 10, "corrupted checkpoint must be skipped"


def test_async_write(tmpdir):
    tree = _tree()
    th = save_checkpoint(tmpdir, 3, tree, blocking=False)
    th.join()
    assert restore_latest(tmpdir, tree)[1] == 3


def _mgr(tmpdir, policy, **kw):
    return CheckpointManager(directory=tmpdir, dist=D.constrained_for(),
                             policy=policy, step_time_hours=0.01,
                             total_steps=1000, async_write=False, **kw)


def test_dp_schedule_nonuniform(tmpdir):
    """DP intervals at pod age 0 start short and lengthen."""
    mgr = _mgr(tmpdir, "dp")
    first = mgr._next_ckpt_step
    tree = _tree()
    mgr.save(first, tree)
    second_gap = mgr._next_ckpt_step - first
    assert second_gap >= first, "DP gaps should lengthen as hazard decays"


def test_young_daly_schedule_uniform(tmpdir):
    mgr = _mgr(tmpdir, "young_daly")
    g1 = mgr._next_ckpt_step
    mgr.save(g1, _tree())
    g2 = mgr._next_ckpt_step - g1
    assert g1 == g2, "Young-Daly is periodic"


def test_emergency_save_is_blocking_and_counted(tmpdir):
    mgr = _mgr(tmpdir, "dp")
    mgr.on_preemption_warning(42, _tree())
    assert mgr.n_emergency == 1
    assert restore_latest(tmpdir, _tree())[1] == 42


def test_restart_recomputes_schedule(tmpdir):
    mgr = _mgr(tmpdir, "dp")
    before = mgr._next_ckpt_step
    mgr.on_restart(pod_age_hours=0.0, resumed_step=500)
    after = mgr._next_ckpt_step
    assert after > 500, "schedule must re-anchor at the resumed step"
    assert after - 500 <= before * 2 + 1


def test_policy_none(tmpdir):
    mgr = _mgr(tmpdir, "none")
    assert not mgr.should_checkpoint(10 ** 6)
