"""End-to-end behaviour tests: the paper's policies running inside the
training loop and the batch service."""
import dataclasses

import numpy as np
import pytest

from repro import configs
from repro.configs.base import TrainConfig
from repro.core import distributions as D
from repro.core import service as SV
from repro.fault import PreemptionSource, StragglerWatchdog, \
    plan_elastic_remesh
from repro.launch.train import train


@pytest.fixture()
def tiny_cfg():
    return dataclasses.replace(configs.smoke("smollm-135m"), n_layers=2,
                               d_model=32, d_ff=64, vocab_size=256)


def test_train_loss_decreases(tiny_cfg, tmp_path):
    tc = TrainConfig(ckpt_dir=str(tmp_path), ckpt_policy="dp",
                     warmup_steps=5)
    res = train(tiny_cfg, tc, total_steps=60, verbose=False)
    assert res.steps_run == 60
    assert res.final_loss < np.mean(res.losses[:5]) - 0.1, \
        "loss must decrease on the structured synthetic stream"
    assert res.checkpoints >= 1


@pytest.mark.slow
def test_train_survives_preemptions_and_resumes(tiny_cfg, tmp_path):
    """Preemption mid-run: emergency checkpoint + restore + replay; the
    trainer must still complete all steps."""
    tc = TrainConfig(ckpt_dir=str(tmp_path), ckpt_policy="dp",
                     warmup_steps=5)
    res = train(tiny_cfg, tc, total_steps=50, inject_preemptions=True,
                sim_hours_per_step=0.25, preemption_seed=3, verbose=False)
    assert res.restarts >= 1, "the 0.25h/step clock must cross a preemption"
    assert res.emergency_checkpoints >= 1
    assert res.steps_run >= 50


@pytest.mark.slow
def test_deterministic_replay_after_restart(tiny_cfg, tmp_path):
    """A run with preemptions must end at the same final params/loss as an
    uninterrupted run (checkpoint + pipeline replay = exactly-once)."""
    tc1 = TrainConfig(ckpt_dir=str(tmp_path / "a"), ckpt_policy="dp",
                      warmup_steps=5)
    clean = train(tiny_cfg, tc1, total_steps=40, verbose=False)
    tc2 = TrainConfig(ckpt_dir=str(tmp_path / "b"), ckpt_policy="dp",
                      warmup_steps=5)
    bumpy = train(tiny_cfg, tc2, total_steps=40, inject_preemptions=True,
                  sim_hours_per_step=0.3, preemption_seed=3, verbose=False)
    assert bumpy.restarts >= 1
    np.testing.assert_allclose(bumpy.losses[-1], clean.losses[-1],
                               rtol=1e-4)


def test_preemption_source_statistics():
    """Simulated pod lifetimes follow the model (KS-style bound)."""
    dist = D.constrained_for()
    src = PreemptionSource(dist, n_pods=500, seed=0)
    lt = src.lifetimes
    assert abs((lt < 3.0).mean() - float(dist.cdf(3.0))) < 0.07
    assert lt.max() <= 24.0


def test_preemption_warning_window():
    dist = D.constrained_for()
    src = PreemptionSource(dist, n_pods=1, seed=1)
    kill = src.launch_age[0] + src.lifetimes[0]
    warn = kill - 30.0 / 3600.0
    assert not src.poll(warn - 1e-4)
    events = src.poll(warn + 1e-4)
    assert len(events) == 1
    assert events[0].preempt_at_hours == pytest.approx(kill)
    # idempotent
    assert not src.poll(kill + 1.0)


def test_elastic_remesh_plans():
    p = plan_elastic_remesh(2, [1])
    assert p.mesh_shape == (16, 16) and p.batch_scale == 0.5
    p3 = plan_elastic_remesh(4, [2])
    assert p3.mesh_shape == (3, 16, 16) and p3.mesh_axes[0] == "pod"
    with pytest.raises(RuntimeError):
        plan_elastic_remesh(2, [0, 1])


def test_straggler_watchdog():
    dog = StragglerWatchdog(threshold=2.0)
    for _ in range(16):
        dog.observe(1.0)
    assert not dog.observe(1.1)
    assert dog.observe(5.0)
    assert dog.flagged == 1


def test_batch_service_cost_reduction():
    """Fig. 8a: ~5x cheaper than on-demand (price ratio caps at 4.9x)."""
    dist = D.constrained_for("n1-highcpu-32")
    r = SV.run_bag(dist, n_jobs=60, job_hours=2.0, cluster_size=16, seed=3)
    assert all(j.finished is not None for j in r.jobs)
    assert r.cost_reduction > 3.5
    assert r.n_preemptions > 0, "preemptions must actually occur in the sim"


def test_batch_service_preemption_overhead_linear():
    """Fig. 8b: each preemption costs ~small% extra running time; more
    preemptions => more makespan (monotone-ish trend over seeds)."""
    dist = D.constrained_for("n1-highcpu-32")
    rows = []
    for seed in range(6):
        r = SV.run_bag(dist, n_jobs=40, job_hours=2.0, cluster_size=8,
                       seed=seed)
        rows.append((r.n_preemptions, r.vm_hours))
    rows.sort()
    lo = np.mean([v for n, v in rows[:3]])
    hi = np.mean([v for n, v in rows[3:]])
    assert hi >= lo * 0.98, "vm-hours should not shrink with more preemptions"
