"""Policy tests: Eq. 6-15 quantities + the paper's headline claims."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distributions as D
from repro.core.policies import checkpointing as C
from repro.core.policies import scheduling as S
from repro.core.policies import young_daly as YD


@pytest.fixture(scope="module")
def dist():
    return D.constrained_for("n1-highcpu-16")


# ---------------------------------------------------------------------------
# scheduling (Eq. 6-10, Fig. 5-6)
# ---------------------------------------------------------------------------

def test_wasted_work_below_uniform_for_long_jobs(dist):
    """Fig. 5a: bathtub wasted work << uniform (J/2) for long jobs."""
    uni = D.Uniform()
    for T in (6.0, 10.0, 15.0):
        w_bath = float(S.expected_wasted_work(dist, T))
        w_uni = float(S.expected_wasted_work(uni, T))
        np.testing.assert_allclose(w_uni, T / 2, rtol=1e-3)
        assert w_bath < 0.5 * w_uni, T


def test_runtime_increase_crossover(dist):
    """Fig. 5b: bathtub worse for short jobs, crossover ~5h, much better
    after; 10h-job increase ~minutes vs hours for uniform."""
    uni = D.Uniform()
    inc = lambda d, T: float(S.expected_runtime_increase(d, T))
    assert inc(dist, 1.0) > inc(uni, 1.0)          # short jobs: bathtub worse
    assert inc(dist, 10.0) < 0.5 * inc(uni, 10.0)  # long jobs: much better
    # uniform increase is quadratic: J^2/48
    np.testing.assert_allclose(inc(uni, 12.0), 12.0 ** 2 / 48, rtol=1e-3)
    # crossover in the paper's stated 3-7h band
    diffs = [(T, inc(dist, T) - inc(uni, T)) for T in np.arange(1, 10, 0.5)]
    cross = next(T for T, d in diffs if d < 0)
    assert 2.0 <= cross <= 7.0


def test_memoryless_always_fails_near_deadline(dist):
    """Fig. 6a: a 6h job started after 18h always fails under memoryless
    reuse; the policy switches to a fresh VM and caps the risk at F(6)."""
    for s in (18.5, 20.0, 22.0):
        assert float(S.job_failure_prob_memoryless(dist, 6.0, s)) == 1.0
        p = float(S.job_failure_prob_policy(dist, 6.0, s))
        np.testing.assert_allclose(p, float(dist.cdf(6.0)), atol=1e-3)
        assert p < 0.55


def test_policy_reduces_mean_failure_probability(dist):
    """Fig. 6b: model-based scheduling roughly halves failure probability."""
    for T in (4.0, 6.0, 8.0):
        pol = float(S.mean_failure_prob_over_starts(dist, T))
        mem = float(S.mean_failure_prob_over_starts(dist, T, policy=False))
        assert pol < 0.75 * mem, (T, pol, mem)
    # mid-length jobs: close to the paper's 2x
    pol6 = float(S.mean_failure_prob_over_starts(dist, 6.0))
    mem6 = float(S.mean_failure_prob_over_starts(dist, 6.0, policy=False))
    assert mem6 / pol6 > 1.4


def test_failure_prob_bathtub_in_start_time(dist):
    """Fig. 6a: conditional job-failure probability is bathtub in s."""
    p = [float(S.job_failure_prob_memoryless(dist, 6.0, s))
         for s in (0.0, 8.0, 17.9)]
    assert p[0] > 5 * p[1] and p[2] > 5 * p[1]


def test_reuse_decision_stable_phase(dist):
    """VMs in the stable phase should be reused (the paper's 'valuable'
    hot spares); VMs near the deadline should not."""
    assert bool(S.reuse_decision(dist, 4.0, 6.0))
    assert bool(S.reuse_decision(dist, 4.0, 12.0))
    assert not bool(S.reuse_decision(dist, 6.0, 19.0))


def test_expected_makespan_matches_paper_forms(dist):
    """E[T] = T + int_0^T t f dt (Eq. 9); E[W1] = that integral / F(T)."""
    T = 5.0
    integral = float(dist.partial_expectation(0.0, T))
    np.testing.assert_allclose(float(S.expected_makespan_new(dist, T)),
                               T + integral, rtol=1e-6)
    np.testing.assert_allclose(float(S.expected_wasted_work(dist, T)),
                               integral / float(dist.cdf(T)), rtol=1e-6)


# ---------------------------------------------------------------------------
# checkpointing (Eq. 11-15, Fig. 7)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tables(dist):
    return C.solve(dist, 300, grid_dt=1.0 / 60.0, delta_steps=1, n_sweeps=3)


def test_dp_intervals_lengthen_at_age_zero(dist, tables):
    """The paper's 5h-job schedule (15,28,38,59,128)min: intervals grow as
    the hazard decays."""
    sched = C.extract_schedule(tables, 300, 0)
    assert len(sched) >= 3
    assert sched == sorted(sched), "intervals must be nondecreasing"
    assert 5 <= sched[0] <= 40, "first interval ~15min (1-min grid)"
    assert sched[-1] >= 2 * sched[0]


def test_dp_skips_checkpoints_in_stable_phase(dist, tables):
    """A 4h job launched at age 6h faces ~zero hazard: the DP writes few or
    no checkpoints (vs Young-Daly's 22)."""
    sched = C.extract_schedule(tables, 240, 6 * 60)
    assert len(sched) <= 3


def test_dp_checkpoints_before_deadline_wall(dist, tables):
    """A job running into the 24h wall must checkpoint tightly before it."""
    sched = C.extract_schedule(tables, 300, 20 * 60)  # 5h job at age 20h
    assert len(sched) >= 3, "must checkpoint aggressively near the wall"


def test_value_function_monotone(tables):
    """V(j, t) nondecreasing in j (more work can't cost less)."""
    V = tables.V
    assert np.all(np.diff(V[:, 0]) >= -1e-5)
    assert np.all(np.diff(V[:, 360]) >= -1e-5)


def test_mc_dp_beats_young_daly_and_none(dist, tables):
    """Fig. 7: DP < Young-Daly < no-checkpointing expected makespan."""
    lf = C.model_lifetimes_fn(dist)
    kw = dict(grid_dt=1.0 / 60.0, delta_steps=1, n_trials=400, seed=11)
    dp = C.simulate_makespan(C.dp_policy_fn(tables), lf, 300, **kw).mean()
    yd = C.simulate_makespan(
        C.young_daly_policy_fn(float(YD.interval(1 / 60.0, 1.0)), 1 / 60.0),
        lf, 300, **kw).mean()
    none = C.simulate_makespan(C.no_checkpoint_policy_fn(), lf, 300,
                               **kw).mean()
    assert dp < yd < none
    assert (dp / 5.0 - 1.0) < 0.10, "DP overhead <10% even from age 0"


def test_stable_phase_overhead_below_paper_bound(dist, tables):
    """Fig. 7a: <5% overhead for jobs launched when the VM is 5-15h old."""
    lf = C.model_lifetimes_fn(dist)
    mc = C.simulate_makespan(C.dp_policy_fn(tables), lf, 240, start_age=6.0,
                             grid_dt=1 / 60.0, n_trials=400, seed=5)
    assert mc.mean() / 4.0 - 1.0 < 0.05


def test_young_daly_analytic_matches_paper_quote():
    """The paper's '>25%' Young-Daly overhead at MTTF=1h, delta=1min is the
    model-predicted overhead (delta/tau + tau/2MTTF + restart)."""
    ov = YD.expected_overhead(1 / 60.0, 1.0, restart_overhead=2 / 60.0)
    assert 0.18 < ov < 0.30


def test_restart_age_conditioning(dist, tables):
    """Lifetimes for a job starting at age s must be conditioned on
    survival to s (no instant bogus failures)."""
    lf = C.model_lifetimes_fn(dist)
    rng = np.random.default_rng(0)
    draws = lf(rng, 2000, min_age=6.0)
    assert draws.min() >= 6.0
