"""Sharding rules engine + multi-device pjit smoke (subprocess with forced
host device count, since the main test process has already initialized the
single-device backend)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec

from repro import sharding


class _FakeMesh:
    """Duck-typed mesh: only .shape (dict) is consulted by spec_for."""
    def __init__(self, shape):
        self.shape = shape


MESH = _FakeMesh({"data": 16, "model": 16})
MESH3 = _FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_spec_basic():
    spec = sharding.spec_for(("w_embed", "w_mlp"), (1024, 8192), MESH,
                             sharding.RULES_BASELINE)
    assert spec == PartitionSpec(None, "model")


def test_divisibility_fallback():
    # 9 heads over a 16-way axis: must drop to replicated, not crash
    spec = sharding.spec_for(("act_heads",), (9,), MESH,
                             {"act_heads": "model"})
    assert spec == PartitionSpec()


def test_axis_reuse_guard():
    # two dims both wanting `model`: the second must be dropped
    spec = sharding.spec_for(("w_mlp", "w_vocab"), (256, 256), MESH,
                             sharding.RULES_BASELINE)
    assert spec == PartitionSpec("model")


def test_multi_axis_batch():
    spec = sharding.spec_for(("act_batch", "act_seq"), (256, 4096), MESH3,
                             sharding.RULES_BASELINE)
    assert spec == PartitionSpec(("pod", "data"))
    # single-pod mesh: the pod name is filtered out
    spec2 = sharding.spec_for(("act_batch",), (256,), MESH,
                              sharding.RULES_BASELINE)
    assert spec2 == PartitionSpec("data")


def test_fsdp_rules_shard_contraction_dims():
    spec = sharding.spec_for(("w_embed", "w_mlp"), (1024, 8192), MESH,
                             sharding.RULES_FSDP)
    assert spec == PartitionSpec("data", "model")


def test_constrain_noop_without_mesh():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    assert sharding.constrain(x, "act_batch", "act_seq") is x


@pytest.mark.slow
def test_pjit_train_step_8_devices():
    """Real pjit on 8 forced host devices (2x4 data x model) - a miniature
    of the production dry-run, executed (not just compiled)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, dataclasses, json
        from repro import configs, sharding
        from repro.configs.base import TrainConfig, ShapeConfig
        from repro.launch import steps
        from repro.models import transformer as T
        from repro.optim import adamw_init

        cfg = dataclasses.replace(configs.smoke("llama3.2-1b"),
                                  d_model=64, d_ff=128)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        shape = ShapeConfig("t", "train", 32, 4)
        with mesh, sharding.use(mesh, "fsdp"):
            in_sh, out_sh, args, _ = steps.shardings_for_cell(
                cfg, shape, mesh, "fsdp")
            fn = steps.make_train_step(cfg, TrainConfig(warmup_steps=1))
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
            params, _ = T.init(cfg, jax.random.PRNGKey(0))
            opt = adamw_init(params)
            batch = {
                "tokens": jnp.zeros((4, 32), jnp.int32),
                "labels": jnp.ones((4, 32), jnp.int32),
                "mask": jnp.ones((4, 32), jnp.float32),
            }
            params = jax.device_put(params, in_sh[0])
            opt = jax.device_put(opt, in_sh[1])
            batch = jax.device_put(batch, in_sh[2])
            p2, o2, metrics = jitted(params, opt, batch)
            print(json.dumps({"loss": float(metrics["loss"]),
                              "devices": len(jax.devices())}))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    result = json.loads(out.stdout.strip().splitlines()[-1])
    assert result["devices"] == 8
    assert result["loss"] > 0
