"""Tonks-gas lemma tests (constrained preemptions <-> hard rods)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tonks


def test_partition_function():
    assert float(tonks.partition_function(3, 24.0, 0.5)) == (24 - 1.5) ** 3
    # Z_{N-1} on the effective deadline L-w has the SAME excluded volume
    # L_e = L - Nw as the original N-preemption system (the paper's
    # 'fortuitous result'): (L-w) - (N-1)w = L - Nw.
    N, L, w = 6, 24.0, 0.3
    le = L - N * w
    np.testing.assert_allclose(
        float(tonks.partition_function(N - 1, L - w, w)), le ** (N - 1),
        rtol=1e-5)
    np.testing.assert_allclose(float(tonks.p_boundary(N, L, w)), 1.0 / le)


def test_boundary_probability_exceeds_uniform():
    """The lemma: P(L - w) = 1/(L - Nw) > 1/L for any N >= 1, w > 0."""
    for N in (1, 4, 10):
        for w in (0.1, 0.3, 1.0):
            assert float(tonks.p_boundary(N, 24.0, w)) > 1.0 / 24.0


def test_mc_matches_exact_boundary():
    mc, exact = tonks.boundary_enhancement(jax.random.PRNGKey(0), 300000,
                                           N=6, L=24.0, w=0.3)
    np.testing.assert_allclose(float(mc), float(exact), rtol=0.1)


def test_density_enhanced_over_uniform():
    """The Lemma's quantitative content: mutual exclusion compresses the
    accessible 'temporal volume' to L - Nw, so the per-preemption start
    density on its support sits at ~1/(L - Nw) > 1/L (the uniform-over-L
    expectation), with the same enhancement at the endpoints (the P(eps),
    P(L-eps) > 1/L statement)."""
    N, L, w = 6, 24.0, 0.3
    c, rho = tonks.start_density(jax.random.PRNGKey(1), 60000, N=N, L=L,
                                 w=w, n_bins=48)
    rho = np.asarray(rho)
    uniform = 1.0 / L
    enhanced = 1.0 / (L - N * w)
    # endpoint bins (within the support) exceed the uniform baseline and
    # track the excluded-volume value
    np.testing.assert_allclose(rho[0], enhanced, rtol=0.1)
    assert rho[0] > uniform
    np.testing.assert_allclose(rho[16:32].mean(), enhanced, rtol=0.1)
    # integrates to ~1
    np.testing.assert_allclose(rho.sum() * (L / 48), 1.0, rtol=0.02)


def test_configurations_respect_exclusion():
    x = tonks.sample_configurations(jax.random.PRNGKey(2), 2000, N=5,
                                    L=24.0, w=0.5)
    gaps = np.diff(np.asarray(x), axis=1)
    assert gaps.min() >= 0.5 - 1e-6, "preemptions must not overlap"
    assert np.asarray(x).max() <= 24.0 - 0.5 + 1e-6
