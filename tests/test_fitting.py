"""Model-fitting tests: the paper's central claim is that Eq. 1 fits
constrained-preemption data and the classical families do not."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distributions as D
from repro.core import fitting as F
from repro.core import simulator as S


@pytest.fixture(scope="module")
def trace():
    return S.trace_for(jax.random.PRNGKey(42), n=1516)


@pytest.fixture(scope="module")
def fits(trace):
    return F.fit_all(trace)


def test_constrained_beats_all_baselines(trace, fits):
    """Fig. 1 / Fig. 3: our model fits far better (LSE and KS)."""
    ours = fits["constrained"]
    for name in ("exponential", "weibull", "gompertz_makeham"):
        other = fits[name]
        assert float(ours.lse) < 0.2 * float(other.lse), name
        assert float(F.ks_statistic(ours.dist, trace)) < \
            0.5 * float(F.ks_statistic(other.dist, trace)), name


def test_fitted_parameters_in_paper_ranges(fits):
    """tau1 in [0.5, 1.5]h, tau2 ~ 0.8h, b ~ 24h, A in [0.4, 0.5]."""
    d = fits["constrained"].dist
    assert 0.4 <= float(d.tau1) <= 2.0
    assert 0.3 <= float(d.tau2) <= 1.5
    assert 23.0 <= float(d.b) <= 25.0
    assert 0.35 <= float(d.A) <= 0.55


def test_boundary_condition(fits):
    """The fit must satisfy F(0) ~= 0 (the paper's constraint)."""
    d = fits["constrained"].dist
    assert abs(float(d.cdf_raw(0.0))) < 0.02


def test_lm_matches_scipy(trace):
    """Our pure-JAX LM vs scipy curve_fit (dogbox - the paper's tool)."""
    from scipy.optimize import curve_fit
    emp = D.Empirical.from_samples(trace)
    t = np.asarray(emp.knots, np.float64)
    y = np.asarray(emp.values, np.float64)

    def model(t, tau1, tau2, b, A):
        return A * (1 - np.exp(-t / tau1) + np.exp((t - b) / tau2))

    popt, _ = curve_fit(model, t, y, p0=(1.0, 1.0, 22.8, 0.45),
                        bounds=([0.05, 0.05, 12.0, 0.05],
                                [10.0, 5.0, 30.0, 1.0]), method="dogbox")
    scipy_lse = float(np.sum((model(t, *popt) - y) ** 2))
    ours = F.fit_samples("constrained", trace)
    # at least as good as scipy up to 10% (different regularization)
    assert float(ours.lse) <= 1.1 * scipy_lse + 1e-3


def test_fit_recovers_own_family():
    """Self-consistency: fitting Eq.1 samples recovers the parameters."""
    true = D.Constrained(tau1=1.2, tau2=0.7, b=23.8, A=0.45)
    s = true.sample(jax.random.PRNGKey(5), (4000,))
    fit = F.fit_samples("constrained", s)
    d = fit.dist
    np.testing.assert_allclose(float(d.tau1), 1.2, rtol=0.2)
    np.testing.assert_allclose(float(d.b), 23.8, rtol=0.03)
    np.testing.assert_allclose(float(d.A), 0.45, rtol=0.15)


def test_qq_quantiles(trace, fits):
    """QQ plot (Fig. 3): our model's quantiles track the empirical ones over
    the entire range; Weibull drifts past the median."""
    q, emp_q, ours_q = F.qq_points(fits["constrained"].dist, trace)
    _, _, weib_q = F.qq_points(fits["weibull"].dist, trace)
    ours_err = np.median(np.abs(np.asarray(ours_q - emp_q)))
    weib_err = np.median(np.abs(np.asarray(weib_q - emp_q)))
    assert ours_err < 0.5 * weib_err
    # upper-tail behavior (the deadline wall)
    hi = slice(80, 99)
    assert np.max(np.abs(np.asarray(ours_q - emp_q))[hi]) < \
        np.max(np.abs(np.asarray(weib_q - emp_q))[hi])


def test_levenberg_marquardt_on_rosenbrock_style():
    """LM solves a generic small least-squares problem."""
    def residual(theta):
        return jnp.stack([10 * (theta[1] - theta[0] ** 2), 1.0 - theta[0]])

    theta, loss, iters, done = F.levenberg_marquardt(residual,
                                                     jnp.asarray([-1.2, 1.0]))
    np.testing.assert_allclose(np.asarray(theta), [1.0, 1.0], atol=1e-4)


# ---------------------------------------------------------------------------
# hardening: non-finite traces must never propagate silently
# ---------------------------------------------------------------------------

def test_lm_nan_residuals_stay_finite_and_unconverged():
    """A residual that is NaN everywhere (the singular-JtJ / poisoned-data
    trace): LM must return FINITE theta with converged=False, not walk the
    iterate into NaN while `accept = new < prev` stays vacuously False."""
    theta0 = jnp.array([1.0, 2.0])
    nan_res = lambda th: jnp.full((3,), jnp.nan) * th[0]
    theta, loss, iters, conv = F.levenberg_marquardt(nan_res, theta0,
                                                     max_iters=24)
    assert np.all(np.isfinite(np.asarray(theta)))
    assert not bool(conv)


def test_lm_nan_theta0_is_sanitized():
    res = lambda th: th - jnp.array([1.0, 2.0])
    theta, loss, iters, conv = F.levenberg_marquardt(
        res, jnp.array([jnp.nan, 0.0]), max_iters=100)
    assert np.all(np.isfinite(np.asarray(theta)))
    assert bool(conv)
    np.testing.assert_allclose(np.asarray(theta), [1.0, 2.0], atol=1e-4)


def test_lm_singular_jtj_zero_jacobian():
    """Constant residuals give a singular JtJ (zero Jacobian): the solve's
    NaN step must be replaced by a zero step, leaving theta0 intact."""
    res = lambda th: jnp.ones((3,)) + 0.0 * th.sum()
    theta, loss, iters, conv = F.levenberg_marquardt(
        res, jnp.array([0.5, -0.5]), max_iters=16)
    assert np.all(np.isfinite(np.asarray(theta)))
    np.testing.assert_allclose(np.asarray(theta), [0.5, -0.5])


def test_fit_samples_rejects_degenerate_traces():
    with pytest.raises(ValueError, match="empty"):
        F.fit_samples("constrained", [])
    with pytest.raises(ValueError, match="non-finite"):
        F.fit_samples("constrained", [1.0, np.nan, 3.0])
    with pytest.raises(ValueError, match="constant"):
        F.fit_samples("constrained", np.full(64, 3.25))
    with pytest.raises(ValueError, match="deadline cap"):
        F.fit_samples("constrained", np.full(64, 24.0))


def test_fit_survives_nan_free_but_extreme_trace():
    """A legal but extreme trace (storm survivors: all tiny lifetimes with
    spread) must produce a finite fit, never NaN parameters."""
    rng = np.random.default_rng(0)
    res = F.fit_samples("constrained", rng.uniform(0.01, 0.05, size=96))
    assert np.all(np.isfinite(np.asarray(res.theta)))
    assert np.isfinite(float(res.lse))
