"""The batched event-synchronous service kernel vs the serial heap loop.

The core contract under test: on a shared per-seed lifetime pool and under
x64, every ``service_kernel`` lane is bit-identical to the retained
``service.BatchService`` ground truth — per-job completion times, failure
and attempt counts, ``vm_hours`` and the full cost accounting (the same
contract ``tests/test_batched.py`` enforces for the makespan executor).
Also covered: the (time, seq) event-tie order, the kernel-only policy
branches (deadline admission, VM deflation), pool/table dedup across the
grid, pool-exhaustion handling, and ``sweep_service``'s two modes.
"""
import itertools

import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core import distributions as D
from repro.core import engine as E
from repro.core import scenarios as SC
from repro.core import service as S
from repro.core import service_kernel as K

RO = S.RELAUNCH_OVERHEAD


def _dist():
    return D.constrained_for("n1-highcpu-32")


def _row_fields(res):
    return (res.makespan, res.vm_hours, res.cost, res.on_demand_cost,
            res.n_preemptions, res.n_job_failures)


def _job_fields(res):
    return [(j.finished, j.attempts, j.failures, j.done_work)
            for j in res.jobs]


def _assert_rows_identical(rows_serial, rows_batched, *, jobs=True):
    assert len(rows_serial) == len(rows_batched)
    for a, b in zip(rows_serial, rows_batched):
        coords = ("vm_type", "policy", "cluster_size", "seed")
        assert {k: a[k] for k in coords} == {k: b[k] for k in coords}
        assert _row_fields(a["result"]) == _row_fields(b["result"])
        if jobs:
            assert _job_fields(a["result"]) == _job_fields(b["result"])


# ---------------------------------------------------------------------------
# x64 bit-identity vs the serial BatchService
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("checkpointing", [False, True])
def test_kernel_bit_identical_to_serial_x64(checkpointing):
    """All policies x several cluster sizes x seeds: batched rows ==
    serial rows float-for-float, including per-job records."""
    kw = dict(vm_types=("n1-highcpu-32",), policies=("model", "memoryless"),
              cluster_sizes=(2, 3), seeds=(0, 1), n_jobs=8,
              job_hours=2.0, jitter=0.1, pool_size=512,
              checkpointing=checkpointing)
    with enable_x64():
        rows_s = S.run_bag_grid(mode="serial", **kw)
        rows_b = S.run_bag_grid(mode="batched", **kw)
    _assert_rows_identical(rows_s, rows_b)


@pytest.mark.slow
def test_kernel_bit_identical_multi_vm_type_x64():
    """Two VM types share one folded ReuseTables tensor (their dists share
    the deadline L); rows stay bit-identical lane-for-lane."""
    kw = dict(vm_types=("n1-highcpu-16", "n1-highcpu-32"),
              policies=("model", "memoryless"), cluster_sizes=(2, 4),
              seeds=(0, 1, 2), n_jobs=8, pool_size=512, checkpointing=True)
    with enable_x64():
        rows_s = S.run_bag_grid(mode="serial", **kw)
        rows_b = S.run_bag_grid(mode="batched", **kw)
    _assert_rows_identical(rows_s, rows_b)


def test_sweep_service_modes_agree_x64():
    """sweep_service(mode='batched') — every (scenario x policy x cluster x
    seed) cell in ONE kernel dispatch — returns exactly the serial rows."""
    kw = dict(policies=("model", "memoryless"), cluster_sizes=(2,),
              seeds=(0,), n_jobs=6, pool_size=512)
    scs = SC.default_grid()[:2]
    with enable_x64():
        rows_s = SC.sweep_service(scs, mode="serial", **kw)
        rows_b = SC.sweep_service(scs, mode="batched", **kw)
    assert rows_b == rows_s


# ---------------------------------------------------------------------------
# event ordering
# ---------------------------------------------------------------------------

def test_event_tie_preempt_beats_finish():
    """A VM whose lifetime exactly equals its job segment dies at the same
    timestamp the finish would fire; the serial heap pops the preempt first
    (its seq is older) — the kernel must resolve the tie the same way."""
    lengths = [[1.0]]
    pool = [[1.0, 5.0]]  # first VM dies exactly at segment end
    with enable_x64():
        res = K.simulate_service_batch(
            lengths=lengths, pools=pool, bag_index=[0], pool_index=[0],
            policy=["memoryless"], cluster_size=[1])
        svc = S.BatchService(_dist(), cluster_size=1, policy="memoryless",
                             lifetime_pool=np.array(pool[0]))
        ref = svc.run(lengths[0])
    assert int(res.n_job_failures[0]) == ref.n_job_failures == 1
    assert int(res.n_preemptions[0]) == 1
    # restart: launch at RO, die at RO+1, relaunch at RO+1+RO, finish +1
    assert float(res.makespan[0]) == ref.makespan == 2.0 + 2 * RO


def test_expire_frees_capacity_for_blocked_jobs():
    """A hot spare the model policy refuses pins the 1-slot cluster; its
    expiry must wake the scheduler (serial loop regression, PR 2)."""
    kw = dict(vm_types=("n1-highcpu-32",), policies=("model",),
              cluster_sizes=(1,), seeds=(0, 3), n_jobs=4, pool_size=512)
    with enable_x64():
        rows_s = S.run_bag_grid(mode="serial", **kw)
        rows_b = S.run_bag_grid(mode="batched", **kw)
    _assert_rows_identical(rows_s, rows_b)
    for r in rows_b:
        assert all(j.finished is not None for j in r["result"].jobs)


# ---------------------------------------------------------------------------
# kernel-only policy branches
# ---------------------------------------------------------------------------

def test_deadline_admission_rejects_before_launch():
    res = K.simulate_service_batch(
        lengths=[[2.0, 2.0]], pools=[[9.0] * 4], bag_index=[0],
        pool_index=[0], policy=["memoryless"], cluster_size=[2],
        deadlines=[[0.5, 0.5]])
    assert int(res.n_rejected[0]) == 2
    assert int(res.n_launches[0]) == 0          # no VM ever provisioned
    assert res.attempts[0].tolist() == [0, 0]   # no lifetime consumed
    assert res.rejected[0].tolist() == [True, True]
    assert np.isnan(res.finished_time[0]).all()


def test_deadline_loose_matches_no_deadline():
    kw = dict(lengths=[[1.0, 2.0, 1.5]], pools=[[9.0] * 8], bag_index=[0],
              pool_index=[0], policy=["memoryless"], cluster_size=[2])
    free = K.simulate_service_batch(**kw)
    loose = K.simulate_service_batch(deadlines=[[1e6] * 3], **kw)
    assert int(loose.n_rejected[0]) == 0
    assert loose.finished_time.tolist() == free.finished_time.tolist()
    assert float(loose.vm_hours[0]) == float(free.vm_hours[0])


def test_deflation_absorbs_first_preemption():
    """len-2 job, lifetime 1: the preemption at RO+1 becomes a capacity
    halving — the remaining 1h stretches to 2h, finish at RO+3 exactly, no
    job failure, one fresh lifetime drawn for the survivor."""
    with enable_x64():
        res = K.simulate_service_batch(
            lengths=[[2.0]], pools=[[1.0, 99.0]], bag_index=[0],
            pool_index=[0], policy=["memoryless"], cluster_size=[1],
            deflate=[True], deflate_factor=0.5)
    assert int(res.n_deflations[0]) == 1
    assert int(res.n_preemptions[0]) == 0
    assert int(res.n_job_failures[0]) == 0
    assert float(res.finished_time[0, 0]) == RO + 3.0
    # second preemption of a deflated VM is a real kill
    res2 = K.simulate_service_batch(
        lengths=[[2.0]], pools=[[1.0, 0.5, 99.0]], bag_index=[0],
        pool_index=[0], policy=["memoryless"], cluster_size=[1],
        deflate=[True], deflate_factor=0.5)
    assert int(res2.n_deflations[0]) == 1
    assert int(res2.n_job_failures[0]) == 1


def test_deflate_policy_suffix_through_grid():
    rows = S.run_bag_grid(mode="batched", policies=("memoryless+deflate",),
                          cluster_sizes=(2,), seeds=(0,), n_jobs=6,
                          pool_size=512)
    assert rows[0]["policy"] == "memoryless+deflate"
    r = rows[0]["result"]
    assert r.n_deflations >= 0 and r.n_preemptions >= 0
    with pytest.raises(ValueError, match="batched"):
        S.run_bag_grid(mode="serial", policies=("model+deflate",),
                       n_jobs=4, pool_size=512)
    with pytest.raises(ValueError, match="unknown service policy"):
        K.split_policy("model+inflate")
    assert K.split_policy("model+deflate") == ("model", True)
    assert K.split_policy("memoryless") == ("memoryless", False)


def test_serial_mode_rejects_deadline():
    with pytest.raises(ValueError, match="batched"):
        S.run_bag_grid(mode="serial", deadline_hours=5.0, n_jobs=4,
                       policies=("memoryless",), pool_size=512)
    with pytest.raises(ValueError, match="batched"):
        SC.sweep_service(SC.default_grid()[:1], mode="serial",
                         deadline_hours=5.0, n_jobs=4,
                         policies=("memoryless",), pool_size=512)


# ---------------------------------------------------------------------------
# shared streams + dedup (satellites 1 & 2)
# ---------------------------------------------------------------------------

def test_pooled_draw_matches_lazy_stream_x64():
    """An up-front draw_service_pool pool leaves the serial results
    unchanged: PCG64 uniforms are call-size invariant, and the sampler
    realigns the rng past the external pool before any refill."""
    bag = S._bag_lengths(6, 2.0, 0.1, 0)
    with enable_x64():
        lazy = S.BatchService(_dist(), cluster_size=3, policy="memoryless",
                              seed=0, pool_size=64).run(bag)
        pool = S.draw_service_pool(_dist(), seed=0, size=64)
        pooled = S.BatchService(_dist(), cluster_size=3, policy="memoryless",
                                seed=0, pool_size=64,
                                lifetime_pool=pool).run(bag)
    assert _row_fields(lazy) == _row_fields(pooled)
    assert _job_fields(lazy) == _job_fields(pooled)


def test_draw_service_pool_batch_matches_serial_pools_x64():
    dists = [D.constrained_for("n1-highcpu-16"),
             D.constrained_for("n1-highcpu-32"),
             D.constrained_for("n1-highcpu-16")]
    seeds = [0, 0, 7]
    with enable_x64():
        mat = K.draw_service_pool_batch(dists, seeds, size=128)
        refs = [S.draw_service_pool(d, seed=s, size=128)
                for d, s in zip(dists, seeds)]
    assert mat.shape == (3, 128)
    for row, ref in zip(mat, refs):
        np.testing.assert_array_equal(row, ref)


def test_one_reuse_table_build_per_grid(monkeypatch):
    """run_bag_grid builds ONE ReuseTables tensor for the whole grid —
    every cluster size, seed and vm_type shares it (satellite 2)."""
    calls = []
    orig_batch = E._reuse_grid_batch

    def spy(*a, **k):
        calls.append(1)
        return orig_batch(*a, **k)

    monkeypatch.setattr(E, "_reuse_grid_batch", spy)

    def no_single(*a, **k):
        raise AssertionError("per-cell reuse grid evaluated")

    no_single.__wrapped__ = E._reuse_grid.__wrapped__  # batch path uses it
    monkeypatch.setattr(E, "_reuse_grid", no_single)
    rows = S.run_bag_grid(vm_types=("n1-highcpu-16", "n1-highcpu-32"),
                          policies=("model",), cluster_sizes=(2, 3, 4),
                          seeds=(0, 1), n_jobs=4, pool_size=512)
    assert len(rows) == 2 * 3 * 2
    assert len(calls) == 1   # ONE vmapped grid call for the whole grid


def test_one_pool_dispatch_per_grid(monkeypatch):
    """All serial cells' lifetime pools come from ONE batched device draw
    (per unique (vm_type, seed)); no per-cell pool refills (satellite 1)."""
    calls = []
    orig = K.draw_service_pool_batch

    def spy(dists, seeds, **kw):
        calls.append(len(list(seeds)))
        return orig(dists, seeds, **kw)

    monkeypatch.setattr(K, "draw_service_pool_batch", spy)
    monkeypatch.setattr(
        S, "draw_service_pool",
        lambda *a, **k: (_ for _ in ()).throw(
            AssertionError("serial cell re-entered the pool helper")))
    rows = S.run_bag_grid(vm_types=("n1-highcpu-32",),
                          policies=("memoryless",), cluster_sizes=(2, 3),
                          seeds=(0, 1), n_jobs=4, pool_size=512)
    assert len(rows) == 4
    assert calls == [2]   # one call, one entry per unique (vm_type, seed)


# ---------------------------------------------------------------------------
# market dollars: launch-price billing (PR-8)
# ---------------------------------------------------------------------------

def test_service_dollars_bit_identical_to_serial_x64():
    """On shared per-seed pools and per-cell price rows, the kernel's
    launch-price dollar accounting equals the serial BatchService's
    bit-for-bit under x64 — the PR-7 equivalence contract extended to
    dollars, for the model and memoryless policies alike.  Unpriced cells
    fall back to dollars == the flat-rate cost in both paths."""
    from repro.core import market as M
    dist = _dist()
    seeds = (0, 1)
    bags = {s: S._bag_lengths(6, 2.0, 0.1, s) for s in seeds}
    values = S.grid_reuse_values(dist, seeds=seeds, n_jobs=6, job_hours=2.0,
                                 jitter=0.1, vm_type="n1-highcpu-32")
    tables = E.ReuseTables([dist], values)
    cells = [dict(dist_index=0, vm_type="n1-highcpu-32", policy=pol,
                  cluster_size=cs, seed=sd)
             for pol in ("memoryless", "model")
             for cs in (2, 3) for sd in seeds]
    price_dt = 0.25
    rows_p = np.stack([M.price_trace(M.spot_price_process(), horizon=48.0,
                                     dt=price_dt, seed=7, leaf=i)
                       for i in range(len(cells))])
    with enable_x64():
        rows_b = K.run_cells_batched(
            cells=cells, dists=[dist], lengths_by_seed=bags,
            reuse_tables=tables, pool_size=512,
            price_rows=rows_p, price_dt=price_dt)
        for i, (cell, row) in enumerate(zip(cells, rows_b)):
            pool = S.draw_service_pool(dist, seed=cell["seed"], size=512)
            ref = S.BatchService(
                dist, cluster_size=cell["cluster_size"],
                policy=cell["policy"], seed=cell["seed"], pool_size=512,
                reuse_table=tables.view(0), lifetime_pool=pool,
                price_trace=rows_p[i], price_dt=price_dt,
            ).run(bags[cell["seed"]])
            assert row["result"].dollars == ref.dollars, cell
            assert row["result"].vm_hours == ref.vm_hours, cell
            assert ref.dollars > 0.0
        # unpriced cells: dollars degrades to the flat-rate cost
        rows_u = K.run_cells_batched(cells=cells[:2], dists=[dist],
                                     lengths_by_seed=bags,
                                     reuse_tables=tables, pool_size=512)
    for row in rows_u:
        assert row["result"].dollars == row["result"].cost


def test_service_price_rows_validation():
    base = dict(lengths=[[1.0]], pools=[[5.0] * 4], bag_index=[0],
                pool_index=[0], policy=["memoryless"], cluster_size=[1])
    with pytest.raises(ValueError, match="strictly positive"):
        K.simulate_service_batch(price_rows=[[1.0, 0.0]], **base)
    with pytest.raises(ValueError, match="price_dt"):
        K.simulate_service_batch(price_rows=[[1.0]], price_dt=0.0, **base)
    with pytest.raises(ValueError, match=r"price_rows must be \(B, Tp\)"):
        K.simulate_service_batch(price_rows=np.ones((3, 4)), **base)


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------

def test_pool_exhaustion_raises_and_flags():
    kw = dict(lengths=[[2.0] * 3], pools=[[0.1, 0.1]], bag_index=[0],
              pool_index=[0], policy=["memoryless"], cluster_size=[2])
    with pytest.raises(RuntimeError, match="pool exhausted"):
        K.simulate_service_batch(**kw)
    res = K.simulate_service_batch(on_exhausted="flag", **kw)
    assert bool(res.pool_exhausted[0])
    with pytest.raises(ValueError, match="on_exhausted"):
        K.simulate_service_batch(on_exhausted="ignore", **kw)


def test_validation_errors():
    base = dict(lengths=[[1.0]], pools=[[5.0] * 4], bag_index=[0],
                pool_index=[0], cluster_size=[1])
    with pytest.raises(ValueError, match="tables"):
        K.simulate_service_batch(policy=["model"], **base)
    with pytest.raises(ValueError, match="bag_index"):
        K.simulate_service_batch(policy=["memoryless"],
                                 **dict(base, bag_index=[2]))
    with pytest.raises(ValueError, match="pool_index"):
        K.simulate_service_batch(policy=["memoryless"],
                                 **dict(base, pool_index=[-1]))
    with pytest.raises(ValueError, match="cluster_size"):
        K.simulate_service_batch(policy=["memoryless"],
                                 **dict(base, cluster_size=[0]))
    with pytest.raises(ValueError, match="deflate_factor"):
        K.simulate_service_batch(policy=["memoryless"], deflate=[True],
                                 deflate_factor=0.0, **base)
    with pytest.raises(ValueError, match="max_slots"):
        K.simulate_service_batch(policy=["memoryless"], max_slots=1,
                                 **dict(base, cluster_size=[4]))
    with pytest.raises(ValueError, match="does not support"):
        S.run_bag_grid(mode="batched", policies=("memoryless",), n_jobs=4,
                       pool_size=512, lifetimes_fn=lambda rng, n: [1.0])


def test_kernel_result_shape_and_counters():
    res = K.simulate_service_batch(
        lengths=[[1.0, 1.5], [2.0, 0.5]], pools=[[9.0] * 8],
        bag_index=[0, 1], pool_index=[0, 0],
        policy=["memoryless", "memoryless"], cluster_size=[2, 2])
    assert len(res) == 2
    assert res.finished_time.shape == (2, 2)
    assert not res.deadlocked.any() and not res.truncated.any()
    # 2 finish events per lane (the loop exits at all-finished, before the
    # hot-spare expiries fire — exactly like the serial loop's break)
    assert (res.n_events == 2).all()
    assert (res.n_launches >= 1).all()


# ---------------------------------------------------------------------------
# property test: random bags / cluster sizes (hypothesis)
# ---------------------------------------------------------------------------

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # pragma: no cover - hypothesis is a test dependency
    st = None

if st is not None:
    _cases = st.fixed_dictionaries({
        # small shape set bounds jit recompiles; variation comes from the
        # seeds (different bags + lifetime streams) and the policy mix
        "n_jobs": st.sampled_from([5, 9]),
        "cluster_sizes": st.sampled_from([(2,), (3,), (2, 4)]),
        "seeds": st.sampled_from([(0,), (3,), (1, 6)]),
        "policies": st.sampled_from([("model",), ("memoryless",),
                                     ("model", "memoryless")]),
        "job_hours": st.sampled_from([1.0, 2.5]),
        "checkpointing": st.booleans(),
    })

    @pytest.mark.slow
    @settings(max_examples=8, deadline=None)
    @given(_cases)
    def test_kernel_equals_serial_property(case):
        """Property: for ANY (bag, cluster mix, policy mix, seed list) the
        batched kernel's rows equal the serial loop's rows under x64."""
        kw = dict(case, pool_size=512)
        with enable_x64():
            rows_s = S.run_bag_grid(mode="serial", **kw)
            rows_b = S.run_bag_grid(mode="batched", **kw)
        _assert_rows_identical(rows_s, rows_b)
else:  # pragma: no cover
    @pytest.mark.skip(reason="property tests need hypothesis installed")
    def test_kernel_equals_serial_property():
        pass
