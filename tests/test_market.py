"""Spot-market layer: the seeded OU price process, the crunch -> Eq. 1
coupling, the ``(S, T)`` price grid, and the dollar-denominated sweep.

The core contracts under test:

  * ``market.price_trace`` is strictly positive and bit-deterministic per
    (seed, leaf) — one independent reproducible noise stream per scenario
    leaf, never shared across leaves;
  * ``PriceProcess`` rides the standard leading-axis convention:
    ``distributions.stack``/``unstack`` round-trip its parameter leaves;
  * ``market.crunch_effective`` goes through the SAME properness cap as
    ``DiurnalConstrained`` (``distributions.capped_constrained``): a crunch
    boost can saturate the cap but never produces an improper Eq. 1 fit
    and never pushes ``A`` below the base fit, and zero crunch intensity
    passes the base model through unchanged;
  * the batched gather ``engine.accumulate_price_cost`` reproduces the
    serial reference ``market.integrate_cost_ref`` BIT-FOR-BIT under x64
    on shared makespans (NaN-flagged unfinished trials included) — the
    market extension of the PR-4/PR-7 equivalence contract;
  * ``scenarios.sweep_market``'s two cost paths (``kernel`` vs
    ``reference``) produce identical rows, ``tables=`` reuse matches the
    self-solving sweep, and one sweep compiles each jitted kernel exactly
    once — repeat sweeps never retrace (trace-count spies).
"""
import dataclasses

import jax
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core import distributions as D
from repro.core import engine as E
from repro.core import market as M
from repro.core import scenarios as SC

ZONES = tuple(M.MARKET_ZONE_PARAMS)


# ---------------------------------------------------------------------------
# price process: positivity, determinism, leaf independence
# ---------------------------------------------------------------------------

def test_price_trace_positive_and_deterministic():
    proc = M.spot_price_process("us-central1-a", crunch_t0=8.0,
                                crunch_t1=16.0)
    a = M.price_trace(proc, seed=3, leaf=2)
    assert a.dtype == np.float64
    assert a.shape == (int(round(M.DEFAULT_HORIZON_HOURS
                                 / M.DEFAULT_PRICE_DT)),)
    assert np.all(a > 0.0) and np.all(np.isfinite(a))
    # bit-identical redraw; different leaf or seed gives a different stream
    np.testing.assert_array_equal(a, M.price_trace(proc, seed=3, leaf=2))
    assert not np.array_equal(a, M.price_trace(proc, seed=3, leaf=3))
    assert not np.array_equal(a, M.price_trace(proc, seed=4, leaf=2))


def test_price_trace_crunch_lifts_exactly_the_window():
    calm = M.spot_price_process()
    crunch = M.spot_price_process(crunch_t0=8.0, crunch_t1=16.0,
                                  crunch_amp=0.9)
    a = M.price_trace(calm, seed=0)
    b = M.price_trace(crunch, seed=0)
    t = M.DEFAULT_PRICE_DT * np.arange(len(a))
    win = (t >= 8.0) & (t < 16.0)
    assert win.any()
    # same OU path underneath: the crunch is a pure exp(amp) price lift
    np.testing.assert_allclose(b[win], a[win] * np.exp(0.9), rtol=1e-12)
    np.testing.assert_array_equal(b[~win], a[~win])


def test_crunch_intensity_window_period_and_disabled():
    p = M.PriceProcess(crunch_t0=2.0, crunch_t1=4.0)
    np.testing.assert_array_equal(
        M.crunch_profile(p, [0.0, 2.0, 3.9, 4.0]), [0.0, 1.0, 1.0, 0.0])
    per = M.PriceProcess(crunch_t0=2.0, crunch_t1=4.0, crunch_period=10.0)
    np.testing.assert_array_equal(
        M.crunch_profile(per, [12.5, 15.0, 23.0]), [1.0, 0.0, 1.0])
    # t1 <= t0 disables the episode entirely
    off = M.PriceProcess()
    assert not M.crunch_profile(off, np.linspace(0.0, 48.0, 97)).any()


def test_price_trace_rejects_degenerate_inputs():
    with pytest.raises(ValueError, match="empty grid"):
        M.price_trace(M.PriceProcess(), horizon=0.01, dt=0.1)
    with pytest.raises(ValueError, match="positive"):
        M.price_trace(M.PriceProcess(p0=0.0))


# ---------------------------------------------------------------------------
# leading-axis convention: stack/unstack round-trip
# ---------------------------------------------------------------------------

def test_price_process_stack_unstack_roundtrip():
    procs = [M.spot_price_process(z, crunch_t0=float(i),
                                  crunch_t1=float(i) + 2.0)
             for i, z in enumerate(ZONES)]
    stacked = D.stack(procs)
    assert type(stacked) is M.PriceProcess
    for leaf in jax.tree_util.tree_leaves(stacked):
        assert leaf.shape[:1] == (len(procs),)
    back = D.unstack(stacked)
    assert len(back) == len(procs)
    for orig, b in zip(procs, back):
        for f in dataclasses.fields(M.PriceProcess):
            assert float(getattr(b, f.name)) == pytest.approx(
                float(np.float64(getattr(orig, f.name)))), f.name


# ---------------------------------------------------------------------------
# crunch -> Eq. 1 coupling through the shared properness cap
# ---------------------------------------------------------------------------

def test_crunch_effective_proper_and_never_below_base():
    """Mirror of the DiurnalConstrained A-cap test: even an extreme crunch
    boost keeps the raw Eq. 1 CDF proper up to the deadline for every
    shipped fit, and never pushes A below the base fit."""
    proc = M.PriceProcess(crunch_t0=0.0, crunch_t1=48.0, crunch_A=4.0,
                          crunch_tau1=0.1)
    for vm_type in D.VM_TYPE_PARAMS:
        base = D.constrained_for(vm_type)
        eff = M.crunch_effective(base, proc, t_launch=1.0)
        assert type(eff) is D.Constrained, vm_type
        assert float(eff.A) >= float(base.A) - 1e-9, vm_type
        raw = float(eff.cdf_raw(float(base.L) - 0.1))
        assert raw <= 1.0 + 1e-6, (vm_type, raw)


def test_crunch_effective_zero_intensity_is_identity():
    """Outside the crunch window the coupling must pass the launch-phase-
    resolved base model through with its parameters unchanged — what makes
    calm-regime tables equal plain per-scenario tables."""
    proc = M.PriceProcess(crunch_t0=8.0, crunch_t1=16.0, crunch_A=3.0)
    d = D.diurnal_for("n1-highcpu-16", launch_clock=20.0)
    eff = M.crunch_effective(d, proc, t_launch=0.0)       # c = 0
    ref = d.effective()
    for f in ("tau1", "tau2", "b", "A", "L"):
        assert float(getattr(eff, f)) == float(getattr(ref, f)), f
    # inside the window the early hazard is strictly harsher
    boosted = M.crunch_effective(d, proc, t_launch=9.0)   # c = 1
    assert float(boosted.tau1) < float(ref.tau1)
    assert float(boosted.cdf(1.0)) > float(ref.cdf(1.0))


# ---------------------------------------------------------------------------
# price grid + the serial dollar reference
# ---------------------------------------------------------------------------

def test_price_grid_cum_shift_and_price_at():
    rows = np.stack([M.price_trace(M.spot_price_process(z), horizon=2.0,
                                   dt=0.5, seed=0, leaf=i)
                     for i, z in enumerate(ZONES[:2])])
    g = M.PriceGrid.from_prices(rows, 0.5)
    assert len(g) == 2 and g.horizon == 2.0
    assert np.all(g.cum[:, 0] == 0.0)
    np.testing.assert_allclose(g.cum[:, -1], rows.sum(axis=1) * 0.5,
                               rtol=1e-12)
    sh = g.shift(0.5)
    np.testing.assert_array_equal(sh.prices[:, :-1], g.prices[:, 1:])
    np.testing.assert_array_equal(sh.prices[:, -1], g.prices[:, -1])
    np.testing.assert_array_equal(g.price_at(0.6), g.prices[:, 1])
    np.testing.assert_array_equal(g.price_at(99.0), g.prices[:, -1])
    with pytest.raises(ValueError, match="strictly positive"):
        M.PriceGrid.from_prices(np.array([[1.0, 0.0]]), 0.5)


def test_integrate_cost_ref_closed_form_tail_and_nan():
    g = M.PriceGrid.from_prices([[2.0, 4.0]], 1.0)

    def f(m):
        return M.integrate_cost_ref(g.prices[0], g.cum[0], g.dt, m)

    assert f(0.0) == 0.0
    assert f(0.5) == 1.0                    # inside the first cell
    assert f(1.5) == 2.0 + 4.0 * 0.5        # straddles the boundary
    assert f(2.0) == 6.0                    # exactly the horizon
    assert f(3.5) == 2.0 + 4.0 * 2.5        # tail billed at the last price
    assert np.isnan(f(float("nan")))


def test_price_grid_shift_at_and_beyond_horizon_boundary():
    """``shift`` clamps to the LAST cell, never reads past the trace: a
    launch exactly at the horizon (or beyond it) yields a constant grid at
    the final price, and a pre-launch (negative) anchor clamps to 0."""
    rows = np.array([[1.0, 2.0, 3.0, 4.0]])
    g = M.PriceGrid.from_prices(rows, 0.5)
    at = g.shift(g.horizon)                   # t0 == horizon: k0 == T
    np.testing.assert_array_equal(at.prices, np.full((1, 4), 4.0))
    beyond = g.shift(g.horizon + 7.25)
    np.testing.assert_array_equal(beyond.prices, np.full((1, 4), 4.0))
    np.testing.assert_array_equal(g.shift(-3.0).prices, g.prices)
    # the last cell BEFORE the horizon still sees its own price first
    last = g.shift(g.horizon - g.dt)
    np.testing.assert_array_equal(last.prices, np.full((1, 4), 4.0))
    # shifted grids re-derive cum, so the integral convention is preserved
    np.testing.assert_allclose(at.cum[0], np.arange(5) * 4.0 * 0.5,
                               rtol=1e-12)


def test_integrate_cost_ref_makespan_exactly_on_grid_edges():
    """A makespan landing exactly on a cell edge bills zero fraction of the
    next cell: ``f(k*dt) == cum[k]`` bit-for-bit, including the horizon
    edge where the clamped last cell takes over."""
    g = M.PriceGrid.from_prices([[2.0, 4.0, 8.0]], 0.5)

    def f(m):
        return M.integrate_cost_ref(g.prices[0], g.cum[0], g.dt, m)

    for k in range(3):
        assert f(k * 0.5) == g.cum[0, k]
    # horizon edge: k clamps to the last cell, frac covers exactly one dt
    assert f(3 * 0.5) == g.cum[0, 3]
    assert f(3 * 0.5) == f(1.5)


def test_price_feed_grid_tracks_the_market_clock():
    """``PriceFeed.grid`` snapshots the ticker from the CURRENT clock cell
    forward — the forecast the dollar-objective runtime solve prices
    against — without disturbing the feed's determinism."""
    feed = M.PriceFeed(seed=11, dt=0.5, tick_hours=0.25)
    g0 = feed.grid(3.0)
    assert len(g0) == 1 and g0.dt == 0.5
    assert g0.prices.shape == (1, 6)          # ceil(3.0 / 0.5)
    np.testing.assert_array_equal(g0.prices[0], feed._trace[:6])
    # advance the clock past two price cells; the snapshot re-anchors
    for _ in range(5):                        # 5 x 0.25h -> clock 1.25h
        feed.advance()
    g1 = feed.grid(1.0)
    np.testing.assert_array_equal(g1.prices[0], feed._trace[2:4])
    # same seed, fresh feed: identical snapshot (determinism preserved)
    np.testing.assert_array_equal(M.PriceFeed(seed=11, dt=0.5).grid(3.0)
                                  .prices, g0.prices)


# ---------------------------------------------------------------------------
# batched gather == serial reference, bit-for-bit under x64
# ---------------------------------------------------------------------------

def _market3(seed=5, horizon=12.0):
    return M.MarketModel(processes=[M.spot_price_process(z) for z in ZONES],
                         horizon=horizon, seed=seed)


def test_accumulate_price_cost_bitexact_x64():
    g = _market3().grid()
    rng = np.random.default_rng(0)
    m = rng.uniform(0.0, 15.0, size=(3, 200))   # includes the tail beyond 12h
    m[rng.uniform(size=m.shape) < 0.1] = np.nan
    with enable_x64():
        out = E.accumulate_price_cost(g, m)
    assert out.shape == m.shape
    for s in range(3):
        for j in range(m.shape[1]):
            ref = M.integrate_cost_ref(g.prices[s], g.cum[s], g.dt, m[s, j])
            if np.isnan(ref):
                assert np.isnan(out[s, j]), (s, j)
            else:
                assert out[s, j] == ref, (s, j)


def test_accumulate_price_cost_index_shapes_and_validation():
    g = _market3().grid()
    rng = np.random.default_rng(1)
    m = rng.uniform(0.0, 10.0, size=(4, 16))
    idx = np.array([2, 0, 1, 2], np.int32)      # lanes share grid rows
    with enable_x64():
        out = E.accumulate_price_cost(g, m, price_index=idx)
        row = E.accumulate_price_cost(g, m[0], price_index=2)
    assert row.shape == (16,)                   # 1-D in, 1-D out
    np.testing.assert_array_equal(row, out[0])
    for b in range(4):
        for j in range(16):
            assert out[b, j] == M.integrate_cost_ref(
                g.prices[idx[b]], g.cum[idx[b]], g.dt, m[b, j]), (b, j)
    with pytest.raises(ValueError, match="out of range"):
        E.accumulate_price_cost(g, m, price_index=[0, 1, 2, 3])


# ---------------------------------------------------------------------------
# market sweep: cost-path equivalence, tables= reuse, validation
# ---------------------------------------------------------------------------

_SWEEP_SCS = None


def _sweep_scenarios():
    global _SWEEP_SCS
    if _SWEEP_SCS is None:
        _SWEEP_SCS = SC.default_grid(vm_types=("n1-highcpu-16",),
                                     phases=("day",))
    return _SWEEP_SCS


_SWEEP_KW = dict(seeds=(0,), job_steps=24, n_trials=24, max_restarts=8)


def _assert_rows_identical(a_rows, b_rows):
    assert len(a_rows) == len(b_rows)
    for ra, rb in zip(a_rows, b_rows):
        assert set(ra) == set(rb)
        for k, va in ra.items():
            vb = rb[k]
            if isinstance(va, float) and np.isnan(va):
                assert isinstance(vb, float) and np.isnan(vb), k
            else:
                assert va == vb, (k, va, vb)


def test_sweep_market_cost_paths_identical_x64():
    """cost_path='kernel' (the batched gather) and 'reference' (the serial
    per-trial loop) must label every row with identical dollars under x64 —
    the sweep-level form of the bit-exactness contract."""
    scs = _sweep_scenarios()
    mkt = M.MarketModel.for_scenarios(scs)
    with enable_x64():
        rk = SC.sweep_market(scs, market=mkt, cost_path="kernel",
                             **_SWEEP_KW)
        rr = SC.sweep_market(scs, market=mkt, cost_path="reference",
                             **_SWEEP_KW)
    assert len(rk) == len(scs) * 2 * 3          # regimes x policies
    _assert_rows_identical(rk, rr)


def test_sweep_market_tables_reuse_and_validation():
    scs = _sweep_scenarios()
    mkt = M.MarketModel.for_scenarios(scs)
    tables = SC.solve_market_tables(scs, mkt,
                                    job_steps=_SWEEP_KW["job_steps"])
    _assert_rows_identical(
        SC.sweep_market(scs, market=mkt, tables=tables, **_SWEEP_KW),
        SC.sweep_market(scs, market=mkt, **_SWEEP_KW))
    with pytest.raises(ValueError):
        SC.sweep_market(scs, market=mkt, tables=tables,
                        **dict(_SWEEP_KW, job_steps=30))
    with pytest.raises(ValueError):
        SC.sweep_market(scs, market=mkt, regimes=("stormy",), **_SWEEP_KW)
    with pytest.raises(ValueError):
        SC.sweep_market(scs, market=mkt, policies=("greedy",), **_SWEEP_KW)


def test_sweep_market_dollar_objective_end_to_end():
    """``dp_objective='dollars'`` threads the regime-anchored price grid
    into the DP solve: tables come back dollar-denominated, ``tables=``
    reuse matches the self-solving sweep row-for-row, and mixing table
    objectives raises before any trial is simulated."""
    scs = _sweep_scenarios()
    mkt = M.MarketModel.for_scenarios(scs)
    tabs = SC.solve_market_tables(scs, mkt,
                                  job_steps=_SWEEP_KW["job_steps"],
                                  dp_objective="dollars")
    for b in tabs.values():
        assert b.objective == "dollars"
        b.validate()
    _assert_rows_identical(
        SC.sweep_market(scs, market=mkt, tables=tabs,
                        dp_objective="dollars", **_SWEEP_KW),
        SC.sweep_market(scs, market=mkt, dp_objective="dollars",
                        **_SWEEP_KW))
    mk_tabs = SC.solve_market_tables(scs, mkt,
                                     job_steps=_SWEEP_KW["job_steps"])
    with pytest.raises(ValueError, match="objective"):
        SC.sweep_market(scs, market=mkt, tables=mk_tabs,
                        dp_objective="dollars", **_SWEEP_KW)
    with pytest.raises(ValueError, match="objective"):
        SC.sweep_market(scs, market=mkt, tables=tabs, **_SWEEP_KW)


# ---------------------------------------------------------------------------
# compile-once regression: trace-count spies (satellite 3)
# ---------------------------------------------------------------------------

def _retrace_spy(monkeypatch, name):
    """Replace a module-level jitted kernel with a fresh jit whose Python
    body counts executions: jax only runs the Python function when TRACING,
    so the list length is the number of compilations."""
    calls = []
    inner = getattr(E, name).__wrapped__

    def counting(*a, **k):
        calls.append(name)
        return inner(*a, **k)

    monkeypatch.setattr(E, name, jax.jit(counting))
    return calls


def test_sweep_market_compiles_each_kernel_once(monkeypatch):
    """One market sweep traces ``_price_cost_kernel`` exactly once (every
    regime/policy/seed billing reuses the cached executable) and
    ``_capped_icdf_kernel`` once per draw-site shape (the pool block and
    the conditioned first draw); repeat sweeps — fresh seeds included —
    never retrace either."""
    icdf = _retrace_spy(monkeypatch, "_capped_icdf_kernel")
    cost = _retrace_spy(monkeypatch, "_price_cost_kernel")
    scs = _sweep_scenarios()
    mkt = M.MarketModel.for_scenarios(scs)
    SC.sweep_market(scs, market=mkt, **_SWEEP_KW)
    first = (len(icdf), len(cost))
    assert first == (2, 1), first
    SC.sweep_market(scs, market=mkt,
                    **dict(_SWEEP_KW, seeds=(1, 2)))
    assert (len(icdf), len(cost)) == first      # zero retraces


# ---------------------------------------------------------------------------
# closed-loop price feed
# ---------------------------------------------------------------------------

def test_price_feed_deterministic_and_extends_without_rewrites():
    feed = M.PriceFeed(seed=4, tick_hours=0.5, block=16)
    seq = [feed.advance() for _ in range(64)]   # 32 h: several lazy blocks
    replay = M.PriceFeed(seed=4, tick_hours=0.5, block=16)
    assert seq == [replay.advance() for _ in range(64)]
    # the lazily-extended trace is a prefix of one long deterministic draw
    long = M.price_trace(feed.process, horizon=64.0, dt=feed.dt,
                         seed=4, leaf=0)
    for i, p in enumerate(seq):
        k = int(np.floor(i * 0.5 / feed.dt))
        assert p == long[k], i
    assert all(p > 0.0 for p in seq)


# ---------------------------------------------------------------------------
# property tests (hypothesis)
# ---------------------------------------------------------------------------

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # pragma: no cover - hypothesis is a test dependency
    st = None

if st is not None:
    _trace_cases = st.fixed_dictionaries({
        "seed": st.integers(0, 2**31 - 1),
        "leaf": st.integers(0, 63),
        "mu": st.floats(-4.0, 1.0),
        "sigma": st.floats(0.0, 0.6),
        "theta": st.floats(0.0, 2.0),
        "p0": st.floats(0.01, 2.0),
        "crunch": st.booleans(),
        "crunch_amp": st.floats(-1.0, 2.0),
    })

    @settings(max_examples=25, deadline=None)
    @given(_trace_cases)
    def test_price_trace_positive_deterministic_property(case):
        """Property: for ANY OU parameterization (crunch lift included,
        negative discounts too) the trace is strictly positive, finite,
        and bit-identical across two draws."""
        kw = dict(mu=case["mu"], sigma=case["sigma"], theta=case["theta"],
                  p0=case["p0"])
        if case["crunch"]:
            kw.update(crunch_t0=1.0, crunch_t1=4.0,
                      crunch_amp=case["crunch_amp"])
        proc = M.PriceProcess(**kw)
        a = M.price_trace(proc, horizon=6.0, dt=0.25,
                          seed=case["seed"], leaf=case["leaf"])
        assert a.shape == (24,)
        assert np.all(a > 0.0) and np.all(np.isfinite(a))
        np.testing.assert_array_equal(
            a, M.price_trace(proc, horizon=6.0, dt=0.25,
                             seed=case["seed"], leaf=case["leaf"]))

    @settings(max_examples=25, deadline=None)
    @given(st.sampled_from(sorted(D.VM_TYPE_PARAMS)),
           st.floats(1.0, 8.0), st.floats(0.05, 1.5), st.booleans())
    def test_crunch_effective_always_proper_property(vm_type, crunch_A,
                                                     crunch_tau1, inside):
        """Property: NO crunch boost — however extreme, launch inside or
        outside the window — yields an improper Eq. 1 fit or an A below
        the base fit (the shared capped_constrained guarantee)."""
        proc = M.PriceProcess(crunch_t0=0.0, crunch_t1=24.0,
                              crunch_A=crunch_A, crunch_tau1=crunch_tau1)
        base = D.constrained_for(vm_type)
        eff = M.crunch_effective(base, proc,
                                 t_launch=1.0 if inside else 30.0)
        assert float(eff.tau1) >= 0.05 - 1e-9
        assert float(eff.A) >= float(base.A) - 1e-9
        assert float(eff.cdf_raw(float(base.L) - 0.1)) <= 1.0 + 1e-6

    @settings(max_examples=10, deadline=None)
    @given(st.lists(st.floats(0.02, 1.0), min_size=1, max_size=5),
           st.floats(0.0, 1.5))
    def test_price_process_stack_roundtrip_property(p0s, amp):
        """Property: stack/unstack round-trips ANY PriceProcess list —
        the (S,) leading-axis convention holds for the market family."""
        procs = [M.PriceProcess(p0=p, crunch_amp=amp, crunch_t0=float(i),
                                crunch_t1=float(i) + 2.0)
                 for i, p in enumerate(p0s)]
        stacked = D.stack(procs)
        for leaf in jax.tree_util.tree_leaves(stacked):
            assert leaf.shape[:1] == (len(procs),)
        for orig, b in zip(procs, D.unstack(stacked)):
            for f in dataclasses.fields(M.PriceProcess):
                assert float(getattr(b, f.name)) == pytest.approx(
                    float(np.float64(getattr(orig, f.name))),
                    rel=1e-6, abs=1e-6), f.name
else:  # pragma: no cover
    @pytest.mark.skip(reason="property tests need hypothesis installed")
    def test_price_trace_positive_deterministic_property():
        pass
