"""Closed-loop fleet runtime: fault-injection matrix, drift adaptation,
retry/backoff degradation, table validation, DP warm starts, and the
mid-sweep table-swap bit-identity contract.

The fault matrix is the PR's acceptance criterion made executable: for
every injected fault kind (drift burst, preemption storm, fit divergence,
solve timeout) — alone and combined — the runtime must finish its run with
ZERO unhandled exceptions, serving only validated tables (last-good under
degradation), with retries recovering inside the configured backoff budget.
All schedules and streams are seeded, so each run replays identically.
"""
import dataclasses

import jax
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro import fault
from repro.core import distributions as D
from repro.core import engine as E
from repro.core import fitting as F
from repro.core import market as M
from repro.core import runtime as rt
from repro.core import scenarios as SC
from repro.core.policies import checkpointing as C

# one shared small workload shape across the module, so every runtime test
# after the first reuses the solver/executor/fit jit caches; the stream is
# the gentle type so the drift events (to the harshest type) sit well above
# the tracker's KS cut
CFG = dict(job_steps=40, grid_dt=0.25, window=128, refit_every=32,
           min_samples=48, stream_block=128, regret_trials=32,
           stream_vm_types=("n1-highcpu-2",),
           retry_backoff_obs=8, max_retries=2)


def _runtime(schedule=(), **over):
    cfg = rt.RuntimeConfig(**{**CFG, **over})
    inj = fault.FaultInjector(schedule, seed=0) if schedule else None
    return rt.FleetRuntime(cfg, injector=inj)


def _assert_serving_valid(fr):
    """The invariant the whole envelope exists to protect: whatever
    happened, the tables being served are finite and well-formed."""
    fr.live_tables.validate()
    for s in range(len(fr.live_tables)):
        E.validate_policy_table(fr.live_tables.K[s])


# ---------------------------------------------------------------------------
# FaultInjector unit behavior
# ---------------------------------------------------------------------------

def test_fault_event_rejects_bad_kind_and_schedule():
    with pytest.raises(ValueError, match="unknown fault kind"):
        fault.FaultEvent("meteor", 10)
    with pytest.raises(ValueError, match="at_obs"):
        fault.FaultEvent("drift", -1)
    with pytest.raises(ValueError, match="duration"):
        fault.FaultEvent("storm", 5, duration=0)


def test_injector_budgets_drift_once_and_storm_window():
    sched = (fault.FaultEvent("drift", 10, param={"vm_types": ("n1-highcpu-2",)}),
             fault.FaultEvent("fit_divergence", 10, duration=2),
             fault.FaultEvent("storm", 20, duration=5))
    inj = fault.FaultInjector(sched, seed=0)
    assert inj.drift_event(9) is None
    assert inj.drift_event(10) is not None
    assert inj.drift_event(10) is None, "a drift fires exactly once"
    # stage budget: duration failures, then drained
    assert inj.take("fit_divergence", 11)
    assert inj.take("fit_divergence", 15)
    assert not inj.take("fit_divergence", 16)
    assert not inj.take("solve_timeout", 16), "no armed event of that kind"
    # storm covers [at_obs, at_obs + duration)
    assert inj.storm_active(19) is None
    ev = inj.storm_active(24)
    assert ev is not None and inj.storm_active(25) is None
    life = inj.storm_lifetime(ev)
    assert 0.0 < life <= 0.05
    assert inj.counts()["storm"] == 1


def test_injector_is_deterministic_under_seed():
    lifes = []
    for _ in range(2):
        inj = fault.FaultInjector((fault.FaultEvent("storm", 0, duration=4),),
                                  seed=7)
        lifes.append([inj.storm_lifetime(inj.storm_active(i))
                      for i in range(4)])
    assert lifes[0] == lifes[1]


# ---------------------------------------------------------------------------
# table validation (engine + BatchDPTables)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_tables():
    d = D.constrained_for()
    return C.solve_batch([d], CFG["job_steps"], grid_dt=CFG["grid_dt"])


def test_validate_policy_table_accepts_real_tables(small_tables):
    out = E.validate_policy_table(small_tables.K[0])
    assert out.dtype == np.int32
    E.validate_policy_table(E.young_daly_policy_table(5, 40))
    E.validate_policy_table(E.no_checkpoint_policy_table(40))


def test_validate_policy_table_rejects_poison(small_tables):
    K = small_tables.K[0]
    with pytest.raises(ValueError, match="non-finite"):
        bad = K.astype(np.float64).copy()
        bad[3, 4] = np.nan
        E.validate_policy_table(bad)
    with pytest.raises(ValueError, match="outside"):
        bad = K.copy()
        bad[2, 0] = 7            # interval > remaining work
        E.validate_policy_table(bad)
    with pytest.raises(ValueError, match="zero interval"):
        bad = K.copy()
        bad[5, 1] = 0
        E.validate_policy_table(bad)
    with pytest.raises(ValueError, match="2-D"):
        E.validate_policy_table(np.zeros((2, 3, 4)))


def test_batch_tables_validate_rejects_poison(small_tables):
    assert small_tables.validate() is small_tables
    badV = small_tables.V.copy()
    badV[0, 1, 1] = np.inf
    with pytest.raises(ValueError, match="non-finite V"):
        dataclasses.replace(small_tables, V=badV).validate()
    negV = small_tables.V.copy()
    negV[0, 1, 0] = -0.5
    with pytest.raises(ValueError, match="negative"):
        dataclasses.replace(small_tables, V=negV).validate()
    badK = small_tables.K.copy()
    badK[0, 4, 2] = 40
    with pytest.raises(ValueError, match="outside"):
        dataclasses.replace(small_tables, K=badK).validate()


def test_batch_tables_validate_rejects_subset_scenario_violation():
    """Regression: the K >= 1 invariant is enforced across the WHOLE
    scenario axis — a violation in only one scenario of a healthy batch
    must still reject (a per-scenario reduction that any-reduces the wrong
    axis would pass it)."""
    ds = [D.constrained_for(), D.Exponential(mttf=8.0)]
    tabs = C.solve_batch(ds, CFG["job_steps"], grid_dt=CFG["grid_dt"])
    assert tabs.validate() is tabs
    badK = tabs.K.copy()
    badK[1, 7, 3] = 0            # work remains (j=7) in scenario 1 only
    with pytest.raises(ValueError, match="K < 1"):
        dataclasses.replace(tabs, K=badK).validate()
    assert np.all(badK[0] == tabs.K[0]), "scenario 0 stayed healthy"


def test_batch_tables_validate_dollar_unit_messages():
    """Dollar tables share the objective-independent invariants but name
    their own unit in the rejection message."""
    price = M.PriceGrid.from_prices(np.full((1, 8), 0.2), 4.0)
    tabs = C.solve_batch([D.constrained_for()], CFG["job_steps"],
                         grid_dt=CFG["grid_dt"], objective="dollars",
                         price=price)
    assert tabs.validate() is tabs
    badV = tabs.V.copy()
    badV[0, 2, 2] = np.nan
    with pytest.raises(ValueError, match=r"non-finite V entries \(dollars\)"):
        dataclasses.replace(tabs, V=badV).validate()
    negV = tabs.V.copy()
    negV[0, 1, 0] = -0.01
    with pytest.raises(ValueError, match="negative dollars"):
        dataclasses.replace(tabs, V=negV).validate()


# ---------------------------------------------------------------------------
# DP warm starts
# ---------------------------------------------------------------------------

def test_warm_start_extends_the_cold_sweep_sequence_exactly(small_tables):
    """The warm start is EXACTLY a continuation of the restart-cost fixed
    point: k warm sweeps seeded with an n-sweep cold V must be bit-identical
    to an (n+k)-sweep cold solve (same scan body, same arithmetic — v_init
    only replaces the carry)."""
    d = D.constrained_for()
    warm = C.solve_batch([d], CFG["job_steps"], grid_dt=CFG["grid_dt"],
                         n_sweeps=1, v_init=small_tables.V)
    cold4 = C.solve_batch([d], CFG["job_steps"], grid_dt=CFG["grid_dt"],
                          n_sweeps=4)
    assert np.array_equal(warm.V, cold4.V)
    assert np.array_equal(warm.K, cold4.K)


def test_warm_start_rejects_mismatched_or_poisoned_init(small_tables):
    d = D.constrained_for()
    with pytest.raises(ValueError, match="shape"):
        C.solve_batch([d], CFG["job_steps"] + 10, grid_dt=CFG["grid_dt"],
                      v_init=small_tables.V)
    bad = small_tables.V.copy()
    bad[0, 0, 0] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        C.solve_batch([d], CFG["job_steps"], grid_dt=CFG["grid_dt"],
                      v_init=bad)


def test_warm_start_none_is_bit_identical_cold_path(small_tables):
    """The v_init=None path must remain byte-identical to the historical
    cold solve (the solve/solve_batch bit contract depends on it)."""
    d = D.constrained_for()
    again = C.solve_batch([d], CFG["job_steps"], grid_dt=CFG["grid_dt"])
    assert np.array_equal(again.V, small_tables.V)
    assert np.array_equal(again.K, small_tables.K)
    ref = C.solve(d, CFG["job_steps"], grid_dt=CFG["grid_dt"])
    assert np.array_equal(again.V[0], ref.V)
    assert np.array_equal(again.K[0], ref.K)


# ---------------------------------------------------------------------------
# the fault matrix (acceptance criterion: zero unhandled exceptions)
# ---------------------------------------------------------------------------

_MATRIX = {
    "drift": (fault.FaultEvent("drift", 120,
                               param={"vm_types": ("n1-highcpu-32",)}),),
    "storm": (fault.FaultEvent("storm", 120, duration=24),),
    "fit_divergence": (fault.FaultEvent("fit_divergence", 40, duration=2),),
    "solve_timeout": (fault.FaultEvent("drift", 120,
                                       param={"vm_types": ("n1-highcpu-32",)}),
                      fault.FaultEvent("solve_timeout", 120, duration=1)),
    "combined": fault.default_schedule(320),
}


@pytest.mark.parametrize("kind", sorted(_MATRIX))
def test_fault_matrix_no_unhandled_exceptions(kind):
    fr = _runtime(_MATRIX[kind])
    rep = fr.run(320)                     # any unhandled exception fails here
    _assert_serving_valid(fr)
    assert rep.n_obs == 320
    # the live model can never be poisoned either
    theta_like = [float(fr.tracker.model.A), float(fr.tracker.model.tau1)]
    assert np.all(np.isfinite(theta_like))
    # injected stage faults are all accounted for as handled retries
    if kind == "fit_divergence":
        assert rep.retries["fit"] >= 2
    if kind == "solve_timeout":
        assert rep.retries["solve"] >= 1


def test_drift_adapts_and_swaps_tables():
    fr = _runtime(_MATRIX["drift"])
    before = fr.live_sc.dist_override
    rep = fr.run(320)
    assert rep.change_points >= 1
    cps = [s for s in rep.swaps if s.reason == "change-point"]
    assert cps, "a confirmed drift must produce a table swap"
    assert rep.adaptation_lag_obs is not None and rep.adaptation_lag_obs > 0
    assert cps[0].warm, "re-solve on an unchanged grid should warm-start"
    # the live scenario now serves the refitted model, not the original
    after = fr.live_sc.dist_override
    assert float(after.tau1) != pytest.approx(float(before.tau1))
    assert rep.regret_hours is not None and np.isfinite(rep.regret_hours)
    _assert_serving_valid(fr)


def test_fit_divergence_degrades_then_recovers():
    """Inject more consecutive fit failures than the retry budget: the
    runtime must degrade to the last-good model (never crash, never adopt
    NaN), then recover on the first clean refit after the budget drains."""
    sched = (fault.FaultEvent("fit_divergence", 40, duration=4),)
    fr = _runtime(sched, max_retries=2)
    rep = fr.run(320)
    kinds = [k for _, k, _ in rep.events]
    assert "fit-failure" in kinds and "fit-degraded" in kinds
    assert rep.retries["fit"] >= 3
    assert rep.n_refits >= 1, "a clean refit must land once the burst drains"
    assert not rep.degraded, "recovery must clear the degraded flag"
    _assert_serving_valid(fr)


def test_solve_timeout_serves_stale_then_swaps():
    fr = _runtime(_MATRIX["solve_timeout"])
    rep = fr.run(320)
    assert rep.retries["solve"] >= 1
    kinds = [k for _, k, _ in rep.events]
    assert "solve-failure" in kinds and "solve-retry-scheduled" in kinds
    cps = [s for s in rep.swaps if s.reason == "change-point"]
    assert cps, "the retried solve must eventually swap"
    assert cps[0].stale_obs > 0, \
        "the failed solve must register as served-stale observations"
    assert rep.stale_obs_total >= cps[0].stale_obs
    _assert_serving_valid(fr)


def test_stream_regime_switch_is_immediate():
    st = rt.FleetStream(seed=0, block=64)
    st.next()
    assert st._buf, "stream should hold buffered draws"
    st.set_regime(("n1-highcpu-2",))
    assert not st._buf, "regime switch must drop buffered old-regime draws"
    assert st.vm_types == ("n1-highcpu-2",)
    x = st.next()
    assert np.isfinite(x) and 0.0 < x <= 24.0


# ---------------------------------------------------------------------------
# mid-sweep table swap: bit-identity with a fresh sweep (satellite)
# ---------------------------------------------------------------------------

def test_mid_sweep_table_swap_rows_bit_identical():
    """Swap semantics of the `tables=` hook: rows evaluated AFTER a hot
    swap must be bit-identical (x64) to a fresh sweep solved directly on
    the new tables — a swap may never leave residue from the old solve."""
    kw = dict(policies=("dp", "none"), seeds=(0,), job_steps=30, n_trials=24)
    name = "test/hot-swap"
    pre = SC.register(SC.Scenario(name=name,
                                  dist_override=D.Constrained(tau1=1.2)),
                      overwrite=True)
    with enable_x64():
        tables_pre = C.solve_batch([pre.dist()], 30)
        rows_pre = SC.sweep_checkpointing([name], tables=tables_pre, **kw)
        # the drift refit lands: swap the live dist + tables atomically
        post = SC.register(
            dataclasses.replace(pre,
                                dist_override=D.Constrained(tau1=0.5)),
            overwrite=True)
        tables_post = C.solve_batch([post.dist()], 30)
        rows_swapped = SC.sweep_checkpointing([name], tables=tables_post,
                                              **kw)
        # reference: a cold sweep that solves the post-drift model itself
        rows_fresh = SC.sweep_checkpointing([name], **kw)
    assert rows_swapped != rows_pre, "swap must actually change the rows"
    assert len(rows_swapped) == len(rows_fresh)
    for a, b in zip(rows_swapped, rows_fresh):
        assert set(a) == set(b)
        for k, va in a.items():
            vb = b[k]
            if isinstance(va, float) and np.isnan(va):
                assert isinstance(vb, float) and np.isnan(vb), k
            else:
                assert va == vb, (k, va, vb)


def test_runtime_evaluate_serves_from_live_tables():
    fr = _runtime()
    fr.run(64)                           # past the initial fit
    rows = fr.evaluate(policies=("dp",), seeds=(0,), n_trials=16)
    assert len(rows) == len(fr.scenario_names)
    live = [r for r in rows if r["scenario"] == fr.cfg.live_name]
    assert live and np.isfinite(live[0]["expected_makespan_dp"])


def test_runtime_dollar_objective_serves_dollar_tables():
    """dp_objective='dollars' without a ticker is a config error; with one,
    every solve — bootstrap and refits alike — prices against the feed's
    forward snapshot and the fleet serves validated dollar tables
    throughout."""
    with pytest.raises(ValueError, match="price_feed"):
        rt.FleetRuntime(rt.RuntimeConfig(**{**CFG,
                                            "dp_objective": "dollars"}))
    feed = M.PriceFeed(seed=3)
    fr = rt.FleetRuntime(rt.RuntimeConfig(**{**CFG,
                                             "dp_objective": "dollars"}),
                         price_feed=feed)
    assert fr.live_tables.objective == "dollars"
    rep = fr.run(64)                     # past the initial fit -> a refit
    assert rep.n_refits >= 1
    assert fr.live_tables.objective == "dollars"
    assert rep.dollars_streamed > 0.0
    _assert_serving_valid(fr)


def test_scenario_dist_override_short_circuits_catalog():
    d = D.Constrained(tau1=0.77)
    sc = SC.Scenario(name="test/override", dist_override=d)
    assert sc.dist() is d
    assert SC.Scenario(name="test/no-override").dist() is not d
