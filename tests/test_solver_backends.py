"""Solver backend equivalence: the bit-exactness contract across the
pluggable backends (reference vs xla vs coarse-to-fine), Pallas interpret
tolerance, backend selection/env-override rules, scenario sharding
transparency, and the FleetRuntime mid-sweep backend swap pinning the
``v_init`` warm-start semantics."""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core import distributions as D
from repro.core import market as M
from repro.core import runtime as rt
from repro.core.policies import checkpointing as C
from repro.core.policies import solver_backends as SB
from repro.core.policies.solver_backends import refine as R

GRID = 1.0 / 12.0
JOB = 60
RO = 0.3          # restart overhead (hours) — exercises launch-priced R_j


@pytest.fixture(scope="module")
def dists():
    # mixed hazards on one deadline: constrained (the paper's family),
    # memoryless, and a decreasing-hazard Weibull whose run-to-completion
    # argmins exercise the refine caps' graceful degradation
    return [D.constrained_for("n1-highcpu-16"), D.Exponential(mttf=8.0),
            D.Weibull(lam=0.12, k=0.8)]


@pytest.fixture(scope="module")
def plain(dists):
    return C.solve_batch(dists, JOB, grid_dt=GRID)


# ---------------------------------------------------------------------------
# bit-identity: reference vs xla vs coarse-to-fine (x64 session dtype)
# ---------------------------------------------------------------------------

def test_reference_vs_xla_bit_identical_x64(dists):
    """The heart of the contract: per scenario slice the batched XLA kernel
    reproduces the serial reference bit-for-bit — under an x64 session
    dtype, because the solver pins its own f32 arithmetic either way.  (The
    CDF grids themselves are built in session dtype, so the comparison is
    within-session, not across dtypes.)"""
    with enable_x64():
        ref = C.solve_batch(dists, JOB, grid_dt=GRID, backend="reference")
        xla = C.solve_batch(dists, JOB, grid_dt=GRID, backend="xla")
    assert ref.backend == "reference" and xla.backend == "xla"
    assert np.array_equal(ref.V, xla.V)
    assert np.array_equal(ref.K, xla.K)


def test_refined_verified_tables_bit_identical_x64(dists):
    """Coarse-to-fine with a passing verification is the plain solve: same
    V, same K, to the bit."""
    with enable_x64():
        plain = C.solve_batch(dists, JOB, grid_dt=GRID)
        ctf = C.solve_batch(dists, JOB, grid_dt=GRID, refine=True,
                            refine_check="full")
    info = ctf.refine_info
    assert info["applied"] and info["verified_col0"]
    assert not info["fallback"]
    assert info["full_check_match"]
    assert ctf.backend == "xla+refine"
    assert np.array_equal(plain.V, ctf.V)
    assert np.array_equal(plain.K, ctf.K)


def test_refined_warm_start_chain(dists, plain):
    """Refined pre-sweeps reproduce the warm-start fixed-point chain too:
    2 warm sweeps (refined) from a 3-sweep cold V == 5-sweep cold solve."""
    warm = C.solve_batch(dists, JOB, grid_dt=GRID, n_sweeps=2,
                         v_init=plain.V, refine=True)
    cold5 = C.solve_batch(dists, JOB, grid_dt=GRID, n_sweeps=5)
    assert warm.refine_info["applied"]
    assert not warm.refine_info["fallback"]
    assert np.array_equal(warm.V, cold5.V)
    assert np.array_equal(warm.K, cold5.K)


def test_refined_fallback_on_sabotaged_caps(dists, plain, monkeypatch):
    """Force every candidate cap to 1 so the pre-sweeps must miss argmins:
    the column-0 verification has to catch it and the dispatcher has to
    serve the plain solve."""
    monkeypatch.setattr(R, "candidate_caps",
                        lambda Kc, segs, **kw: (1,) * len(segs))
    ctf = C.solve_batch(dists, JOB, grid_dt=GRID, refine=True)
    assert not ctf.refine_info["verified_col0"]
    assert ctf.refine_info["fallback"]
    assert np.array_equal(plain.V, ctf.V)
    assert np.array_equal(plain.K, ctf.K)


def test_refine_plan_degenerate_and_bad_backend(dists):
    small = C.solve_batch(dists, 6, grid_dt=1.0, refine=True)
    assert small.refine_info == {"applied": False, "reason": "degenerate"}
    assert R.plan(300, 1440, 1, 1, 4, None) is None     # no pre-sweeps
    with pytest.raises(ValueError, match="contradictory"):
        C.solve_batch(dists, JOB, grid_dt=GRID, refine=True,
                      backend="pallas")


# ---------------------------------------------------------------------------
# dollar objective: the same bit-exactness contract, in a new currency
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def price():
    # flat / crunch spike / ramp — one row per scenario in `dists`, 15-min
    # price cells over 16h (ages beyond the trace bill at the last cell)
    n = 64
    flat = np.full(n, 0.12)
    spike = np.full(n, 0.10)
    spike[12:28] = 0.55
    ramp = np.linspace(0.08, 0.40, n)
    return M.PriceGrid.from_prices(np.stack([flat, spike, ramp]), 0.25)


def test_dollar_reference_vs_xla_bit_identical_x64(dists, price):
    """The tentpole contract: the dollar objective rides the same operand
    set (host-precomputed Pc/Elp grids) through both backends, so per
    scenario slice the batched XLA kernel reproduces the serial reference
    bit-for-bit under an x64 session dtype too."""
    with enable_x64():
        ref = C.solve_batch(dists, JOB, grid_dt=GRID, restart_overhead=RO,
                            objective="dollars", price=price,
                            backend="reference")
        xla = C.solve_batch(dists, JOB, grid_dt=GRID, restart_overhead=RO,
                            objective="dollars", price=price, backend="xla")
    assert ref.objective == "dollars" and xla.objective == "dollars"
    assert np.array_equal(ref.V, xla.V)
    assert np.array_equal(ref.K, xla.K)
    ref.validate()


def test_dollar_refined_verified_bit_identical_x64(dists, price):
    """Coarse-to-fine under the dollar objective (the coarse hint solve runs
    dollars too) with a passing full check equals the plain dollar solve."""
    with enable_x64():
        # refine always runs on the XLA machinery, so compare against an
        # explicit xla plain solve (env-robust under the backend matrix)
        plain = C.solve_batch(dists, JOB, grid_dt=GRID, restart_overhead=RO,
                              objective="dollars", price=price,
                              backend="xla")
        ctf = C.solve_batch(dists, JOB, grid_dt=GRID, restart_overhead=RO,
                            objective="dollars", price=price, refine=True,
                            refine_check="full")
    assert ctf.refine_info["applied"] and not ctf.refine_info["fallback"]
    assert ctf.refine_info["full_check_match"]
    assert np.array_equal(plain.V, ctf.V)
    assert np.array_equal(plain.K, ctf.K)


def test_dollar_warm_start_chain(dists, price):
    """Warm starts stay inside one objective's fixed-point chain: 2 warm
    sweeps from a 3-sweep dollar V == 5-sweep cold dollar solve."""
    kw = dict(grid_dt=GRID, restart_overhead=RO, objective="dollars",
              price=price)
    cold3 = C.solve_batch(dists, JOB, n_sweeps=3, **kw)
    warm = C.solve_batch(dists, JOB, n_sweeps=2, v_init=cold3.V, **kw)
    cold5 = C.solve_batch(dists, JOB, n_sweeps=5, **kw)
    assert np.array_equal(warm.V, cold5.V)
    assert np.array_equal(warm.K, cold5.K)


def test_dollar_flat_price_reduces_to_makespan(dists):
    """On a constant price grid the dollar recurrence is the makespan
    recurrence scaled by the rate — dollar V must equal rate x makespan V
    up to float32 rounding (allclose, not bitwise: the scaled arithmetic
    rounds at different points)."""
    rate = 0.17
    flat = M.PriceGrid.from_prices(np.full((1, 8), rate), 4.0)
    mk = C.solve_batch(dists, JOB, grid_dt=GRID, restart_overhead=RO)
    dl = C.solve_batch(dists, JOB, grid_dt=GRID, restart_overhead=RO,
                       objective="dollars", price=flat)
    np.testing.assert_allclose(np.asarray(dl.V), rate * np.asarray(mk.V),
                               rtol=1e-4, atol=1e-6)
    # the scaled arithmetic rounds near-ties differently, so argmin flips
    # are more common than across backends — demand bulk agreement only
    assert (np.asarray(dl.K) == np.asarray(mk.K)).mean() > 0.99


def test_dollar_solve_single_scenario_unwraps_batch(dists, price):
    """solve(objective='dollars') routes through the batched machinery with
    S=1 and must equal the matching solve_batch slice bit-for-bit."""
    one = M.PriceGrid.from_prices(np.asarray(price.prices)[1:2], price.dt)
    tab = C.solve(dists[1], 30, grid_dt=GRID, restart_overhead=RO,
                  objective="dollars", price=one)
    bat = C.solve_batch(dists[1:2], 30, grid_dt=GRID, restart_overhead=RO,
                        objective="dollars", price=one,
                        backend="reference")
    assert tab.objective == "dollars"
    assert np.array_equal(tab.V, bat.V[0])
    assert np.array_equal(tab.K, bat.K[0])


def test_dollar_objective_validation_errors(dists, price):
    with pytest.raises(ValueError, match="expected one of"):
        C.solve_batch(dists, JOB, grid_dt=GRID, objective="euros")
    with pytest.raises(ValueError, match="requires price"):
        C.solve_batch(dists, JOB, grid_dt=GRID, objective="dollars")
    with pytest.raises(ValueError, match="only meaningful"):
        C.solve_batch(dists, JOB, grid_dt=GRID, price=price)
    two = M.PriceGrid.from_prices(np.asarray(price.prices)[:2], price.dt)
    with pytest.raises(ValueError, match="rows"):
        C.solve_batch(dists, JOB, grid_dt=GRID, objective="dollars",
                      price=two)


@pytest.mark.pallas
def test_dollar_pallas_interpret_within_tolerance(dists, price):
    """The Pallas kernel recomputes the expected-lost-dollars term in-lane
    (it ignores the host Elp grids), so the dollar objective keeps it under
    the tolerance contract, not the bit-identity one."""
    job, grid = 24, 1.0 / 6.0
    kw = dict(grid_dt=grid, n_sweeps=2, restart_overhead=RO,
              objective="dollars", price=price)
    ref = C.solve_batch(dists, job, backend="reference", **kw)
    pal = C.solve_batch(dists, job, backend="pallas", **kw)
    assert pal.backend == "pallas"
    np.testing.assert_allclose(pal.V, ref.V, rtol=1e-5, atol=1e-5)
    # in-lane recompute flips a few more argmin near-ties than makespan's
    # hoisted grids do; the contract for dollar-K agreement is 99.5%
    assert (pal.K == ref.K).mean() > 0.995


def test_dollar_sharding_single_device_mesh_transparent(dists, price):
    """The dollar operands (Pc, Elp, per-scenario overhead) ride the sharded
    scenario axis: a 1-device mesh must not change a bit."""
    import jax
    from jax.sharding import Mesh
    from repro import sharding as sh
    kw = dict(grid_dt=GRID, restart_overhead=RO, objective="dollars",
              price=price, backend="xla")
    plain = C.solve_batch(dists, JOB, **kw)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    with mesh, sh.use(mesh):
        shd = C.solve_batch(dists, JOB, **kw)
        ctf = C.solve_batch(dists, JOB, refine=True,
                            **{**kw, "backend": "auto"})
    assert np.array_equal(plain.V, shd.V)
    assert np.array_equal(plain.K, shd.K)
    assert not ctf.refine_info["fallback"]
    assert np.array_equal(plain.V, ctf.V)


# ---------------------------------------------------------------------------
# backend selection
# ---------------------------------------------------------------------------

def test_resolve_env_override_applies_only_to_auto(monkeypatch):
    monkeypatch.delenv(SB.ENV_VAR, raising=False)
    assert SB.resolve("auto") == "xla"           # CPU container
    assert SB.resolve("reference") == "reference"
    monkeypatch.setenv(SB.ENV_VAR, "reference")
    assert SB.resolve("auto") == "reference"
    assert SB.resolve("xla") == "xla"            # explicit name wins
    monkeypatch.setenv(SB.ENV_VAR, "bogus")
    with pytest.raises(ValueError, match="unknown solver backend"):
        SB.resolve("auto")
    with pytest.raises(ValueError, match="unknown solver backend"):
        SB.resolve("bogus")


def test_solve_single_scenario_explicit_backends(dists):
    """solve(backend=...) routes through the batched machinery with S=1 and
    unwraps to the same tables as the reference path."""
    d = dists[0]
    ref = C.solve(d, 30, grid_dt=GRID)
    via_xla = C.solve(d, 30, grid_dt=GRID, backend="xla")
    assert np.array_equal(ref.V, via_xla.V)
    assert np.array_equal(ref.K, via_xla.K)


# ---------------------------------------------------------------------------
# Pallas backend (interpret mode on CPU)
# ---------------------------------------------------------------------------

@pytest.mark.pallas
def test_pallas_interpret_within_tolerance(dists):
    """The VMEM-resident kernel recomputes the probability grids on the fly,
    so it is tolerance-tested (not bit-pinned) against the reference."""
    job, grid = 24, 1.0 / 6.0
    ref = C.solve_batch(dists, job, grid_dt=grid, n_sweeps=2,
                        backend="reference")
    pal = C.solve_batch(dists, job, grid_dt=grid, n_sweeps=2,
                        backend="pallas")
    assert pal.backend == "pallas"
    np.testing.assert_allclose(pal.V, ref.V, rtol=1e-5, atol=1e-5)
    # argmin ties may flip at ulp scale; demand near-total agreement
    assert (pal.K == ref.K).mean() > 0.999


@pytest.mark.pallas
def test_pallas_warm_start_column_seed(dists):
    """The kernel's warm start is the seed column V[:, :, 0] — sweeps couple
    only through column 0, so one warm sweep from a 2-sweep V must land on
    the 3-sweep solve (within kernel tolerance)."""
    job, grid = 24, 1.0 / 6.0
    cold2 = C.solve_batch(dists, job, grid_dt=grid, n_sweeps=2)
    warm = C.solve_batch(dists, job, grid_dt=grid, n_sweeps=1,
                         v_init=cold2.V, backend="pallas")
    cold3 = C.solve_batch(dists, job, grid_dt=grid, n_sweeps=3)
    np.testing.assert_allclose(warm.V, cold3.V, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# scenario sharding
# ---------------------------------------------------------------------------

def test_sharding_single_device_mesh_transparent(dists, plain):
    """An active 1-device mesh context engages the shard_map wrapper (the
    'scenario' rule maps, S divides 1) without changing a bit."""
    import jax
    from jax.sharding import Mesh
    from repro import sharding as sh
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    with mesh, sh.use(mesh):
        shd = C.solve_batch(dists, JOB, grid_dt=GRID)
        ctf = C.solve_batch(dists, JOB, grid_dt=GRID, refine=True)
    assert np.array_equal(plain.V, shd.V)
    assert np.array_equal(plain.K, shd.K)
    assert not ctf.refine_info["fallback"]
    assert np.array_equal(plain.V, ctf.V)


def test_sharding_no_mesh_returns_unwrapped():
    fn = lambda x: (x,)
    out, sharded = SB.shard_scenarios(fn, 8, 1, 1)
    assert out is fn and not sharded


@pytest.mark.slow
def test_sharding_two_devices_bit_identical():
    """Real shard_map over 2 forced host devices: the sharded S=4 solve
    (plain and refined) must equal the unsharded single-device tables
    bit-for-bit; an indivisible S=3 falls back transparently."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import json
        import jax
        import numpy as np
        from jax.sharding import Mesh
        from repro import sharding as sh
        from repro.core import distributions as D
        from repro.core.policies import checkpointing as C
        dists = [D.Exponential(mttf=8.0), D.Weibull(lam=0.12, k=0.8),
                 D.constrained_for("n1-highcpu-16"), D.Exponential(mttf=16.0)]
        plain = C.solve_batch(dists, 30, grid_dt=1.0 / 6.0, n_sweeps=2)
        mesh = Mesh(np.array(jax.devices()).reshape(2), ("data",))
        with mesh, sh.use(mesh):
            shd = C.solve_batch(dists, 30, grid_dt=1.0 / 6.0, n_sweeps=2)
            ctf = C.solve_batch(dists, 30, grid_dt=1.0 / 6.0, n_sweeps=2,
                                refine=True)
            p3 = C.solve_batch(dists[:3], 30, grid_dt=1.0 / 6.0, n_sweeps=2)
        u3 = C.solve_batch(dists[:3], 30, grid_dt=1.0 / 6.0, n_sweeps=2)
        print(json.dumps({
            "devices": jax.device_count(),
            "plain_eq": bool(np.array_equal(plain.V, shd.V)
                             and np.array_equal(plain.K, shd.K)),
            "refine_eq": bool(np.array_equal(plain.V, ctf.V)),
            "refine_ok": bool(not ctf.refine_info["fallback"]),
            "indivisible_eq": bool(np.array_equal(p3.V, u3.V)),
        }))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    result = json.loads(out.stdout.strip().splitlines()[-1])
    assert result == {"devices": 2, "plain_eq": True, "refine_eq": True,
                      "refine_ok": True, "indivisible_eq": True}


# ---------------------------------------------------------------------------
# FleetRuntime mid-sweep backend swap
# ---------------------------------------------------------------------------

def test_runtime_mid_sweep_backend_swap_pins_v_init(monkeypatch):
    """Swapping the solver backend between refits must not disturb the
    warm-start chain: the fixed point couples backends only through V, so
    warm sweeps on a DIFFERENT backend continue the cold sweep sequence
    bit-exactly (reference/xla/refined are interchangeable mid-loop)."""
    cfg = dict(job_steps=40, grid_dt=0.25, window=128, refit_every=32,
               min_samples=48, stream_block=128, regret_trials=32,
               stream_vm_types=("n1-highcpu-2",), solver_backend="xla")
    fr = rt.FleetRuntime(rt.RuntimeConfig(**cfg))
    dists = fr._dists()
    cold = fr.live_tables                      # n_sweeps=3 cold solve, xla
    want = C.solve_batch(dists, cfg["job_steps"], grid_dt=cfg["grid_dt"],
                         n_sweeps=3 + fr.cfg.warm_sweeps)
    for swap in ({"solver_backend": "reference"},
                 {"solver_backend": "auto", "solver_refine": True}):
        fr.cfg = dataclasses.replace(fr.cfg, **swap)
        tab = fr._solve(warm=True)             # warm_sweeps=2 from cold.V
        assert fr._last_solve_warm, swap
        assert np.array_equal(tab.V, want.V), swap
        assert np.array_equal(tab.K, want.K), swap
    assert np.array_equal(cold.V, fr.live_tables.V)  # swap did not publish
