"""Online model maintenance: refitting + change-point detection."""
import jax
import numpy as np

from repro.core import distributions as D
from repro.core import simulator as S
from repro.core.online import OnlineModelTracker


def test_tracker_converges_to_fleet_behavior():
    gt = S.ground_truth_for("n1-highcpu-16")
    samples = np.asarray(gt.sample(jax.random.PRNGKey(0), (512,)))
    trk = OnlineModelTracker(min_samples=128, refit_every=128)
    for x in samples:
        trk.observe(x)
    assert trk.n_refits >= 2
    d = trk.model
    # fitted parameters in the paper's ranges
    assert 0.4 <= float(d.tau1) <= 2.5
    assert 23.0 <= float(d.b) <= 25.0
    assert trk.change_points == 0, "stationary fleet: no change points"


def test_tracker_detects_policy_change():
    """Fleet switches from gentle to aggressive preemption mid-stream: the
    tracker must flag a change point and adapt the model."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    gentle = np.asarray(S.ground_truth_for("n1-highcpu-2").sample(k1, (384,)))
    harsh = np.asarray(S.ground_truth_for("n1-highcpu-32").sample(k2, (384,)))
    trk = OnlineModelTracker(min_samples=128, refit_every=128, window=384)
    for x in gentle:
        trk.observe(x)
    f3_before = float(trk.model.cdf(3.0))
    for x in harsh:
        trk.observe(x)
    f3_after = float(trk.model.cdf(3.0))
    assert trk.change_points >= 1, "policy change must be detected"
    assert f3_after > f3_before + 0.1, "model must adapt to faster preemption"
