"""Online model maintenance: refitting + change-point detection."""
import jax
import numpy as np
import pytest

from repro.core import distributions as D
from repro.core import simulator as S
from repro.core.online import OnlineModelTracker, ks_critical_value


def test_tracker_converges_to_fleet_behavior():
    gt = S.ground_truth_for("n1-highcpu-16")
    samples = np.asarray(gt.sample(jax.random.PRNGKey(0), (512,)))
    trk = OnlineModelTracker(min_samples=128, refit_every=128)
    for x in samples:
        trk.observe(x)
    assert trk.n_refits >= 2
    d = trk.model
    # fitted parameters in the paper's ranges
    assert 0.4 <= float(d.tau1) <= 2.5
    assert 23.0 <= float(d.b) <= 25.0
    assert trk.change_points == 0, "stationary fleet: no change points"


def test_tracker_detects_policy_change():
    """Fleet switches from gentle to aggressive preemption mid-stream: the
    tracker must flag a change point and adapt the model."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    gentle = np.asarray(S.ground_truth_for("n1-highcpu-2").sample(k1, (384,)))
    harsh = np.asarray(S.ground_truth_for("n1-highcpu-32").sample(k2, (384,)))
    trk = OnlineModelTracker(min_samples=128, refit_every=128, window=384)
    for x in gentle:
        trk.observe(x)
    f3_before = float(trk.model.cdf(3.0))
    for x in harsh:
        trk.observe(x)
    f3_after = float(trk.model.cdf(3.0))
    assert trk.change_points >= 1, "policy change must be detected"
    assert f3_after > f3_before + 0.1, "model must adapt to faster preemption"


def test_ks_critical_value_scaling():
    """The derived cut must shrink with sample count (the fixed 0.15 ignored
    it) and widen when the reference model is itself a small-sample fit."""
    one_small = ks_critical_value(0.01, 64)
    one_large = ks_critical_value(0.01, 1024)
    assert one_small > one_large > 0
    np.testing.assert_allclose(one_small / one_large, np.sqrt(1024 / 64),
                               rtol=1e-12)
    two = ks_critical_value(0.01, 128, n_fit=128)
    assert two > ks_critical_value(0.01, 128)
    np.testing.assert_allclose(two, ks_critical_value(0.01, 128) * np.sqrt(2),
                               rtol=1e-12)
    # stricter alpha -> wider cut
    assert ks_critical_value(0.001, 128) > ks_critical_value(0.05, 128)


def test_tracker_small_window_regression():
    """Regression for the stationary false positive: small refit windows see
    KS noise well above 0.15 purely from the two-sample geometry, so the
    derived cut must hold change_points at zero — while a genuinely drifting
    fleet with the SAME window sizes still trips it."""
    gt = S.ground_truth_for("n1-highcpu-16")
    samples = np.asarray(gt.sample(jax.random.PRNGKey(7), (512,)))
    trk = OnlineModelTracker(min_samples=128, refit_every=128)
    for x in samples:
        trk.observe(x)
    assert trk.change_points == 0
    assert np.isfinite(trk.last_cut) and trk.last_cut < 0.15
    # drifting fleet, same tracker geometry
    k1, k2 = jax.random.split(jax.random.PRNGKey(8))
    gentle = np.asarray(S.ground_truth_for("n1-highcpu-2").sample(k1, (256,)))
    harsh = np.asarray(S.ground_truth_for("n1-highcpu-32").sample(k2, (256,)))
    drift = OnlineModelTracker(min_samples=128, refit_every=128, window=384)
    for x in np.concatenate([gentle, harsh]):
        drift.observe(x)
    assert drift.change_points >= 1
    assert drift.drifted or drift.change_points >= 1


def test_tracker_legacy_fixed_threshold():
    """A user-pinned ks_threshold bypasses the derived cut entirely."""
    trk = OnlineModelTracker(ks_threshold=0.4, min_samples=64, refit_every=64)
    gt = S.ground_truth_for("n1-highcpu-16")
    for x in np.asarray(gt.sample(jax.random.PRNGKey(3), (192,))):
        trk.observe(x)
    assert trk.last_cut == pytest.approx(0.4)
    assert trk.change_points == 0


def test_change_point_trims_window_refit_matches_post_drift():
    """Satellite regression: on a confirmed change point the rolling window
    is trimmed to the post-change slice, so the refitted model tracks the
    POST-drift fleet — not a blend of pre- and post-drift lifetimes (the
    old full-window refit's failure mode)."""
    from repro.core import fitting

    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(11), 3)
    gentle = np.asarray(S.ground_truth_for("n1-highcpu-2").sample(k1, (384,)))
    harsh = np.asarray(S.ground_truth_for("n1-highcpu-32").sample(k2, (384,)))
    trk = OnlineModelTracker(min_samples=128, refit_every=128, window=384)
    for x in gentle:
        trk.observe(x)
    for i, x in enumerate(harsh):
        trk.observe(x)
        if trk.change_points:
            break
    assert trk.change_points >= 1, "phase flip must be detected"
    assert len(trk._obs) < 384, "window must be trimmed at the change point"
    for x in harsh[i + 1:]:
        trk.observe(x)
    # reference blend: what the old un-trimmed refit would have fitted at
    # detection time — half stale gentle lifetimes, half harsh
    blend = fitting.fit_samples(
        "constrained", np.concatenate([gentle[-192:], harsh[:192]]))
    probe = np.asarray(S.ground_truth_for("n1-highcpu-32").sample(k3, (512,)))
    ks_model = float(fitting.ks_statistic(trk.model, probe))
    ks_blend = float(fitting.ks_statistic(blend.dist, probe))
    assert ks_model < ks_blend, \
        f"refit must match the post-drift fleet (ks {ks_model:.3f}) better " \
        f"than a pre/post blend (ks {ks_blend:.3f})"


def test_tracker_keeps_last_good_model_on_fit_failure():
    """An injected diverging fit raises FitDiverged and must leave the live
    model untouched; defer_refit then backs the next attempt off."""
    from repro.core import fitting

    calls = {"n": 0}

    def poisoned(family, data, **kw):
        calls["n"] += 1
        return fitting.FitResult(dist=None, theta=np.full(3, np.nan),
                                 lse=np.nan, iterations=0, converged=False)

    gt = S.ground_truth_for("n1-highcpu-16")
    samples = np.asarray(gt.sample(jax.random.PRNGKey(5), (80,)))
    trk = OnlineModelTracker(min_samples=64, refit_every=64, fit_fn=poisoned)
    before = trk.model
    raised = 0
    for x in samples:
        try:
            trk.observe(x)
        except fitting.FitDiverged:
            raised += 1
            trk.defer_refit(8)
    assert raised >= 1 and calls["n"] >= 1
    assert trk.model is before, "last-good model must keep serving"
    assert trk.n_refits == 0
