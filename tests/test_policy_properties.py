"""Property-based tests (hypothesis) on the DP checkpointing policy and the
scheduling quantities - system invariants that must hold for ANY plausible
model parameters, not just the calibrated ones."""
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis installed")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import distributions as D
from repro.core.policies import checkpointing as C
from repro.core.policies import scheduling as S

params = st.fixed_dictionaries({
    "tau1": st.floats(0.5, 2.0),
    "tau2": st.floats(0.5, 1.2),
    "b": st.floats(23.0, 24.5),
    "A": st.floats(0.35, 0.5),
})


@settings(max_examples=10, deadline=None)
@given(params)
def test_failure_probabilities_are_probabilities(p):
    d = D.Constrained(**p)
    for T in (1.0, 6.0, 12.0):
        for s in (0.0, 6.0, 18.0, 23.0):
            for fn in (S.p_fail_existing, ):
                v = float(fn(d, T, s))
                assert 0.0 <= v <= 1.0
            v = float(S.p_fail_new(d, T))
            assert 0.0 <= v <= 1.0


@settings(max_examples=10, deadline=None)
@given(params)
def test_makespan_at_least_job_length(p):
    d = D.Constrained(**p)
    for T in (1.0, 5.0, 10.0):
        assert float(S.expected_makespan_new(d, T)) >= T - 1e-6
        m = float(S.expected_makespan_at_age(d, T, 6.0))
        assert m >= T - 1e-6 or m == np.inf


@settings(max_examples=6, deadline=None)
@given(params, st.integers(60, 240))
def test_dp_value_bounds(p, job_steps):
    """V(j, t) between the bare work time and a generous blowup bound, and
    monotone in j."""
    d = D.Constrained(**p)
    tab = C.solve(d, job_steps, grid_dt=1.0 / 12.0, delta_steps=1,
                  n_sweeps=2)
    dt = 1.0 / 12.0
    V = tab.V
    work = np.arange(V.shape[0]) * dt
    assert np.all(V[:, 0] >= work - 1e-4)
    assert np.all(np.diff(V[:, 0]) >= -1e-4)


def test_dp_intervals_shrink_with_cheaper_checkpoints():
    """delta -> 0 should never lengthen the optimal first interval."""
    d = D.constrained_for()
    t_cheap = C.solve(d, 120, grid_dt=1.0 / 12.0, delta_steps=1)
    t_dear = C.solve(d, 120, grid_dt=1.0 / 12.0, delta_steps=4)
    i_cheap = C.extract_schedule(t_cheap, 120, 0)[0]
    i_dear = C.extract_schedule(t_dear, 120, 0)[0]
    assert i_cheap <= i_dear


def test_dp_degenerates_to_no_checkpoint_when_safe():
    """With a near-zero-hazard stable phase and a short job started there,
    the optimal schedule is a single segment."""
    d = D.Constrained(tau1=0.5, tau2=0.5, b=24.0, A=0.45)
    tab = C.solve(d, 24, grid_dt=1.0 / 12.0, delta_steps=2)
    sched = C.extract_schedule(tab, 24, 8 * 12)   # 2h job at age 8h
    assert len(sched) == 1
