"""Property-based tests (hypothesis) on the DP checkpointing policy and the
scheduling quantities - system invariants that must hold for ANY plausible
model parameters, not just the calibrated ones."""
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis installed")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import distributions as D
from repro.core.policies import checkpointing as C
from repro.core.policies import scheduling as S

params = st.fixed_dictionaries({
    "tau1": st.floats(0.5, 2.0),
    "tau2": st.floats(0.5, 1.2),
    "b": st.floats(23.0, 24.5),
    "A": st.floats(0.35, 0.5),
})


@settings(max_examples=10, deadline=None)
@given(params)
def test_failure_probabilities_are_probabilities(p):
    d = D.Constrained(**p)
    for T in (1.0, 6.0, 12.0):
        for s in (0.0, 6.0, 18.0, 23.0):
            for fn in (S.p_fail_existing, ):
                v = float(fn(d, T, s))
                assert 0.0 <= v <= 1.0
            v = float(S.p_fail_new(d, T))
            assert 0.0 <= v <= 1.0


@settings(max_examples=10, deadline=None)
@given(params)
def test_makespan_at_least_job_length(p):
    d = D.Constrained(**p)
    for T in (1.0, 5.0, 10.0):
        assert float(S.expected_makespan_new(d, T)) >= T - 1e-6
        m = float(S.expected_makespan_at_age(d, T, 6.0))
        assert m >= T - 1e-6 or m == np.inf


@settings(max_examples=6, deadline=None)
@given(params, st.integers(60, 240))
def test_dp_value_bounds(p, job_steps):
    """V(j, t) between the bare work time and a generous blowup bound, and
    monotone in j."""
    d = D.Constrained(**p)
    tab = C.solve(d, job_steps, grid_dt=1.0 / 12.0, delta_steps=1,
                  n_sweeps=2)
    dt = 1.0 / 12.0
    V = tab.V
    work = np.arange(V.shape[0]) * dt
    assert np.all(V[:, 0] >= work - 1e-4)
    assert np.all(np.diff(V[:, 0]) >= -1e-4)


prices4 = st.lists(st.floats(0.05, 0.60), min_size=4, max_size=4)


def _flat_grid(rate, n=8, pdt=4.0):
    from repro.core import market as M
    return M.PriceGrid.from_prices(np.full((1, n), rate), pdt)


@settings(max_examples=6, deadline=None)
@given(params, st.floats(0.06, 0.55))
def test_dollar_flat_price_proportional_to_makespan(p, rate):
    """Constant price: dollar V == rate x makespan V (up to f32 rounding)
    for ANY plausible model and ANY rate — the exchange-rate identity that
    anchors the dollar objective to the makespan one."""
    d = D.Constrained(**p)
    mk = C.solve(d, 36, grid_dt=1.0 / 12.0, n_sweeps=2)
    dl = C.solve(d, 36, grid_dt=1.0 / 12.0, n_sweeps=2,
                 objective="dollars", price=_flat_grid(rate))
    np.testing.assert_allclose(np.asarray(dl.V), rate * np.asarray(mk.V),
                               rtol=2e-4, atol=1e-6)


@settings(max_examples=6, deadline=None)
@given(params, prices4)
def test_dollar_value_monotone_in_price(p, base):
    """Raising the price pointwise can only raise expected dollars: every
    term of the recurrence (segment bill, priced lost work, launch-priced
    restart) is monotone in the price trace."""
    from repro.core import market as M
    d = D.Constrained(**p)
    lo = M.PriceGrid.from_prices(np.asarray(base)[None, :], 8.0)
    hi = M.PriceGrid.from_prices(np.asarray(base)[None, :] * 1.5, 8.0)
    kw = dict(grid_dt=1.0 / 12.0, n_sweeps=2, restart_overhead=0.2,
              objective="dollars")
    v_lo = np.asarray(C.solve(d, 36, price=lo, **kw).V)
    v_hi = np.asarray(C.solve(d, 36, price=hi, **kw).V)
    assert np.all(v_hi >= v_lo * (1.0 - 1e-4) - 1e-6)


def test_dollar_crunch_window_stretches_checkpoint_interval():
    """Where the price spikes, each checkpoint's delta costs real dollars
    while the lost-work risk is only expensive if the VM dies INSIDE the
    window — so over the expensive window the dollar DP checkpoints less
    aggressively on average than the makespan DP.  (Pointwise K can still
    shrink in spots: deep in the window a tiny segment that defers the bulk
    of the work past the spike is genuinely optimal, so the property is a
    mean over the window, not a per-cell dominance.)"""
    from repro.core import market as M
    d = D.constrained_for()
    prices = np.full(24, 0.10)
    prices[17:23] = 0.60        # expensive window over the hazard rise,
    price = M.PriceGrid.from_prices(prices[None, :], 1.0)  # hours 17-23
    mk = C.solve(d, 60, grid_dt=1.0 / 12.0, delta_steps=2, n_sweeps=3,
                 restart_overhead=0.2)
    dl = C.solve(d, 60, grid_dt=1.0 / 12.0, delta_steps=2, n_sweeps=3,
                 restart_overhead=0.2, objective="dollars", price=price)
    # the chosen interval for a full fresh job launched inside the window
    # (the makespan DP checkpoints actively there: K < j on most cells)
    cells = slice(17 * 12, 22 * 12)
    K_mk = np.asarray(mk.K)[60, cells]
    K_dl = np.asarray(dl.K)[60, cells]
    assert (K_mk < 60).mean() > 0.5        # the window is actually active
    assert K_dl.mean() > K_mk.mean() * 1.1


def test_dp_intervals_shrink_with_cheaper_checkpoints():
    """delta -> 0 should never lengthen the optimal first interval."""
    d = D.constrained_for()
    t_cheap = C.solve(d, 120, grid_dt=1.0 / 12.0, delta_steps=1)
    t_dear = C.solve(d, 120, grid_dt=1.0 / 12.0, delta_steps=4)
    i_cheap = C.extract_schedule(t_cheap, 120, 0)[0]
    i_dear = C.extract_schedule(t_dear, 120, 0)[0]
    assert i_cheap <= i_dear


def test_dp_degenerates_to_no_checkpoint_when_safe():
    """With a near-zero-hazard stable phase and a short job started there,
    the optimal schedule is a single segment."""
    d = D.Constrained(tau1=0.5, tau2=0.5, b=24.0, A=0.45)
    tab = C.solve(d, 24, grid_dt=1.0 / 12.0, delta_steps=2)
    sched = C.extract_schedule(tab, 24, 8 * 12)   # 2h job at age 8h
    assert len(sched) == 1
