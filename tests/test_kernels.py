"""Kernel sweeps: every Pallas kernel (interpret=True on CPU) and every XLA
production implementation against the pure-jnp oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention as pl_decode
from repro.kernels.flash_attention import flash_attention as pl_flash
from repro.kernels.rglru_scan import linear_recurrence as pl_linrec

KEY = jax.random.PRNGKey(0)


def _qkv(B, Sq, Sk, H, KV, D, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Sk, KV, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Sk, KV, D), jnp.float32).astype(dtype)
    return q, k, v


FLASH_CASES = [
    # B, Sq, Sk, H, KV, D, causal, window
    (2, 128, 128, 4, 2, 32, True, 0),
    (1, 256, 256, 6, 2, 64, True, 0),
    (2, 128, 128, 3, 3, 32, False, 0),
    (1, 256, 256, 2, 1, 64, True, 64),
    (1, 64, 64, 9, 3, 64, True, 0),      # smollm-like head count
    (2, 64, 64, 4, 4, 128, True, 0),     # MHA, wide head
]


@pytest.mark.pallas
@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_flash_matches_ref(case, dtype):
    B, Sq, Sk, H, KV, D, causal, window = case
    q, k, v = _qkv(B, Sq, Sk, H, KV, D, dtype)
    o_ref = ref.attention(q, k, v, causal=causal, window=window)
    o_pl = pl_flash(q, k, v, causal=causal, window=window, block_q=64,
                    block_k=64, interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o_pl, np.float32),
                               np.asarray(o_ref, np.float32), atol=tol)


@pytest.mark.parametrize("case", FLASH_CASES[:4])
def test_xla_flash_matches_ref(case):
    B, Sq, Sk, H, KV, D, causal, window = case
    q, k, v = _qkv(B, Sq, Sk, H, KV, D, jnp.float32)
    o_ref = ref.attention(q, k, v, causal=causal, window=window)
    o_fl = ops.flash_attention_xla(q, k, v, causal, window, None, 64, 64)
    np.testing.assert_allclose(np.asarray(o_fl), np.asarray(o_ref), atol=2e-5)


def test_xla_flash_gradients_match_ref():
    q, k, v = _qkv(2, 128, 128, 4, 2, 32, jnp.float32)

    def loss_fl(q, k, v):
        return (ops.flash_attention_xla(q, k, v, True, 0, None, 64, 64)
                ** 2).sum()

    def loss_ref(q, k, v):
        return (ref.attention(q, k, v, causal=True) ** 2).sum()

    g1 = jax.grad(loss_fl, (0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3,
                                   rtol=1e-4)


def test_xla_flash_uneven_block_sizes():
    """Block pick must handle sequence lengths not divisible by defaults."""
    q, k, v = _qkv(1, 96, 96, 2, 2, 16, jnp.float32)
    o_ref = ref.attention(q, k, v, causal=True)
    o_fl = ops.flash_attention_xla(q, k, v, True, 0, None, 512, 512)
    np.testing.assert_allclose(np.asarray(o_fl), np.asarray(o_ref), atol=2e-5)


DECODE_CASES = [
    (2, 128, 4, 2, 32, 100),
    (1, 256, 6, 3, 64, 256),
    (2, 64, 3, 1, 32, 64),
    (4, 128, 8, 8, 64, 77),
]


@pytest.mark.pallas
@pytest.mark.parametrize("case", DECODE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_decode_matches_ref(case, dtype):
    B, S, H, KV, D, ln = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32).astype(dtype)
    kc = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32).astype(dtype)
    vc = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32).astype(dtype)
    lengths = jnp.full((B,), ln, jnp.int32)
    o_ref = ref.decode_attention(q, kc, vc, lengths)
    o_pl = pl_decode(q, kc, vc, lengths, block_k=32, interpret=True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(o_pl, np.float32),
                               np.asarray(o_ref, np.float32), atol=tol)


LINREC_CASES = [(2, 64, 32), (1, 128, 16), (3, 96, 8), (2, 256, 64)]


@pytest.mark.pallas
@pytest.mark.parametrize("case", LINREC_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_linrec_matches_ref(case, dtype):
    B, S, W = case
    ks = jax.random.split(KEY, 3)
    a = jax.random.uniform(ks[0], (B, S, W), minval=0.8,
                           maxval=0.999).astype(dtype)
    b = (0.1 * jax.random.normal(ks[1], (B, S, W))).astype(dtype)
    h0 = (0.1 * jax.random.normal(ks[2], (B, W))).astype(dtype)
    hr, hlr = ref.linear_recurrence(a, b, h0)
    hp, hlp = pl_linrec(a, b, h0, block_s=32, interpret=True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(hp, np.float32),
                               np.asarray(hr, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(hlp, np.float32),
                               np.asarray(hlr, np.float32), atol=tol)


@pytest.mark.parametrize("case", LINREC_CASES)
def test_assoc_linrec_matches_ref(case):
    B, S, W = case
    ks = jax.random.split(KEY, 2)
    a = jax.random.uniform(ks[0], (B, S, W), minval=0.8, maxval=0.999)
    b = 0.1 * jax.random.normal(ks[1], (B, S, W))
    hr, hlr = ref.linear_recurrence(a, b)
    ha, hla = ops.linear_recurrence(a, b, impl="assoc")
    np.testing.assert_allclose(np.asarray(ha), np.asarray(hr), atol=1e-5)


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_mlstm_chunkwise_matches_sequential(chunk):
    from repro.models.xlstm import mlstm_chunkwise_parallel
    B, S, H, D = 2, 64, 3, 16
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    lf = jax.nn.log_sigmoid(2.0 + jax.random.normal(ks[3], (B, S, H)))
    li = 0.5 * jax.random.normal(ks[4], (B, S, H))
    o_ref, (C1, n1, m1) = ref.mlstm_chunkwise(q, k, v, lf, li)
    o_par, (C2, n2, m2) = mlstm_chunkwise_parallel(q, k, v, lf, li,
                                                   chunk=chunk)
    np.testing.assert_allclose(np.asarray(o_par), np.asarray(o_ref),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(C2), np.asarray(C1), atol=1e-4)
    np.testing.assert_allclose(np.asarray(m2), np.asarray(m1), atol=1e-5)


def test_decode_consistent_with_attention():
    """decode(q) == attention with Sq=1 at the last position."""
    q, k, v = _qkv(2, 64, 64, 4, 2, 32, jnp.float32)
    lengths = jnp.full((2,), 64, jnp.int32)
    od = ref.decode_attention(q[:, -1], k, v, lengths)
    oa = ref.attention(q[:, -1:], k, v, causal=True)[:, 0]
    np.testing.assert_allclose(np.asarray(od), np.asarray(oa), atol=1e-6)
