"""Elastic multi-pod e2e: train on a (pod,data,model) mesh with 8 forced
host devices, checkpoint, lose a pod, resume on the survivor mesh - the
full large-scale fault-tolerance path executed (not just compiled)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest


@pytest.mark.slow
def test_elastic_pod_loss_resume(tmp_path):
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, json
        import jax, jax.numpy as jnp
        from repro import configs, sharding
        from repro.checkpoint import CheckpointManager
        from repro.configs.base import TrainConfig, ShapeConfig
        from repro.core import distributions
        from repro.data.pipeline import SyntheticLM
        from repro.fault import plan_elastic_remesh
        from repro.launch import steps
        from repro.models import transformer as T
        from repro.optim import adamw_init

        cfg = dataclasses.replace(configs.smoke("llama3.2-1b"),
                                  d_model=64, d_ff=128)
        tc = TrainConfig(warmup_steps=2)
        dist = distributions.constrained_for()
        pipe = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32,
                           global_batch=8, seed=0)
        mgr = CheckpointManager(directory={str(tmp_path)!r}, dist=dist,
                                policy="fixed", fixed_interval_steps=3,
                                async_write=False)

        def run(mesh, rules, start, end, params=None, opt=None):
            shape = ShapeConfig("t", "train", 32, 8)
            with mesh, sharding.use(mesh, rules):
                in_sh, out_sh, args, _ = steps.shardings_for_cell(
                    cfg, shape, mesh, rules)
                fn = steps.make_train_step(cfg, tc)
                jitted = jax.jit(fn, in_shardings=in_sh,
                                 out_shardings=out_sh)
                if params is None:
                    params, _ = T.init(cfg, jax.random.PRNGKey(0))
                    opt = adamw_init(params)
                params = jax.device_put(params, in_sh[0])
                opt = jax.device_put(opt, in_sh[1])
                losses = []
                for step in range(start, end):
                    batch = jax.device_put(pipe.batch(step), in_sh[2])
                    params, opt, m = jitted(params, opt, batch)
                    losses.append(float(m["loss"]))
                    if mgr.should_checkpoint(step + 1):
                        mgr.save(step + 1, (params, opt))
                return params, opt, losses

        # phase 1: 2 pods (2,2,2) mesh
        mesh2 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        params, opt, l1 = run(mesh2, "fsdp", 0, 5)
        mgr.save(5, (params, opt))

        # pod 1 preempted -> survivor plan: (2,2) data x model
        plan = plan_elastic_remesh(2, [1], pod_shape=(2, 2))
        assert plan.mesh_shape == (2, 2)
        mesh1 = jax.make_mesh(plan.mesh_shape, plan.mesh_axes)
        restored = mgr.restore((params, opt))
        assert restored is not None
        (params, opt), step0, _ = restored
        params = jax.device_get(params)
        opt = jax.device_get(opt)
        _, _, l2 = run(mesh1, "fsdp", step0, step0 + 5, params, opt)
        print(json.dumps({{"l1": l1, "l2": l2, "resumed": step0}}))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["resumed"] == 5
    # training continues sanely on the survivor mesh
    assert all(np.isfinite(v) for v in res["l2"]) if (np := __import__("numpy")) else True
    assert res["l2"][0] < res["l1"][0] + 1.0
