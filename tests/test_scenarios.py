"""Scenario layer: DiurnalConstrained distribution contract + sweep runner.

The contract tests mirror tests/test_distributions.py but do not need
hypothesis, so they run in the quick tier too — the diurnal family must
satisfy exactly the same cdf/pdf/partial_expectation/icdf invariants as the
static families (that is what lets the DP solver, ReuseTable and lifetime
pools consume it unchanged).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core import distributions as D
from repro.core import engine as E
from repro.core import scenarios as SC
from repro.core.policies import checkpointing as C

DIURNAL = {
    "day": lambda: D.diurnal_for("n1-highcpu-16", launch_clock=20.0),
    "night": lambda: D.diurnal_for("n1-highcpu-16", launch_clock=8.0),
    "day_32": lambda: D.diurnal_for("n1-highcpu-32", launch_clock=20.0),
    "night_32": lambda: D.diurnal_for("n1-highcpu-32", launch_clock=8.0),
}


# ---------------------------------------------------------------------------
# distribution contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(DIURNAL))
def test_cdf_monotone_and_bounded(name):
    d = DIURNAL[name]()
    f = np.asarray(d.cdf(jnp.linspace(0.0, 24.0, 512)))
    assert np.all(f >= -1e-6) and np.all(f <= 1 + 1e-6)
    assert np.all(np.diff(f) >= -1e-6), "CDF must be nondecreasing"


@pytest.mark.parametrize("name", sorted(DIURNAL))
def test_pdf_is_cdf_derivative(name):
    d = DIURNAL[name]()
    t = jnp.linspace(0.1, 23.9, 64)
    eps = 1e-3
    numeric = (d.cdf(t + eps) - d.cdf(t - eps)) / (2 * eps)
    np.testing.assert_allclose(np.asarray(d.pdf(t)), np.asarray(numeric),
                               rtol=2e-2, atol=2e-3)


@pytest.mark.parametrize("name", sorted(DIURNAL))
def test_partial_expectation_matches_quadrature(name):
    d = DIURNAL[name]()
    closed = float(d.partial_expectation(2.0, 17.0))
    numeric = float(D._gauss_legendre(lambda x: x * d.pdf(x), 2.0, 17.0))
    np.testing.assert_allclose(closed, numeric, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("name", sorted(DIURNAL))
def test_icdf_roundtrip(name):
    d = DIURNAL[name]()
    u = jnp.linspace(0.02, float(d.cdf(d.L)) - 0.02, 25)
    np.testing.assert_allclose(np.asarray(d.cdf(d.icdf(u))), np.asarray(u),
                               atol=1e-5)


def test_icdf_newton_accuracy_bounds():
    """The bracketed-Newton inversion must hit machine precision for every
    proper (production-envelope) fit under x64, and degrade gracefully —
    not silently stall — on out-of-envelope saturating fits whose clipped
    CDF plateaus at 1 before L (the documented ~1e-4 worst case)."""
    with enable_x64():
        for vm_type in D.VM_TYPE_PARAMS:
            d = D.constrained_for(vm_type)
            u = jnp.linspace(1e-9, float(d.cdf(d.L)) - 1e-9, 20001)
            err = np.abs(np.asarray(d.cdf(d.icdf(u))) - np.asarray(u))
            assert err.max() < 1e-12, (vm_type, err.max())
        sat = D.Constrained(tau1=0.2, tau2=0.05, b=23.9, A=0.49)
        assert float(sat.cdf_raw(23.95)) > 1.0, "fit must saturate"
        u = jnp.linspace(1e-9, float(sat.cdf(sat.L)) - 1e-9, 20001)
        err = np.abs(np.asarray(sat.cdf(sat.icdf(u))) - np.asarray(u))
        assert err.max() < 5e-4, err.max()


def test_sampling_matches_cdf():
    d = DIURNAL["night"]()
    s = d.sample(jax.random.PRNGKey(0), (40000,))
    assert float(s.min()) >= 0 and float(s.max()) <= 24.0
    for t in (1.0, 3.0, 12.0, 23.0):
        np.testing.assert_allclose(float((s <= t).mean()), float(d.cdf(t)),
                                   atol=0.02)


def test_diurnal_phase_ordering():
    """Obs. 5: day launches preempt more than night launches; the shoulder
    (zero-modulation) launch recovers the static per-type fit exactly."""
    day, night = DIURNAL["day"](), DIURNAL["night"]()
    static = D.constrained_for("n1-highcpu-16")
    assert float(day.cdf(3.0)) > float(static.cdf(3.0)) > float(night.cdf(3.0))
    shoulder = D.diurnal_for("n1-highcpu-16", launch_clock=14.0)
    t = jnp.linspace(0.0, 24.0, 97)
    np.testing.assert_allclose(np.asarray(shoulder.cdf(t)),
                               np.asarray(static.cdf(t)), atol=1e-6)


def test_diurnal_never_inverts_below_static():
    """The properness cap on the day-phase A boost must saturate, never
    invert: for every VM type, day A_eff >= static A >= night A_eff (for
    large-A types the boost is fully absorbed by the cap and the day-phase
    severity comes from tau1 alone)."""
    for vm_type in D.VM_TYPE_PARAMS:
        static_A = D.VM_TYPE_PARAMS[vm_type]["A"]
        day = D.diurnal_for(vm_type, launch_clock=20.0).effective()
        night = D.diurnal_for(vm_type, launch_clock=8.0).effective()
        assert float(day.A) >= static_A - 1e-9, vm_type
        assert float(night.A) < static_A, vm_type
        assert float(day.tau1) < float(night.tau1), vm_type
        # the effective day-phase model still stays proper on [0, L)
        raw = float(day.cdf_raw(23.9))
        assert raw <= 1.0 + 1e-6, (vm_type, raw)


def test_diurnal_for_overrides_base_params():
    """Scenario.dist_kwargs must be able to override the type's base Eq. 1
    parameters, not just the diurnal knobs."""
    d = D.diurnal_for("n1-highcpu-16", launch_clock=8.0, A=0.3, amp_A=0.0)
    assert float(d.A) == pytest.approx(0.3)
    sc = SC.Scenario(name="override-test", vm_type="n1-highcpu-16",
                     phase="night", dist_kwargs={"A": 0.3, "tau2": 0.9})
    dist = sc.dist()
    assert float(dist.A) == pytest.approx(0.3)
    assert float(dist.tau2) == pytest.approx(0.9)


def test_diurnal_vmap_over_launch_clock():
    """The pytree contract: one vmapped call evaluates the whole profile."""
    clocks = jnp.linspace(0.0, 24.0, 13)
    f3 = jax.vmap(lambda c: D.DiurnalConstrained(launch_clock=c).cdf(3.0))(clocks)
    f3 = np.asarray(f3)
    assert f3.argmax() != f3.argmin()
    np.testing.assert_allclose(f3[0], f3[-1], rtol=1e-6)  # 24 h periodic


# ---------------------------------------------------------------------------
# registry + sweep runner
# ---------------------------------------------------------------------------

def test_registry_roundtrip_and_duplicate_guard():
    grid = SC.default_grid(vm_types=("n1-highcpu-16",), phases=("day",))
    assert SC.get(grid[0].name) is grid[0]
    assert grid[0].name in SC.names()
    with pytest.raises(ValueError, match="already registered"):
        SC.register(SC.Scenario(name=grid[0].name))
    # repeated default_grid calls reuse the registered scenarios
    assert SC.default_grid(vm_types=("n1-highcpu-16",),
                           phases=("day",))[0] is grid[0]


def test_register_overwrite():
    """Re-registering a taken name must be an explicit decision: it raises
    by default (a silent clobber would invalidate resolved grids) and
    replaces the scenario only with overwrite=True."""
    name = "overwrite-regression"
    first = SC.register(SC.Scenario(name=name, phase="day"))
    with pytest.raises(ValueError, match="overwrite=True"):
        SC.register(SC.Scenario(name=name, phase="night"))
    assert SC.get(name) is first, "failed registration must not clobber"
    second = SC.register(SC.Scenario(name=name, phase="night"),
                         overwrite=True)
    assert SC.get(name) is second
    assert SC.get(name).phase == "night"
    # the deprecated pre-PR-3 spelling keeps working
    third = SC.register(SC.Scenario(name=name, phase="day"), replace=True)
    assert SC.get(name) is third


def test_default_grid_zone_dimension():
    """The grown default grid is the (zone x phase x vm_type) product, and
    zone scaling orders the initial-phase severity: a tighter market
    (us-central1-a) preempts young VMs more than the identity zone."""
    grid = SC.default_grid()
    assert len(grid) == 8
    assert {sc.zone for sc in grid} == {"us-east1-b", "us-central1-a"}
    coords = {(sc.zone, sc.phase, sc.vm_type) for sc in grid}
    assert len(coords) == 8
    base = SC.get("us-east1-b/day/n1-highcpu-16").dist()
    tight = SC.get("us-central1-a/day/n1-highcpu-16").dist()
    assert float(tight.cdf(1.0)) > float(base.cdf(1.0))
    # the identity zone reproduces the pre-zone scenario definition
    legacy = D.diurnal_for("n1-highcpu-16", SC.PHASE_CLOCKS["day"])
    t = jnp.linspace(0.0, 24.0, 49)
    np.testing.assert_allclose(np.asarray(base.cdf(t)),
                               np.asarray(legacy.cdf(t)), rtol=1e-6)


def test_sweep_checkpointing_grid_shape_and_determinism():
    grid = SC.default_grid(vm_types=("n1-highcpu-16", "n1-highcpu-32"),
                           phases=("day", "night"))
    kw = dict(policies=("dp", "none"), seeds=(0, 1), job_steps=60,
              n_trials=50)
    rows = SC.sweep_checkpointing(grid, **kw)
    assert len(rows) == len(grid) * 2 * 2  # scenario x policy x seed
    coords = {(r["scenario"], r["policy"], r["seed"]) for r in rows}
    assert len(coords) == len(rows), "grid coordinates must be unique"
    assert all(r["unfinished_frac"] == 0.0 for r in rows)
    # per-seed determinism: a re-run reproduces every cell exactly
    again = SC.sweep_checkpointing(grid, **kw)
    for a, b in zip(rows, again):
        assert a == b


def test_sweep_checkpointing_modes_match_serial():
    """The one-kernel fold (mode="batched") and the PR-3 grouped path must
    both reproduce the serial per-scenario sweep: identical row
    order/coords, bit-identical DP expectations and fresh-VM failure
    probabilities, and makespan statistics within the pool's float32
    inverse-CDF rounding (far below Monte-Carlo noise)."""
    grid = SC.default_grid(vm_types=("n1-highcpu-16", "n1-highcpu-32"),
                           phases=("day", "night"), zones=("us-east1-b",))
    kw = dict(policies=("dp", "young_daly", "none"), seeds=(0, 1),
              job_steps=60, n_trials=80)
    serial = SC.sweep_checkpointing(grid, mode="serial", **kw)
    for mode in ("batched", "grouped"):
        rows = SC.sweep_checkpointing(grid, mode=mode, **kw)
        assert len(rows) == len(serial) == len(grid) * 3 * 2
        for b, s in zip(rows, serial):
            assert (b["scenario"], b["policy"], b["seed"]) == \
                (s["scenario"], s["policy"], s["seed"])
            assert b["expected_makespan_dp"] == s["expected_makespan_dp"]
            assert b["p_fail_fresh"] == s["p_fail_fresh"]
            assert b["unfinished_frac"] == s["unfinished_frac"] == 0.0
            np.testing.assert_allclose(b["makespan_mean"],
                                       s["makespan_mean"], rtol=5e-3)
    with pytest.raises(ValueError, match="mode"):
        SC.sweep_checkpointing(grid, mode="bogus", **kw)


def test_sweep_service_grid_shape():
    grid = SC.default_grid(vm_types=("n1-highcpu-32",), phases=("day", "night"),
                           zones=("us-east1-b",))
    rows = SC.sweep_service(grid, policies=("model", "memoryless"),
                            cluster_sizes=(8,), seeds=(0,), n_jobs=15)
    assert len(rows) == len(grid) * 2 * 1 * 1 == 4
    for r in rows:
        assert r["cost"] > 0 and r["cost_reduction"] > 1.0
        assert 0.0 <= r["job_failure_rate"] <= r["n_job_failures"]


def test_diurnal_cell_engine_matches_reference():
    """One diurnal cell, shared pool, float64 kernel: the vectorized engine
    must match the Python reference bit-for-bit — the scenario layer must
    not disturb the PR-1 exactness contract."""
    dist = SC.default_grid(vm_types=("n1-highcpu-16",),
                           phases=("night",))[0].dist()
    job = 120
    tables = C.solve(dist, job, grid_dt=1.0 / 60.0, delta_steps=1, n_sweeps=3)
    lf = C.model_lifetimes_fn(dist)
    first, pool = E.draw_lifetime_pool(lf, 200, seed=11)
    ref = C.simulate_makespan(C.dp_policy_fn(tables), lf, job,
                              grid_dt=1.0 / 60.0, pool=pool, first=first)
    with enable_x64():
        vec = E.simulate_makespan_batch(E.dp_policy_table(tables), job,
                                        first=first, pool=pool,
                                        grid_dt=1.0 / 60.0)
    assert np.array_equal(ref, vec)
