"""Batched scenario axis: distribution stacking, the batched DP solver, the
device lifetime pools, the scenario-batched executor and ReuseTable.batch.

The core contracts under test:

  * ``checkpointing.solve_batch`` matches the per-scenario reference
    ``checkpointing.solve`` table-for-table (bit-exact V and K) on the full
    default scenario grid — the batched kernel restructures the loop but
    keeps the reference expression tree;
  * ``engine.draw_lifetime_pool_batch`` slices reproduce the numpy-reference
    ``engine.draw_lifetime_pool`` under a shared seed (bit-exact under x64,
    float32-close otherwise);
  * a scenario-batched ``engine.simulate_makespan_batch`` keeps the float64
    bit-exactness contract per scenario slice on a shared pool.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core import distributions as D
from repro.core import engine as E
from repro.core import scenarios as SC
from repro.core.policies import checkpointing as C

GRID = 1.0 / 60.0


@pytest.fixture(scope="module")
def grid_dists():
    return [sc.dist() for sc in SC.default_grid()]


# ---------------------------------------------------------------------------
# distribution stacking
# ---------------------------------------------------------------------------

def test_stack_leading_axis_and_vmap(grid_dists):
    stacked = D.stack(grid_dists)
    S = len(grid_dists)
    assert type(stacked) is type(grid_dists[0])
    for leaf in jax.tree_util.tree_leaves(stacked):
        assert leaf.shape[:1] == (S,)
    t = jnp.linspace(0.0, 24.0, 33)
    batched = jax.vmap(lambda d: d.cdf(t))(stacked)
    assert batched.shape == (S, 33)
    for s, d in enumerate(grid_dists):
        np.testing.assert_allclose(np.asarray(batched[s]),
                                   np.asarray(d.cdf(t)), rtol=1e-6,
                                   atol=1e-7)


def test_stack_unstack_roundtrip(grid_dists):
    back = D.unstack(D.stack(grid_dists))
    assert len(back) == len(grid_dists)
    for orig, d in zip(grid_dists, back):
        assert float(d.tau1) == pytest.approx(float(orig.tau1))
        assert float(d.launch_clock) == pytest.approx(float(orig.launch_clock))


def test_stack_rejects_mixed_families_and_empty():
    with pytest.raises(TypeError):
        D.stack([D.Constrained(), D.Exponential()])
    with pytest.raises(ValueError):
        D.stack([])
    with pytest.raises(ValueError):
        D.unstack(D.Constrained())


# ---------------------------------------------------------------------------
# batched DP solver
# ---------------------------------------------------------------------------

def test_solve_batch_matches_solve_on_default_grid(grid_dists):
    """Table-for-table equivalence on the FULL default grid: every scenario
    slice of solve_batch must be bit-identical to the per-scenario solve."""
    job = 72
    batch = C.solve_batch(grid_dists, job, grid_dt=GRID)
    assert batch.V.shape == (len(grid_dists), job + 1, batch.horizon_idx + 1)
    assert len(batch) == len(grid_dists)
    for s, d in enumerate(grid_dists):
        ref = C.solve(d, job, grid_dt=GRID)
        assert np.array_equal(ref.V, batch.V[s]), f"V differs at scenario {s}"
        assert np.array_equal(ref.K, batch.K[s]), f"K differs at scenario {s}"
        view = batch.tables(s)
        assert np.array_equal(view.K, ref.K)
        assert view.expected_makespan(job) == ref.expected_makespan(job)
        assert batch.expected_makespan(s, job) == ref.expected_makespan(job)


def test_solve_batch_nondefault_workload():
    """delta_steps > 1, restart overhead and a tiny job exercise the
    final-column patch and the segment split edge cases."""
    ds = [D.constrained_for("n1-highcpu-16"), D.constrained_for("n1-highcpu-32")]
    for job, delta, ro in [(2, 1, 0.0), (25, 3, 0.1)]:
        batch = C.solve_batch(ds, job, grid_dt=1.0 / 20.0, delta_steps=delta,
                              restart_overhead=ro)
        for s, d in enumerate(ds):
            ref = C.solve(d, job, grid_dt=1.0 / 20.0, delta_steps=delta,
                          restart_overhead=ro)
            assert np.array_equal(ref.V, batch.V[s]), (job, delta, s)
            assert np.array_equal(ref.K, batch.K[s]), (job, delta, s)


def test_solve_batch_input_validation():
    with pytest.raises(ValueError):
        C.solve_batch([], 10)
    with pytest.raises(ValueError, match="shared deadline"):
        C.solve_batch([D.Constrained(), D.Constrained(L=12.0)], 10)


# ---------------------------------------------------------------------------
# batched lifetime pools
# ---------------------------------------------------------------------------

def test_pool_batch_close_to_reference(grid_dists):
    """Default float32 mode: batched pool slices match the float64 numpy
    reference to float32 precision for every scenario and seed."""
    n, mr = 200, 16
    for seed in (0, 3):
        first_b, pool_b = E.draw_lifetime_pool_batch(
            grid_dists, n, max_restarts=mr, seed=seed)
        assert first_b.shape == (len(grid_dists), n)
        assert pool_b.shape == (len(grid_dists), n, mr + 2)
        for s, d in enumerate(grid_dists):
            first, pool = E.draw_lifetime_pool(
                C.model_lifetimes_fn(d), n, max_restarts=mr, seed=seed)
            np.testing.assert_allclose(pool_b[s], pool, rtol=2e-5, atol=2e-4)
            np.testing.assert_allclose(first_b[s], first, rtol=2e-5,
                                       atol=2e-4)


@pytest.mark.slow
def test_pool_batch_bitexact_x64(grid_dists):
    """Under x64 a batched pool slice reproduces the numpy-reference pool
    bit-for-bit (shared seed, shared draw order), including the conditioned
    first draw of an aged VM."""
    n, mr = 200, 16
    with enable_x64():
        for start_age in (0.0, 6.0):
            first_b, pool_b = E.draw_lifetime_pool_batch(
                grid_dists, n, max_restarts=mr, seed=11, start_age=start_age)
            for s, d in enumerate(grid_dists):
                first, pool = E.draw_lifetime_pool(
                    C.model_lifetimes_fn(d), n, max_restarts=mr, seed=11,
                    start_age=start_age)
                assert np.array_equal(pool, pool_b[s]), (start_age, s)
                assert np.array_equal(first, first_b[s]), (start_age, s)


# ---------------------------------------------------------------------------
# scenario-batched executor
# ---------------------------------------------------------------------------

def test_batched_executor_bitexact_per_slice(grid_dists):
    """Shared pool, float64: every scenario slice of the batched executor is
    bit-identical to the unbatched kernel, for per-scenario and shared
    policy tables alike."""
    ds = grid_dists[:3]
    job = 60
    batch = C.solve_batch(ds, job, grid_dt=GRID)
    tables3 = np.asarray(batch.K, np.int32)             # (S, j+1, t+1)
    shared = E.no_checkpoint_policy_table(job)          # 2-D, broadcast
    first_b, pool_b = E.draw_lifetime_pool_batch(ds, 150, max_restarts=16,
                                                 seed=5)
    with enable_x64():
        for table_b, table_of in [(tables3, lambda s: tables3[s]),
                                  (shared, lambda s: shared)]:
            mk_b = E.simulate_makespan_batch(
                table_b, job, first=first_b, pool=pool_b, grid_dt=GRID,
                max_restarts=16, unfinished="partial")
            assert mk_b.shape == (len(ds), 150)
            for s in range(len(ds)):
                mk = E.simulate_makespan_batch(
                    table_of(s), job, first=first_b[s], pool=pool_b[s],
                    grid_dt=GRID, max_restarts=16, unfinished="partial")
                assert np.array_equal(mk, mk_b[s]), s


def test_batched_executor_finished_mask_and_errors():
    job = 60
    table = E.no_checkpoint_policy_table(job)
    # scenario 0 finishes, scenario 1 never does (VMs die at 0.5 h)
    first = np.stack([np.full(4, 24.0), np.full(4, 0.5)])
    pool = np.stack([np.full((4, 18), 24.0), np.full((4, 18), 0.5)])
    mk, fin = E.simulate_makespan_batch(table, job, first=first, pool=pool,
                                        grid_dt=GRID, max_restarts=16,
                                        return_finished=True)
    assert fin.shape == (2, 4)
    assert fin[0].all() and not fin[1].any()
    assert np.isnan(mk[1]).all()
    np.testing.assert_allclose(mk[0], 1.0, rtol=1e-6)
    with pytest.raises(ValueError, match="scenario-batched pool"):
        E.simulate_makespan_batch(np.stack([table, table]), job,
                                  first=first[0], pool=pool[0], grid_dt=GRID)
    with pytest.raises(ValueError, match="needs first of shape"):
        E.simulate_makespan_batch(table, job, first=first[0], pool=pool,
                                  grid_dt=GRID)


# ---------------------------------------------------------------------------
# batched ReuseTable
# ---------------------------------------------------------------------------

def test_reuse_table_batch_matches_per_scenario(grid_dists):
    T_vals = np.array([0.5, 1.0, 2.0, 4.0])
    batched = E.ReuseTable.batch(grid_dists, T_vals, n_age=97)
    assert len(batched) == len(grid_dists)
    for d, bt in zip(grid_dists, batched):
        ref = E.ReuseTable(d, T_vals, n_age=97)
        assert bt.L == ref.L and bt.n_age == ref.n_age
        assert np.array_equal(bt.T_values, ref.T_values)
        # boolean decisions may flip only where Eq. 9 and Eq. 10 tie to
        # within float rounding; on this grid they must agree everywhere
        assert np.array_equal(bt.table, ref.table)


def test_reuse_table_batch_requires_shared_L():
    with pytest.raises(ValueError, match="shared L"):
        E.ReuseTable.batch([D.Constrained(), D.Constrained(L=12.0)],
                           np.array([1.0]))


# ---------------------------------------------------------------------------
# bench-artifact stamping (benchmarks.common satellite)
# ---------------------------------------------------------------------------

def test_write_bench_json_stamps_commit_and_schema(tmp_path, monkeypatch):
    import json

    from benchmarks import common

    monkeypatch.setattr(common, "REPO_ROOT", str(tmp_path))
    path = common.write_bench_json("BENCH_stamp_test.json",
                                   {"schema": 9, "payload": [1, 2]},
                                   emit_as="test/json")
    data = json.loads(open(path).read())
    assert data["schema"] == 9 and data["payload"] == [1, 2]
    assert data["bench_schema_version"] == common.BENCH_SCHEMA_VERSION
    commit = data["git_commit"]
    assert isinstance(commit, str) and commit
    # stamped commit matches the repo's HEAD when running inside the repo
    assert commit == common.git_commit()
