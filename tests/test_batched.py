"""Batched scenario axis + the PR-4 one-kernel fold: distribution stacking,
the batched DP solver, the device lifetime pools, the cell-batched executor
(including the deduplicated table/pool indexing) and the folded ReuseTables.

The core contracts under test:

  * ``checkpointing.solve_batch`` matches the per-scenario reference
    ``checkpointing.solve`` table-for-table (bit-exact V and K) on the full
    default scenario grid — the batched kernel restructures the loop but
    keeps the reference expression tree;
  * ``engine.draw_lifetime_pool_batch`` slices reproduce the numpy-reference
    ``engine.draw_lifetime_pool`` under a shared seed — and under PER-ENTRY
    seeds, entry ``i`` reproduces the reference draw for ``seed_i`` (bit-exact
    under x64, float32-close otherwise);
  * a cell-batched ``engine.simulate_makespan_batch`` keeps the float64
    bit-exactness contract per lane on a shared pool, whether lanes are
    materialized ``(B, ...)`` slices or ``table_index``/``pool_index``
    gathers into deduplicated tensors;
  * ``scenarios.sweep_checkpointing(mode="batched")`` — the whole
    (scenario x policy x seed) grid in ONE executor dispatch — unflattens
    to rows that are exactly the serial reference's rows (property test,
    x64, NaN-flagged unfinished trials included).
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core import distributions as D
from repro.core import engine as E
from repro.core import scenarios as SC
from repro.core.policies import checkpointing as C

GRID = 1.0 / 60.0


@pytest.fixture(scope="module")
def grid_dists():
    return [sc.dist() for sc in SC.default_grid()]


# ---------------------------------------------------------------------------
# distribution stacking
# ---------------------------------------------------------------------------

def test_stack_leading_axis_and_vmap(grid_dists):
    stacked = D.stack(grid_dists)
    S = len(grid_dists)
    assert type(stacked) is type(grid_dists[0])
    for leaf in jax.tree_util.tree_leaves(stacked):
        assert leaf.shape[:1] == (S,)
    t = jnp.linspace(0.0, 24.0, 33)
    batched = jax.vmap(lambda d: d.cdf(t))(stacked)
    assert batched.shape == (S, 33)
    for s, d in enumerate(grid_dists):
        np.testing.assert_allclose(np.asarray(batched[s]),
                                   np.asarray(d.cdf(t)), rtol=1e-6,
                                   atol=1e-7)


def test_stack_unstack_roundtrip(grid_dists):
    back = D.unstack(D.stack(grid_dists))
    assert len(back) == len(grid_dists)
    for orig, d in zip(grid_dists, back):
        assert float(d.tau1) == pytest.approx(float(orig.tau1))
        assert float(d.launch_clock) == pytest.approx(float(orig.launch_clock))


def test_stack_rejects_mixed_families_and_empty():
    with pytest.raises(TypeError):
        D.stack([D.Constrained(), D.Exponential()])
    with pytest.raises(ValueError):
        D.stack([])
    with pytest.raises(ValueError):
        D.unstack(D.Constrained())


# ---------------------------------------------------------------------------
# batched DP solver
# ---------------------------------------------------------------------------

def test_solve_batch_matches_solve_on_default_grid(grid_dists):
    """Table-for-table equivalence on the FULL default grid: every scenario
    slice of solve_batch must be bit-identical to the per-scenario solve."""
    job = 72
    batch = C.solve_batch(grid_dists, job, grid_dt=GRID)
    assert batch.V.shape == (len(grid_dists), job + 1, batch.horizon_idx + 1)
    assert len(batch) == len(grid_dists)
    for s, d in enumerate(grid_dists):
        ref = C.solve(d, job, grid_dt=GRID)
        assert np.array_equal(ref.V, batch.V[s]), f"V differs at scenario {s}"
        assert np.array_equal(ref.K, batch.K[s]), f"K differs at scenario {s}"
        view = batch.tables(s)
        assert np.array_equal(view.K, ref.K)
        assert view.expected_makespan(job) == ref.expected_makespan(job)
        assert batch.expected_makespan(s, job) == ref.expected_makespan(job)


def test_solve_batch_nondefault_workload():
    """delta_steps > 1, restart overhead and a tiny job exercise the
    final-column patch and the segment split edge cases."""
    ds = [D.constrained_for("n1-highcpu-16"), D.constrained_for("n1-highcpu-32")]
    for job, delta, ro in [(2, 1, 0.0), (25, 3, 0.1)]:
        batch = C.solve_batch(ds, job, grid_dt=1.0 / 20.0, delta_steps=delta,
                              restart_overhead=ro)
        for s, d in enumerate(ds):
            ref = C.solve(d, job, grid_dt=1.0 / 20.0, delta_steps=delta,
                          restart_overhead=ro)
            assert np.array_equal(ref.V, batch.V[s]), (job, delta, s)
            assert np.array_equal(ref.K, batch.K[s]), (job, delta, s)


def test_solve_batch_input_validation():
    with pytest.raises(ValueError):
        C.solve_batch([], 10)
    with pytest.raises(ValueError, match="shared deadline"):
        C.solve_batch([D.Constrained(), D.Constrained(L=12.0)], 10)


# ---------------------------------------------------------------------------
# batched lifetime pools
# ---------------------------------------------------------------------------

def test_pool_batch_close_to_reference(grid_dists):
    """Default float32 mode: batched pool slices match the float64 numpy
    reference to float32 precision for every scenario and seed."""
    n, mr = 200, 16
    for seed in (0, 3):
        first_b, pool_b = E.draw_lifetime_pool_batch(
            grid_dists, n, max_restarts=mr, seed=seed)
        assert first_b.shape == (len(grid_dists), n)
        assert pool_b.shape == (len(grid_dists), n, mr + 2)
        for s, d in enumerate(grid_dists):
            first, pool = E.draw_lifetime_pool(
                C.model_lifetimes_fn(d), n, max_restarts=mr, seed=seed)
            np.testing.assert_allclose(pool_b[s], pool, rtol=2e-5, atol=2e-4)
            np.testing.assert_allclose(first_b[s], first, rtol=2e-5,
                                       atol=2e-4)


@pytest.mark.slow
def test_pool_batch_bitexact_x64(grid_dists):
    """Under x64 a batched pool slice reproduces the numpy-reference pool
    bit-for-bit (shared seed, shared draw order), including the conditioned
    first draw of an aged VM."""
    n, mr = 200, 16
    with enable_x64():
        for start_age in (0.0, 6.0):
            first_b, pool_b = E.draw_lifetime_pool_batch(
                grid_dists, n, max_restarts=mr, seed=11, start_age=start_age)
            for s, d in enumerate(grid_dists):
                first, pool = E.draw_lifetime_pool(
                    C.model_lifetimes_fn(d), n, max_restarts=mr, seed=11,
                    start_age=start_age)
                assert np.array_equal(pool, pool_b[s]), (start_age, s)
                assert np.array_equal(first, first_b[s]), (start_age, s)


def test_pool_batch_per_entry_seeds(grid_dists):
    """The (B,)-keyed seed fold: entry i of a per-entry-seeded call must
    reproduce the same distribution's single-seed batched draw for seed_i —
    the contract the one-kernel sweep's (scenario x seed) flattening rests
    on.  Also: a constant seed list equals the scalar-seed call exactly."""
    ds = grid_dists[:2]
    n, mr = 60, 6
    cells = [(d, s) for d in ds for s in (0, 7)]
    first_b, pool_b = E.draw_lifetime_pool_batch(
        [d for d, _ in cells], n, max_restarts=mr,
        seed=[s for _, s in cells])
    assert pool_b.shape == (len(cells), n, mr + 2)
    for i, (d, s) in enumerate(cells):
        ref_first, ref_pool = E.draw_lifetime_pool_batch(
            [d], n, max_restarts=mr, seed=s)
        np.testing.assert_array_equal(pool_b[i], ref_pool[0])
        np.testing.assert_array_equal(first_b[i], ref_first[0])
    f_scalar, p_scalar = E.draw_lifetime_pool_batch(ds, n, max_restarts=mr,
                                                    seed=3)
    f_list, p_list = E.draw_lifetime_pool_batch(ds, n, max_restarts=mr,
                                                seed=[3, 3])
    np.testing.assert_array_equal(p_scalar, p_list)
    np.testing.assert_array_equal(f_scalar, f_list)
    with pytest.raises(ValueError, match="one seed per entry"):
        E.draw_lifetime_pool_batch(ds, n, max_restarts=mr, seed=[0])


# ---------------------------------------------------------------------------
# scenario-batched executor
# ---------------------------------------------------------------------------

def test_batched_executor_bitexact_per_slice(grid_dists):
    """Shared pool, float64: every scenario slice of the batched executor is
    bit-identical to the unbatched kernel, for per-scenario and shared
    policy tables alike."""
    ds = grid_dists[:3]
    job = 60
    batch = C.solve_batch(ds, job, grid_dt=GRID)
    tables3 = np.asarray(batch.K, np.int32)             # (S, j+1, t+1)
    shared = E.no_checkpoint_policy_table(job)          # 2-D, broadcast
    first_b, pool_b = E.draw_lifetime_pool_batch(ds, 150, max_restarts=16,
                                                 seed=5)
    with enable_x64():
        for table_b, table_of in [(tables3, lambda s: tables3[s]),
                                  (shared, lambda s: shared)]:
            mk_b = E.simulate_makespan_batch(
                table_b, job, first=first_b, pool=pool_b, grid_dt=GRID,
                max_restarts=16, unfinished="partial")
            assert mk_b.shape == (len(ds), 150)
            for s in range(len(ds)):
                mk = E.simulate_makespan_batch(
                    table_of(s), job, first=first_b[s], pool=pool_b[s],
                    grid_dt=GRID, max_restarts=16, unfinished="partial")
                assert np.array_equal(mk, mk_b[s]), s


def test_stack_policy_tables_widening_and_errors():
    """Stacking tables of differing provenance: age-independent columns are
    replicated (identical lookups), age-dependent tables pass through, and
    anything that would need resampling is rejected."""
    job = 12
    dp_like = np.tile(np.arange(job + 1, dtype=np.int32)[:, None], (1, 5))
    yd = E.young_daly_policy_table(3, job)                 # (job+1, 1)
    none = E.no_checkpoint_policy_table(job)               # (job+1, 1)
    out = E.stack_policy_tables([dp_like, yd, none])
    assert out.shape == (3, job + 1, 5) and out.dtype == np.int32
    np.testing.assert_array_equal(out[0], dp_like)
    for t in range(5):                                     # replication only
        np.testing.assert_array_equal(out[1][:, t], yd[:, 0])
        np.testing.assert_array_equal(out[2][:, t], none[:, 0])
    # explicit t_axis widens 1-wide tables too
    assert E.stack_policy_tables([yd], t_axis=7).shape == (1, job + 1, 7)
    with pytest.raises(ValueError, match="at least one"):
        E.stack_policy_tables([])
    with pytest.raises(ValueError, match="share the remaining-work axis"):
        E.stack_policy_tables([yd, E.no_checkpoint_policy_table(job + 1)])
    with pytest.raises(ValueError, match="resampling"):
        E.stack_policy_tables([dp_like[:, :3], dp_like])
    with pytest.raises(ValueError, match="2-D"):
        E.stack_policy_tables([np.zeros((2, 3, 4), np.int32)])


def test_indexed_executor_matches_materialized(grid_dists):
    """table_index/pool_index gathers into deduplicated tensors must run
    each lane bit-identically to the materialized (B, ...) call (shared
    x64 pool => exact equality is required, not approximate)."""
    ds = grid_dists[:2]
    job = 60
    batch = C.solve_batch(ds, job, grid_dt=GRID)
    uniq = E.stack_policy_tables(
        [np.asarray(batch.K[0]), np.asarray(batch.K[1]),
         E.no_checkpoint_policy_table(job)])
    first_q, pool_q = E.draw_lifetime_pool_batch(
        [d for d in ds for _ in (0, 1)], 80, max_restarts=8,
        seed=[s for _ in ds for s in (0, 1)])
    # B = 8 lanes: (scenario s, seed r, policy p in {dp, none})
    cells = [(s, r, p) for s in range(2) for r in range(2) for p in range(2)]
    tix = np.array([s if p == 0 else 2 for s, r, p in cells], np.int32)
    pix = np.array([s * 2 + r for s, r, p in cells], np.int32)
    with enable_x64():
        mk_idx = E.simulate_makespan_batch(
            uniq, job, first=first_q[pix], pool=pool_q, grid_dt=GRID,
            max_restarts=8, unfinished="partial",
            table_index=tix, pool_index=pix)
        mk_mat = E.simulate_makespan_batch(
            uniq[tix], job, first=first_q[pix], pool=pool_q[pix],
            grid_dt=GRID, max_restarts=8, unfinished="partial")
    assert mk_idx.shape == (8, 80)
    np.testing.assert_array_equal(mk_idx, mk_mat)


def test_indexed_executor_validation(grid_dists):
    job = 30
    table = E.no_checkpoint_policy_table(job)
    uniq = E.stack_policy_tables([table])
    first = np.full((2, 4), 24.0)
    pool = np.full((1, 4, 6), 24.0)
    ix = np.zeros(2, np.int32)
    with pytest.raises(ValueError, match="passed together"):
        E.simulate_makespan_batch(uniq, job, first=first, pool=pool,
                                  max_restarts=4, table_index=ix)
    with pytest.raises(ValueError, match="indexed fold needs"):
        E.simulate_makespan_batch(table, job, first=first, pool=pool,
                                  max_restarts=4, table_index=ix,
                                  pool_index=ix)
    with pytest.raises(ValueError, match="table_index out of range"):
        E.simulate_makespan_batch(uniq, job, first=first, pool=pool,
                                  max_restarts=4,
                                  table_index=np.array([0, 5], np.int32),
                                  pool_index=ix)
    with pytest.raises(ValueError, match="pool_index out of range"):
        E.simulate_makespan_batch(uniq, job, first=first, pool=pool,
                                  max_restarts=4, table_index=ix,
                                  pool_index=np.array([0, 1], np.int32))


def test_batched_executor_finished_mask_and_errors():
    job = 60
    table = E.no_checkpoint_policy_table(job)
    # scenario 0 finishes, scenario 1 never does (VMs die at 0.5 h)
    first = np.stack([np.full(4, 24.0), np.full(4, 0.5)])
    pool = np.stack([np.full((4, 18), 24.0), np.full((4, 18), 0.5)])
    mk, fin = E.simulate_makespan_batch(table, job, first=first, pool=pool,
                                        grid_dt=GRID, max_restarts=16,
                                        return_finished=True)
    assert fin.shape == (2, 4)
    assert fin[0].all() and not fin[1].any()
    assert np.isnan(mk[1]).all()
    np.testing.assert_allclose(mk[0], 1.0, rtol=1e-6)
    with pytest.raises(ValueError, match="scenario-batched pool"):
        E.simulate_makespan_batch(np.stack([table, table]), job,
                                  first=first[0], pool=pool[0], grid_dt=GRID)
    with pytest.raises(ValueError, match="needs first of shape"):
        E.simulate_makespan_batch(table, job, first=first[0], pool=pool,
                                  grid_dt=GRID)


# ---------------------------------------------------------------------------
# market dollars through the batched executor
# ---------------------------------------------------------------------------

def test_executor_dollar_rows_bitexact_x64(grid_dists):
    """simulate_makespan_batch(price=...) bills every lane's makespans
    bit-identically to the serial market.integrate_cost_ref loop on a
    shared x64 pool — NaN-flagged unfinished trials cost NaN in both
    paths, and price_index dedup cannot change any lane's dollars."""
    from repro.core import market as M
    ds = grid_dists[:3]
    job = 60
    batch = C.solve_batch(ds, job, grid_dt=GRID)
    tables3 = np.asarray(batch.K, np.int32)
    # max_restarts=2 leaves some trials unfinished => NaN dollars covered
    first_b, pool_b = E.draw_lifetime_pool_batch(ds, 80, max_restarts=2,
                                                 seed=5)
    grid = M.MarketModel(
        processes=[M.spot_price_process(z) for z in M.MARKET_ZONE_PARAMS],
        horizon=12.0, seed=3).grid()
    with enable_x64():
        mk, fin, dollars = E.simulate_makespan_batch(
            tables3, job, first=first_b, pool=pool_b, grid_dt=GRID,
            max_restarts=2, return_finished=True, price=grid)
        mk_plain = E.simulate_makespan_batch(
            tables3, job, first=first_b, pool=pool_b, grid_dt=GRID,
            max_restarts=2)
        _, d_indexed = E.simulate_makespan_batch(
            tables3, job, first=first_b, pool=pool_b, grid_dt=GRID,
            max_restarts=2, price=grid,
            price_index=np.arange(3, dtype=np.int32))
    assert not fin.all(), "workload failed to produce unfinished trials"
    np.testing.assert_array_equal(mk, mk_plain)   # billing changes nothing
    np.testing.assert_array_equal(dollars, d_indexed)
    assert dollars.shape == mk.shape
    for s in range(len(ds)):
        for j in range(mk.shape[1]):
            ref = M.integrate_cost_ref(grid.prices[s], grid.cum[s],
                                       grid.dt, mk[s, j])
            if np.isnan(ref):
                assert np.isnan(dollars[s, j]), (s, j)
            else:
                assert dollars[s, j] == ref, (s, j)
    with pytest.raises(ValueError, match="price_index needs price"):
        E.simulate_makespan_batch(tables3, job, first=first_b, pool=pool_b,
                                  grid_dt=GRID, max_restarts=2,
                                  price_index=np.arange(3, dtype=np.int32))


# ---------------------------------------------------------------------------
# batched ReuseTable
# ---------------------------------------------------------------------------

def test_reuse_table_batch_matches_per_scenario(grid_dists):
    T_vals = np.array([0.5, 1.0, 2.0, 4.0])
    batched = E.ReuseTable.batch(grid_dists, T_vals, n_age=97)
    assert len(batched) == len(grid_dists)
    for d, bt in zip(grid_dists, batched):
        ref = E.ReuseTable(d, T_vals, n_age=97)
        assert bt.L == ref.L and bt.n_age == ref.n_age
        assert np.array_equal(bt.T_values, ref.T_values)
        # boolean decisions may flip only where Eq. 9 and Eq. 10 tie to
        # within float rounding; on this grid they must agree everywhere
        assert np.array_equal(bt.table, ref.table)


def test_reuse_table_batch_requires_shared_L():
    with pytest.raises(ValueError, match="shared L"):
        E.ReuseTable.batch([D.Constrained(), D.Constrained(L=12.0)],
                           np.array([1.0]))


def test_reuse_tables_container_shares_backing_tensor(grid_dists):
    """ReuseTables is the folded form: one (S, T, age) tensor, per-scenario
    views that share it (no copies) and decide exactly like individually
    constructed tables."""
    ds = grid_dists[:3]
    T_vals = np.array([0.5, 1.5, 3.0])
    folded = E.ReuseTables(ds, T_vals, n_age=65)
    assert len(folded) == 3 and folded.tables.shape == (3, 3, 65)
    for s, (d, view) in enumerate(zip(ds, folded)):
        assert view.table.base is folded.tables
        ref = E.ReuseTable(d, T_vals, n_age=65)
        assert np.array_equal(view.table, ref.table)
        assert view.decide(1.5, 2.0) == ref.decide(1.5, 2.0)
    assert np.array_equal(folded[1].table, folded.view(1).table)
    with pytest.raises(ValueError, match="at least one"):
        E.ReuseTables([], T_vals)


# ---------------------------------------------------------------------------
# one-kernel sweep: unflattening bookkeeping (PR-4 fold)
# ---------------------------------------------------------------------------

def _assert_rows_identical(a_rows, b_rows):
    """Exact row-for-row equality, treating the engine's NaN flag for
    unfinished-trial statistics as equal to itself."""
    assert len(a_rows) == len(b_rows)
    for ra, rb in zip(a_rows, b_rows):
        assert set(ra) == set(rb)
        for k, va in ra.items():
            vb = rb[k]
            if isinstance(va, float) and np.isnan(va):
                assert isinstance(vb, float) and np.isnan(vb), k
            else:
                assert va == vb, (k, va, vb)


_SWEEP_GRID = None


def _sweep_scenarios():
    global _SWEEP_GRID
    if _SWEEP_GRID is None:
        _SWEEP_GRID = SC.default_grid(vm_types=("n1-highcpu-16",),
                                      phases=("day", "night"),
                                      zones=("us-east1-b",))
    return _SWEEP_GRID


def test_one_kernel_unfinished_rows_match_serial():
    """max_restarts=0 forces unfinished trials: the NaN-flagged statistics
    (makespan_* NaN when no trial finished, unfinished_frac > 0) must come
    through the one-kernel unflattening exactly as the serial path reports
    them."""
    kw = dict(policies=("dp", "none"), seeds=(0, 3), job_steps=30,
              n_trials=24, max_restarts=0)
    with enable_x64():
        rows_b = SC.sweep_checkpointing(_sweep_scenarios(), mode="batched",
                                        **kw)
        rows_s = SC.sweep_checkpointing(_sweep_scenarios(), mode="serial",
                                        **kw)
    assert any(r["unfinished_frac"] > 0 for r in rows_s), \
        "workload failed to produce unfinished trials"
    _assert_rows_identical(rows_b, rows_s)


def test_sweep_tables_reuse_and_validation():
    """tables= skips the DP solve for whole-grid re-evaluation: rows equal
    the self-solving sweep exactly; mismatched workloads are rejected."""
    scs = _sweep_scenarios()
    kw = dict(policies=("dp", "none"), seeds=(1,), job_steps=30, n_trials=20)
    batch = C.solve_batch([sc.dist() for sc in scs], 30, grid_dt=1.0 / 60.0)
    for mode in ("batched", "grouped"):
        _assert_rows_identical(
            SC.sweep_checkpointing(scs, mode=mode, tables=batch, **kw),
            SC.sweep_checkpointing(scs, mode=mode, **kw))
    with pytest.raises(ValueError, match="serial reference"):
        SC.sweep_checkpointing(scs, mode="serial", tables=batch, **kw)
    with pytest.raises(ValueError, match="needs 2 x 40"):
        SC.sweep_checkpointing(scs, tables=batch,
                               **dict(kw, job_steps=40))
    with pytest.raises(ValueError, match="different"):
        SC.sweep_checkpointing(scs, tables=batch, delta_steps=2, **kw)


try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # pragma: no cover - hypothesis is a test dependency
    st = None

if st is not None:
    _sweep_cases = st.fixed_dictionaries({
        "policies": st.sampled_from([
            ("dp",), ("none",), ("young_daly",),
            ("dp", "none"), ("none", "young_daly", "dp")]),
        "seeds": st.sampled_from([(0,), (1, 4), (2, 0)]),
        "max_restarts": st.sampled_from([0, 2, 64]),
    })

    @settings(max_examples=5, deadline=None)
    @given(_sweep_cases)
    def test_one_kernel_rows_equal_serial_property(case):
        """Property: for ANY (policy subset, seed list, restart budget) the
        one-kernel sweep's labeled rows — produced by one executor dispatch
        plus unflattening — are exactly the serial reference's rows under
        x64, NaN flags included."""
        kw = dict(job_steps=30, n_trials=24, **case)
        with enable_x64():
            rows_b = SC.sweep_checkpointing(_sweep_scenarios(),
                                            mode="batched", **kw)
            rows_s = SC.sweep_checkpointing(_sweep_scenarios(),
                                            mode="serial", **kw)
        coords = [(r["scenario"], r["policy"], r["seed"]) for r in rows_b]
        assert len(set(coords)) == len(coords) == \
            len(_sweep_scenarios()) * len(case["policies"]) * \
            len(case["seeds"])
        _assert_rows_identical(rows_b, rows_s)
else:  # pragma: no cover
    @pytest.mark.skip(reason="property tests need hypothesis installed")
    def test_one_kernel_rows_equal_serial_property():
        pass


# ---------------------------------------------------------------------------
# bench-artifact stamping (benchmarks.common satellite)
# ---------------------------------------------------------------------------

def test_write_bench_json_stamps_commit_and_schema(tmp_path, monkeypatch):
    import json

    from benchmarks import common

    monkeypatch.setattr(common, "REPO_ROOT", str(tmp_path))
    path = common.write_bench_json("BENCH_stamp_test.json",
                                   {"schema": 9, "payload": [1, 2]},
                                   emit_as="test/json")
    data = json.loads(open(path).read())
    assert data["schema"] == 9 and data["payload"] == [1, 2]
    assert data["bench_schema_version"] == common.BENCH_SCHEMA_VERSION
    commit = data["git_commit"]
    assert isinstance(commit, str) and commit
    # stamped commit matches the repo's HEAD when running inside the repo
    assert commit == common.git_commit()
