"""Vectorized-engine tests: the batched JAX Monte-Carlo executor must agree
with the retained Python reference (bit-for-bit on a shared lifetime pool in
float64), the table-driven batch service must match the exact-dispatch
service distributionally, and the simulator fast paths must preserve
values."""
import jax
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core import distributions as D
from repro.core import engine as E
from repro.core import service as SV
from repro.core import simulator as SIM
from repro.core.policies import checkpointing as C
from repro.core.policies import young_daly as YD

GRID = 1.0 / 60.0
JOB = 300  # 5h job


@pytest.fixture(scope="module")
def dist():
    return D.constrained_for("n1-highcpu-16")


@pytest.fixture(scope="module")
def tables(dist):
    return C.solve(dist, JOB, grid_dt=GRID, delta_steps=1, n_sweeps=3)


def _policies(tables):
    tau = float(YD.interval(GRID, 1.0))
    tau_steps = max(1, int(round(tau / GRID)))
    return [
        ("dp", C.dp_policy_fn(tables), E.dp_policy_table(tables)),
        ("young_daly", C.young_daly_policy_fn(tau, GRID),
         E.young_daly_policy_table(tau_steps, JOB)),
        ("none", C.no_checkpoint_policy_fn(), E.no_checkpoint_policy_table(JOB)),
    ]


# ---------------------------------------------------------------------------
# executor equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("start_age,restart_overhead",
                         [(0.0, 0.0), (6.0, 2.0 / 60.0), (15.25, 0.0)])
def test_vectorized_executor_exact_vs_reference(dist, tables, start_age,
                                                restart_overhead):
    """Same pre-drawn pool, float64 kernel: makespans must be IDENTICAL."""
    lf = C.model_lifetimes_fn(dist)
    first, pool = E.draw_lifetime_pool(lf, 300, seed=7, start_age=start_age)
    for name, policy_fn, table in _policies(tables):
        ref = C.simulate_makespan(policy_fn, lf, JOB, grid_dt=GRID,
                                  delta_steps=1, start_age=start_age,
                                  restart_overhead=restart_overhead,
                                  pool=pool, first=first)
        with enable_x64():
            vec = E.simulate_makespan_batch(
                table, JOB, first=first, pool=pool, grid_dt=GRID,
                delta_steps=1, start_age=start_age,
                restart_overhead=restart_overhead)
        assert np.array_equal(ref, vec), \
            f"{name}: max diff {np.abs(ref - vec).max()}"


def test_vectorized_executor_float32_close(dist, tables):
    """Default float32 kernel: agreement to well below Monte-Carlo noise."""
    lf = C.model_lifetimes_fn(dist)
    first, pool = E.draw_lifetime_pool(lf, 300, seed=3)
    table = E.dp_policy_table(tables)
    ref = C.simulate_makespan(C.dp_policy_fn(tables), lf, JOB, grid_dt=GRID,
                              pool=pool, first=first)
    vec = E.simulate_makespan_batch(table, JOB, first=first, pool=pool,
                                    grid_dt=GRID)
    np.testing.assert_allclose(vec, ref, rtol=1e-4)


def test_engine_seed_matches_reference_draws(dist, tables):
    """simulate_makespan_engine(seed) must consume the same lifetimes as
    simulate_makespan(seed) - drop-in replacement contract."""
    lf = C.model_lifetimes_fn(dist)
    ref = C.simulate_makespan(C.dp_policy_fn(tables), lf, JOB, grid_dt=GRID,
                              n_trials=200, seed=42)
    vec = E.simulate_makespan_engine(E.dp_policy_table(tables), lf, JOB,
                                     grid_dt=GRID, n_trials=200, seed=42)
    np.testing.assert_allclose(vec, ref, rtol=1e-4)


def test_executor_trivial_cases(dist, tables):
    """A job that always fits its first VM takes exactly its work time plus
    checkpoint writes; pool exhaustion terminates."""
    table = E.no_checkpoint_policy_table(60)
    first = np.full((8,), 24.0)
    pool = np.full((8, 66), 24.0)
    out = E.simulate_makespan_batch(table, 60, first=first, pool=pool,
                                    grid_dt=GRID)
    np.testing.assert_allclose(out, 1.0, rtol=1e-6)  # 60 steps, no ckpt
    # immortal failure loop: every VM dies at 0.5h, job needs 1h contiguous;
    # unfinished="partial" is the Python reference's restart-exhaustion value
    first = np.full((4,), 0.5)
    pool = np.full((4, 66), 0.5)
    out = E.simulate_makespan_batch(table, 60, first=first, pool=pool,
                                    grid_dt=GRID, max_restarts=16,
                                    unfinished="partial")
    np.testing.assert_allclose(out, 0.5 * 17, rtol=1e-5)  # 17 failed attempts


def test_executor_restart_exhaustion_is_flagged(dist, tables):
    """Trials that run out of restarts must never masquerade as completed:
    NaN by default, partial time matching the Python reference on request,
    error on 'raise', and an explicit mask via return_finished."""
    table = E.no_checkpoint_policy_table(60)
    # trials 0/2 finish on the first VM; trials 1/3 can never finish
    first = np.array([24.0, 0.5, 24.0, 0.5])
    pool = np.tile(np.array([24.0, 0.5, 24.0, 0.5])[:, None], (1, 66))
    kw = dict(first=first, pool=pool, grid_dt=GRID, max_restarts=16)
    out, finished = E.simulate_makespan_batch(table, 60, return_finished=True,
                                              **kw)
    assert finished.tolist() == [True, False, True, False]
    np.testing.assert_allclose(out[finished], 1.0, rtol=1e-6)
    assert np.isnan(out[~finished]).all()
    # 'partial' reproduces the reference loop's value for the same pool
    ref = C.simulate_makespan(C.no_checkpoint_policy_fn(), None, 60,
                              grid_dt=GRID, max_restarts=16, pool=pool,
                              first=first)
    part = E.simulate_makespan_batch(table, 60, unfinished="partial", **kw)
    np.testing.assert_allclose(part, ref, rtol=1e-5)
    with pytest.raises(RuntimeError, match="2/4 trials"):
        E.simulate_makespan_batch(table, 60, unfinished="raise", **kw)
    with pytest.raises(ValueError):
        E.simulate_makespan_batch(table, 60, unfinished="bogus", **kw)


def test_executor_max_events_truncation_is_flagged(dist, tables):
    """An undersized max_events cap truncates even finishable trials — the
    engine must flag them instead of returning the partial makespan."""
    table = E.young_daly_policy_table(10, 60)
    first = np.full((4,), 24.0)
    pool = np.full((4, 66), 24.0)
    out, finished = E.simulate_makespan_batch(
        table, 60, first=first, pool=pool, grid_dt=GRID, max_events=3,
        return_finished=True)
    assert not finished.any()
    assert np.isnan(out).all()
    # a sufficient cap finishes the same workload
    out2 = E.simulate_makespan_batch(table, 60, first=first, pool=pool,
                                     grid_dt=GRID, unfinished="raise")
    assert np.isfinite(out2).all()


# ---------------------------------------------------------------------------
# batch service
# ---------------------------------------------------------------------------

def test_service_table_matches_exact_distributionally():
    """Table-driven reuse decisions vs per-candidate exact dispatches: the
    service-level metrics must agree within (tight) statistical tolerance."""
    dist = D.constrained_for("n1-highcpu-32")
    seeds = range(4)
    kw = dict(n_jobs=40, job_hours=2.0, cluster_size=8)
    exact = [SV.run_bag(dist, seed=s, vectorized_reuse=False, **kw)
             for s in seeds]
    table = [SV.run_bag(dist, seed=s, **kw) for s in seeds]
    for r in table:
        assert all(j.finished is not None for j in r.jobs)
    cost_e = np.mean([r.cost for r in exact])
    cost_t = np.mean([r.cost for r in table])
    np.testing.assert_allclose(cost_t, cost_e, rtol=0.05)
    mk_e = np.mean([r.makespan for r in exact])
    mk_t = np.mean([r.makespan for r in table])
    np.testing.assert_allclose(mk_t, mk_e, rtol=0.05)


def test_reuse_table_matches_pointwise_policy():
    """ReuseTable.decide == scheduling.reuse_decision on its own grid."""
    from repro.core.policies import scheduling as S

    dist = D.constrained_for("n1-highcpu-32")
    T_vals = np.array([0.5, 1.0, 2.0, 4.0])
    rt = E.ReuseTable(dist, T_vals, n_age=97)
    for T in T_vals:
        for age in np.linspace(0.0, 23.9, 13):
            # quantize age exactly onto the table's grid for the comparison
            ai = int(round(age / rt.L * (rt.n_age - 1)))
            age_q = ai * rt.L / (rt.n_age - 1)
            assert rt.decide(T, age) == bool(
                S.reuse_decision(dist, T, age_q)), (T, age)


def test_run_bag_grid_cells_match_run_bag():
    """Each grid cell equals the corresponding run_bag call when both use
    the same shared reuse table."""
    dist = D.constrained_for("n1-highcpu-32")
    rows = SV.run_bag_grid(vm_types=("n1-highcpu-32",),
                           policies=("model", "memoryless"),
                           cluster_sizes=(8,), seeds=(0, 1), n_jobs=30,
                           job_hours=2.0)
    assert len(rows) == 4
    for row in rows:
        if row["policy"] != "memoryless":
            continue
        # memoryless makes no reuse decisions: must match run_bag exactly
        r_ref = SV.run_bag(dist, n_jobs=30, job_hours=2.0, cluster_size=8,
                           policy="memoryless", seed=row["seed"])
        assert row["result"].makespan == r_ref.makespan
        assert row["result"].cost == r_ref.cost


def test_service_rebuilds_table_for_new_lengths():
    """A second run() with different job lengths must not reuse the first
    run's auto-built table (its T-grid would miss the new lengths)."""
    dist = D.constrained_for("n1-highcpu-32")
    svc = SV.BatchService(dist, cluster_size=8, seed=0)
    svc.run([2.0] * 10)
    t_first = svc._run_reuse_table
    svc.run([0.5] * 10)
    assert svc._run_reuse_table is not t_first
    assert 0.5 in svc._run_reuse_table.T_values
    # exact-dispatch agreement for the short bag
    svc_exact = SV.BatchService(dist, cluster_size=8, seed=0,
                                vectorized_reuse=False)
    r_e = svc_exact.run([0.5] * 10)
    assert all(j.finished is not None for j in r_e.jobs)


def test_service_event_heap_keys_unique(monkeypatch):
    """Every event (finish/preempt/expire) must carry a distinct monotonic
    seq tiebreaker: the old expire key ``len(jobs) + vm_id`` could collide
    with early seq values, making same-timestamp ordering nondeterministic."""
    import heapq

    keys = []
    orig = heapq.heappush

    def record(heap, item):
        if isinstance(item, tuple) and len(item) == 4:
            keys.append(item[:2])
        return orig(heap, item)

    monkeypatch.setattr(heapq, "heappush", record)
    dist = D.constrained_for("n1-highcpu-32")
    r = SV.run_bag(dist, n_jobs=30, job_hours=2.0, cluster_size=8, seed=0)
    assert all(j.finished is not None for j in r.jobs)
    kinds = len(keys)
    assert kinds > 30, "expected finish+preempt+expire events to be recorded"
    assert len(set(keys)) == kinds, "heap keys (time, seq) must be unique"
    seqs = [s for _, s in keys]
    assert len(set(seqs)) == len(seqs), "seq tiebreakers must never repeat"


# ---------------------------------------------------------------------------
# simulator fast paths
# ---------------------------------------------------------------------------

def test_ground_truth_grid_cached():
    gt1 = SIM.ground_truth_for("n1-highcpu-16")
    gt2 = SIM.ground_truth_for("n1-highcpu-16")
    t1, F1 = gt1._grid()
    t2, F2 = gt2._grid()
    assert t1 is t2 and F1 is F2, "identical processes must share one grid"
    # different parameters => different grid
    t3, F3 = SIM.ground_truth_for("n1-highcpu-32")._grid()
    assert F3 is not F1


def test_grid_cache_consistent_with_compute():
    gt = SIM.ground_truth_for("n1-highcpu-8", launch_clock=3.0)
    t_c, F_c = gt._grid()
    t_r, F_r = gt._grid_compute()
    np.testing.assert_array_equal(np.asarray(F_c), np.asarray(F_r))


def test_fleet_trace_grouped_sampling_statistics():
    """Grouped per-type sampling: each type's lifetimes follow its own
    process (KS-style bound against the type's own CDF)."""
    tr = SIM.generate_fleet_trace(jax.random.PRNGKey(0), n_vms=1000)
    life = np.asarray(tr.lifetime)
    types = np.asarray(tr.vm_type_idx)
    assert life.shape == (1000,) and life.min() > 0 and life.max() <= 24.0
    vm_types = ("n1-highcpu-2", "n1-highcpu-4", "n1-highcpu-8",
                "n1-highcpu-16", "n1-highcpu-32")
    for ti, name in enumerate(vm_types):
        sel = life[types == ti]
        assert sel.size > 100  # ~200 expected per type
        gt = SIM.ground_truth_for(name)  # clock-averaged check, loose bound
        emp = (sel < 3.0).mean()
        model = float(gt.cdf(3.0))
        assert abs(emp - model) < 0.12, (name, emp, model)
    # Obs. 4 ordering: larger VMs die earlier on average
    means = [life[types == ti].mean() for ti in range(5)]
    assert means[0] > means[4]
