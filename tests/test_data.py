"""Data pipeline: determinism + elastic re-sharding contract."""
import jax.numpy as jnp
import numpy as np

from repro.data import SyntheticLM


def test_step_addressable_determinism():
    p = SyntheticLM(vocab_size=128, seq_len=16, global_batch=8, seed=3)
    b1 = p.batch(12)
    b2 = p.batch(12)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = p.batch(13)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_labels_are_shifted_tokens():
    p = SyntheticLM(vocab_size=128, seq_len=16, global_batch=4, seed=0)
    b = p.batch(0)
    # labels[t] is the next token of the same underlying stream:
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_replica_slices_partition_global_batch():
    p = SyntheticLM(vocab_size=128, seq_len=8, global_batch=8, seed=1)
    full_shape = p.batch(5, 0, 1)["tokens"].shape
    halves = [p.batch(5, r, 2)["tokens"] for r in (0, 1)]
    assert full_shape == (8, 8)
    assert halves[0].shape == (4, 8)
    # different replicas draw different streams
    assert not np.array_equal(np.asarray(halves[0]), np.asarray(halves[1]))


def test_learnable_structure():
    """The Markov copy structure must make labels partially predictable."""
    p = SyntheticLM(vocab_size=1024, seq_len=64, global_batch=16, seed=2)
    b = p.batch(0)
    toks = np.asarray(b["tokens"])
    period = p.markov_period
    idx = np.arange(toks.shape[1])
    rep = (idx % period) >= (period // 2)
    src = np.maximum(idx - period // 2, 0)
    match = (toks[:, rep] == toks[:, src[rep]]).mean()
    assert match > 0.9
