"""Shared benchmark utilities: CSV emission + timing."""
from __future__ import annotations

import time


def emit(name: str, us_per_call: float, derived: str):
    """The harness contract: ``name,us_per_call,derived`` CSV rows."""
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn, *args, reps: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(reps):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / reps
    return out, dt * 1e6
