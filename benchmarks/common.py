"""Shared benchmark utilities: CSV emission, timing + BENCH-JSON output."""
from __future__ import annotations

import json
import os
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def emit(name: str, us_per_call: float, derived: str):
    """The harness contract: ``name,us_per_call,derived`` CSV rows."""
    print(f"{name},{us_per_call:.1f},{derived}")


def write_bench_json(filename: str, payload: dict, *, emit_as: str):
    """Write a machine-readable ``BENCH_*.json`` artifact at the repo root
    (the cross-PR perf-trajectory contract) and emit its CSV row."""
    path = os.path.join(REPO_ROOT, filename)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    emit(emit_as, 0.0, os.path.relpath(path, REPO_ROOT))
    return path


def timed(fn, *args, reps: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(reps):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / reps
    return out, dt * 1e6
