"""Shared benchmark utilities: CSV emission, timing + BENCH-JSON output.

The ``BENCH_*.json`` artifacts written through :func:`write_bench_json` are
the cross-PR perf-trajectory contract; their field-by-field layout, schema
versioning and diffing workflow are documented in ``docs/bench_schemas.md``.
"""
from __future__ import annotations

import functools
import json
import os
import subprocess
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Version of the BENCH_*.json envelope written by write_bench_json (the
# git_commit/bench_schema_version stamps themselves).  Module payloads keep
# their own "schema" field for module-specific row formats.
BENCH_SCHEMA_VERSION = 1


def emit(name: str, us_per_call: float, derived: str):
    """The harness contract: ``name,us_per_call,derived`` CSV rows."""
    print(f"{name},{us_per_call:.1f},{derived}")


@functools.lru_cache(maxsize=1)
def git_commit() -> str:
    """Short hash of the checked-out commit, with a ``+dirty`` suffix when
    the worktree has uncommitted changes ('unknown' outside a repo)."""
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             cwd=REPO_ROOT, capture_output=True, text=True,
                             timeout=10)
        head = out.stdout.strip()
        if not head:
            return "unknown"
        dirty = subprocess.run(["git", "status", "--porcelain"],
                               cwd=REPO_ROOT, capture_output=True, text=True,
                               timeout=10).stdout.strip()
        return f"{head}+dirty" if dirty else head
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def write_bench_json(filename: str, payload: dict, *, emit_as: str):
    """Write a machine-readable ``BENCH_*.json`` artifact at the repo root
    (the cross-PR perf-trajectory contract) and emit its CSV row.

    Every artifact is stamped with the producing git commit and the
    envelope schema version, so the perf trajectory stays diffable across
    PRs without guessing which commit wrote which numbers.  See
    ``docs/bench_schemas.md`` for every artifact's field reference.
    """
    payload = dict(payload)
    payload["git_commit"] = git_commit()
    payload["bench_schema_version"] = BENCH_SCHEMA_VERSION
    path = os.path.join(REPO_ROOT, filename)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    emit(emit_as, 0.0, os.path.relpath(path, REPO_ROOT))
    return path


def timed(fn, *args, reps: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(reps):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / reps
    return out, dt * 1e6
