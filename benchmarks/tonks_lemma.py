"""The paper's Lemma: Tonks-gas boundary enhancement of constrained
preemptions - exact 1/(L-Nw) vs Monte-Carlo."""
from __future__ import annotations

import jax

from repro.core import tonks

from .common import emit, timed


def run():
    L = 24.0
    for (N, w) in ((6, 0.3), (12, 0.1), (20, 0.5)):
        (mc, exact), us = timed(tonks.boundary_enhancement,
                                jax.random.PRNGKey(0), 200000, N=N, L=L, w=w)
        emit(f"tonks/N{N}_w{w}", us,
             f"mc={float(mc):.4f};exact={float(exact):.4f};"
             f"uniform=1/L={1/L:.4f}")
    c, rho = tonks.start_density(jax.random.PRNGKey(1), 60000, N=6, L=L,
                                 w=0.3, n_bins=48)
    mid = float(rho[16:32].mean())
    emit("tonks/density_enhancement", 0.0,
         f"rho_start={float(rho[0]):.4f};rho_mid={mid:.4f};"
         f"exact=1/(L-Nw)={1/(L-6*0.3):.4f};uniform=1/L={1/L:.4f}")


if __name__ == "__main__":
    run()
