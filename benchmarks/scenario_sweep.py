"""Scenario-sweep benchmark (paper Obs. 5 x Figs. 7/8 workloads) with the
batched-vs-serial scenario-axis comparison.

Expands the grown default (zone x diurnal phase x VM type) scenario grid
(>= 8 scenarios) from ``repro.core.scenarios`` over both vectorized
evaluation paths:

  * the checkpointing executor — (scenario x policy x seed) cells on the
    BATCHED path: one ``solve_batch`` DP call, one device pool call per
    seed, one scenario-batched executor call per (seed, policy);
  * the batch service — (scenario x policy x cluster x seed) cells with all
    scenarios' reuse grids from one vmapped ``ReuseTable.batch`` call.

It also times the serial per-scenario path (one DP solve + one numpy pool
round-trip per scenario — the pre-batching implementation, retained as
``mode="serial"``) against the batched path, and re-runs the full sweep
serially to confirm the rows agree.  ``BENCH_scenarios.json`` (repo root)
records:

    {"schema": 2, "mode": "full"|"quick", "generated_unix": ...,
     "grid": {"zones": [...], "phases": [...], "vm_types": [...],
              "checkpoint_policies": [...], "service_policies": [...],
              "seeds": [...]},
     "checkpointing": {"workload": {...}, "wall_clock_s": ...,
                       "rows": [...batched per-cell makespan stats...]},
     "service": {"workload": {...}, "wall_clock_s": ..., "rows": [...]},
     "batch_vs_serial": {"n_scenarios": ..., "solver": {...}, "pool": {...},
                         "combined_speedup": ...,
                         "serial_sweep_wall_clock_s": ...,
                         "dp_values_bitexact": ...,
                         "rows_max_rel_diff_makespan_mean": ...},
     "summary": {...Obs. 5 ratios + batched_combined_speedup...}}

``--quick`` (or run(quick=True)) shrinks trials/steps so the module finishes
fast; the JSON records which mode produced it.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import engine as E
from repro.core import scenarios as SC
from repro.core.policies import checkpointing as ckpt

from .common import emit, write_bench_json

ZONES = ("us-east1-b", "us-central1-a")
PHASES = ("day", "night")
VM_TYPES = ("n1-highcpu-16", "n1-highcpu-32")
CKPT_POLICIES = ("dp", "young_daly", "none")
SERVICE_POLICIES = ("model", "memoryless")


def _phase_mean(rows, phase, key, **match):
    vals = [r[key] for r in rows
            if r["phase"] == phase and not np.isnan(r[key])
            and all(r[k] == v for k, v in match.items())]
    return float(np.mean(vals)) if vals else float("nan")


def _bench_batch_vs_serial(dist_list, *, job_steps, n_trials, grid_dt,
                           max_restarts, seeds) -> dict:
    """Warm-timed comparison of the per-scenario setup work the batched
    scenario axis replaces: the DP solves and the lifetime-pool draws."""
    S = len(dist_list)
    # warm both compile caches at the measured shapes
    ckpt.solve(dist_list[0], job_steps, grid_dt=grid_dt)
    ckpt.solve_batch(dist_list, job_steps, grid_dt=grid_dt)
    E.draw_lifetime_pool(ckpt.model_lifetimes_fn(dist_list[0]), n_trials,
                         max_restarts=max_restarts, seed=seeds[0])
    E.draw_lifetime_pool_batch(dist_list, n_trials,
                               max_restarts=max_restarts, seed=seeds[0])

    t0 = time.perf_counter()
    serial_tabs = [ckpt.solve(d, job_steps, grid_dt=grid_dt)
                   for d in dist_list]
    t_solver_serial = time.perf_counter() - t0
    t0 = time.perf_counter()
    batch_tabs = ckpt.solve_batch(dist_list, job_steps, grid_dt=grid_dt)
    t_solver_batched = time.perf_counter() - t0
    bitexact = all(
        np.array_equal(serial_tabs[s].V, batch_tabs.V[s])
        and np.array_equal(serial_tabs[s].K, batch_tabs.K[s])
        for s in range(S))

    t0 = time.perf_counter()
    for seed in seeds:
        for d in dist_list:
            E.draw_lifetime_pool(ckpt.model_lifetimes_fn(d), n_trials,
                                 max_restarts=max_restarts, seed=seed)
    t_pool_serial = time.perf_counter() - t0
    t0 = time.perf_counter()
    for seed in seeds:
        E.draw_lifetime_pool_batch(dist_list, n_trials,
                                   max_restarts=max_restarts, seed=seed)
    t_pool_batched = time.perf_counter() - t0

    return {
        "n_scenarios": S,
        "solver": {"serial_s": t_solver_serial,
                   "batched_s": t_solver_batched,
                   "speedup": t_solver_serial / t_solver_batched},
        "pool": {"serial_s": t_pool_serial, "batched_s": t_pool_batched,
                 "speedup": t_pool_serial / t_pool_batched},
        "combined_speedup": (t_solver_serial + t_pool_serial)
                            / (t_solver_batched + t_pool_batched),
        "dp_values_bitexact": bool(bitexact),
    }


def run(quick: bool = False):
    grid = SC.default_grid(vm_types=VM_TYPES, phases=PHASES, zones=ZONES)
    seeds = (0,) if quick else (0, 1)

    ck_workload = dict(job_steps=180 if quick else 300,
                       n_trials=300 if quick else 4000,
                       grid_dt=1.0 / 60.0, delta_steps=1, max_restarts=64)
    job_steps, n_trials = ck_workload["job_steps"], ck_workload["n_trials"]

    t0 = time.perf_counter()
    ck_rows = SC.sweep_checkpointing(grid, policies=CKPT_POLICIES,
                                     seeds=seeds, **ck_workload)
    t_ck = time.perf_counter() - t0
    emit(f"scenarios/ckpt_{len(ck_rows)}cells_J{job_steps}_n{n_trials}",
         t_ck / len(ck_rows) * 1e6,
         f"wall_s={t_ck:.2f};"
         f"day_dp={_phase_mean(ck_rows, 'day', 'makespan_mean', policy='dp'):.3f}h;"
         f"night_dp={_phase_mean(ck_rows, 'night', 'makespan_mean', policy='dp'):.3f}h")

    # batched-vs-serial: the per-scenario setup (DP solves + pool draws)
    dist_list = [sc.dist() for sc in grid]
    bvs = _bench_batch_vs_serial(
        dist_list, job_steps=job_steps, n_trials=n_trials,
        grid_dt=ck_workload["grid_dt"],
        max_restarts=ck_workload["max_restarts"], seeds=seeds)
    t0 = time.perf_counter()
    ck_rows_serial = SC.sweep_checkpointing(grid, policies=CKPT_POLICIES,
                                            seeds=seeds, mode="serial",
                                            **ck_workload)
    bvs["serial_sweep_wall_clock_s"] = time.perf_counter() - t0
    rel = [abs(a["makespan_mean"] - b["makespan_mean"])
           / max(abs(b["makespan_mean"]), 1e-9)
           for a, b in zip(ck_rows, ck_rows_serial)
           if np.isfinite(a["makespan_mean"]) and np.isfinite(b["makespan_mean"])]
    bvs["rows_max_rel_diff_makespan_mean"] = float(np.max(rel)) if rel else 0.0
    emit(f"scenarios/batch_vs_serial_S{len(grid)}",
         bvs["solver"]["batched_s"] / len(grid) * 1e6,
         f"solver={bvs['solver']['speedup']:.2f}x;"
         f"pool={bvs['pool']['speedup']:.2f}x;"
         f"combined={bvs['combined_speedup']:.2f}x;"
         f"dp_bitexact={bvs['dp_values_bitexact']};"
         f"rows_maxrel={bvs['rows_max_rel_diff_makespan_mean']:.1e}")

    n_jobs = 20 if quick else 60
    cluster_sizes = (8,) if quick else (16,)
    t0 = time.perf_counter()
    sv_rows = SC.sweep_service(grid, policies=SERVICE_POLICIES,
                               cluster_sizes=cluster_sizes, seeds=seeds,
                               n_jobs=n_jobs, job_hours=2.0)
    t_sv = time.perf_counter() - t0
    red = float(np.mean([r["cost_reduction"] for r in sv_rows
                         if r["policy"] == "model"]))
    emit(f"scenarios/service_{len(sv_rows)}cells_n{n_jobs}",
         t_sv / len(sv_rows) * 1e6,
         f"wall_s={t_sv:.2f};reduction={red:.2f}x")

    day_mk = _phase_mean(ck_rows, "day", "makespan_mean", policy="dp")
    night_mk = _phase_mean(ck_rows, "night", "makespan_mean", policy="dp")
    day_pf = _phase_mean(ck_rows, "day", "p_fail_fresh", policy="dp")
    night_pf = _phase_mean(ck_rows, "night", "p_fail_fresh", policy="dp")
    day_fr = _phase_mean(sv_rows, "day", "job_failure_rate", policy="model")
    night_fr = _phase_mean(sv_rows, "night", "job_failure_rate",
                           policy="model")
    payload = {
        "schema": 2,
        "mode": "quick" if quick else "full",
        "generated_unix": time.time(),
        "grid": {"zones": list(ZONES), "phases": list(PHASES),
                 "vm_types": list(VM_TYPES),
                 "checkpoint_policies": list(CKPT_POLICIES),
                 "service_policies": list(SERVICE_POLICIES),
                 "seeds": list(seeds)},
        "checkpointing": {
            "workload": dict(ck_workload),
            "wall_clock_s": t_ck, "rows": ck_rows},
        "service": {
            "workload": {"n_jobs": n_jobs, "job_hours": 2.0,
                         "cluster_sizes": list(cluster_sizes)},
            "wall_clock_s": t_sv, "rows": sv_rows},
        "batch_vs_serial": bvs,
        "summary": {
            # Obs. 5 headline: night launches preempt less (< 1).  Makespan
            # need not follow — night failures arrive later in a VM's life,
            # so each failed attempt wastes more wall-clock; both ratios are
            # recorded so the trade-off is visible across PRs.
            "night_over_day_fail_prob": night_pf / day_pf,
            "night_over_day_makespan": night_mk / day_mk,
            "night_over_day_failure_rate":
                night_fr / day_fr if day_fr else float("nan"),
            "cost_reduction_mean": red,
            "batched_combined_speedup": bvs["combined_speedup"]},
    }
    write_bench_json("BENCH_scenarios.json", payload, emit_as="scenarios/json")


if __name__ == "__main__":
    import sys

    run(quick="--quick" in sys.argv)
