"""Scenario-sweep benchmark (paper Obs. 5 x Figs. 7/8 workloads) with the
one-kernel-vs-PR-3-batched-vs-serial sweep comparison.

Expands the default (zone x diurnal phase x VM type) scenario grid
(>= 8 scenarios) from ``repro.core.scenarios`` over both vectorized
evaluation paths:

  * the checkpointing executor — the full (scenario x policy x seed) grid
    folded to ONE deduplicated kernel dispatch
    (``sweep_checkpointing(mode="batched")``, PR 4);
  * the batch service — (scenario x policy x cluster x seed) cells with all
    scenarios' reuse grids from one folded ``engine.ReuseTables`` tensor.

Three checkpointing sweep implementations are timed against each other on
the same grid: the one-kernel fold, the PR-3 path (``mode="grouped"``:
scenario axis batched, (seed x policy) cell groups looped in Python), and
the per-scenario serial reference.  The PR-3 comparison is taken twice:
against today's ``mode="grouped"`` (same jit-cached Newton pools, isolating
the fold itself) and against the path as PR 3 shipped it (the generic
64-iteration bisection icdf invoked eagerly, re-traced and re-compiled on
every pool call — both costs PR 4 removed) — the cross-PR perf-trajectory
number.
"Combined" always means the post-solve stages combined (pool draws +
policy-table prep + executor dispatch + row assembly); the DP solve is an
identical shared ``solve_batch`` call in every non-serial mode and is timed
separately (``batch_vs_serial``, continued from schema 2).

``BENCH_scenarios.json`` (repo root, see docs/bench_schemas.md) records::

    {"schema": 4, "mode": "full"|"quick", "generated_unix": ...,
     "grid": {...},
     "checkpointing": {"workload": {...}, "wall_clock_s": ...,
                       "rows": [...one-kernel per-cell makespan stats...]},
     "service": {"workload": {...}, "wall_clock_s": ..., "rows": [...]},
     "one_kernel": {"n_cells": ...,
                    "sweep_wall_clock_s": {"batched": ..., "grouped": ...,
                                           "serial": ...},
                    "post_solve": {"one_kernel_s": ..., "grouped_s": ...,
                                   "pr3_grouped_s": ...,
                                   "combined_speedup_vs_pr3": ...,
                                   "combined_speedup_vs_grouped": ...},
                    "agreement": {"rows_max_rel_diff_vs_serial": ...,
                                  "rows_bitexact_x64": ...,
                                  "x64_check_n_trials": ...}},
     "batch_vs_serial": {"n_scenarios": ..., "solver": {...}, "pool": {...},
                         "combined_speedup": ..., "dp_values_bitexact": ...},
     "solver": {...solver_bench.measure: plain-XLA vs coarse-to-fine wall
                clock, speedup, verification + bit-agreement (schema 4)...},
     "summary": {...Obs. 5 ratios + one_kernel/solver speedups...}}

``--quick`` (or run(quick=True)) shrinks trials/steps so the module finishes
fast; the JSON records which mode produced it.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core import distributions as D
from repro.core import engine as E
from repro.core import scenarios as SC
from repro.core.policies import checkpointing as ckpt

from .common import emit, write_bench_json

ZONES = ("us-east1-b", "us-central1-a")
PHASES = ("day", "night")
VM_TYPES = ("n1-highcpu-16", "n1-highcpu-32")
CKPT_POLICIES = ("dp", "young_daly", "none")
SERVICE_POLICIES = ("model", "memoryless")


class _Pr3Constrained(D.Constrained):
    """Eq. 1 with the PR-3-era sampler: the generic 64-iteration bisection
    icdf (still shipped as ``distributions._bisect_icdf``) instead of the
    bracketed-Newton inversion PR 4 gave :class:`~repro.core.distributions.
    Constrained`.  Only used to time the PR-3 batched path as it shipped."""

    def icdf(self, u):
        return D._bisect_icdf(self.cdf, u, 0.0, self.L)


_Pr3Constrained = D._dist(_Pr3Constrained)


def _pr3_dists(dist_list):
    out = []
    for d in dist_list:
        eff = d.effective() if hasattr(d, "effective") else d
        out.append(_Pr3Constrained(tau1=eff.tau1, tau2=eff.tau2, b=eff.b,
                                   A=eff.A, L=eff.L))
    return out


def _pr3_draw_lifetime_pool_batch(dists, n_trials, *, max_restarts, seed):
    """``engine.draw_lifetime_pool_batch`` as PR 3 shipped it: one shared
    seed, and — the crucial cost difference — the inverse CDF invoked
    *eagerly*, so the bisection graph was re-traced and re-compiled through
    a fresh closure on every call (PR 4 fixed this by routing all sampling
    through one jitted kernel that takes the distribution as an argument).
    Retained verbatim here so the baseline costs what the PR-3 path
    actually cost per sweep."""
    dtype = jnp.result_type(float)
    norm = [jax.tree_util.tree_map(lambda l: jnp.asarray(l, dtype), d)
            for d in dists]
    d_b = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls)[:, None], *norm)
    S = len(dists)
    rng = np.random.default_rng(seed)
    u_pool = rng.uniform(size=n_trials * (max_restarts + 2))
    u_first = rng.uniform(size=n_trials)
    fl = np.array([float(d.cdf(d.L)) for d in norm])[:, None]
    L = np.array([float(d.L) for d in norm])[:, None]

    def capped(u):
        t = np.asarray(d_b.icdf(jnp.minimum(jnp.asarray(u),
                                            jnp.asarray(fl * (1.0 - 1e-6)))),
                       np.float64)
        return np.where(u >= fl, L, t)

    pool = capped(np.broadcast_to(u_pool, (S, u_pool.size)))
    first = capped(np.broadcast_to(u_first, (S, u_first.size)))
    return first, pool.reshape(S, n_trials, max_restarts + 2)


def _phase_mean(rows, phase, key, **match):
    vals = [r[key] for r in rows
            if r["phase"] == phase and not np.isnan(r[key])
            and all(r[k] == v for k, v in match.items())]
    return float(np.mean(vals)) if vals else float("nan")


def _rows_equal(a_rows, b_rows) -> bool:
    """Exact row-for-row equality, treating NaN == NaN (the engine's flag
    for unfinished trials must survive the unflattening unchanged)."""
    if len(a_rows) != len(b_rows):
        return False
    for a, b in zip(a_rows, b_rows):
        if set(a) != set(b):
            return False
        for k, va in a.items():
            vb = b[k]
            if isinstance(va, float) and isinstance(vb, float) \
                    and np.isnan(va) and np.isnan(vb):
                continue
            if va != vb:
                return False
    return True


def _rows_max_rel_diff(a_rows, b_rows, key="makespan_mean") -> float:
    rel = [abs(a[key] - b[key]) / max(abs(b[key]), 1e-9)
           for a, b in zip(a_rows, b_rows)
           if np.isfinite(a[key]) and np.isfinite(b[key])]
    return float(np.max(rel)) if rel else 0.0


def _bench_one_kernel(grid, dist_list, batch, *, policies, seeds,
                      workload) -> dict:
    """Warm re-evaluation comparison of the post-solve sweep stages (solver
    tables reused via ``tables=``): the PR-4 one-kernel fold vs the PR-3
    grouped dispatch, the latter both with today's pools and with the
    PR-3-era bisection pools."""
    wk = dict(workload)
    job_steps, n_trials = wk["job_steps"], wk["n_trials"]
    grid_dt, delta_steps = wk["grid_dt"], wk["delta_steps"]
    max_restarts = wk["max_restarts"]

    def sweep(mode):
        return lambda: SC.sweep_checkpointing(
            grid, policies=policies, seeds=seeds, mode=mode, tables=batch,
            **wk)

    run_one, run_grouped = sweep("batched"), sweep("grouped")

    # the PR-3 path as shipped: per-seed pool calls through the eagerly
    # re-compiled 64-iteration bisection icdf, one executor dispatch per
    # (seed, policy) cell group, and the same row assembly
    pr3 = _pr3_dists(dist_list)
    ptables = {p: SC._policy_tables_batch(p, batch, job_steps, grid_dt,
                                          delta_steps, dist_list)
               for p in policies}

    def run_pr3():
        # the per-call scalar evals PR-3's sweep performed stay inside the
        # timed closure, like the one-kernel sweep's own
        p_fail_fresh = [float(d.cdf(job_steps * grid_dt))
                        for d in dist_list]
        cells = {}
        for seed in seeds:
            first, pool = _pr3_draw_lifetime_pool_batch(
                pr3, n_trials, max_restarts=max_restarts, seed=seed)
            for p in policies:
                cells[seed, p] = E.simulate_makespan_batch(
                    ptables[p], job_steps, first=first, pool=pool,
                    grid_dt=grid_dt, delta_steps=delta_steps,
                    max_restarts=max_restarts, unfinished="nan",
                    return_finished=True)
        rows = []
        for s, sc in enumerate(grid):
            for seed in seeds:
                for p in policies:
                    mk, finished = cells[seed, p]
                    rows.append(SC._ckpt_row(
                        sc, p, seed, mk[s], finished[s], n_trials=n_trials,
                        job_steps=job_steps, p_fail_fresh=p_fail_fresh[s],
                        expected_makespan_dp=batch.expected_makespan(
                            s, job_steps)))
        return rows

    # interleaved median-of-5: one sample of every path per round, so a
    # noisy-neighbor phase on this shared box biases all three paths alike
    # instead of whichever happened to be timed during it
    samples = {"one": [], "grouped": [], "pr3": []}
    for fn in (run_one, run_grouped, run_pr3):
        fn()  # warm (the pr3 eager icdf recompiles per call regardless)
    for _ in range(5):
        for key, fn in (("one", run_one), ("grouped", run_grouped),
                        ("pr3", run_pr3)):
            t0 = time.perf_counter()
            fn()
            samples[key].append(time.perf_counter() - t0)
    t_one, t_grouped, t_pr3 = (float(np.median(samples[k]))
                               for k in ("one", "grouped", "pr3"))

    return {
        "n_cells": len(grid) * len(policies) * len(seeds),
        "timing": "interleaved median of 5",
        "post_solve": {
            "one_kernel_s": t_one,
            "grouped_s": t_grouped,
            "pr3_grouped_s": t_pr3,
            "combined_speedup_vs_pr3": t_pr3 / t_one,
            "combined_speedup_vs_grouped": t_grouped / t_one,
        },
    }


def run(quick: bool = False):
    grid = SC.default_grid(vm_types=VM_TYPES, phases=PHASES, zones=ZONES)
    seeds = (0,) if quick else (0, 1)

    ck_workload = dict(job_steps=180 if quick else 300,
                       n_trials=300 if quick else 4000,
                       grid_dt=1.0 / 60.0, delta_steps=1, max_restarts=64)
    job_steps, n_trials = ck_workload["job_steps"], ck_workload["n_trials"]
    dist_list = [sc.dist() for sc in grid]

    # the one-kernel sweep (the production path; includes its own solve)
    t0 = time.perf_counter()
    ck_rows = SC.sweep_checkpointing(grid, policies=CKPT_POLICIES,
                                     seeds=seeds, **ck_workload)
    t_ck = time.perf_counter() - t0
    emit(f"scenarios/ckpt_{len(ck_rows)}cells_J{job_steps}_n{n_trials}",
         t_ck / len(ck_rows) * 1e6,
         f"wall_s={t_ck:.2f};"
         f"day_dp={_phase_mean(ck_rows, 'day', 'makespan_mean', policy='dp'):.3f}h;"
         f"night_dp={_phase_mean(ck_rows, 'night', 'makespan_mean', policy='dp'):.3f}h")

    # the PR-3 grouped sweep and the serial reference, same grid
    t0 = time.perf_counter()
    rows_grouped = SC.sweep_checkpointing(grid, policies=CKPT_POLICIES,
                                          seeds=seeds, mode="grouped",
                                          **ck_workload)
    t_ck_grouped = time.perf_counter() - t0
    t0 = time.perf_counter()
    rows_serial = SC.sweep_checkpointing(grid, policies=CKPT_POLICIES,
                                         seeds=seeds, mode="serial",
                                         **ck_workload)
    t_ck_serial = time.perf_counter() - t0

    # warm post-solve comparison on reused solver tables, at the sweep's
    # own stats workload — the whole-grid re-evaluation regime the fold
    # targets, where the PR-3 path's per-sweep recompile cost is real
    batch = ckpt.solve_batch(dist_list, job_steps,
                             grid_dt=ck_workload["grid_dt"],
                             delta_steps=ck_workload["delta_steps"])
    onek_workload = dict(ck_workload, n_trials=1000 if quick else 4000)
    onek = _bench_one_kernel(grid, dist_list, batch, policies=CKPT_POLICIES,
                             seeds=seeds, workload=onek_workload)
    onek["workload"] = onek_workload
    onek["sweep_wall_clock_s"] = {"batched": t_ck, "grouped": t_ck_grouped,
                                  "serial": t_ck_serial}

    # x64 bit-exactness of the unflattening: one-kernel rows must equal the
    # serial reference rows exactly (reduced trials keep the check cheap)
    n64 = 80 if quick else 250
    wk64 = dict(ck_workload, n_trials=n64)
    with enable_x64():
        rows64_b = SC.sweep_checkpointing(grid, policies=CKPT_POLICIES,
                                          seeds=seeds, **wk64)
        rows64_s = SC.sweep_checkpointing(grid, policies=CKPT_POLICIES,
                                          seeds=seeds, mode="serial", **wk64)
    onek["agreement"] = {
        "rows_max_rel_diff_vs_serial": _rows_max_rel_diff(ck_rows,
                                                          rows_serial),
        "rows_max_rel_diff_grouped_vs_serial":
            _rows_max_rel_diff(rows_grouped, rows_serial),
        "rows_bitexact_x64": _rows_equal(rows64_b, rows64_s),
        "x64_check_n_trials": n64,
    }
    ps = onek["post_solve"]
    emit(f"scenarios/one_kernel_B{onek['n_cells']}",
         ps["one_kernel_s"] / onek["n_cells"] * 1e6,
         f"vs_pr3={ps['combined_speedup_vs_pr3']:.2f}x;"
         f"vs_grouped={ps['combined_speedup_vs_grouped']:.2f}x;"
         f"rows_bitexact_x64={onek['agreement']['rows_bitexact_x64']};"
         f"rows_maxrel={onek['agreement']['rows_max_rel_diff_vs_serial']:.1e}")

    # solver/pool batched-vs-serial continuity block (schema 2 lineage)
    bvs = _bench_batch_vs_serial(
        dist_list, job_steps=job_steps, n_trials=n_trials,
        grid_dt=ck_workload["grid_dt"],
        max_restarts=ck_workload["max_restarts"], seeds=seeds)
    emit(f"scenarios/batch_vs_serial_S{len(grid)}",
         bvs["solver"]["batched_s"] / len(grid) * 1e6,
         f"solver={bvs['solver']['speedup']:.2f}x;"
         f"pool={bvs['pool']['speedup']:.2f}x;"
         f"combined={bvs['combined_speedup']:.2f}x;"
         f"dp_bitexact={bvs['dp_values_bitexact']}")

    # solver backend block (schema 4): plain XLA vs coarse-to-fine at this
    # sweep's own workload — the cross-PR solver wall-clock trajectory
    from . import solver_bench
    solver = solver_bench.measure(dist_list, job_steps=job_steps,
                                  grid_dt=ck_workload["grid_dt"])
    emit(f"scenarios/solver_ctf_S{len(grid)}",
         solver["refine_s"] / len(grid) * 1e6,
         f"xla_s={solver['xla_s']:.2f};refine_s={solver['refine_s']:.2f};"
         f"speedup={solver['speedup']:.2f}x;"
         f"bitexact={solver['bit_identical_to_plain']}")

    n_jobs = 20 if quick else 60
    cluster_sizes = (8,) if quick else (16,)
    t0 = time.perf_counter()
    sv_rows = SC.sweep_service(grid, policies=SERVICE_POLICIES,
                               cluster_sizes=cluster_sizes, seeds=seeds,
                               n_jobs=n_jobs, job_hours=2.0)
    t_sv = time.perf_counter() - t0
    red = float(np.mean([r["cost_reduction"] for r in sv_rows
                         if r["policy"] == "model"]))
    emit(f"scenarios/service_{len(sv_rows)}cells_n{n_jobs}",
         t_sv / len(sv_rows) * 1e6,
         f"wall_s={t_sv:.2f};reduction={red:.2f}x")

    day_mk = _phase_mean(ck_rows, "day", "makespan_mean", policy="dp")
    night_mk = _phase_mean(ck_rows, "night", "makespan_mean", policy="dp")
    day_pf = _phase_mean(ck_rows, "day", "p_fail_fresh", policy="dp")
    night_pf = _phase_mean(ck_rows, "night", "p_fail_fresh", policy="dp")
    day_fr = _phase_mean(sv_rows, "day", "job_failure_rate", policy="model")
    night_fr = _phase_mean(sv_rows, "night", "job_failure_rate",
                           policy="model")
    payload = {
        "schema": 4,
        "mode": "quick" if quick else "full",
        "generated_unix": time.time(),
        "grid": {"zones": list(ZONES), "phases": list(PHASES),
                 "vm_types": list(VM_TYPES),
                 "checkpoint_policies": list(CKPT_POLICIES),
                 "service_policies": list(SERVICE_POLICIES),
                 "seeds": list(seeds)},
        "checkpointing": {
            "workload": dict(ck_workload),
            "wall_clock_s": t_ck, "rows": ck_rows},
        "service": {
            "workload": {"n_jobs": n_jobs, "job_hours": 2.0,
                         "cluster_sizes": list(cluster_sizes)},
            "wall_clock_s": t_sv, "rows": sv_rows},
        "one_kernel": onek,
        "batch_vs_serial": bvs,
        "solver": solver,
        "summary": {
            # Obs. 5 headline: night launches preempt less (< 1).  Makespan
            # need not follow — night failures arrive later in a VM's life,
            # so each failed attempt wastes more wall-clock; both ratios are
            # recorded so the trade-off is visible across PRs.
            "night_over_day_fail_prob": night_pf / day_pf,
            "night_over_day_makespan": night_mk / day_mk,
            "night_over_day_failure_rate":
                night_fr / day_fr if day_fr else float("nan"),
            "cost_reduction_mean": red,
            "one_kernel_combined_speedup":
                ps["combined_speedup_vs_pr3"],
            "batched_combined_speedup": bvs["combined_speedup"],
            "solver_ctf_speedup": solver["speedup"]},
    }
    write_bench_json("BENCH_scenarios.json", payload, emit_as="scenarios/json")


def _bench_batch_vs_serial(dist_list, *, job_steps, n_trials, grid_dt,
                           max_restarts, seeds) -> dict:
    """Warm-timed comparison of the per-scenario setup work the batched
    scenario axis replaced in PR 3: the DP solves and the lifetime-pool
    draws (schema-2 continuity block)."""
    S = len(dist_list)
    # warm both compile caches at the measured shapes
    ckpt.solve(dist_list[0], job_steps, grid_dt=grid_dt)
    ckpt.solve_batch(dist_list, job_steps, grid_dt=grid_dt)
    E.draw_lifetime_pool(ckpt.model_lifetimes_fn(dist_list[0]), n_trials,
                         max_restarts=max_restarts, seed=seeds[0])
    E.draw_lifetime_pool_batch(dist_list, n_trials,
                               max_restarts=max_restarts, seed=seeds[0])

    t0 = time.perf_counter()
    serial_tabs = [ckpt.solve(d, job_steps, grid_dt=grid_dt)
                   for d in dist_list]
    t_solver_serial = time.perf_counter() - t0
    t0 = time.perf_counter()
    batch_tabs = ckpt.solve_batch(dist_list, job_steps, grid_dt=grid_dt)
    t_solver_batched = time.perf_counter() - t0
    bitexact = all(
        np.array_equal(serial_tabs[s].V, batch_tabs.V[s])
        and np.array_equal(serial_tabs[s].K, batch_tabs.K[s])
        for s in range(S))

    t0 = time.perf_counter()
    for seed in seeds:
        for d in dist_list:
            E.draw_lifetime_pool(ckpt.model_lifetimes_fn(d), n_trials,
                                 max_restarts=max_restarts, seed=seed)
    t_pool_serial = time.perf_counter() - t0
    t0 = time.perf_counter()
    for seed in seeds:
        E.draw_lifetime_pool_batch(dist_list, n_trials,
                                   max_restarts=max_restarts, seed=seed)
    t_pool_batched = time.perf_counter() - t0

    return {
        "n_scenarios": S,
        "solver": {"serial_s": t_solver_serial,
                   "batched_s": t_solver_batched,
                   "speedup": t_solver_serial / t_solver_batched},
        "pool": {"serial_s": t_pool_serial, "batched_s": t_pool_batched,
                 "speedup": t_pool_serial / t_pool_batched},
        "combined_speedup": (t_solver_serial + t_pool_serial)
                            / (t_solver_batched + t_pool_batched),
        "dp_values_bitexact": bool(bitexact),
    }


if __name__ == "__main__":
    import sys

    run(quick="--quick" in sys.argv)
