"""Diurnal scenario-sweep benchmark (paper Obs. 5 x Figs. 7/8 workloads).

Expands the default (diurnal phase x VM type) scenario grid from
``repro.core.scenarios`` over both vectorized evaluation paths:

  * the checkpointing executor — (scenario x policy x seed) cells, one DP
    solve + one shared device lifetime pool per (scenario, seed);
  * the batch service — (scenario x policy x cluster x seed) cells, one
    jitted ReuseTable grid call per scenario.

Besides the CSV rows, writes machine-readable ``BENCH_scenarios.json`` at
the repo root so the perf/quality trajectory extends beyond the single
static Fig. 7/8 workloads:

    {"schema": 1, "mode": "full"|"quick", "generated_unix": ...,
     "grid": {"phases": [...], "vm_types": [...],
              "checkpoint_policies": [...], "service_policies": [...],
              "seeds": [...]},
     "checkpointing": {"workload": {...}, "wall_clock_s": ...,
                       "rows": [...per-cell makespan stats...]},
     "service": {"workload": {...}, "wall_clock_s": ...,
                 "rows": [...per-cell cost/failure stats...]},
     "summary": {"night_over_day_fail_prob": ...,
                 "night_over_day_makespan": ...,
                 "night_over_day_failure_rate": ...,
                 "cost_reduction_mean": ...}}

``--quick`` (or run(quick=True)) shrinks trials/jobs so the module finishes
in seconds; the JSON records which mode produced it.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import scenarios as SC

from .common import emit, write_bench_json

PHASES = ("day", "night")
VM_TYPES = ("n1-highcpu-16", "n1-highcpu-32")
CKPT_POLICIES = ("dp", "young_daly", "none")
SERVICE_POLICIES = ("model", "memoryless")


def _phase_mean(rows, phase, key, **match):
    vals = [r[key] for r in rows
            if r["phase"] == phase and not np.isnan(r[key])
            and all(r[k] == v for k, v in match.items())]
    return float(np.mean(vals)) if vals else float("nan")


def run(quick: bool = False):
    grid = SC.default_grid(vm_types=VM_TYPES, phases=PHASES)
    seeds = (0,) if quick else (0, 1)

    ck_workload = dict(job_steps=180 if quick else 300,
                       n_trials=300 if quick else 2000,
                       grid_dt=1.0 / 60.0, delta_steps=1, max_restarts=64)
    job_steps, n_trials = ck_workload["job_steps"], ck_workload["n_trials"]
    t0 = time.perf_counter()
    ck_rows = SC.sweep_checkpointing(grid, policies=CKPT_POLICIES,
                                     seeds=seeds, **ck_workload)
    t_ck = time.perf_counter() - t0
    emit(f"scenarios/ckpt_{len(ck_rows)}cells_J{job_steps}_n{n_trials}",
         t_ck / len(ck_rows) * 1e6,
         f"wall_s={t_ck:.2f};"
         f"day_dp={_phase_mean(ck_rows, 'day', 'makespan_mean', policy='dp'):.3f}h;"
         f"night_dp={_phase_mean(ck_rows, 'night', 'makespan_mean', policy='dp'):.3f}h")

    n_jobs = 20 if quick else 60
    cluster_sizes = (8,) if quick else (16,)
    t0 = time.perf_counter()
    sv_rows = SC.sweep_service(grid, policies=SERVICE_POLICIES,
                               cluster_sizes=cluster_sizes, seeds=seeds,
                               n_jobs=n_jobs, job_hours=2.0)
    t_sv = time.perf_counter() - t0
    red = float(np.mean([r["cost_reduction"] for r in sv_rows
                         if r["policy"] == "model"]))
    emit(f"scenarios/service_{len(sv_rows)}cells_n{n_jobs}",
         t_sv / len(sv_rows) * 1e6,
         f"wall_s={t_sv:.2f};reduction={red:.2f}x")

    day_mk = _phase_mean(ck_rows, "day", "makespan_mean", policy="dp")
    night_mk = _phase_mean(ck_rows, "night", "makespan_mean", policy="dp")
    day_pf = _phase_mean(ck_rows, "day", "p_fail_fresh", policy="dp")
    night_pf = _phase_mean(ck_rows, "night", "p_fail_fresh", policy="dp")
    day_fr = _phase_mean(sv_rows, "day", "job_failure_rate", policy="model")
    night_fr = _phase_mean(sv_rows, "night", "job_failure_rate",
                           policy="model")
    payload = {
        "schema": 1,
        "mode": "quick" if quick else "full",
        "generated_unix": time.time(),
        "grid": {"phases": list(PHASES), "vm_types": list(VM_TYPES),
                 "checkpoint_policies": list(CKPT_POLICIES),
                 "service_policies": list(SERVICE_POLICIES),
                 "seeds": list(seeds)},
        "checkpointing": {
            "workload": dict(ck_workload),
            "wall_clock_s": t_ck, "rows": ck_rows},
        "service": {
            "workload": {"n_jobs": n_jobs, "job_hours": 2.0,
                         "cluster_sizes": list(cluster_sizes)},
            "wall_clock_s": t_sv, "rows": sv_rows},
        "summary": {
            # Obs. 5 headline: night launches preempt less (< 1).  Makespan
            # need not follow — night failures arrive later in a VM's life,
            # so each failed attempt wastes more wall-clock; both ratios are
            # recorded so the trade-off is visible across PRs.
            "night_over_day_fail_prob": night_pf / day_pf,
            "night_over_day_makespan": night_mk / day_mk,
            "night_over_day_failure_rate":
                night_fr / day_fr if day_fr else float("nan"),
            "cost_reduction_mean": red},
    }
    write_bench_json("BENCH_scenarios.json", payload, emit_as="scenarios/json")


if __name__ == "__main__":
    import sys

    run(quick="--quick" in sys.argv)
