"""Paper Fig. 5: wasted computation (a) and expected running-time increase
(b) for uniform vs bathtub constrained preemptions, over job lengths."""
from __future__ import annotations

import numpy as np

from repro.core import distributions as D
from repro.core.policies import scheduling as S

from .common import emit, timed


def run():
    bath = D.constrained_for("n1-highcpu-16")
    uni = D.Uniform()
    jobs = [1, 2, 5, 10, 15, 20]
    _, us = timed(lambda: [float(S.expected_wasted_work(bath, t))
                           for t in jobs])
    for T in jobs:
        wb = float(S.expected_wasted_work(bath, T))
        wu = float(S.expected_wasted_work(uni, T))
        emit(f"fig5a/wasted_work_T{T}h", us / len(jobs),
             f"bathtub={wb:.2f}h;uniform={wu:.2f}h")
    for T in jobs:
        ib = float(S.expected_runtime_increase(bath, T))
        iu = float(S.expected_runtime_increase(uni, T))
        emit(f"fig5b/runtime_increase_T{T}h", 0.0,
             f"bathtub={ib*60:.0f}min;uniform={iu*60:.0f}min")
    # the paper's two headline anchors
    i10 = float(S.expected_runtime_increase(bath, 10.0)) * 60
    u10 = float(S.expected_runtime_increase(uni, 10.0)) * 60
    emit("fig5b/10h_job_anchor", 0.0,
         f"bathtub={i10:.0f}min(paper~30min);uniform={u10:.0f}min(paper~120min)")
    diffs = [(T, float(S.expected_runtime_increase(bath, T))
              - float(S.expected_runtime_increase(uni, T)))
             for T in np.arange(1.0, 10.0, 0.25)]
    cross = next((T for T, d in diffs if d < 0), None)
    emit("fig5b/crossover", 0.0, f"hours={cross}(paper~5h)")


if __name__ == "__main__":
    run()
