"""Simulation-engine benchmark: Python reference loops vs the vectorized JAX
Monte-Carlo engine (repro.core.engine).

Covers the three hot paths the engine replaces:
  * the Fig. 7 checkpointing executor (DP policy, 720-step job, >=5000
    trials) - Python per-trial loop vs the batched lax.while_loop kernel on
    a SHARED pre-drawn lifetime pool, so the comparison is pure execution;
  * the Fig. 8 batch service - exact per-candidate reuse dispatches vs the
    precomputed reuse-decision table, plus a (policy x seed) grid sweep;
  * fleet-trace generation - grouped per-type batched sampling.

Besides the usual CSV rows, writes a machine-readable ``BENCH_simulation.json``
at the repo root so the perf trajectory can be diffed across PRs:

    {"schema": 2, "mode": "full"|"quick",
     "checkpointing_executor": {"workload": {...}, "python_reference_s": ...,
                                "vectorized_s": ..., "speedup": ...,
                                "mean_makespan_python": ...,
                                "mean_makespan_vectorized": ...},
     "batch_service": {"exact_reuse_s": ..., "table_reuse_s": ...,
                       "grid_cells": ..., "grid_s": ..., "per_cell_s": ...,
                       "cost_reduction_mean": ...},
     "fleet_trace": {"n_vms": ..., "warm_s": ...},
     "service_kernel": {"fig8": {...}, "scale": {...},
                        "one_dispatch": {...}}}

Schema 2 adds the ``service_kernel`` block (measured by
``benchmarks.service_bench``, which can also refresh just that block via
``--only service``).

``--quick`` (or run(quick=True)) shrinks the workload so the module finishes
in seconds; the JSON records which mode produced it.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import distributions as D
from repro.core import engine as E
from repro.core import service as SV
from repro.core import simulator as SIM
from repro.core.policies import checkpointing as C

from .common import emit, write_bench_json


def _bench_executor(quick: bool) -> dict:
    dist = D.constrained_for("n1-highcpu-16")
    job_steps = 240 if quick else 720
    n_trials = 1000 if quick else 5000
    tables = C.solve(dist, job_steps, grid_dt=1.0 / 60.0, delta_steps=1,
                     n_sweeps=3)
    lf = C.model_lifetimes_fn(dist)
    first, pool = E.draw_lifetime_pool(lf, n_trials, seed=0)
    table = E.dp_policy_table(tables)

    t0 = time.perf_counter()
    ref = C.simulate_makespan(C.dp_policy_fn(tables), lf, job_steps,
                              pool=pool, first=first)
    t_py = time.perf_counter() - t0

    kw = dict(first=first, pool=pool, grid_dt=1.0 / 60.0, delta_steps=1)
    E.simulate_makespan_batch(table, job_steps, **kw)      # compile warm-up
    t_vec, vec = np.inf, None
    for _ in range(3):
        t0 = time.perf_counter()
        vec = E.simulate_makespan_batch(table, job_steps, **kw)
        t_vec = min(t_vec, time.perf_counter() - t0)

    speedup = t_py / t_vec
    emit(f"sim_engine/fig7_dp_J{job_steps}_n{n_trials}", t_vec * 1e6,
         f"python_s={t_py:.3f};speedup={speedup:.0f}x;"
         f"mean_py={ref.mean():.4f}h;mean_vec={vec.mean():.4f}h")
    return dict(
        workload=dict(policy="dp", job_steps=job_steps, n_trials=n_trials,
                      grid_dt=1.0 / 60.0, delta_steps=1, max_restarts=64,
                      seed=0),
        python_reference_s=t_py, vectorized_s=t_vec, speedup=speedup,
        mean_makespan_python=float(ref.mean()),
        mean_makespan_vectorized=float(vec.mean()))


def _bench_service(quick: bool) -> dict:
    dist = D.constrained_for("n1-highcpu-32")
    n_jobs = 40 if quick else 100
    seeds = range(2 if quick else 6)
    kw = dict(n_jobs=n_jobs, job_hours=2.0, cluster_size=32)

    # warm both variants first so neither timing absorbs one-time jit
    # compiles (reuse_decision, the sampler's icdf) the other then reuses
    SV.run_bag(dist, seed=0, vectorized_reuse=False, **kw)
    SV.run_bag(dist, seed=0, **kw)
    t0 = time.perf_counter()
    SV.run_bag(dist, seed=0, vectorized_reuse=False, **kw)
    t_exact = time.perf_counter() - t0
    t0 = time.perf_counter()
    SV.run_bag(dist, seed=0, **kw)
    t_table = time.perf_counter() - t0

    t0 = time.perf_counter()
    rows = SV.run_bag_grid(vm_types=("n1-highcpu-32",),
                           policies=("model", "memoryless"),
                           cluster_sizes=(32,), seeds=seeds, n_jobs=n_jobs,
                           job_hours=2.0)
    t_grid = time.perf_counter() - t0
    red = float(np.mean([r["result"].cost_reduction for r in rows
                         if r["policy"] == "model"]))
    emit(f"sim_engine/service_bag_n{n_jobs}", t_table * 1e6,
         f"exact_s={t_exact:.3f};table_s={t_table:.3f};"
         f"grid{len(rows)}cells_s={t_grid:.3f};reduction={red:.2f}x")
    return dict(exact_reuse_s=t_exact, table_reuse_s=t_table,
                grid_cells=len(rows), grid_s=t_grid,
                per_cell_s=t_grid / len(rows), cost_reduction_mean=red)


def _bench_fleet(quick: bool) -> dict:
    n_vms = 300 if quick else 1516
    SIM.generate_fleet_trace(jax.random.PRNGKey(0), n_vms=n_vms)  # warm-up
    t0 = time.perf_counter()
    SIM.generate_fleet_trace(jax.random.PRNGKey(1), n_vms=n_vms)
    t_warm = time.perf_counter() - t0
    emit(f"sim_engine/fleet_trace_{n_vms}", t_warm * 1e6, "grouped_by_type")
    return dict(n_vms=n_vms, warm_s=t_warm)


def run(quick: bool = False):
    from . import service_bench

    payload = {
        "schema": 2,
        "mode": "quick" if quick else "full",
        "generated_unix": time.time(),
        "checkpointing_executor": _bench_executor(quick),
        "batch_service": _bench_service(quick),
        "fleet_trace": _bench_fleet(quick),
        "service_kernel": service_bench.bench_block(quick),
    }
    write_bench_json("BENCH_simulation.json", payload,
                     emit_as="sim_engine/json")


if __name__ == "__main__":
    import sys

    run(quick="--quick" in sys.argv)
