"""DP solver backend benchmark (``--only solver``).

Times the pluggable ``checkpointing.solve_batch`` backends on the standard
S=8 scenario grid: the plain XLA production solve against the coarse-to-fine
refinement (``refine=True`` — coarse hint solve, cone/cap-pruned pre-sweeps,
one full-resolution sweep), verifying bit-agreement alongside the timings.
The measurement doubles as the ``"solver"`` block of ``BENCH_scenarios.json``
schema 4 (``scenario_sweep`` embeds :func:`measure`), which is where the
cross-PR >= 2x solver wall-clock criterion is recorded.

Timings are warm (post-compile): the sweep regime this matters for re-solves
the same workload shape on every market refit, so compile cost amortizes
away; ``solve_compile_s`` records it separately.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import scenarios as SC
from repro.core.policies import checkpointing as ckpt

from .common import emit

REPS = 3


def measure(dist_list, *, job_steps: int, grid_dt: float,
            n_sweeps: int = 3) -> dict:
    """The schema-4 ``"solver"`` block: plain-vs-refined wall clock (warm,
    best of ``REPS``), verification state and bit-agreement."""
    S = len(dist_list)

    t0 = time.perf_counter()
    plain = ckpt.solve_batch(dist_list, job_steps, grid_dt=grid_dt,
                             n_sweeps=n_sweeps)
    compile_s = time.perf_counter() - t0
    refined = ckpt.solve_batch(dist_list, job_steps, grid_dt=grid_dt,
                               n_sweeps=n_sweeps, refine=True)

    def best(run):
        times = []
        for _ in range(REPS):
            t0 = time.perf_counter()
            out = run()
            times.append(time.perf_counter() - t0)
        return out, min(times)

    plain, plain_s = best(lambda: ckpt.solve_batch(
        dist_list, job_steps, grid_dt=grid_dt, n_sweeps=n_sweeps))
    refined, refine_s = best(lambda: ckpt.solve_batch(
        dist_list, job_steps, grid_dt=grid_dt, n_sweeps=n_sweeps,
        refine=True))

    info = refined.refine_info or {}
    return {
        "n_scenarios": S,
        "workload": {"job_steps": job_steps, "grid_dt": grid_dt,
                     "n_sweeps": n_sweeps},
        "xla_s": plain_s,
        "refine_s": refine_s,
        "speedup": plain_s / refine_s,
        "solve_compile_s": compile_s,
        "refine_info": {k: info.get(k) for k in
                        ("applied", "verified_col0", "fallback", "factor",
                         "radius", "caps")},
        "bit_identical_to_plain": bool(
            np.array_equal(plain.V, refined.V)
            and np.array_equal(plain.K, refined.K)),
    }


def run(quick: bool = False):
    grid = SC.default_grid()
    dist_list = [sc.dist() for sc in grid]
    job_steps = 120 if quick else 300
    block = measure(dist_list, job_steps=job_steps, grid_dt=1.0 / 60.0)
    emit(f"solver/ctf_S{len(dist_list)}_J{job_steps}",
         block["refine_s"] / len(dist_list) * 1e6,
         f"xla_s={block['xla_s']:.2f};refine_s={block['refine_s']:.2f};"
         f"speedup={block['speedup']:.2f}x;"
         f"verified={block['refine_info']['verified_col0']};"
         f"fallback={block['refine_info']['fallback']};"
         f"bitexact={block['bit_identical_to_plain']}")


if __name__ == "__main__":
    import sys

    run(quick="--quick" in sys.argv)
