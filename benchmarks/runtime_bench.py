"""Closed-loop runtime benchmark: adaptation lag vs stale-table regret.

Runs ``repro.core.runtime.FleetRuntime`` against the deterministic default
fault schedule (drift regime switch + injected fit divergences + one solve
timeout + a preemption storm, all seeded) at several refit cadences, and
records per cadence:

* ``adaptation_lag_obs`` — observations between the injected drift and the
  table swap that answered it (detection + retries + solve);
* ``regret_hours`` / ``regret_frac`` — the paired stale-vs-fresh makespan
  gap at that swap (same lifetime pool, displaced K vs fresh K);
* staleness, retry and fault counters.

The (lag, regret) rows trace the operational trade-off the paper's
Discussion gestures at but never measures: refit more often and you adapt
faster but burn more solves; refit rarely and the fleet serves a stale
schedule for longer, paying `regret x lag` in makespan.  Results land in
``BENCH_runtime.json`` (schema in ``docs/bench_schemas.md``).
"""
from __future__ import annotations

import time

from repro import fault
from repro.core import runtime as rt

from .common import emit, timed, write_bench_json

SCHEMA = 1


def _run_one(refit_every: int, *, n_obs: int, quick: bool) -> dict:
    cfg = rt.RuntimeConfig(
        job_steps=40, grid_dt=0.25, window=4 * refit_every,
        refit_every=refit_every, min_samples=48,
        stream_block=128, stream_vm_types=("n1-highcpu-2",),
        regret_trials=64 if quick else 256,
        retry_backoff_obs=max(refit_every // 4, 4), max_retries=3)
    inj = fault.FaultInjector(fault.default_schedule(n_obs), seed=0)
    runtime = rt.FleetRuntime(cfg, injector=inj)
    t0 = time.perf_counter()
    rep = runtime.run(n_obs)
    wall_s = time.perf_counter() - t0
    swaps = [s for s in rep.swaps if s.reason == "change-point"]
    return {
        "refit_every": refit_every,
        "n_obs": rep.n_obs,
        "n_refits": rep.n_refits,
        "change_points": rep.change_points,
        "n_swaps": len(rep.swaps),
        "adaptation_lag_obs": rep.adaptation_lag_obs,
        "regret_hours": rep.regret_hours,
        "regret_frac": rep.regret_frac,
        "stale_obs_total": rep.stale_obs_total,
        "fit_retries": rep.retries["fit"],
        "solve_retries": rep.retries["solve"],
        "degraded_at_end": rep.degraded,
        "warm_swaps": sum(1 for s in rep.swaps if s.warm),
        "mean_solve_seconds": (sum(s.solve_seconds for s in swaps)
                               / len(swaps) if swaps else None),
        "wall_seconds": round(wall_s, 3),
    }


def run(quick: bool = False) -> None:
    n_obs = 400 if quick else 1200
    cadences = (32, 64) if quick else (32, 64, 128)
    rows = []
    for refit_every in cadences:
        row, us = timed(_run_one, refit_every, n_obs=n_obs, quick=quick)
        rows.append(row)
        lag = row["adaptation_lag_obs"]
        reg = row["regret_frac"]
        emit(f"runtime/refit_every={refit_every}", us,
             f"lag={lag} regret_frac="
             f"{'None' if reg is None else f'{reg:.4f}'}")
    payload = {
        "schema": SCHEMA,
        "mode": "quick" if quick else "full",
        "quick": bool(quick),
        "n_obs": n_obs,
        "fault_schedule": [
            {"kind": e.kind, "at_obs": e.at_obs, "duration": e.duration,
             "param": {} if e.param is None
             else {k: list(v) if isinstance(v, tuple) else v
                   for k, v in e.param.items()}}
            for e in fault.default_schedule(n_obs)],
        "rows": rows,
    }
    write_bench_json("BENCH_runtime.json", payload, emit_as="runtime/json")


if __name__ == "__main__":
    run()
