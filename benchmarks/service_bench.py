"""Service-kernel benchmark: the Python heap event loop vs the batched
event-synchronous JAX kernel (repro.core.service_kernel).

Three blocks, written into ``BENCH_simulation.json`` under
``"service_kernel"`` (payload schema 2 — see docs/bench_schemas.md):

  * ``fig8`` — the paper's Fig. 8 workload (100-job bags, cluster 32,
    model + memoryless policies over seeds): wall-clock for the whole grid
    through ``run_bag_grid`` in both modes, plus the number of rows that
    are bit-identical when the comparison is repeated under x64;
  * ``scale`` — the kernel's design point (10^4-job bags, where the serial
    loop's per-event O(J) bookkeeping dominates): events/sec measured
    directly for ONE serial lane and for a 50-lane kernel dispatch of the
    same workload, and their ratio (the headline speedup);
  * ``one_dispatch`` — a >=10^5-job batch completing in ONE jitted
    dispatch: jobs/sec, events/sec and the step count.

Serial event counts are taken from the kernel lane that replays the same
(bag, pool, policy) — the trajectories are identical by construction (and
bit-identical under x64; see tests/test_service_kernel.py), and the serial
loop does not count events itself.

``run(quick=True)`` shrinks every block (2,000-job bags, fewer seeds) so a
CI smoke pass finishes in tens of seconds; standalone runs (``--only
service``) update only the ``service_kernel`` block of an existing
``BENCH_simulation.json`` and leave the sibling blocks in place.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import distributions as D
from repro.core import service as SV
from repro.core import service_kernel as K

from .common import REPO_ROOT, emit, write_bench_json

VM_TYPE = "n1-highcpu-32"


def _grid_kw(quick: bool) -> dict:
    return dict(vm_types=(VM_TYPE,),
                policies=("model", "memoryless"),
                cluster_sizes=(32,),
                seeds=tuple(range(2 if quick else 10)),
                n_jobs=40 if quick else 100,
                job_hours=2.0)


def _rows_identical(rows_a, rows_b) -> int:
    n = 0
    for a, b in zip(rows_a, rows_b):
        x, y = a["result"], b["result"]
        n += (x.makespan == y.makespan and x.vm_hours == y.vm_hours
              and x.n_preemptions == y.n_preemptions
              and x.n_job_failures == y.n_job_failures)
    return n


def _bench_fig8(quick: bool) -> dict:
    kw = _grid_kw(quick)
    SV.run_bag_grid(mode="batched", **kw)  # jit warm-up
    t0 = time.perf_counter()
    rows_s = SV.run_bag_grid(mode="serial", **kw)
    t_serial = time.perf_counter() - t0
    t0 = time.perf_counter()
    rows_b = SV.run_bag_grid(mode="batched", **kw)
    t_batched = time.perf_counter() - t0

    # repeat the comparison under x64, where the contract is bit-identity
    from jax.experimental import enable_x64
    with enable_x64():
        bitexact = _rows_identical(SV.run_bag_grid(mode="serial", **kw),
                                   SV.run_bag_grid(mode="batched", **kw))
    emit(f"service/fig8_grid_n{kw['n_jobs']}", t_batched * 1e6,
         f"serial_s={t_serial:.3f};batched_s={t_batched:.3f};"
         f"speedup={t_serial / t_batched:.1f}x;"
         f"bitexact_x64={bitexact}/{len(rows_s)}")
    return dict(n_jobs=kw["n_jobs"], grid_rows=len(rows_s),
                serial_s=t_serial, batched_s=t_batched,
                speedup_wall=t_serial / t_batched,
                rows_bitexact_x64=bitexact)


def _kernel_dispatch(n_jobs: int, lanes: int, n_bags: int,
                     pool_size: int) -> tuple:
    """Warm up then time one B-lane memoryless dispatch; returns timings."""
    dist = D.constrained_for(VM_TYPE)
    seeds = list(range(n_bags))
    bags = np.stack([SV._bag_lengths(n_jobs, 2.0, 0.1, s) for s in seeds])
    pools = K.draw_service_pool_batch([dist] * n_bags, seeds, size=pool_size)
    kw = dict(lengths=bags, pools=pools,
              bag_index=[i % n_bags for i in range(lanes)],
              pool_index=[i % n_bags for i in range(lanes)],
              policy=["memoryless"] * lanes, cluster_size=[32] * lanes)
    K.simulate_service_batch(**kw)  # compile warm-up
    t0 = time.perf_counter()
    res = K.simulate_service_batch(**kw)
    return res, time.perf_counter() - t0


def _bench_scale(quick: bool) -> dict:
    n_jobs = 2_000 if quick else 10_000
    lanes = 50
    pool_size = 4 * n_jobs

    res, t_kernel = _kernel_dispatch(n_jobs, lanes, 2, pool_size)
    ev_kernel = int(res.n_events.sum())

    # ONE serial lane of the same workload (same bag, same pooled stream)
    t0 = time.perf_counter()
    SV.run_bag_grid(mode="serial", vm_types=(VM_TYPE,),
                    policies=("memoryless",), cluster_sizes=(32,),
                    seeds=(0,), n_jobs=n_jobs, job_hours=2.0,
                    pool_size=pool_size)
    t_serial = time.perf_counter() - t0
    ev_serial = int(res.n_events[0])  # lane 0 replays the serial trajectory

    eps_serial = ev_serial / t_serial
    eps_kernel = ev_kernel / t_kernel
    speedup = eps_kernel / eps_serial
    emit(f"service/scale_n{n_jobs}_B{lanes}", t_kernel * 1e6,
         f"serial_ev_s={eps_serial:.0f};kernel_ev_s={eps_kernel:.0f};"
         f"speedup_events_per_sec={speedup:.0f}x")
    return dict(
        n_jobs=n_jobs,
        serial=dict(events=ev_serial, wall_s=t_serial,
                    events_per_s=eps_serial),
        kernel=dict(lanes=lanes, jobs_total=lanes * n_jobs,
                    events=ev_kernel, wall_s=t_kernel,
                    events_per_s=eps_kernel,
                    jobs_per_s=lanes * n_jobs / t_kernel),
        speedup_events_per_sec=speedup)


def _bench_one_dispatch(quick: bool) -> dict:
    n_jobs = 2_000 if quick else 100_000
    lanes = 50 if quick else 10
    res, t = _kernel_dispatch(n_jobs, lanes, 2, 4 * n_jobs)
    jobs_total = lanes * n_jobs
    ev = int(res.n_events.sum())
    emit(f"service/one_dispatch_{jobs_total}jobs", t * 1e6,
         f"jobs_per_s={jobs_total / t:.0f};events_per_s={ev / t:.0f};"
         f"steps_max={int(res.steps.max())}")
    return dict(n_jobs_per_lane=n_jobs, lanes=lanes, jobs_total=jobs_total,
                events=ev, wall_s=t, events_per_s=ev / t,
                jobs_per_s=jobs_total / t, steps_max=int(res.steps.max()))


def bench_block(quick: bool = False) -> dict:
    """The ``service_kernel`` block embedded in ``BENCH_simulation.json``."""
    return {
        "fig8": _bench_fig8(quick),
        "scale": _bench_scale(quick),
        "one_dispatch": _bench_one_dispatch(quick),
    }


def run(quick: bool = False):
    block = bench_block(quick)
    # standalone runs patch the existing artifact in place so the sibling
    # blocks (written by sim_engine_bench) keep their numbers
    path = os.path.join(REPO_ROOT, "BENCH_simulation.json")
    payload = {"schema": 2, "mode": "quick" if quick else "full"}
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
        payload["schema"] = max(2, int(payload.get("schema", 0)))
    payload["service_kernel"] = block
    payload["generated_unix"] = time.time()
    write_bench_json("BENCH_simulation.json", payload,
                     emit_as="service/json")


if __name__ == "__main__":
    import sys

    run(quick="--quick" in sys.argv)
