"""Paper Fig. 1 + Fig. 3: model fit quality on the (synthetic-calibrated)
preemption trace - our constrained model vs exponential / Weibull /
Gompertz-Makeham, by LSE, KS statistic, and QQ tail error."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import fitting as F
from repro.core import simulator as S

from .common import emit, timed


def run():
    trace = S.trace_for(jax.random.PRNGKey(42), n=1516)
    fits, us = timed(F.fit_all, trace)
    ours = fits["constrained"]
    d = ours.dist
    emit("fig1/fit_constrained", us / 4,
         f"lse={float(ours.lse):.4f};tau1={float(d.tau1):.2f};"
         f"tau2={float(d.tau2):.2f};b={float(d.b):.2f};A={float(d.A):.3f}")
    for name in ("exponential", "weibull", "gompertz_makeham"):
        r = fits[name]
        ks = float(F.ks_statistic(r.dist, trace))
        emit(f"fig1/fit_{name}", 0.0,
             f"lse={float(r.lse):.3f};ks={ks:.4f};"
             f"lse_ratio_vs_ours={float(r.lse / ours.lse):.1f}x")
    ks_ours = float(F.ks_statistic(d, trace))
    emit("fig1/ks_ours", 0.0, f"ks={ks_ours:.4f}")
    # Fig. 3 (QQ): worst quantile error over the deadline tail
    for name in ("constrained", "weibull", "gompertz_makeham"):
        q, emp_q, mod_q = F.qq_points(fits[name].dist, trace)
        tail = np.max(np.abs(np.asarray(mod_q - emp_q))[80:])
        emit(f"fig3/qq_tail_err_{name}", 0.0, f"hours={tail:.2f}")
    # phase boundaries recovered by the fit
    t1, t2 = d.phases()
    emit("fig1/phases", 0.0, f"initial_end={float(t1):.1f}h;"
         f"deadline_start={float(t2):.1f}h")
    # Fig. 2a: per-VM-type fits (Obs. 4 - larger VMs preempt faster)
    for vm in ("n1-highcpu-2", "n1-highcpu-8", "n1-highcpu-32"):
        tr = S.trace_for(jax.random.PRNGKey(7), vm_type=vm, n=300)
        r = F.fit_samples("constrained", tr)
        emit(f"fig2a/{vm}", 0.0,
             f"tau1={float(r.dist.tau1):.2f};A={float(r.dist.A):.3f};"
             f"F3h={float(r.dist.cdf(3.0)):.3f}")
    # Fig. 2b: day vs night launches (Obs. 5)
    for label, clock in (("day", 12.0), ("night", 2.0)):
        tr = S.trace_for(jax.random.PRNGKey(8), launch_clock=clock, n=300)
        emit(f"fig2b/{label}", 0.0,
             f"median_life={float(np.median(np.asarray(tr))):.1f}h")


if __name__ == "__main__":
    run()
