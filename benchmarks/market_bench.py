"""Spot-market benchmark: dollar-denominated policy evaluation.

Runs ``scenarios.sweep_market`` over the default (zone x phase x vm_type)
grid under both market regimes — calm, and a capacity crunch scheduled on
the tight zone — and records what each cost policy (fixed / cheapest /
migrate) actually pays, in dollars, against the seeded OU price traces.
The DP tables are solved once per regime through
``scenarios.solve_market_tables`` and reused through ``tables=`` for every
policy/seed re-evaluation (the PR-4 whole-grid reuse contract).

``BENCH_market.json`` (repo root, see docs/bench_schemas.md) records::

    {"schema": 2, "mode": "full"|"quick", "generated_unix": ...,
     "grid": {...workload coordinates...},
     "wall_clock_s": ...,
     "expected_dollars": {regime: {policy: mean over scenario rows}},
     "crunch_vs_calm": {policy: crunch/calm expected-dollar ratio},
     "policy_vs_fixed_crunch": {policy: policy/fixed ratio on crunch rows},
     "dollar_dp_vs_makespan_dp": {regime: {"per_leaf": [...],
                                           "mean_ratio": ...}},
     "agreement": {"rows_bitexact_x64": ..., "x64_check_n_trials": ...},
     "acceptance": {"cost_aware_beats_fixed_crunch": ...,
                    "dollar_dp_beats_makespan_dp_crunch": ...},
     "rows": [...per (scenario x regime x policy x seed) row...]}

``agreement.rows_bitexact_x64`` re-runs a reduced sweep under x64 through
BOTH cost paths (the batched ``engine.accumulate_price_cost`` gather and
the serial ``market.integrate_cost_ref`` loop) and asserts every row's
dollars match bit-for-bit — the acceptance criterion that the batched cost
rows are x64 bit-identical to the serial reference.

``dollar_dp_vs_makespan_dp`` solves each regime's tables twice — once per
objective — and compares the two K policies IN THE SAME CURRENCY through
``checkpointing.evaluate_policy_dollars`` (the float64 model-based
evaluator: no Monte-Carlo noise, so the comparison is exact up to the
solver's float32 argmin slack).  ``ratio`` is dollar-DP / makespan-DP
expected dollars for a fresh full job; the acceptance flag
``dollar_dp_beats_makespan_dp_crunch`` requires ratio <= 1 + 1e-6 on every
crunch-scheduled leaf — the dollar DP may never pay MORE than the makespan
DP under the model both were given.
"""
from __future__ import annotations

import time

import numpy as np
from jax.experimental import enable_x64

from repro.core import market as M
from repro.core import scenarios as SC
from repro.core.policies import checkpointing as ckpt

from .common import emit, write_bench_json

REGIMES = ("calm", "crunch")
POLICIES = ("fixed", "cheapest", "migrate")


def _mean(vals):
    vals = [v for v in vals if v == v]      # drop NaN
    return sum(vals) / len(vals) if vals else float("nan")


def _aggregate(rows):
    by = {}
    for r in rows:
        by.setdefault((r["regime"], r["policy"]), []).append(
            r["expected_dollars"])
    return {reg: {pol: _mean(by.get((reg, pol), []))
                  for pol in POLICIES} for reg in REGIMES}


def run(quick: bool = False) -> dict:
    job_steps = 60 if quick else 300
    n_trials = 60 if quick else 400
    seeds = (0,) if quick else (0, 1)
    scs = SC.default_grid()
    market = M.MarketModel.for_scenarios(scs)

    t0 = time.perf_counter()
    tables = SC.solve_market_tables(scs, market, regimes=REGIMES,
                                    job_steps=job_steps)
    solve_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    rows = SC.sweep_market(scs, market=market, regimes=REGIMES,
                           policies=POLICIES, seeds=seeds,
                           job_steps=job_steps, n_trials=n_trials,
                           tables=tables)
    sweep_s = time.perf_counter() - t0

    agg = _aggregate(rows)
    crunch_vs_calm = {pol: (agg["crunch"][pol] / agg["calm"][pol]
                            if agg["calm"][pol] else float("nan"))
                      for pol in POLICIES}
    vs_fixed = {pol: (agg["crunch"][pol] / agg["crunch"]["fixed"]
                      if agg["crunch"]["fixed"] else float("nan"))
                for pol in POLICIES}

    # the acceptance criterion: on every scenario leaf that actually has a
    # crunch scheduled, the cost-aware policy pays less than fixed
    fixed_d = {(r["scenario"], r["seed"]): r["expected_dollars"]
               for r in rows if r["regime"] == "crunch"
               and r["policy"] == "fixed" and r["crunch"]}
    cheap_d = {(r["scenario"], r["seed"]): r["expected_dollars"]
               for r in rows if r["regime"] == "crunch"
               and r["policy"] == "cheapest" and r["crunch"]}
    beats = bool(fixed_d) and all(cheap_d[k] < fixed_d[k] for k in fixed_d)

    # dollar-DP vs makespan-DP: solve each regime under both objectives and
    # price BOTH K policies through the float64 model-based evaluator — same
    # currency, same model, no Monte-Carlo noise
    t0 = time.perf_counter()
    tables_d = SC.solve_market_tables(scs, market, regimes=REGIMES,
                                      job_steps=job_steps,
                                      dp_objective="dollars")
    dollar_solve_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    grid0 = market.grid()
    crunched = [float(np.float64(p.crunch_t1)) > float(np.float64(p.crunch_t0))
                for p in market.processes]
    ev_kw = dict(grid_dt=1.0 / 60.0, delta_steps=1, n_sweeps=3,
                 restart_overhead=0.0)
    ddp = {}
    crunch_ok = []
    for regime in REGIMES:
        t_launch = market.launch_time(regime)
        dists = market.crunch_dists(scs, t_launch)
        g = grid0.shift(t_launch)
        ev_mk = ckpt.evaluate_policy_dollars(
            np.asarray(tables[regime].K), dists, g, **ev_kw)
        ev_d = ckpt.evaluate_policy_dollars(
            np.asarray(tables_d[regime].K), dists, g, **ev_kw)
        leaves = []
        for s, sc in enumerate(scs):
            mk_d = float(ev_mk[s, job_steps, 0])
            dl_d = float(ev_d[s, job_steps, 0])
            on = regime == "crunch" and crunched[s]
            leaves.append(dict(
                scenario=sc.name, crunch=on, makespan_dp_dollars=mk_d,
                dollar_dp_dollars=dl_d,
                ratio=dl_d / mk_d if mk_d else float("nan")))
            if on:
                crunch_ok.append(dl_d <= mk_d * (1.0 + 1e-6))
        ddp[regime] = dict(per_leaf=leaves,
                           mean_ratio=_mean([l["ratio"] for l in leaves]))
    ddp_beats = bool(crunch_ok) and all(crunch_ok)
    dollar_eval_s = time.perf_counter() - t0

    # x64 bit-identity: batched gather vs serial reference, row for row
    x64_trials = 40 if quick else 100
    with enable_x64():
        kw = dict(market=market, regimes=REGIMES, policies=POLICIES,
                  seeds=(0,), job_steps=min(job_steps, 120),
                  n_trials=x64_trials)
        rk = SC.sweep_market(scs, cost_path="kernel", **kw)
        rr = SC.sweep_market(scs, cost_path="reference", **kw)
    bitexact = all(
        a["expected_dollars"] == b["expected_dollars"]
        or (a["expected_dollars"] != a["expected_dollars"]
            and b["expected_dollars"] != b["expected_dollars"])
        for a, b in zip(rk, rr))

    payload = dict(
        schema=2,
        mode="quick" if quick else "full",
        generated_unix=int(time.time()),
        grid=dict(
            scenarios=[sc.name for sc in scs], regimes=list(REGIMES),
            policies=list(POLICIES), seeds=list(seeds),
            job_steps=job_steps, n_trials=n_trials,
            horizon_hours=market.horizon, price_dt=market.dt,
            market_seed=market.seed),
        wall_clock_s=dict(solve=solve_s, sweep=sweep_s,
                          dollar_solve=dollar_solve_s,
                          dollar_eval=dollar_eval_s),
        expected_dollars=agg,
        crunch_vs_calm=crunch_vs_calm,
        policy_vs_fixed_crunch=vs_fixed,
        dollar_dp_vs_makespan_dp=ddp,
        agreement=dict(rows_bitexact_x64=bitexact,
                       x64_check_n_trials=x64_trials),
        acceptance=dict(cost_aware_beats_fixed_crunch=beats,
                        dollar_dp_beats_makespan_dp_crunch=ddp_beats),
        rows=rows)
    write_bench_json("BENCH_market.json", payload, emit_as="market_json")
    emit("market_sweep", sweep_s * 1e6,
         f"cheapest/fixed_crunch={vs_fixed['cheapest']:.3f} "
         f"bitexact={bitexact} beats_fixed={beats}")
    emit("market_dollar_dp", dollar_eval_s * 1e6,
         f"crunch_ratio={ddp['crunch']['mean_ratio']:.4f} "
         f"dollar_dp_beats_makespan_dp={ddp_beats}")
    if not bitexact:
        raise AssertionError(
            "market dollars: batched gather diverged from the serial "
            "reference under x64")
    return payload
