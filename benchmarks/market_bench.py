"""Spot-market benchmark: dollar-denominated policy evaluation.

Runs ``scenarios.sweep_market`` over the default (zone x phase x vm_type)
grid under both market regimes — calm, and a capacity crunch scheduled on
the tight zone — and records what each cost policy (fixed / cheapest /
migrate) actually pays, in dollars, against the seeded OU price traces.
The DP tables are solved once per regime through
``scenarios.solve_market_tables`` and reused through ``tables=`` for every
policy/seed re-evaluation (the PR-4 whole-grid reuse contract).

``BENCH_market.json`` (repo root, see docs/bench_schemas.md) records::

    {"schema": 1, "mode": "full"|"quick", "generated_unix": ...,
     "grid": {...workload coordinates...},
     "wall_clock_s": ...,
     "expected_dollars": {regime: {policy: mean over scenario rows}},
     "crunch_vs_calm": {policy: crunch/calm expected-dollar ratio},
     "policy_vs_fixed_crunch": {policy: policy/fixed ratio on crunch rows},
     "agreement": {"rows_bitexact_x64": ..., "x64_check_n_trials": ...},
     "acceptance": {"cost_aware_beats_fixed_crunch": ...},
     "rows": [...per (scenario x regime x policy x seed) row...]}

``agreement.rows_bitexact_x64`` re-runs a reduced sweep under x64 through
BOTH cost paths (the batched ``engine.accumulate_price_cost`` gather and
the serial ``market.integrate_cost_ref`` loop) and asserts every row's
dollars match bit-for-bit — the acceptance criterion that the batched cost
rows are x64 bit-identical to the serial reference.
"""
from __future__ import annotations

import time

from jax.experimental import enable_x64

from repro.core import market as M
from repro.core import scenarios as SC

from .common import emit, write_bench_json

REGIMES = ("calm", "crunch")
POLICIES = ("fixed", "cheapest", "migrate")


def _mean(vals):
    vals = [v for v in vals if v == v]      # drop NaN
    return sum(vals) / len(vals) if vals else float("nan")


def _aggregate(rows):
    by = {}
    for r in rows:
        by.setdefault((r["regime"], r["policy"]), []).append(
            r["expected_dollars"])
    return {reg: {pol: _mean(by.get((reg, pol), []))
                  for pol in POLICIES} for reg in REGIMES}


def run(quick: bool = False) -> dict:
    job_steps = 60 if quick else 300
    n_trials = 60 if quick else 400
    seeds = (0,) if quick else (0, 1)
    scs = SC.default_grid()
    market = M.MarketModel.for_scenarios(scs)

    t0 = time.perf_counter()
    tables = SC.solve_market_tables(scs, market, regimes=REGIMES,
                                    job_steps=job_steps)
    solve_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    rows = SC.sweep_market(scs, market=market, regimes=REGIMES,
                           policies=POLICIES, seeds=seeds,
                           job_steps=job_steps, n_trials=n_trials,
                           tables=tables)
    sweep_s = time.perf_counter() - t0

    agg = _aggregate(rows)
    crunch_vs_calm = {pol: (agg["crunch"][pol] / agg["calm"][pol]
                            if agg["calm"][pol] else float("nan"))
                      for pol in POLICIES}
    vs_fixed = {pol: (agg["crunch"][pol] / agg["crunch"]["fixed"]
                      if agg["crunch"]["fixed"] else float("nan"))
                for pol in POLICIES}

    # the acceptance criterion: on every scenario leaf that actually has a
    # crunch scheduled, the cost-aware policy pays less than fixed
    fixed_d = {(r["scenario"], r["seed"]): r["expected_dollars"]
               for r in rows if r["regime"] == "crunch"
               and r["policy"] == "fixed" and r["crunch"]}
    cheap_d = {(r["scenario"], r["seed"]): r["expected_dollars"]
               for r in rows if r["regime"] == "crunch"
               and r["policy"] == "cheapest" and r["crunch"]}
    beats = bool(fixed_d) and all(cheap_d[k] < fixed_d[k] for k in fixed_d)

    # x64 bit-identity: batched gather vs serial reference, row for row
    x64_trials = 40 if quick else 100
    with enable_x64():
        kw = dict(market=market, regimes=REGIMES, policies=POLICIES,
                  seeds=(0,), job_steps=min(job_steps, 120),
                  n_trials=x64_trials)
        rk = SC.sweep_market(scs, cost_path="kernel", **kw)
        rr = SC.sweep_market(scs, cost_path="reference", **kw)
    bitexact = all(
        a["expected_dollars"] == b["expected_dollars"]
        or (a["expected_dollars"] != a["expected_dollars"]
            and b["expected_dollars"] != b["expected_dollars"])
        for a, b in zip(rk, rr))

    payload = dict(
        schema=1,
        mode="quick" if quick else "full",
        generated_unix=int(time.time()),
        grid=dict(
            scenarios=[sc.name for sc in scs], regimes=list(REGIMES),
            policies=list(POLICIES), seeds=list(seeds),
            job_steps=job_steps, n_trials=n_trials,
            horizon_hours=market.horizon, price_dt=market.dt,
            market_seed=market.seed),
        wall_clock_s=dict(solve=solve_s, sweep=sweep_s),
        expected_dollars=agg,
        crunch_vs_calm=crunch_vs_calm,
        policy_vs_fixed_crunch=vs_fixed,
        agreement=dict(rows_bitexact_x64=bitexact,
                       x64_check_n_trials=x64_trials),
        acceptance=dict(cost_aware_beats_fixed_crunch=beats),
        rows=rows)
    write_bench_json("BENCH_market.json", payload, emit_as="market_json")
    emit("market_sweep", sweep_s * 1e6,
         f"cheapest/fixed_crunch={vs_fixed['cheapest']:.3f} "
         f"bitexact={bitexact} beats_fixed={beats}")
    if not bitexact:
        raise AssertionError(
            "market dollars: batched gather diverged from the serial "
            "reference under x64")
    return payload
