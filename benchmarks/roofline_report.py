"""Render the §Dry-run / §Roofline tables for EXPERIMENTS.md from the JSON
records produced by ``repro.launch.dryrun``.

Analytic roofline terms are recomputed here from the current
``repro.analytics`` model (single source of truth), while compile/memory/
HLO-collective numbers come from the stored records.

Usage: PYTHONPATH=src python -m benchmarks.roofline_report results/dryrun_fsdp.json
"""
from __future__ import annotations

import json
import sys

from repro import analytics, configs
from repro.configs import SHAPES


def _fmt_t(sec: float) -> str:
    if sec <= 0:
        return "0"
    if sec < 1e-3:
        return f"{sec*1e6:.0f}us"
    if sec < 1.0:
        return f"{sec*1e3:.1f}ms"
    return f"{sec:.2f}s"


def render(path: str, mesh: str = "16x16") -> str:
    recs = json.load(open(path))
    rows = []
    header = ("| arch | shape | status | HBM/chip (arg+tmp) | t_compute | "
              "t_memory | t_collective | dominant | roofline | 6ND/HLO | "
              "compile |")
    sep = "|" + "---|" * 11
    rows.append(header)
    rows.append(sep)
    for r in recs:
        if r["mesh"] != mesh:
            continue
        arch, shp = r["arch"], r["shape"]
        if r["status"] == "skip":
            rows.append(f"| {arch} | {shp} | skip (full attention) "
                        "| - | - | - | - | - | - | - | - |")
            continue
        if r["status"] == "fail":
            rows.append(f"| {arch} | {shp} | **FAIL** | - | - | - | - | - "
                        "| - | - | - |")
            continue
        cfg = configs.get(arch)
        cost = analytics.cell_cost(
            cfg, SHAPES[shp], chips=r["chips"],
            pods=2 if r["mesh"] == "2x16x16" else 1, rules=r["rules"])
        roof = analytics.roofline(cost, chips=r["chips"])
        mem = r.get("memory", {})
        gb = (mem.get("argument_size_in_bytes", 0)
              + mem.get("temp_size_in_bytes", 0)) / 1e9
        hlo_coll = r.get("collectives", {}).get("total_bytes", 0) \
            / analytics.ICI_BW
        rows.append(
            f"| {arch} | {shp} | ok | {gb:.1f} GB "
            f"| {_fmt_t(roof['t_compute'])} | {_fmt_t(roof['t_memory'])} "
            f"| {_fmt_t(roof['t_collective'])} (hlo {_fmt_t(hlo_coll)}) "
            f"| {roof['dominant']} | {roof['roofline_fraction']*100:.0f}% "
            f"| {roof['model_flops_ratio']*100:.0f}% "
            f"| {r.get('compile_s', 0):.0f}s |")
    return "\n".join(rows)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_fsdp.json"
    mesh = sys.argv[2] if len(sys.argv) > 2 else "16x16"
    print(render(path, mesh))


if __name__ == "__main__":
    main()
