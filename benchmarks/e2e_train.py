"""End-to-end trainer benchmark: steps/sec on a reduced config and the cost
of the paper's fault-tolerance machinery (DP checkpoint scheduling +
preemption handling) vs a bare loop."""
from __future__ import annotations

import dataclasses

from repro import configs
from repro.configs.base import TrainConfig
from repro.launch.train import train

from .common import emit, timed


def run():
    cfg = dataclasses.replace(configs.smoke("smollm-135m"), n_layers=2,
                              d_model=32, d_ff=64, vocab_size=256)
    tc = TrainConfig(ckpt_dir="/tmp/repro_bench_ckpt_none",
                     ckpt_policy="none", warmup_steps=5)
    res, us = timed(train, cfg, tc, total_steps=40, verbose=False)
    emit("e2e/train_40steps_no_ft", us, f"final_loss={res.final_loss:.3f}")

    tc2 = TrainConfig(ckpt_dir="/tmp/repro_bench_ckpt_dp",
                      ckpt_policy="dp", warmup_steps=5)
    import shutil
    shutil.rmtree("/tmp/repro_bench_ckpt_dp", ignore_errors=True)
    res2, us2 = timed(train, cfg, tc2, total_steps=40,
                      inject_preemptions=True, sim_hours_per_step=0.3,
                      preemption_seed=3, verbose=False)
    emit("e2e/train_40steps_dp_preempted", us2,
         f"final_loss={res2.final_loss:.3f};restarts={res2.restarts};"
         f"ckpts={res2.checkpoints};ft_overhead={us2/us-1:.1%}")


if __name__ == "__main__":
    run()
