"""Paper Fig. 7: DP model-based checkpointing vs Young-Daly (MTTF=1h) vs no
checkpointing - expected running-time increase by start age (a) and job
length (b), via the Monte-Carlo executor."""
from __future__ import annotations

import numpy as np

from repro.core import distributions as D
from repro.core.policies import checkpointing as C
from repro.core.policies import young_daly as YD

from .common import emit, timed

GRID = 1.0 / 60.0


def run():
    dist = D.constrained_for("n1-highcpu-16")
    tables, us = timed(C.solve, dist, 720, grid_dt=GRID, delta_steps=1,
                       n_sweeps=3)
    emit("fig7/dp_solve_720x1440", us, "table=(721,1441);sweeps=3")

    sched = C.extract_schedule(tables, 300, 0)
    emit("fig7/dp_schedule_5h_age0", 0.0,
         "intervals_min=" + "/".join(map(str, sched))
         + "(paper 15/28/38/59/128)")
    lf = C.model_lifetimes_fn(dist)
    tau = float(YD.interval(GRID, 1.0))
    kw = dict(grid_dt=GRID, delta_steps=1, n_trials=600, seed=17)

    # Fig 7a: 4h job, varying start age
    for age in (0.0, 2.0, 6.0, 10.0, 15.0):
        dp = C.simulate_makespan(C.dp_policy_fn(tables), lf, 240,
                                 start_age=age, **kw).mean()
        yd = C.simulate_makespan(C.young_daly_policy_fn(tau, GRID), lf, 240,
                                 start_age=age, **kw).mean()
        emit(f"fig7a/overhead_age{age:g}h", 0.0,
             f"dp={100*(dp/4-1):.1f}%;young_daly={100*(yd/4-1):.1f}%")

    # Fig 7b: jobs from age 0, varying length
    for Th in (1, 2, 4, 6, 8):
        J = Th * 60
        dp = C.simulate_makespan(C.dp_policy_fn(tables), lf, J, **kw).mean()
        yd = C.simulate_makespan(C.young_daly_policy_fn(tau, GRID), lf, J,
                                 **kw).mean()
        none = C.simulate_makespan(C.no_checkpoint_policy_fn(), lf, J,
                                   **kw).mean()
        emit(f"fig7b/overhead_T{Th}h", 0.0,
             f"dp={100*(dp/Th-1):.1f}%;young_daly={100*(yd/Th-1):.1f}%;"
             f"none={100*(none/Th-1):.1f}%")

    yd_pred = YD.expected_overhead(GRID, 1.0, restart_overhead=2 / 60.0)
    emit("fig7/young_daly_model_predicted_overhead", 0.0,
         f"{100*yd_pred:.1f}%(paper>25%)")


if __name__ == "__main__":
    run()
