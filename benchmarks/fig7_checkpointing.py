"""Paper Fig. 7: DP model-based checkpointing vs Young-Daly (MTTF=1h) vs no
checkpointing - expected running-time increase by start age (a) and job
length (b), via the vectorized Monte-Carlo engine (repro.core.engine; same
seed => same lifetime draws as the retained Python reference executor)."""
from __future__ import annotations

import numpy as np

from repro.core import distributions as D
from repro.core import engine as E
from repro.core.policies import checkpointing as C
from repro.core.policies import young_daly as YD

from .common import emit, timed

GRID = 1.0 / 60.0
N_TRIALS = 600
SEED = 17


def run():
    dist = D.constrained_for("n1-highcpu-16")
    tables, us = timed(C.solve, dist, 720, grid_dt=GRID, delta_steps=1,
                       n_sweeps=3)
    emit("fig7/dp_solve_720x1440", us, "table=(721,1441);sweeps=3")

    sched = C.extract_schedule(tables, 300, 0)
    emit("fig7/dp_schedule_5h_age0", 0.0,
         "intervals_min=" + "/".join(map(str, sched))
         + "(paper 15/28/38/59/128)")
    lf = C.model_lifetimes_fn(dist)
    tau = float(YD.interval(GRID, 1.0))
    dp_tab = E.dp_policy_table(tables)
    yd_tab = E.young_daly_policy_table(max(1, int(round(tau / GRID))), 720)
    nc_tab = E.no_checkpoint_policy_table(720)

    def sim(tab, J, **k):
        return E.simulate_makespan_engine(
            tab, lf, J, grid_dt=GRID, delta_steps=1, n_trials=N_TRIALS,
            seed=SEED, **k)

    # Fig 7a: 4h job, varying start age
    for age in (0.0, 2.0, 6.0, 10.0, 15.0):
        dp = sim(dp_tab, 240, start_age=age).mean()
        yd = sim(yd_tab, 240, start_age=age).mean()
        emit(f"fig7a/overhead_age{age:g}h", 0.0,
             f"dp={100*(dp/4-1):.1f}%;young_daly={100*(yd/4-1):.1f}%")

    # Fig 7b: jobs from age 0, varying length
    for Th in (1, 2, 4, 6, 8):
        J = Th * 60
        dp = sim(dp_tab, J).mean()
        yd = sim(yd_tab, J).mean()
        none = sim(nc_tab, J).mean()
        emit(f"fig7b/overhead_T{Th}h", 0.0,
             f"dp={100*(dp/Th-1):.1f}%;young_daly={100*(yd/Th-1):.1f}%;"
             f"none={100*(none/Th-1):.1f}%")

    yd_pred = YD.expected_overhead(GRID, 1.0, restart_overhead=2 / 60.0)
    emit("fig7/young_daly_model_predicted_overhead", 0.0,
         f"{100*yd_pred:.1f}%(paper>25%)")


if __name__ == "__main__":
    run()
