"""Kernel micro-benchmarks (XLA production paths on CPU; Pallas kernels are
TPU-targeted and validated in interpret mode, so their CPU timings are not
meaningful - we time the XLA flash/assoc implementations the dry-run lowers,
against the naive references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

from .common import emit, timed


def _bench(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    (out), us = timed(lambda: jax.block_until_ready(fn(*args)), reps=reps)
    return us


def run():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    B, S, H, KV, Dh = 1, 2048, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, Dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, Dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, Dh), jnp.float32)

    naive = jax.jit(lambda q, k, v: ref.attention(q, k, v, causal=True))
    flash = jax.jit(lambda q, k, v: ops.flash_attention_xla(q, k, v, True, 0,
                                                            None, 512, 512))
    us_n = _bench(naive, q, k, v)
    us_f = _bench(flash, q, k, v)
    flops = 4 * B * S * S / 2 * H * Dh
    emit("kernels/attn_naive_2k", us_n, f"gflops={flops/us_n/1e3:.1f}")
    emit("kernels/attn_xla_flash_2k", us_f,
         f"gflops={flops/us_f/1e3:.1f};vs_naive={us_n/us_f:.2f}x")

    a = jax.random.uniform(ks[0], (4, 4096, 256), minval=0.9, maxval=0.999)
    b = 0.1 * jax.random.normal(ks[1], (4, 4096, 256))
    seq = jax.jit(lambda a, b: ref.linear_recurrence(a, b))
    assoc = jax.jit(lambda a, b: ops.linear_recurrence(a, b, impl="assoc"))
    us_s = _bench(seq, a, b)
    us_a = _bench(assoc, a, b)
    emit("kernels/linrec_scan_4k", us_s, "impl=lax.scan")
    emit("kernels/linrec_assoc_4k", us_a,
         f"impl=associative_scan;vs_scan={us_s/us_a:.2f}x")

    qd = jax.random.normal(ks[0], (8, 16, 64), jnp.float32)
    kc = jax.random.normal(ks[1], (8, 8192, 4, 64), jnp.float32)
    vc = jax.random.normal(ks[2], (8, 8192, 4, 64), jnp.float32)
    ln = jnp.full((8,), 8192, jnp.int32)
    dec = jax.jit(lambda q, k, v, l: ref.decode_attention(q, k, v, l))
    us_d = _bench(dec, qd, kc, vc, ln)
    bytes_read = kc.size * 4 * 2
    emit("kernels/decode_8k_cache", us_d,
         f"GBps={bytes_read/us_d/1e3:.1f}")

    _bench_dp_recurrence()


def _bench_dp_recurrence():
    """The checkpointing-DP inner recurrence across the three solver
    backends on one small workload: the XLA production kernel, the Pallas
    kernel in interpret mode (CPU emulation — timing is a smoke number, not
    a device number), and coarse-to-fine on the XLA machinery (see
    benchmarks/solver_bench.py for the production-scale comparison)."""
    from repro.core import distributions as D
    from repro.core.policies import checkpointing as ckpt

    dists = [D.constrained_for("n1-highcpu-16"), D.Exponential(mttf=8.0),
             D.Weibull(lam=0.12, k=0.8)]
    wl = dict(grid_dt=1.0 / 6.0, n_sweeps=2)
    job = 24
    us_x = _bench(lambda: ckpt.solve_batch(dists, job, backend="xla", **wl))
    us_p = _bench(lambda: ckpt.solve_batch(dists, job, backend="pallas",
                                           **wl))
    us_c = _bench(lambda: ckpt.solve_batch(dists, job, refine=True, **wl))
    emit("kernels/dp_recurrence_xla_S3_J24", us_x, "backend=xla")
    emit("kernels/dp_recurrence_pallas_S3_J24", us_p,
         f"backend=pallas;interpret=True(cpu_smoke);vs_xla={us_x/us_p:.2f}x")
    emit("kernels/dp_recurrence_ctf_S3_J24", us_c,
         f"backend=xla+refine;vs_xla={us_x/us_c:.2f}x")


if __name__ == "__main__":
    run()
