"""Benchmark harness - one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  The dry-run/roofline numbers
(deliverables e,g) are produced by ``repro.launch.dryrun`` (512-device
placeholder mesh) and reported in EXPERIMENTS.md; this harness covers the
paper's own tables/figures plus kernel and end-to-end microbenches.
"""
from __future__ import annotations

import sys
import traceback

from . import (e2e_train, fig1_fit, fig5_wasted_work, fig6_scheduling,
               fig7_checkpointing, fig8_service, kernels_bench, tonks_lemma)

MODULES = [
    ("fig1_fit", fig1_fit),
    ("fig5_wasted_work", fig5_wasted_work),
    ("fig6_scheduling", fig6_scheduling),
    ("fig7_checkpointing", fig7_checkpointing),
    ("fig8_service", fig8_service),
    ("tonks_lemma", tonks_lemma),
    ("kernels_bench", kernels_bench),
    ("e2e_train", e2e_train),
]


def main() -> None:
    print("name,us_per_call,derived")
    failed = []
    for name, mod in MODULES:
        try:
            mod.run()
        except Exception as e:  # keep the harness going; report at the end
            failed.append(name)
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}",
                  file=sys.stdout)
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == '__main__':
    main()
