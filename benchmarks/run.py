"""Benchmark harness - one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  The dry-run/roofline numbers
(deliverables e,g) are produced by ``repro.launch.dryrun`` (512-device
placeholder mesh) and reported in EXPERIMENTS.md; this harness covers the
paper's own tables/figures plus kernel and end-to-end microbenches.

Usage::

    python -m benchmarks.run [--quick] [--only MODULE[,MODULE...]]

``--quick`` shrinks the workloads of modules that support it (the
simulation-engine and scenario-sweep benchmarks) so a full-harness smoke run
finishes in seconds and still refreshes ``BENCH_simulation.json`` /
``BENCH_scenarios.json`` at the repo root.
"""
from __future__ import annotations

import argparse
import inspect
import sys
import traceback

from . import (e2e_train, fig1_fit, fig5_wasted_work, fig6_scheduling,
               fig7_checkpointing, fig8_service, kernels_bench, market_bench,
               runtime_bench, scenario_sweep, service_bench, sim_engine_bench,
               solver_bench, tonks_lemma)

MODULES = [
    ("fig1_fit", fig1_fit),
    ("fig5_wasted_work", fig5_wasted_work),
    ("fig6_scheduling", fig6_scheduling),
    ("fig7_checkpointing", fig7_checkpointing),
    ("fig8_service", fig8_service),
    ("sim_engine_bench", sim_engine_bench),
    ("service", service_bench),
    ("scenario_sweep", scenario_sweep),
    ("market", market_bench),
    ("solver", solver_bench),
    ("runtime", runtime_bench),
    ("tonks_lemma", tonks_lemma),
    ("kernels_bench", kernels_bench),
    ("e2e_train", e2e_train),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="shrink workloads where supported (seconds, not "
                         "minutes); still writes BENCH_simulation.json")
    ap.add_argument("--only", default=None,
                    help="comma-separated module names to run")
    args = ap.parse_args(argv)
    if args.only is None:
        selected = MODULES
    else:
        names = args.only.split(",")
        unknown = sorted(set(names) - {n for n, _ in MODULES})
        if unknown:
            ap.error(f"unknown module(s) {unknown}; "
                     f"choose from {[n for n, _ in MODULES]}")
        selected = [(n, m) for n, m in MODULES if n in names]

    print("name,us_per_call,derived")
    failed = []
    for name, mod in selected:
        try:
            if "quick" in inspect.signature(mod.run).parameters:
                mod.run(quick=args.quick)
            else:
                mod.run()
        except Exception as e:  # keep the harness going; report at the end
            failed.append(name)
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}",
                  file=sys.stdout)
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == '__main__':
    main()
