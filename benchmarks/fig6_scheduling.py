"""Paper Fig. 6: job failure probability under model-based scheduling
(VM-reuse policy) vs memoryless reuse - by start time (a) and job length (b)."""
from __future__ import annotations

import numpy as np

from repro.core import distributions as D
from repro.core.policies import scheduling as S

from .common import emit, timed


def run():
    dist = D.constrained_for("n1-highcpu-16")
    # Fig 6a: 6h job across start ages
    for s in (0.0, 6.0, 12.0, 17.0, 18.0, 20.0, 22.0):
        pm = float(S.job_failure_prob_memoryless(dist, 6.0, s))
        pp = float(S.job_failure_prob_policy(dist, 6.0, s))
        emit(f"fig6a/fail_prob_start{s:g}h", 0.0,
             f"memoryless={pm:.3f};policy={pp:.3f}")
    # Fig 6b: averaged over start times, per job length
    _, us = timed(lambda: float(S.mean_failure_prob_over_starts(dist, 6.0)))
    for T in (1, 2, 4, 6, 8, 10, 12):
        pol = float(S.mean_failure_prob_over_starts(dist, float(T)))
        mem = float(S.mean_failure_prob_over_starts(dist, float(T),
                                                    policy=False))
        emit(f"fig6b/mean_fail_T{T}h", us,
             f"policy={pol:.3f};memoryless={mem:.3f};"
             f"reduction={mem/max(pol,1e-9):.2f}x(paper~2x)")


if __name__ == "__main__":
    run()
