"""Inject the generated roofline tables into EXPERIMENTS.md at the
ROOFLINE_TABLE markers.

Usage: PYTHONPATH=src python -m benchmarks.update_experiments \
           results/dryrun_production.json
"""
from __future__ import annotations

import re
import sys

from .roofline_report import render

MARKERS = {
    "16x16": "<!-- ROOFLINE_TABLE_16x16 -->",
    "2x16x16": "<!-- ROOFLINE_TABLE_2x16x16 -->",
}


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_production.json"
    md_path = "EXPERIMENTS.md"
    text = open(md_path).read()
    for mesh, marker in MARKERS.items():
        table = render(path, mesh)
        block = (f"{marker}\n\n### Mesh {mesh} "
                 f"({256 if mesh == '16x16' else 512} chips)\n\n{table}\n")
        # replace marker plus any previously injected table up to the next
        # heading or marker
        pat = re.escape(marker) + r"(?:\n\n### Mesh.*?(?=\n## |\n<!-- |\Z))?"
        text = re.sub(pat, block, text, count=1, flags=re.S)
    open(md_path, "w").write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
