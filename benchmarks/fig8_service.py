"""Paper Fig. 8: batch service on preemptible VMs - cost vs on-demand (a)
and running-time increase vs number of preemptions (b)."""
from __future__ import annotations

import numpy as np

from repro.core import distributions as D
from repro.core import service as SV

from .common import emit, timed


def run():
    dist = D.constrained_for("n1-highcpu-32")
    # Fig 8a: bag of 100 jobs, 32 VMs (three "applications" = three lengths)
    for app, jh in (("nanoconfinement", 1.5), ("shapes", 2.0),
                    ("lulesh", 3.0)):
        r, us = timed(SV.run_bag, dist, n_jobs=100, job_hours=jh,
                      cluster_size=32, seed=3)
        emit(f"fig8a/cost_{app}", us,
             f"preemptible=${r.cost:.0f};on_demand=${r.on_demand_cost:.0f};"
             f"reduction={r.cost_reduction:.2f}x(paper~5x)")
    # Fig 8b: running-time (makespan) increase vs observed preemptions -
    # the paper's metric is the bag's wall-clock increase (~3%/preemption
    # on their 32-VM nanoconfinement runs).  The 10-seed replication goes
    # through run_bag_grid, which shares one vectorized reuse-decision table
    # across all seeds.
    grid = SV.run_bag_grid(vm_types=("n1-highcpu-32",), policies=("model",),
                           cluster_sizes=(32,), seeds=range(10), n_jobs=100,
                           job_hours=2.0)
    rows = [(row["result"].n_preemptions, row["result"].makespan)
            for row in grid]
    rows.sort()
    ideal = min(m for _, m in rows)
    for n, mk in rows[::3]:
        emit(f"fig8b/preempts_{n}", 0.0,
             f"makespan={mk:.1f}h;overhead={100*(mk/ideal-1):.1f}%")
    if len(rows) > 1 and rows[-1][0] > rows[0][0]:
        slope = (np.mean([m for _, m in rows[-3:]])
                 - np.mean([m for _, m in rows[:3]])) \
            / max(np.mean([n for n, _ in rows[-3:]])
                  - np.mean([n for n, _ in rows[:3]]), 1)
        emit("fig8b/per_preemption_increase", 0.0,
             f"{100*slope/ideal:.2f}%(paper~3%)")


if __name__ == "__main__":
    run()
