"""Pod-level fault tolerance: preemption signals, elastic re-meshing,
straggler watchdog.

The preemption unit of a transient TPU fleet is a pod reservation: losing it
removes a whole data-parallel replica group.  ``PreemptionSource`` simulates
the provider signal (lifetimes drawn from the fitted constrained-preemption
model, with the provider's 30 s advance warning); the training loop polls it
every step and on warning (a) flushes an emergency checkpoint through the
CheckpointManager and (b) asks ``plan_elastic_remesh`` for the survivor
topology.

On real hardware the same interface is backed by the metadata server's
preemption notice (GCE: /computeMetadata/v1/instance/preempted) - only
``poll`` changes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import engine
from ..core.policies import scheduling as sched_policy

WARNING_SECONDS = 30.0  # Google's advance notice


@dataclasses.dataclass
class PreemptionEvent:
    pod_id: int
    warning_at_hours: float
    preempt_at_hours: float


@dataclasses.dataclass
class PreemptionSource:
    """Simulated provider preemption signal for ``n_pods`` reservations.

    ``clock()`` is injectable simulated time (hours since run start);
    lifetimes resample on ``replace_pod`` (a relaunched reservation is a
    fresh draw, age 0).
    """
    dist: object
    n_pods: int = 1
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        # normalize parameter leaves once so every _draw hits the shared
        # module-level kernel's cache (same pytree structure/dtype) instead
        # of re-tracing per source instance
        self._dist_n = jax.tree_util.tree_map(
            lambda l: jnp.asarray(l, jnp.result_type(float)), self.dist)
        self._fl = float(self.dist.cdf(self.dist.L))
        self.launch_age = np.zeros(self.n_pods)       # run-clock at pod launch
        self.lifetimes = self._draw(self.n_pods)
        self.preempted = np.zeros(self.n_pods, bool)

    def _draw(self, n):
        u = self._rng.uniform(size=n)
        return engine.capped_icdf_draw(self._dist_n, u, self._fl,
                                       float(self.dist.L))

    def pod_age(self, pod_id: int, now_hours: float) -> float:
        return now_hours - self.launch_age[pod_id]

    def poll(self, now_hours: float) -> list[PreemptionEvent]:
        """Pods whose preemption lands within the warning window (or has
        passed).  Idempotent: each pod reports once."""
        warn_h = WARNING_SECONDS / 3600.0
        out = []
        for i in range(self.n_pods):
            if self.preempted[i]:
                continue
            t_kill = self.launch_age[i] + self.lifetimes[i]
            if now_hours >= t_kill - warn_h:
                self.preempted[i] = True
                out.append(PreemptionEvent(i, max(t_kill - warn_h, 0.0),
                                           t_kill))
        return out

    def replace_pod(self, pod_id: int, now_hours: float):
        """Provision a replacement reservation (fresh lifetime, age 0)."""
        self.launch_age[pod_id] = now_hours
        self.lifetimes[pod_id] = self._draw(1)[0]
        self.preempted[pod_id] = False

    def reuse_decision(self, pod_id: int, job_hours: float,
                       now_hours: float,
                       relaunch_overhead: float = 5.0 / 60.0) -> bool:
        """The paper's VM-reuse policy at pod granularity: keep scheduling
        the next segment on this pod, or relinquish it for a fresh one.
        Pod provisioning is minutes, not seconds, so it is charged here."""
        if self.preempted[pod_id]:
            return False
        age = self.pod_age(pod_id, now_hours)
        return bool(sched_policy.reuse_decision(self.dist, job_hours, age,
                                                relaunch_overhead))


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """Survivor topology after losing pods."""
    surviving_pods: tuple
    mesh_shape: tuple
    mesh_axes: tuple
    batch_scale: float          # global batch multiplier (survivors / total)
    reshard: bool               # params need re-sharding across survivors


def plan_elastic_remesh(n_pods: int, lost: Sequence[int], *,
                        pod_shape=(16, 16), axes=("data", "model")) -> ElasticPlan:
    """Drop lost pods from the ``pod`` axis and continue on the survivors.

    Multi-pod training shards batch over ("pod","data") and keeps parameters
    replicated across pods (or FSDP within a pod), so pod loss is handled by
    (a) shrinking the pod axis, (b) rescaling the global batch, (c) restoring
    optimizer/param state from the last checkpoint on the survivors.  With
    one survivor the mesh degenerates to the single-pod (16,16) layout.
    """
    survivors = tuple(i for i in range(n_pods) if i not in set(lost))
    n = len(survivors)
    if n == 0:
        raise RuntimeError("all pods lost; job must re-queue")
    if n == 1:
        return ElasticPlan(survivors, pod_shape, axes, 1.0 / n_pods, False)
    return ElasticPlan(survivors, (n,) + tuple(pod_shape), ("pod",) + tuple(axes),
                       n / n_pods, False)


@dataclasses.dataclass
class StragglerWatchdog:
    """Flags slow steps (failing hosts, thermal throttling) from step-time
    telemetry; the runbook response on a fleet is to demote the pod, which
    in this framework means treating it as a voluntary preemption."""
    threshold: float = 2.0      # x median
    window: int = 64

    def __post_init__(self):
        self._times: list[float] = []
        self.flagged = 0

    def observe(self, seconds: float) -> bool:
        self._times.append(seconds)
        if len(self._times) > self.window:
            self._times.pop(0)
        if len(self._times) < 8:
            return False
        med = float(np.median(self._times))
        if seconds > self.threshold * med:
            self.flagged += 1
            return True
        return False
