from .preemption import (ElasticPlan, PreemptionEvent, PreemptionSource,
                         StragglerWatchdog, plan_elastic_remesh)  # noqa: F401
