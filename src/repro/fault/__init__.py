from .injection import (FaultEvent, FaultInjector,  # noqa: F401
                        default_schedule)
from .preemption import (ElasticPlan, PreemptionEvent, PreemptionSource,
                         StragglerWatchdog, plan_elastic_remesh)  # noqa: F401
