"""Deterministic fault injection for the closed-loop fleet runtime.

The runtime (``repro.core.runtime.FleetRuntime``) streams lifetimes through
a refit -> re-solve -> table-swap pipeline; :class:`FaultInjector` perturbs
that pipeline with the four failure modes a long-running service actually
sees, on a fixed seeded schedule so every CI run replays the same storm:

``drift``
    The fleet's preemption behavior changes regime at a known observation
    index (e.g. the provider moves capacity, a zone flips day/night policy).
    A stream-level fault: the lifetime source switches distribution and the
    runtime is expected to *detect* it (KS change-point), refit, and swap
    tables — the gap between injection and swap is the adaptation lag.

``storm``
    A preemption storm: for ``duration`` observations every lifetime draw is
    overridden with a near-immediate kill.  Stresses the degenerate-window
    guards in ``fit_samples`` (constant / all-tiny traces) and the tracker's
    change-point logic.

``fit_divergence``
    The next ``duration`` refits return non-finite parameters (the NaN /
    singular-``JtJ`` trace the LM hardening turns into ``converged=False``).
    A stage fault consumed by the runtime's fit stage; expected response is
    retry-with-backoff and last-good model/tables in the meantime.

``solve_timeout``
    The next ``duration`` DP solves exceed their wall-clock budget.  Stage
    fault on the solve stage; expected response is retry-with-backoff and
    serving from the last-good (stale) tables.

Events are *scheduled by observation index*, not wall time, so runs are
reproducible regardless of host speed.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

KINDS = ("drift", "storm", "fit_divergence", "solve_timeout")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.

    ``at_obs``    observation index at which the fault arms.
    ``duration``  stream faults (storm): active for this many observations;
                  stage faults (fit_divergence / solve_timeout): a budget of
                  this many failures to inject on matching stage attempts.
    ``param``     kind-specific payload — drift: ``{"vm_types": (...)}`` or
                  ``{"dist": <distribution>}`` selecting the new regime;
                  storm: ``{"lifetime_hours": float}`` override draw.
    """
    kind: str
    at_obs: int
    duration: int = 1
    param: Optional[dict] = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        if self.at_obs < 0 or self.duration < 1:
            raise ValueError("at_obs must be >= 0 and duration >= 1")


@dataclasses.dataclass
class FaultInjector:
    """Replays a fixed schedule of :class:`FaultEvent`\\ s against the
    runtime.  All state advances with ``observation index`` (the runtime
    calls the query methods each observation / stage attempt), so a given
    ``(schedule, seed)`` pair injects the identical fault trace on every
    run — the CI quick tier depends on this.
    """
    schedule: Sequence[FaultEvent] = ()
    seed: int = 0

    def __post_init__(self):
        self.schedule = tuple(sorted(self.schedule, key=lambda e: e.at_obs))
        self._rng = np.random.default_rng(self.seed)
        # stage-fault budgets: remaining injections per armed event
        self._budgets = {}
        self._fired_drift = set()
        self.log: list[tuple[int, str, str]] = []   # (obs, kind, note)

    # -- stream faults -----------------------------------------------------
    def drift_event(self, obs: int) -> Optional[FaultEvent]:
        """The drift event firing exactly at ``obs`` (once), else None."""
        for i, ev in enumerate(self.schedule):
            if ev.kind == "drift" and ev.at_obs == obs \
                    and i not in self._fired_drift:
                self._fired_drift.add(i)
                self.log.append((obs, "drift", "regime switch"))
                return ev
        return None

    def storm_active(self, obs: int) -> Optional[FaultEvent]:
        """The storm covering ``obs`` (``at_obs <= obs < at_obs+duration``),
        else None."""
        for ev in self.schedule:
            if ev.kind == "storm" and ev.at_obs <= obs < ev.at_obs + ev.duration:
                return ev
        return None

    def storm_lifetime(self, ev: FaultEvent) -> float:
        """The overridden lifetime draw during a storm: near-immediate kill
        with a little jitter so the window isn't exactly constant unless the
        event pins ``lifetime_hours``."""
        p = ev.param or {}
        if "lifetime_hours" in p:
            return float(p["lifetime_hours"])
        return float(self._rng.uniform(0.01, 0.05))

    # -- stage faults ------------------------------------------------------
    def take(self, kind: str, obs: int) -> bool:
        """Consume one injection from an armed ``kind`` budget, if any.

        The runtime calls this at the top of the matching stage (fit stage
        -> ``fit_divergence``, solve stage -> ``solve_timeout``); True means
        "fail this attempt".  Each event supplies ``duration`` failures, so
        a bounded-retry runtime recovers once the budget drains.
        """
        for i, ev in enumerate(self.schedule):
            if ev.kind != kind or ev.at_obs > obs:
                continue
            left = self._budgets.get(i, ev.duration)
            if left > 0:
                self._budgets[i] = left - 1
                self.log.append((obs, kind, f"injected ({left - 1} left)"))
                return True
        return False

    def counts(self) -> dict:
        out = {k: 0 for k in KINDS}
        for ev in self.schedule:
            out[ev.kind] += 1
        return out


def default_schedule(n_obs: int, *,
                     drift_vm_types: tuple = ("n1-highcpu-32",)) -> tuple:
    """The benchmark/CI fault matrix scaled to an ``n_obs``-observation run:
    one drift regime switch at 40%, a preemption storm at 60%, two injected
    fit divergences right after the drift (so the first refit attempts fail
    and the retry path is exercised), and one solve timeout.

    The drift targets the harshest type (``n1-highcpu-32``, 1.45x the base
    hazard); paired with a gentle-fleet stream (``n1-highcpu-2``) the regime
    switch sits well above the tracker's two-sample KS cut — a mix-to-member
    switch lands within sampling noise of a 64-observation window and is NOT
    reliably detectable (measured: KS ~0.24 vs a ~0.25 cut)."""
    d = max(int(0.40 * n_obs), 1)
    return (
        FaultEvent("drift", d, param={"vm_types": drift_vm_types}),
        FaultEvent("fit_divergence", d, duration=2),
        FaultEvent("solve_timeout", d, duration=1),
        FaultEvent("storm", max(int(0.60 * n_obs), 2),
                   duration=max(n_obs // 20, 8)),
    )
