"""Batched serving driver: prefill + decode with preemption-aware placement.

Serving on preemptible pods uses the paper's *scheduling* policy rather than
checkpointing: each request batch is a "job" of estimated length
(prefill + n_decode steps x step time), and ``PreemptionSource.reuse_decision``
decides whether to keep the current pod or rotate to a fresh reservation
before admitting the batch (Fig. 6 economics at pod granularity).

Run: PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..core import distributions
from ..fault import PreemptionSource
from ..models import transformer as T
from . import steps


def serve_batch(cfg, params, prompts, *, n_decode: int = 16,
                positions=None):
    """Greedy-decode ``n_decode`` tokens for a batch of token prompts."""
    B, S = prompts.shape
    cache = T.init_cache(cfg, B, S + n_decode)
    prefill = jax.jit(steps.make_prefill_step(cfg))
    decode = jax.jit(steps.make_decode_step(cfg))
    logits, cache = prefill(params, cache, {"tokens": prompts})
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    out = [tok]
    for _ in range(n_decode - 1):
        logits, tok, cache = decode(params, cache, {"tokens": tok[:, None]})
        out.append(tok)
    return jnp.stack(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batches", type=int, default=4)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode", type=int, default=16)
    args = ap.parse_args()

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    if cfg.embeds_input:
        raise SystemExit("serve driver feeds tokens; pick a token-input arch")
    params, _ = T.init(cfg, jax.random.PRNGKey(0))
    dist = distributions.constrained_for()
    src = PreemptionSource(dist, n_pods=1, seed=3)

    rng = np.random.default_rng(0)
    sim_now = 0.0
    rotations = 0
    for i in range(args.batches):
        # the paper's reuse policy at admission time
        est_job_hours = 0.05
        if not src.reuse_decision(0, est_job_hours, sim_now):
            src.replace_pod(0, sim_now)
            rotations += 1
        prompts = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                           (args.batch_size, args.prompt_len)),
                              jnp.int32)
        t0 = time.time()
        toks = serve_batch(cfg, params, prompts, n_decode=args.decode)
        dt = time.time() - t0
        sim_now += est_job_hours
        print(f"batch {i}: {toks.shape} tokens in {dt:.2f}s "
              f"(pod age {src.pod_age(0, sim_now):.2f}h)")
    print(f"served {args.batches} batches, {rotations} pod rotations")


if __name__ == "__main__":
    main()
