"""Step builders shared by the trainer, the server, and the dry-run:
train_step / prefill_step / decode_step plus abstract (no-allocation)
parameter, optimizer-state, cache and batch specs with their shardings.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .. import sharding
from ..configs.base import ModelConfig, ShapeConfig, TrainConfig
from ..data.pipeline import make_batch_specs
from ..models import transformer as T
from ..optim import adamw_init, adamw_update, cosine_schedule


# ---------------------------------------------------------------------------
# abstract trees (ShapeDtypeStruct; zero allocation - the dry-run pattern)
# ---------------------------------------------------------------------------

def abstract_init(cfg: ModelConfig):
    """(param ShapeDtypeStructs, logical axes) without allocating."""
    box = {}

    def f(k):
        p, ax = T.init(cfg, k)
        box["axes"] = ax            # static tuples captured at trace time
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, box["axes"]


def abstract_opt_state(param_shapes):
    return jax.eval_shape(adamw_init, param_shapes)


def opt_axes(param_axes_tree):
    """Optimizer-state axes: parameter axes under ``opt::`` aliases so rule
    sets can shard m/v independently of the weights (ZeRO-1)."""
    from ..optim.adamw import AdamWState
    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    aliased = jax.tree_util.tree_map(sharding.opt_alias, param_axes_tree,
                                     is_leaf=is_ax)
    return AdamWState(step=(), mu=aliased, nu=aliased)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    # batch/max_len must stay static python ints during shape evaluation
    return jax.eval_shape(lambda: T.init_cache(cfg, batch, max_len))


def batch_axes(cfg: ModelConfig, specs: dict) -> dict:
    ax = {}
    for name in specs:
        if name == "embeds":
            ax[name] = ("act_batch", "act_seq", "act_embed")
        elif name == "positions" and cfg.pos_type == "mrope":
            ax[name] = (None, "act_batch", "act_seq")
        else:
            ax[name] = ("act_batch", "act_seq")
    return ax


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStruct stand-ins for every model input of a cell.

    train  : {tokens/embeds, labels, mask [, positions]}
    prefill: {tokens/embeds [, positions]} + empty cache
    decode : single-token inputs + a seq_len-deep cache
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"batch": make_batch_specs(cfg, shape, for_loss=True)}
    if shape.kind == "prefill":
        return {"batch": make_batch_specs(cfg, shape, for_loss=False),
                "cache": abstract_cache(cfg, B, S)}
    if shape.kind == "decode":
        specs = {}
        if cfg.embeds_input:
            specs["embeds"] = jax.ShapeDtypeStruct(
                (B, 1, cfg.d_model), jnp.dtype(cfg.compute_dtype))
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        if cfg.pos_type == "mrope":
            specs["positions"] = jax.ShapeDtypeStruct((3, B, 1), jnp.int32)
        return {"batch": specs, "cache": abstract_cache(cfg, B, S)}
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, tc: TrainConfig, param_axes=None):
    accum = max(int(tc.grad_accum), 1)
    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)

    def _anchor(tree):
        """Pin a grad-shaped tree to the parameter sharding: without this the
        accumulation carry propagates as replicated and GSPMD emits one
        full-shape f32 all-reduce per weight per microbatch (measured 2.1
        TB/chip/step on yi-34b; EXPERIMENTS.md §Perf iteration A4)."""
        if param_axes is None:
            return tree
        return jax.tree_util.tree_map(
            lambda g, ax: sharding.constrain(g, *ax), tree, param_axes,
            is_leaf=lambda x: is_ax(x))

    def train_step(params, opt_state, batch):
        def loss_fn(p, mb):
            return T.lm_loss(cfg, p, mb)

        if accum == 1:
            (loss, aux), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            # microbatch scan: bounds activation peak at fixed global batch
            mb = jax.tree_util.tree_map(
                lambda x: x.reshape((accum, x.shape[0] // accum)
                                    + x.shape[1:])
                if x.ndim >= 1 and x.shape[0] % accum == 0 else
                jnp.broadcast_to(x, (accum,) + x.shape), batch)
            if cfg.pos_type == "mrope" and "positions" in batch:
                # positions are (3, B, S): slice the batch dim, not dim 0
                p3 = batch["positions"]
                mb["positions"] = jnp.moveaxis(
                    p3.reshape(3, accum, p3.shape[1] // accum, p3.shape[2]),
                    1, 0)

            def micro(acc, mbi):
                (loss, aux), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mbi)
                acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                return _anchor(acc), (loss, aux)

            g0 = _anchor(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            grads, (losses, auxes) = jax.lax.scan(micro, g0, mb)
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
            loss = jnp.mean(losses)
            aux = jax.tree_util.tree_map(jnp.mean, auxes)

        lr = cosine_schedule(opt_state.step, base_lr=tc.learning_rate,
                             warmup_steps=tc.warmup_steps,
                             total_steps=tc.total_steps)
        params, opt_state, om = adamw_update(
            grads, opt_state, params, learning_rate=lr, beta1=tc.beta1,
            beta2=tc.beta2, eps=tc.eps, weight_decay=tc.weight_decay,
            grad_clip=tc.grad_clip)
        metrics = {"loss": loss, **aux, **om}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill(params, cache, batch):
        logits, cache = T.prefill_step(
            cfg, params, batch.get("tokens"), embeds=batch.get("embeds"),
            positions=batch.get("positions"), cache=cache)
        return logits, cache

    return prefill


def make_decode_step(cfg: ModelConfig):
    def decode(params, cache, batch):
        logits, cache = T.decode_step(
            cfg, params, batch.get("tokens"), embeds=batch.get("embeds"),
            positions=batch.get("positions"), cache=cache)
        # greedy next token (kept in-graph so serving is one dispatch)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return logits, next_tok, cache

    return decode


# ---------------------------------------------------------------------------
# sharding assembly
# ---------------------------------------------------------------------------

def shardings_for_cell(cfg, shape, mesh, rules="baseline"):
    """(in_shardings, out_shardings, abstract_args, step_fn) for a cell."""
    if isinstance(rules, str):
        rules = sharding.RULE_SETS[rules]
    p_shapes, p_axes = abstract_init(cfg)
    sh = lambda ax_tree, shp_tree: jax.tree_util.tree_map(
        lambda ax, s: sharding.sharding_for(ax, s.shape, mesh, rules),
        ax_tree, shp_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    p_sh = sh(p_axes, p_shapes)
    repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    specs = input_specs(cfg, shape)
    b_ax = batch_axes(cfg, specs["batch"])
    b_sh = sh(b_ax, specs["batch"])

    if shape.kind == "train":
        opt_shapes = abstract_opt_state(p_shapes)
        o_sh = sh(opt_axes(p_axes), opt_shapes)
        args = (p_shapes, opt_shapes, specs["batch"])
        in_sh = (p_sh, o_sh, b_sh)
        metrics_sh = jax.tree_util.tree_map(
            lambda _: repl, {"loss": 0, "nll": 0, "zloss": 0, "grad_norm": 0,
                             "lr": 0})
        out_sh = (p_sh, o_sh, metrics_sh)
        return in_sh, out_sh, args, None

    cache_shapes = specs["cache"]
    c_ax = T.cache_axes(cfg)
    c_sh = sh(c_ax, cache_shapes)
    args = (p_shapes, cache_shapes, specs["batch"])
    in_sh = (p_sh, c_sh, b_sh)
    if shape.kind == "prefill":
        logits_sh = sharding.sharding_for(
            ("act_batch", "act_seq", "act_vocab"),
            (shape.global_batch, 1, cfg.vocab_size), mesh, rules)
        out_sh = (logits_sh, c_sh)
    else:
        logits_sh = sharding.sharding_for(
            ("act_batch", "act_seq", "act_vocab"),
            (shape.global_batch, 1, cfg.vocab_size), mesh, rules)
        tok_sh = sharding.sharding_for(("act_batch",), (shape.global_batch,),
                                       mesh, rules)
        out_sh = (logits_sh, tok_sh, c_sh)
    return in_sh, out_sh, args, None
