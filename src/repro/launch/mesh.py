"""Production mesh construction.

A function (never a module-level constant) so importing this module does not
touch jax device state - the dry-run must set XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips ("data", "model").
    Multi-pod: (2, 16, 16) = 512 chips ("pod", "data", "model") - the pod
    axis is the fault domain (pure DP over DCN)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
