"""HLO-text statistics: collective operand bytes (trip-count aware) for the
roofline's collective term.

``collective_bytes(hlo_text)`` walks the module's computations, finds every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute,
sizes its result shape(s), and multiplies by the estimated execution count of
the computation it lives in (while-loop bodies execute trip_count times -
this framework compiles scan-over-layers, so ignoring trip counts would
undercount by ~n_layers x).

Trip counts are recovered from the canonical XLA counted-loop pattern: the
while condition compares the induction variable against a constant; we take
the largest integer constant compared in the condition computation.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\).*condition=%?([\w\.\-]+).*body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CALL_RE = re.compile(
    r"(?:to_apply|calls|condition|body|branch_computations)=\{?%?([\w\.\-]+)")
# "<result> = <shape> <opcode>(" - the opcode must directly follow the result
# shape, otherwise fusions CONSUMING a collective get miscounted at their own
# (often much larger) output size
_OPCODE_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[\w\[\]{},.:]+))\s*([a-z][\w\-]*)\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))


def _parse_computations(hlo: str) -> dict:
    """computation name -> list of instruction lines."""
    comps = {}
    cur = None
    for line in hlo.splitlines():
        m = _COMP_RE.match(line)
        if m and "{" in line:
            cur = m.group(1)
            comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def collective_bytes(hlo: str) -> CollectiveStats:
    comps = _parse_computations(hlo)

    # while body -> trip count (from its condition computation)
    body_trip = {}
    for name, lines in comps.items():
        for line in lines:
            if "while(" not in line and " while(" not in line \
                    and "= while" not in line.replace("(", "("):
                pass
            m = _WHILE_RE.search(line)
            if m:
                cond, body = m.group(1), m.group(2)
                consts = []
                for cl in comps.get(cond, []):
                    consts += [int(c) for c in _CONST_RE.findall(cl)]
                # the trip bound is the compare constant; exclude init values
                # (0/1) and shape-sized constants that also appear in
                # condition blocks
                consts = [c for c in consts if 1 < c < 100_000]
                body_trip[body] = max(consts) if consts else 1

    # execution multiplier per computation: product of trip counts along the
    # call chain from the entry
    children = defaultdict(set)
    for name, lines in comps.items():
        for line in lines:
            for callee in _CALL_RE.findall(line):
                if callee in comps:
                    children[name].add((callee, body_trip.get(callee, 1)
                                        if "body" in line or callee in body_trip
                                        else 1))

    mult = defaultdict(float)
    entry = next((n for n in comps if "main" in n or n.startswith("entry")),
                 None)
    if entry is None and comps:
        entry = list(comps)[0]

    def walk(name, m, depth=0):
        if depth > 64:
            return
        mult[name] = max(mult[name], m)
        for callee, trips in children.get(name, ()):
            walk(callee, m * max(trips, 1), depth + 1)

    if entry:
        walk(entry, 1.0)
    for name in comps:
        if name not in mult:
            mult[name] = 1.0

    bytes_by_kind = defaultdict(float)
    count_by_kind = defaultdict(int)
    for name, lines in comps.items():
        for line in lines:
            m = _OPCODE_RE.search(line)
            if not m:
                continue
            shape_txt, opcode = m.group(1), m.group(2)
            base = opcode[:-6] if opcode.endswith("-start") else opcode
            if base not in _COLLECTIVES or opcode.endswith("-done"):
                continue
            b = _shape_bytes(shape_txt)
            bytes_by_kind[base] += b * mult[name]
            count_by_kind[base] += 1
    return CollectiveStats(dict(bytes_by_kind), dict(count_by_kind))
