"""End-to-end preemption-aware training driver.

This is the integration point of the paper's contribution with the training
substrate: the loop trains a model on the synthetic pipeline while

  * a ``PreemptionSource`` (bathtub model) delivers simulated pod
    preemptions with the provider's 30 s warning,
  * a ``CheckpointManager`` runs the paper's DP checkpoint schedule
    (non-uniform, pod-age-dependent) and flushes an emergency checkpoint
    inside the warning window,
  * on pod loss the job restarts on a replacement pod, restores the newest
    intact checkpoint, replays the deterministic data pipeline to the
    resumed step, and recomputes the DP schedule (the paper's resume rule),
  * a ``StragglerWatchdog`` demotes slow pods (treated as preemptions).

Simulated time: ``sim_hours_per_step`` maps steps to pod age so a 200-step
CPU run can traverse hours of the preemption model.  On a real fleet the
same loop runs with wall-clock time and the metadata-server signal.

Run: PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import numpy as np

from .. import configs, sharding
from ..checkpoint import CheckpointManager
from ..configs.base import TrainConfig
from ..core import distributions
from ..data.pipeline import SyntheticLM
from ..fault import PreemptionSource, StragglerWatchdog
from ..models import transformer as T
from ..optim import adamw_init
from . import steps


@dataclasses.dataclass
class TrainResult:
    losses: list
    steps_run: int
    restarts: int
    checkpoints: int
    emergency_checkpoints: int
    wasted_steps: int
    final_loss: float


def train(cfg, tc: TrainConfig, *, total_steps: int = 200,
          seq_len: int = 64, global_batch: int = 8,
          inject_preemptions: bool = False, sim_hours_per_step: float = 0.02,
          preemption_seed: int = 7, mesh=None, rules: str = "baseline",
          log_every: int = 25, verbose: bool = True) -> TrainResult:
    dist = distributions.constrained_for(tc.vm_type)
    pipe = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq_len,
                       global_batch=global_batch, seed=tc.seed)
    key = jax.random.PRNGKey(tc.seed)

    params, _ = T.init(cfg, key)
    opt_state = adamw_init(params)
    step_fn = steps.make_train_step(cfg, tc)
    jitted = jax.jit(step_fn)

    mgr = CheckpointManager(
        directory=tc.ckpt_dir, dist=dist, policy=tc.ckpt_policy,
        delta_hours=tc.ckpt_cost_hours, step_time_hours=sim_hours_per_step,
        total_steps=total_steps, async_write=tc.async_checkpoint)
    src = PreemptionSource(dist, n_pods=1, seed=preemption_seed) \
        if inject_preemptions else None
    dog = StragglerWatchdog()

    # resume if a checkpoint exists
    step = 0
    restarts = 0
    wasted = 0
    restored = mgr.restore((params, opt_state))
    if restored is not None:
        (params, opt_state), step, _ = restored
        if verbose:
            print(f"resumed from checkpoint at step {step}")

    losses = []
    sim_now = 0.0
    while step < total_steps:
        t0 = time.time()
        batch = pipe.batch(step)
        params, opt_state, metrics = jitted(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        step += 1
        sim_now += sim_hours_per_step
        mgr.observe_step_time(sim_hours_per_step * 3600.0)
        dog.observe(time.time() - t0)

        if verbose and step % log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"grad {float(metrics['grad_norm']):.3f} "
                  f"ckpts {mgr.n_saved}")

        # --- the paper's policies in action ---
        if mgr.should_checkpoint(step):
            mgr.save(step, (params, opt_state))
        if src is not None:
            events = src.poll(sim_now)
            if events:
                # 30 s warning: emergency checkpoint, then the pod dies
                mgr.on_preemption_warning(step, (params, opt_state))
                # relaunch on a fresh pod + restore + replay pipeline
                restarts += 1
                src.replace_pod(0, sim_now)
                restored = mgr.restore((params, opt_state))
                assert restored is not None
                (params, opt_state), ckpt_step, _ = restored
                wasted += step - ckpt_step
                step = ckpt_step
                mgr.on_restart(pod_age_hours=0.0, resumed_step=step)
                if verbose:
                    print(f"  !! pod preempted at sim t={sim_now:.2f}h -> "
                          f"restart from step {step}")

    return TrainResult(losses=losses, steps_run=len(losses),
                       restarts=restarts, checkpoints=mgr.n_saved,
                       emergency_checkpoints=mgr.n_emergency,
                       wasted_steps=wasted,
                       final_loss=float(np.mean(losses[-10:])))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--preemptions", action="store_true")
    ap.add_argument("--ckpt-policy", default="dp",
                    choices=("dp", "young_daly", "fixed", "none"))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    tc = TrainConfig(ckpt_policy=args.ckpt_policy, ckpt_dir=args.ckpt_dir,
                     total_steps=args.steps)
    res = train(cfg, tc, total_steps=args.steps,
                inject_preemptions=args.preemptions)
    print(f"done: {res.steps_run} steps, final loss {res.final_loss:.4f}, "
          f"{res.restarts} restarts, {res.checkpoints} checkpoints "
          f"({res.emergency_checkpoints} emergency), "
          f"{res.wasted_steps} wasted steps")


if __name__ == "__main__":
    main()
