import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may touch jax ----------------------------------------
import argparse      # noqa: E402
import json          # noqa: E402
import math          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from .. import analytics, configs, sharding   # noqa: E402
from ..configs import SHAPES                  # noqa: E402
from ..configs.base import TrainConfig        # noqa: E402
from . import hlo_stats, steps                # noqa: E402
from .mesh import make_production_mesh        # noqa: E402

"""Multi-pod dry-run: prove every (architecture x input shape x mesh) cell
lowers, GSPMD-partitions, and compiles on the production meshes - 16x16
("data","model") single pod and 2x16x16 ("pod","data","model") multi-pod -
and extract the memory / FLOP / collective numbers the roofline analysis
(EXPERIMENTS.md §Roofline) consumes.

Run:  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
          [--multi-pod] [--rules fsdp] [--out results.json]
Defaults to the full 40-cell grid on both meshes with the baseline rules.
"""


def _mem_summary(compiled):
    out = {}
    try:
        ma = compiled.memory_analysis()
    except Exception as e:      # backend without memory analysis
        return {"error": repr(e)}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    if not out and isinstance(ma, dict):
        out = {k: int(v) for k, v in ma.items()}
    return out


def _cost_summary(compiled):
    try:
        ca = compiled.cost_analysis()
    except Exception as e:
        return {"error": repr(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    keep = {}
    for k, v in (ca or {}).items():
        if k in ("flops", "bytes accessed", "transcendentals") or \
                k.startswith("bytes accessed"):
            keep[k] = float(v)
    return keep


# per-arch microbatch accumulation for train_4k: picked so activation peak
# fits 16 GB/chip HBM under the fsdp rule set (see EXPERIMENTS.md §Perf)
DEFAULT_ACCUM = {
    "deepseek-coder-33b": 4, "yi-34b": 4, "recurrentgemma-2b": 4,
    "xlstm-1.3b": 8, "moonshot-v1-16b-a3b": 2, "qwen2-vl-2b": 2,
    "llama3.2-1b": 2, "musicgen-medium": 2, "phi3.5-moe-42b-a6.6b": 2,
}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             rules: str = "baseline", accum: int = 0,
             serve_bf16: bool = False, verbose: bool = True) -> dict:
    import dataclasses
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    if rules == "production":
        # per-workload layouts (§Perf): FSDP+seq-parallel for training,
        # TP-only weights + bf16 for serving (no per-token weight gathers)
        rules = "fsdp" if shape.kind == "train" else "baseline"
        serve_bf16 = True
    if serve_bf16 and shape.kind != "train":
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    chips = 512 if multi_pod else 256
    if accum <= 0:
        accum = DEFAULT_ACCUM.get(arch, 1) if rules in ("fsdp",) else 1
    rec = {"arch": arch, "shape": shape_name, "rules": rules,
           "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
           "grad_accum": accum, "serve_bf16": serve_bf16}

    if shape_name == "long_500k" and not cfg.is_subquadratic:
        rec["status"] = "skip"
        rec["reason"] = ("pure full-attention arch: 524k dense-KV decode is "
                         "inherently quadratic; see DESIGN.md "
                         "§Arch-applicability")
        return rec

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        with mesh, sharding.use(mesh, rules):
            in_sh, out_sh, args, _ = steps.shardings_for_cell(
                cfg, shape, mesh, rules)
            if shape.kind == "train":
                _, p_axes = steps.abstract_init(cfg)
                fn = steps.make_train_step(cfg, TrainConfig(grad_accum=accum),
                                           param_axes=p_axes)
                donate = (0, 1)        # params, opt_state update in place
            elif shape.kind == "prefill":
                fn = steps.make_prefill_step(cfg)
                donate = (1,)          # cache
            else:
                fn = steps.make_decode_step(cfg)
                donate = (1,)          # cache
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            rec["status"] = "ok"
            rec["lower_s"] = round(t_lower, 1)
            rec["compile_s"] = round(t_compile, 1)
            rec["memory"] = _mem_summary(compiled)
            rec["hlo_cost"] = _cost_summary(compiled)
            coll = hlo_stats.collective_bytes(compiled.as_text())
            rec["collectives"] = {"bytes_by_kind": coll.bytes_by_kind,
                                  "count_by_kind": coll.count_by_kind,
                                  "total_bytes": coll.total_bytes}
    except Exception as e:
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        return rec

    # analytic roofline terms (HLO while-bodies are counted once by XLA's
    # cost analysis; the analytic model is the reconciled source - §Roofline)
    cost = analytics.cell_cost(cfg, shape, chips=chips,
                               pods=2 if multi_pod else 1, rules=rules)
    rec["analytic"] = {
        "flops": cost.flops, "hbm_bytes": cost.hbm_bytes,
        "ici_bytes_per_chip": cost.ici_bytes,
        "dcn_bytes_per_chip": cost.dcn_bytes,
        "model_flops": cost.model_flops,
        "params_bytes": cost.params_bytes, "notes": cost.notes,
    }
    rec["roofline"] = analytics.roofline(cost, chips=chips)
    # secondary collective term from the HLO parse: the compiled module is
    # post-SPMD-partitioning, so operand shapes (and hence bytes) are already
    # per-chip local
    rec["roofline"]["t_collective_hlo"] = \
        rec["collectives"]["total_bytes"] / analytics.ICI_BW

    if verbose:
        r = rec["roofline"]
        mem = rec["memory"]
        arg_gb = mem.get("argument_size_in_bytes", 0) / 1e9
        tmp_gb = mem.get("temp_size_in_bytes", 0) / 1e9
        print(f"  ok lower={rec['lower_s']}s compile={rec['compile_s']}s "
              f"args={arg_gb:.2f}GB temp={tmp_gb:.2f}GB "
              f"| t_comp={r['t_compute']*1e3:.1f}ms t_mem={r['t_memory']*1e3:.1f}ms "
              f"t_coll={r['t_collective']*1e3:.1f}ms -> {r['dominant']}"
              f" (roofline {r['roofline_fraction']*100:.0f}%)", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true",
                    help="only the 2x16x16 mesh")
    ap.add_argument("--single-pod", action="store_true",
                    help="only the 16x16 mesh")
    ap.add_argument("--rules", default="baseline",
                    choices=sorted(sharding.RULE_SETS) + ["production"])
    ap.add_argument("--accum", type=int, default=0,
                    help="grad accumulation (0 = per-arch default)")
    ap.add_argument("--serve-bf16", action="store_true",
                    help="bf16 weights for prefill/decode cells")
    ap.add_argument("--out", default=None, help="write JSON records here")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(configs.ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = []
    if not args.multi_pod:
        meshes.append(False)
    if not args.single_pod:
        meshes.append(True)

    records = []
    n_fail = 0
    for multi in meshes:
        for arch in archs:
            for shp in shapes:
                label = f"[{'2x16x16' if multi else '16x16'}] {arch} x {shp}"
                print(label, flush=True)
                rec = run_cell(arch, shp, multi_pod=multi, rules=args.rules,
                               accum=args.accum, serve_bf16=args.serve_bf16)
                records.append(rec)
                if rec["status"] == "fail":
                    n_fail += 1
                    print("  FAIL:", rec["error"], flush=True)
                elif rec["status"] == "skip":
                    print("  skip:", rec["reason"].split(";")[0], flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {len(records)} records to {args.out}")
    ok = sum(r["status"] == "ok" for r in records)
    skip = sum(r["status"] == "skip" for r in records)
    print(f"dry-run: {ok} ok, {skip} skip, {n_fail} fail "
          f"/ {len(records)} cells")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
