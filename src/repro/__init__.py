"""repro - preemption-aware JAX training framework.

The paper's contribution lives in ``repro.core``:
    distributions  - constrained-preemption model (Eq. 1-5) + baselines
    fitting        - pure-JAX Levenberg-Marquardt CDF fitting + GoF
    policies       - DP checkpointing (Eq. 11-15), scheduling (Eq. 6-10),
                     Young-Daly
    tonks          - the constrained-preemption lemma (exact + MC)
    simulator      - calibrated synthetic fleet traces
    service        - batch-computing-service simulation (Fig. 8)
    online         - continuous refitting + change-point detection

The training framework around it:
    models, kernels, sharding, data, optim, checkpoint, fault, configs,
    launch (mesh / train / serve / dryrun).
"""
