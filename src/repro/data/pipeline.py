"""Deterministic, shardable synthetic LM data pipeline.

Design requirements inherited from the fault-tolerance story:
  * **step-addressable** - batch(step) is a pure function of (seed, step), so
    a job resumed from checkpoint step k regenerates exactly the batches it
    would have seen (no data-loader state to checkpoint);
  * **elastic** - the global batch is carved by (replica_id, n_replicas), so
    after a pod loss the survivors re-shard the same global stream;
  * **structured** - tokens follow a Zipfian marginal with Markov mixing so
    the loss actually decreases during the e2e examples (a uniform stream
    would pin the loss at log V).

A real deployment swaps this module for a tokenized corpus reader with the
same (seed, step, replica) addressing contract.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.1
    markov_period: int = 16

    def _probs(self):
        ranks = np.arange(1, self.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-self.zipf_alpha)
        return jnp.asarray(p / p.sum(), jnp.float32)

    def batch(self, step: int, replica_id: int = 0, n_replicas: int = 1):
        """Returns {tokens, labels, mask} for this replica's slice of the
        global batch at ``step``; fully deterministic."""
        assert self.global_batch % n_replicas == 0
        local = self.global_batch // n_replicas
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        key = jax.random.fold_in(key, replica_id)
        logp = jnp.log(self._probs())
        draw = jax.random.categorical(
            key, logp[None, None, :], shape=(local, self.seq_len + 1))
        # Markov mixing: periodically repeat earlier tokens so there is
        # learnable structure (copy task flavored); the copy source sits in
        # the unreplaced half of the previous half-period so targets always
        # equal an OBSERVED token
        idx = jnp.arange(self.seq_len + 1)
        src = jnp.maximum(idx - self.markov_period // 2, 0)
        repeat_mask = (idx % self.markov_period) >= (self.markov_period // 2)
        seq = jnp.where(repeat_mask[None, :], draw[:, src], draw)
        tokens = seq[:, :-1]
        labels = seq[:, 1:]
        return {"tokens": tokens.astype(jnp.int32),
                "labels": labels.astype(jnp.int32),
                "mask": jnp.ones_like(labels, jnp.float32)}


def make_batch_specs(cfg, shape, *, for_loss: bool = True):
    """ShapeDtypeStructs of a training batch for (arch cfg, shape cell) -
    the dry-run's stand-ins (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    specs = {}
    if cfg.embeds_input:
        specs["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                               jnp.dtype(cfg.compute_dtype))
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.pos_type == "mrope":
        specs["positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
    if for_loss:
        specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        specs["mask"] = jax.ShapeDtypeStruct((B, S), jnp.float32)
    return specs
