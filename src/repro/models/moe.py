"""Mixture-of-Experts block: top-k router + capacity-bounded dispatch/combine
(GShard/Switch style, einsum-based so GSPMD shards experts over the `model`
mesh axis = expert parallelism).

Used by moonshot-v1-16b-a3b (64e top-6) and phi3.5-moe-42b-a6.6b (16e top-2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import sharding
from ..sharding import annotate as A
from .layers import cdt, pdt, init_rmsnorm, rms_norm, init_attention, \
    attention_block, _normal


def init_moe_mlp(key, cfg):
    ks = jax.random.split(key, 4)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": A(_normal(ks[0], (d, e), pdt(cfg)), "w_embed", "w_experts"),
        "gate": A(_normal(ks[1], (e, d, f), pdt(cfg)), "w_experts",
                  "w_expert_ff", None),
        "up": A(_normal(ks[2], (e, d, f), pdt(cfg)), "w_experts",
                "w_expert_ff", None),
        "down": A(_normal(ks[3], (e, f, d), pdt(cfg)), "w_experts", None,
                  "w_expert_ff"),
    }


MOE_GROUP = 512  # tokens per dispatch group (bounds the one-hot tensors)


def _group_size(T: int) -> int:
    g = min(MOE_GROUP, T)
    while T % g:
        g //= 2
    return max(g, 1)


def moe_mlp(cfg, p, x):
    """x: (B, S, d) -> (B, S, d) with top-k expert routing.

    GShard-style grouped dense dispatch: tokens are split into groups of
    ~MOE_GROUP, each group routes into per-expert capacity buffers via
    one-hot einsums, so everything stays GSPMD-shardable (groups follow the
    batch/data axis, experts the `model` axis) and the dispatch tensors stay
    O(group * E * C) instead of O(T * E * C).
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    dt = cdt(cfg)
    T = B * S
    Tg = _group_size(T)
    G = T // Tg
    xt = x.reshape(G, Tg, d)
    logits = jnp.einsum("gtd,de->gte", xt,
                        p["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (G, Tg, E)
    gate_vals, idx = jax.lax.top_k(probs, K)                    # (G, Tg, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    capacity = max(int(cfg.capacity_factor * Tg * K / E), 4)
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)          # (G, Tg, K, E)
    # position of each (token, k) within its expert's per-group buffer
    pos = jnp.cumsum(onehot.reshape(G, Tg * K, E), axis=1) \
        .reshape(G, Tg, K, E) - 1.0
    keep = (pos < capacity) & (onehot > 0)
    slot = jnp.where(keep, pos, -1.0).max(-1)                   # (G, Tg, K)
    pos_oh = jax.nn.one_hot(slot, capacity, dtype=jnp.float32)  # (G, Tg, K, C)
    disp = jnp.einsum("gtke,gtkc->gtec", onehot * keep, pos_oh)  # (G,Tg,E,C)
    comb = jnp.einsum("gtec,gtk,gtke->gtec", disp,
                      gate_vals.astype(jnp.float32), onehot)

    xe = jnp.einsum("gtd,gtec->gecd", xt.astype(jnp.float32),
                    disp).astype(dt)                            # (G, E, C, d)
    xe = sharding.constrain(xe, "act_batch", "act_experts", None, None)
    g = jnp.einsum("gecd,edf->gecf", xe, p["gate"].astype(dt))
    u = jnp.einsum("gecd,edf->gecf", xe, p["up"].astype(dt))
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("gecf,efd->gecd", h, p["down"].astype(dt))
    ye = sharding.constrain(ye, "act_batch", "act_experts", None, None)
    yt = jnp.einsum("gecd,gtec->gtd", ye.astype(jnp.float32), comb)
    y = yt.reshape(B, S, d).astype(x.dtype)
    return sharding.constrain(y, "act_batch", "act_seq", "act_embed")


def aux_load_balance_loss(cfg, x, p):
    """Switch-style load-balance auxiliary (fraction * router prob per expert)."""
    dt = cdt(cfg)
    T = x.shape[0] * x.shape[1]
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(dt))
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1).reshape(T, -1)
    top1 = jnp.argmax(probs, -1)
    frac = jnp.mean(jax.nn.one_hot(top1, cfg.n_experts, dtype=jnp.float32), 0)
    return cfg.n_experts * jnp.sum(frac * probs.mean(0))


def init_moe_layer(key, cfg):
    ks = jax.random.split(key, 2)
    return {"ln1": init_rmsnorm(cfg), "attn": init_attention(ks[0], cfg),
            "ln2": init_rmsnorm(cfg), "moe": init_moe_mlp(ks[1], cfg)}


def moe_layer(cfg, p, x, *, positions, cache=None, mode="train", window=0):
    h, new_cache = attention_block(cfg, p["attn"],
                                   rms_norm(x, p["ln1"], cfg.norm_eps),
                                   positions=positions, cache=cache, mode=mode,
                                   window=window)
    x = x + h
    x = x + moe_mlp(cfg, p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps))
    return x, new_cache
