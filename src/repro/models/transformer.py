"""Model assembly: block pattern -> scanned groups (+ tail), train / prefill /
decode entry points, loss.

Layers are grouped by the architecture's block-pattern period (dense/MoE: 1;
RecurrentGemma: (rglru, rglru, local_attn); xLSTM: 7x mlstm + 1x slstm) and
per-period-position parameters are stacked over groups so the forward pass is
a single ``lax.scan`` - HLO size and compile time are O(pattern), not
O(n_layers), which is what makes 60-layer 34B dry-runs tractable.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .. import sharding
from ..sharding import split_annotated
from . import layers as L
from . import moe as M
from . import rglru as R
from . import xlstm as X


def _kv_cache_len(cfg, kind, max_len):
    if kind == "local_attn" and cfg.window:
        return min(max_len, cfg.window)
    return max_len


BLOCKS = {
    "attn": dict(init=L.init_attn_layer, apply=L.attn_layer,
                 cache=lambda cfg, b, s: L.init_kv_cache(cfg, b, s),
                 window=lambda cfg: 0),
    "local_attn": dict(init=L.init_attn_layer, apply=L.attn_layer,
                       cache=lambda cfg, b, s: L.init_kv_cache(
                           cfg, b, _kv_cache_len(cfg, "local_attn", s)),
                       window=lambda cfg: cfg.window),
    "moe": dict(init=M.init_moe_layer, apply=M.moe_layer,
                cache=lambda cfg, b, s: L.init_kv_cache(cfg, b, s),
                window=lambda cfg: 0),
    "rglru": dict(init=R.init_rglru_layer, apply=R.rglru_layer,
                  cache=lambda cfg, b, s: R.init_rglru_cache(cfg, b),
                  window=lambda cfg: 0),
    "mlstm": dict(init=X.init_mlstm_layer, apply=X.mlstm_layer,
                  cache=lambda cfg, b, s: X.init_mlstm_cache(cfg, b),
                  window=lambda cfg: 0),
    "slstm": dict(init=X.init_slstm_layer, apply=X.slstm_layer,
                  cache=lambda cfg, b, s: X.init_slstm_cache(cfg, b),
                  window=lambda cfg: 0),
}


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init(cfg, key):
    """Returns (params, logical_axes) plain pytrees."""
    period = cfg.block_pattern
    n_groups, n_tail = cfg.n_groups, cfg.n_tail
    keys = jax.random.split(key, 4 + len(period) + n_tail)
    k_embed, k_head = keys[0], keys[1]

    annotated = {
        "embed": L.init_embed(k_embed, cfg),
        "final_norm": L.init_rmsnorm(cfg),
    }
    if not cfg.tie_embeddings:
        annotated["lm_head"] = L.init_lm_head(k_head, cfg)
    params, axes = split_annotated(annotated)

    groups_p, groups_ax = [], []
    for pidx, kind in enumerate(period):
        init_fn = BLOCKS[kind]["init"]
        _, ax1 = split_annotated(init_fn(keys[4 + pidx], cfg))
        gkeys = jax.random.split(keys[4 + pidx], n_groups)
        stacked = jax.vmap(lambda k: split_annotated(init_fn(k, cfg))[0])(gkeys)
        groups_p.append(stacked)
        groups_ax.append(jax.tree_util.tree_map(
            lambda a: ("layers",) + a, ax1,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x)))
    params["groups"] = groups_p
    axes["groups"] = groups_ax

    tail_p, tail_ax = [], []
    for t in range(n_tail):
        kind = period[t]
        p1, ax1 = split_annotated(
            BLOCKS[kind]["init"](keys[4 + len(period) + t], cfg))
        tail_p.append(p1)
        tail_ax.append(ax1)
    params["tail"] = tail_p
    axes["tail"] = tail_ax
    return params, axes


def init_cache(cfg, batch, max_len):
    """Decode/prefill cache pytree, mirroring the group/tail structure."""
    period = cfg.block_pattern
    groups = []
    for kind in period:
        single = BLOCKS[kind]["cache"](cfg, batch, max_len)
        groups.append(jax.tree_util.tree_map(
            lambda a: jnp.zeros((cfg.n_groups,) + a.shape, a.dtype), single))
    tail = [BLOCKS[period[t]]["cache"](cfg, batch, max_len)
            for t in range(cfg.n_tail)]
    return {"groups": groups, "tail": tail,
            "t": jnp.zeros((), jnp.int32)}


def cache_axes(cfg):
    """Logical axes for the cache pytree (for dry-run shardings).

    Built from the *unstacked* per-layer cache structure (eval_shape, no
    allocation); group entries get a leading "layers" axis for the scan
    stacking.
    """
    def one_ax(name, ndim):
        if name in ("k", "v"):
            return ("cache_batch", "cache_seq", "cache_kv", "cache_dim")
        if name == "conv":
            return ("cache_batch", None, "act_lru")
        if name == "pos":
            return ()
        # recurrent states: batch-sharded, rest replicated
        return ("cache_batch",) + (None,) * (ndim - 1)

    period = cfg.block_pattern
    groups, tail = [], []
    for pidx, kind in enumerate(period):
        single = jax.eval_shape(
            lambda: BLOCKS[kind]["cache"](cfg, 2, 8))
        ax = {k: one_ax(k, v.ndim) for k, v in single.items()}
        groups.append({k: ("layers",) + tuple(v) for k, v in ax.items()})
        if pidx < cfg.n_tail:
            tail.append(ax)
    return {"groups": groups, "tail": tail, "t": ()}


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _apply_block(cfg, kind, p, x, *, positions, cache, mode):
    window = BLOCKS[kind]["window"](cfg)
    return BLOCKS[kind]["apply"](cfg, p, x, positions=positions, cache=cache,
                                 mode=mode, window=window)


def forward(cfg, params, tokens=None, *, embeds=None, positions=None,
            cache=None, mode: str = "train"):
    """Returns (logits, new_cache)."""
    period = cfg.block_pattern
    if tokens is not None:
        x = L.embed(params["embed"], tokens, cfg)
        B, S = tokens.shape
    else:
        x = embeds.astype(L.cdt(cfg))
        B, S = embeds.shape[:2]
        x = sharding.constrain(x, "act_batch", "act_seq", "act_embed")

    if positions is None:
        t0 = cache["t"] if cache is not None else jnp.zeros((), jnp.int32)
        base = t0 + jnp.arange(S, dtype=jnp.int32)[None, :]
        pos_arr = jnp.broadcast_to(base, (B, S))
        if cfg.pos_type == "mrope":
            pos_arr = jnp.broadcast_to(pos_arr[None], (3, B, S))
        positions = pos_arr

    def group_body(x, xs):
        gparams, gcache = xs
        new_caches = []
        for pidx, kind in enumerate(period):
            c = None if gcache is None else gcache[pidx]
            x, nc = _apply_block(cfg, kind, gparams[pidx], x,
                                 positions=positions, cache=c, mode=mode)
            new_caches.append(nc)
        return x, (None if gcache is None else new_caches)

    body = group_body
    if cfg.remat and mode == "train":
        body = jax.checkpoint(group_body,
                              policy=jax.checkpoint_policies.nothing_saveable)

    new_cache = None
    gcaches = None if cache is None else cache["groups"]
    if cfg.scan_layers and cfg.n_groups > 1:
        x, new_gcaches = jax.lax.scan(body, x, (params["groups"], gcaches))
    else:
        new_gcaches = [] if gcaches is not None else None
        for g in range(cfg.n_groups):
            gp = jax.tree_util.tree_map(lambda a: a[g], params["groups"])
            gc = None if gcaches is None else jax.tree_util.tree_map(
                lambda a: a[g], gcaches)
            x, nc = body(x, (gp, gc))
            if gcaches is not None:
                new_gcaches.append(nc)
        if gcaches is not None and new_gcaches:
            new_gcaches = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *new_gcaches)

    new_tail = None if cache is None else []
    for t in range(cfg.n_tail):
        kind = period[t]
        c = None if cache is None else cache["tail"][t]
        x, nc = _apply_block(cfg, kind, params["tail"][t], x,
                             positions=positions, cache=c, mode=mode)
        if cache is not None:
            new_tail.append(nc)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params.get("lm_head"), params["embed"], x, cfg)
    if cache is not None:
        new_cache = {"groups": new_gcaches, "tail": new_tail,
                     "t": cache["t"] + S}
    return logits, new_cache


# ---------------------------------------------------------------------------
# loss / steps
# ---------------------------------------------------------------------------

def lm_loss(cfg, params, batch):
    """Next-token cross-entropy (mean over valid positions).  ``batch`` has
    tokens (B,S) [or embeds], labels (B,S), and optional mask (B,S)."""
    logits, _ = forward(cfg, params, batch.get("tokens"),
                        embeds=batch.get("embeds"),
                        positions=batch.get("positions"), mode="train")
    labels = batch["labels"]
    mask = batch.get("mask")
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        mask = jnp.ones_like(nll)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    # z-loss keeps logits bounded on long runs (Chowdhery et al.)
    zloss = 1e-4 * jnp.sum((logz * mask) ** 2) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + zloss, {"nll": loss, "zloss": zloss}


def prefill_step(cfg, params, tokens=None, *, embeds=None, positions=None,
                 cache=None):
    """Full-context forward building the KV/state cache."""
    logits, cache = forward(cfg, params, tokens, embeds=embeds,
                            positions=positions, cache=cache, mode="prefill")
    return logits[:, -1:], cache


def decode_step(cfg, params, tokens=None, *, embeds=None, positions=None,
                cache=None):
    """One new token against an existing cache."""
    logits, cache = forward(cfg, params, tokens, embeds=embeds,
                            positions=positions, cache=cache, mode="decode")
    return logits, cache
