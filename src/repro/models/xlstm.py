"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable) and
sLSTM (scalar memory, sequential) with exponential gating and stabilizers.

mLSTM runs in chunkwise-parallel form for train/prefill (O(S/chunk) sequential
steps, intra-chunk parallel) and in pure recurrent form for decode; the
step-by-step oracle lives in kernels/ref.py (mlstm_chunkwise).

Block layout follows xLSTM[7:1]: mostly mLSTM blocks with a periodic sLSTM.
The mLSTM block up-projects 2x (pre-LN residual), applies the cell over
heads, gates the output, and down-projects; d_ff == 0 (no separate FFN).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import sharding
from ..kernels import ref as kref
from ..sharding import annotate as A
from .layers import _normal, cdt, pdt, init_rmsnorm, rms_norm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm_layer(key, cfg):
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    inner = 2 * d
    H = cfg.n_heads
    hd = inner // H
    return {
        "ln": init_rmsnorm(cfg),
        "up_v": A(_normal(ks[0], (d, inner), pdt(cfg)), "w_embed", "w_inner"),
        "up_g": A(_normal(ks[1], (d, inner), pdt(cfg)), "w_embed", "w_inner"),
        # block-diagonal per-head projections (xLSTM implementation choice);
        # 2-D sharded: contraction dim over data (FSDP), output over model
        "wq": A(_normal(ks[2], (H, hd, hd), pdt(cfg)), None, "w_embed",
                "w_inner"),
        "wk": A(_normal(ks[3], (H, hd, hd), pdt(cfg)), None, "w_embed",
                "w_inner"),
        "wv": A(_normal(ks[4], (H, hd, hd), pdt(cfg)), None, "w_embed",
                "w_inner"),
        "w_i": A(_normal(ks[5], (inner, H), pdt(cfg)), "w_inner", None),
        "b_i": A(jnp.zeros((H,), pdt(cfg)), None),
        "w_f": A(_normal(ks[6], (inner, H), pdt(cfg)), "w_inner", None),
        # forget bias init positive => long memory at init
        "b_f": A(3.0 * jnp.ones((H,), pdt(cfg)), None),
        "down": A(_normal(ks[7], (inner, d), pdt(cfg)), "w_inner", "w_embed"),
    }


def init_mlstm_cache(cfg, batch):
    H = cfg.n_heads
    hd = (2 * cfg.d_model) // H
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }


def _mlstm_gates(p, h, H, dt):
    """log-space input/forget gates per head. h: (B,S,inner)."""
    li = (jnp.einsum("bsi,ih->bsh", h, p["w_i"].astype(dt))
          + p["b_i"].astype(dt)).astype(jnp.float32)
    lf_pre = (jnp.einsum("bsi,ih->bsh", h, p["w_f"].astype(dt))
              + p["b_f"].astype(dt)).astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(lf_pre)
    return log_f, li


def mlstm_layer(cfg, p, x, *, positions=None, cache=None, mode="train",
                window=0):
    B, S, d = x.shape
    dt = cdt(cfg)
    H = cfg.n_heads
    inner = 2 * d
    hd = inner // H
    h_in = rms_norm(x, p["ln"], cfg.norm_eps)
    hv = jnp.einsum("bsd,di->bsi", h_in, p["up_v"].astype(dt))
    hg = jnp.einsum("bsd,di->bsi", h_in, p["up_g"].astype(dt))
    hv = sharding.constrain(hv, "act_batch", "act_seq", "act_inner")
    hvh = hv.reshape(B, S, H, hd)
    q = jnp.einsum("bshd,hde->bshe", hvh, p["wq"].astype(dt))
    k = jnp.einsum("bshd,hde->bshe", hvh, p["wk"].astype(dt))
    v = jnp.einsum("bshd,hde->bshe", hvh, p["wv"].astype(dt))
    log_f, log_i = _mlstm_gates(p, hv, H, dt)

    if mode == "decode":
        assert cache is not None and S == 1
        out, (C, n, m) = kref.mlstm_chunkwise(
            q, k, v, log_f, log_i, c0=cache["C"], n0=cache["n"], m0=cache["m"])
        new_cache = {"C": C, "n": n, "m": m, "pos": cache["pos"] + 1}
    else:
        out, (C, n, m) = mlstm_chunkwise_parallel(q, k, v, log_f, log_i,
                                                  chunk=cfg.mlstm_chunk)
        new_cache = cache
        if mode == "prefill" and cache is not None:
            new_cache = {"C": C, "n": n, "m": m,
                         "pos": cache["pos"] + S}
    out = out.reshape(B, S, inner)
    out = out * jax.nn.silu(hg)
    y = jnp.einsum("bsi,id->bsd", out.astype(dt), p["down"].astype(dt))
    return x + sharding.constrain(y, "act_batch", "act_seq", "act_embed"), \
        new_cache


def mlstm_chunkwise_parallel(q, k, v, log_f, log_i, *, chunk=256, eps=1e-6):
    """Chunkwise-parallel mLSTM: sequential scan over chunks, parallel inside
    each chunk (quadratic in chunk only).  Matches kernels/ref.py
    mlstm_chunkwise to fp32 tolerance (tests sweep shapes).
    """
    B, S, H, D = q.shape
    c = min(chunk, S)
    while S % c:
        c //= 2
    N = S // c
    scale = D ** -0.5
    qc = q.astype(jnp.float32).reshape(B, N, c, H, D) * scale
    kc = k.astype(jnp.float32).reshape(B, N, c, H, D)
    vc = v.astype(jnp.float32).reshape(B, N, c, H, D)
    lf = log_f.astype(jnp.float32).reshape(B, N, c, H)
    li = log_i.astype(jnp.float32).reshape(B, N, c, H)

    # cumulative log forget within each chunk: F[t] = sum_{u<=t} lf[u]
    Fc = jnp.cumsum(lf, axis=2)                       # (B,N,c,H)
    Ftot = Fc[:, :, -1]                               # (B,N,H)

    def chunk_step(carry, xs):
        C, n, m = carry                  # (B,H,D,D), (B,H,D), (B,H)
        qb, kb, vb, ib, Fb, Ftot_b = xs  # (B,c,H,D) / (B,c,H) / (B,H)
        # source term s[j] = li[j] - F[j]; intra weight for j<=t is
        # exp(F[t] + s[j] - m_t); inter (carry) weight is exp(F[t] + m - m_t)
        s_src = ib - Fb                               # (B,c,H)
        cummax_s = jax.lax.associative_scan(jnp.maximum, s_src, axis=1)
        # per-position stabilizer (equals the sequential recursion's m_t):
        m_t = jnp.maximum(Fb + m[:, None], Fb + cummax_s)   # (B,c,H)
        logits = Fb[:, :, None] - Fb[:, None, :] + ib[:, None, :]  # (B,t,j,H)
        tri = jnp.tril(jnp.ones((c, c), bool))
        logits = jnp.where(tri[None, :, :, None], logits, -jnp.inf)
        w = jnp.exp(logits - m_t[:, :, None])         # (B,t,j,H)
        att = jnp.einsum("bthd,bjhd->btjh", qb, kb)   # (B,t,j,H)
        num_intra = jnp.einsum("btjh,btjh,bjhd->bthd", att, w, vb)
        den_intra = jnp.einsum("btjh,btjh->bth", att, w)
        inter_w = jnp.exp(Fb + m[:, None] - m_t)      # (B,c,H)
        num_inter = jnp.einsum("bthd,bhde->bthe", qb, C) * inter_w[..., None]
        den_inter = jnp.einsum("bthd,bhd->bth", qb, n) * inter_w
        num = num_intra + num_inter
        den = jnp.maximum(jnp.abs(den_intra + den_inter), jnp.exp(-m_t)) + eps
        out = num / den[..., None]
        # carry update to the chunk end (t = c)
        m_next = jnp.maximum(Ftot_b + m, Ftot_b + jnp.max(s_src, axis=1))
        wC = jnp.exp(Ftot_b[:, None] + s_src - m_next[:, None])  # (B,c,H)
        decay = jnp.exp(Ftot_b + m - m_next)
        C_new = decay[:, :, None, None] * C \
            + jnp.einsum("bjh,bjhd,bjhe->bhde", wC, kb, vb)
        n_new = decay[:, :, None] * n + jnp.einsum("bjh,bjhd->bhd", wC, kb)
        return (C_new, n_new, m_next), out

    C0 = jnp.zeros((B, H, D, D), jnp.float32)
    n0 = jnp.zeros((B, H, D), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    xs = (jnp.moveaxis(qc, 1, 0), jnp.moveaxis(kc, 1, 0),
          jnp.moveaxis(vc, 1, 0), jnp.moveaxis(li, 1, 0),
          jnp.moveaxis(Fc, 1, 0), jnp.moveaxis(Ftot, 1, 0))
    (C, n, m), out = jax.lax.scan(chunk_step, (C0, n0, m0), xs)
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, H, D)
    return out.astype(q.dtype), (C, n, m)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm_layer(key, cfg):
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "ln": init_rmsnorm(cfg),
        # fused (z, i, f, o) input projection
        "w_in": A(_normal(ks[0], (d, 4 * d), pdt(cfg)), "w_embed", "w_inner"),
        "w_rec": A(_normal(ks[1], (d, 4 * d), pdt(cfg)), "w_embed", "w_inner"),
        "bias": A(jnp.concatenate([jnp.zeros((2 * d,)), 3.0 * jnp.ones((d,)),
                                   jnp.zeros((d,))]).astype(pdt(cfg)),
                  "w_inner"),
        "down": A(_normal(ks[2], (d, d), pdt(cfg)), "w_embed", "w_inner"),
    }


def init_slstm_cache(cfg, batch):
    d = cfg.d_model
    z = lambda: jnp.zeros((batch, d), jnp.float32)
    return {"h": z(), "c": z(), "n": z(), "m": jnp.full((batch, d), -1e30,
                                                        jnp.float32),
            "pos": jnp.zeros((), jnp.int32)}


def _slstm_cell(x_t, state):
    """One sLSTM step with exponential gating + stabilizer.
    x_t: (B, 4d) pre-activations (input part); state h used for recurrence."""
    h, c, n, m, w_rec, bias = state
    pre = x_t + h @ w_rec + bias
    z, i_pre, f_pre, o_pre = jnp.split(pre, 4, axis=-1)
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    c_new = f_g * c + i_g * jnp.tanh(z)
    n_new = f_g * n + i_g
    h_new = jax.nn.sigmoid(o_pre) * c_new / jnp.maximum(n_new, 1e-6)
    return h_new, c_new, n_new, m_new


def slstm_layer(cfg, p, x, *, positions=None, cache=None, mode="train",
                window=0):
    B, S, d = x.shape
    dt = cdt(cfg)
    h_in = rms_norm(x, p["ln"], cfg.norm_eps)
    pre = jnp.einsum("bsd,dk->bsk", h_in, p["w_in"].astype(dt)) \
        .astype(jnp.float32)
    w_rec = p["w_rec"].astype(jnp.float32)
    bias = p["bias"].astype(jnp.float32)
    if cache is not None and mode == "decode":
        h, c, n, m = cache["h"], cache["c"], cache["n"], cache["m"]
    else:
        zeros = jnp.zeros((B, d), jnp.float32)
        h, c, n, m = zeros, zeros, zeros, jnp.full((B, d), -1e30, jnp.float32)

    def step(carry, x_t):
        h, c, n, m = carry
        h, c, n, m = _slstm_cell(x_t, (h, c, n, m, w_rec, bias))
        return (h, c, n, m), h

    (h, c, n, m), hs = jax.lax.scan(step, (h, c, n, m),
                                    jnp.moveaxis(pre, 1, 0))
    out = jnp.moveaxis(hs, 0, 1)                       # (B,S,d)
    y = jnp.einsum("bsd,dk->bsk", out.astype(dt), p["down"].astype(dt))
    new_cache = cache
    if cache is not None and mode in ("decode", "prefill"):
        new_cache = {"h": h, "c": c, "n": n, "m": m,
                     "pos": cache["pos"] + S}
    return x + sharding.constrain(y, "act_batch", "act_seq", "act_embed"), \
        new_cache
