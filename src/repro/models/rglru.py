"""RecurrentGemma-style recurrent block: RG-LRU gated linear recurrence with a
short temporal conv, mixed 2:1 with local sliding-window attention
(arXiv:2402.19427).

The RG-LRU core once gates are computed is the generic linear recurrence
h_t = a_t * h_{t-1} + b_t, dispatched through kernels.ops (associative scan
on XLA, Pallas sequence-blocked kernel on TPU).

    r_t = sigmoid(W_a x_t)                      (recurrence gate)
    i_t = sigmoid(W_x x_t)                      (input gate)
    log a_t = -c * softplus(Lambda) * r_t       (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import sharding
from ..kernels import ops
from ..sharding import annotate as A
from .layers import (_normal, cdt, pdt, init_rmsnorm, init_mlp, mlp_block,
                     rms_norm)

_C = 8.0


def init_rglru_layer(key, cfg):
    ks = jax.random.split(key, 8)
    d, w = cfg.d_model, cfg.lru_width
    p = {
        "ln1": init_rmsnorm(cfg),
        "in_x": A(_normal(ks[0], (d, w), pdt(cfg)), "w_embed", "w_lru"),
        "in_gate": A(_normal(ks[1], (d, w), pdt(cfg)), "w_embed", "w_lru"),
        "conv": A(_normal(ks[2], (cfg.conv_width, w), pdt(cfg)), "w_conv",
                  "w_lru"),
        "w_a": A(_normal(ks[3], (w,), pdt(cfg)), "w_lru"),
        "b_a": A(jnp.zeros((w,), pdt(cfg)), "w_lru"),
        "w_i": A(_normal(ks[4], (w,), pdt(cfg)), "w_lru"),
        "b_i": A(jnp.zeros((w,), pdt(cfg)), "w_lru"),
        # Lambda init so a^c lands in (0.9, 0.999) - the paper's stable range
        "lam": A(jnp.asarray(
            jnp.log(jnp.expm1(-jnp.log(jnp.linspace(0.9, 0.999, w)) / _C)),
            pdt(cfg)), "w_lru"),
        "out": A(_normal(ks[5], (w, d), pdt(cfg)), "w_lru", "w_embed"),
    }
    if cfg.d_ff:
        p["ln2"] = init_rmsnorm(cfg)
        p["mlp"] = init_mlp(ks[6], cfg)
    return p


def init_rglru_cache(cfg, batch, dtype=None):
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    w = cfg.lru_width
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
        "h": jnp.zeros((batch, w), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }


def _causal_conv(x, kernel, state=None):
    """Depthwise causal conv along seq. x: (B,S,W); kernel: (cw, W);
    state: (B, cw-1, W) history for decode."""
    cw = kernel.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)             # (B, S+cw-1, W)
    out = sum(xp[:, i:i + x.shape[1]] * kernel[i] for i in range(cw))
    new_state = xp[:, -(cw - 1):] if cw > 1 else pad
    return out, new_state


def rglru_core(cfg, p, u, h0=None):
    """u: (B,S,W) conv output. Returns (y, h_last)."""
    dt = cdt(cfg)
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf * p["w_a"].astype(jnp.float32)
                       + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf * p["w_i"].astype(jnp.float32)
                       + p["b_i"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * (i * uf)
    h, h_last = ops.linear_recurrence(a.astype(dt), b.astype(dt),
                                      None if h0 is None else h0.astype(dt))
    return h, h_last


def rglru_layer(cfg, p, x, *, positions=None, cache=None, mode="train",
                window=0):
    """The recurrent block: norm -> (gate branch || conv+RG-LRU branch) ->
    out-proj -> +residual -> MLP."""
    B, S, d = x.shape
    dt = cdt(cfg)
    h_in = rms_norm(x, p["ln1"], cfg.norm_eps)
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", h_in, p["in_gate"].astype(dt)))
    u = jnp.einsum("bsd,dw->bsw", h_in, p["in_x"].astype(dt))
    u = sharding.constrain(u, "act_batch", "act_seq", "act_lru")

    conv_state = cache["conv"] if cache is not None else None
    h0 = cache["h"] if cache is not None else None
    u, new_conv = _causal_conv(u, p["conv"].astype(dt), conv_state)
    rec, h_last = rglru_core(cfg, p, u, h0)
    y = jnp.einsum("bsw,wd->bsd", (rec * gate).astype(dt),
                   p["out"].astype(dt))
    x = x + sharding.constrain(y, "act_batch", "act_seq", "act_embed")
    if cfg.d_ff:
        x = x + mlp_block(cfg, p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
    new_cache = cache
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "h": h_last.astype(jnp.float32),
                     "pos": cache["pos"] + S}
    return x, new_cache
