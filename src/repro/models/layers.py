"""Shared model layers: norms, projections, rotary embeddings, GQA attention
blocks, SwiGLU MLP, KV caches.

Everything is a pure function over explicit parameter pytrees.  Parameters are
created annotated with logical sharding axes (repro.sharding.P) and stripped
by the model assembler; activations pass through ``sharding.constrain`` at
strategic points so GSPMD propagation has anchors.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import sharding
from ..kernels import ops
from ..sharding import annotate as A

_INIT_SCALE = 0.02


def _normal(key, shape, dtype, scale=_INIT_SCALE):
    return scale * jax.random.normal(key, shape, dtype)


def cdt(cfg):
    return jnp.dtype(cfg.compute_dtype)


def pdt(cfg):
    return jnp.dtype(cfg.param_dtype)


# -- norms --------------------------------------------------------------------

def init_rmsnorm(cfg, d=None):
    d = d or cfg.d_model
    return {"scale": A(jnp.ones((d,), pdt(cfg)), "act_embed")}


def rms_norm(x, p, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# -- rotary embeddings ---------------------------------------------------------

def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta):
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (B,S,D/2)
    cos, sin = jnp.cos(ang)[:, :, None], jnp.sin(ang)[:, :, None]  # (B,S,1,D/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions, theta, sections):
    """Qwen2-VL M-RoPE. x: (B,S,H,D); positions: (3,B,S) (t/h/w streams);
    ``sections`` split D/2 rotary frequencies across the three streams."""
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang_all = positions[..., None].astype(jnp.float32) * freqs  # (3,B,S,D/2)
    idx = []
    for i, sec in enumerate(sections):
        idx += [i] * sec
    onehot = jax.nn.one_hot(jnp.asarray(idx), 3, dtype=jnp.float32)  # (D/2,3)
    ang = jnp.einsum("nbsd,dn->bsd", ang_all, onehot)  # (B,S,D/2)
    cos, sin = jnp.cos(ang)[:, :, None], jnp.sin(ang)[:, :, None]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -- embedding / unembedding ---------------------------------------------------

def init_embed(key, cfg):
    p = {"table": A(_normal(key, (cfg.vocab_size, cfg.d_model), pdt(cfg)),
                    "w_vocab", "w_embed")}
    return p


def embed(p, tokens, cfg):
    x = jnp.take(p["table"].astype(cdt(cfg)), tokens, axis=0)
    return sharding.constrain(x, "act_batch", "act_seq", "act_embed")


def init_lm_head(key, cfg):
    return {"out": A(_normal(key, (cfg.d_model, cfg.vocab_size), pdt(cfg)),
                     "w_embed", "w_vocab")}


def unembed(p_head, p_embed, x, cfg):
    if cfg.tie_embeddings:
        w = p_embed["table"].astype(cdt(cfg)).T
    else:
        w = p_head["out"].astype(cdt(cfg))
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    return sharding.constrain(logits, "act_batch", "act_seq", "act_vocab")


# -- attention block -----------------------------------------------------------

def init_attention(key, cfg):
    ks = jax.random.split(key, 4)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    return {
        "wq": A(_normal(ks[0], (d, qd), pdt(cfg)), "w_embed", "w_qdim"),
        "wk": A(_normal(ks[1], (d, kvd), pdt(cfg)), "w_embed", "w_kv_dim"),
        "wv": A(_normal(ks[2], (d, kvd), pdt(cfg)), "w_embed", "w_kv_dim"),
        "wo": A(_normal(ks[3], (qd, d), pdt(cfg)), "w_qdim", "w_embed"),
    }


def init_kv_cache(cfg, batch, max_len, dtype=None):
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((batch, max_len, kv, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def _rope_qk(cfg, q, k, positions):
    if cfg.pos_type == "rope":
        return (apply_rope(q, positions, cfg.rope_theta),
                apply_rope(k, positions, cfg.rope_theta))
    if cfg.pos_type == "mrope":
        return (apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections),
                apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections))
    return q, k


def attention_block(cfg, p, x, *, positions, cache=None, mode="train",
                    window=0):
    """x: (B, S, d).  Returns (out, new_cache).

    train/prefill: full (windowed-)causal attention; prefill writes the cache.
    decode: S == 1; append to cache (ring buffer when windowed).
    """
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cdt(cfg)
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"].astype(dt)).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,dq->bsq", x, p["wk"].astype(dt)).reshape(B, S, KV, hd)
    v = jnp.einsum("bsd,dq->bsq", x, p["wv"].astype(dt)).reshape(B, S, KV, hd)
    q, k = _rope_qk(cfg, q, k, positions)
    if mode != "decode":
        # under sequence parallelism the residual stream is seq-sharded, but
        # attention mixes the whole sequence: gather q/k/v ONCE here so the
        # collective hoists out of the blocked-attention scan (without this
        # anchor GSPMD re-gathers every (q-block, kv-block) iteration -
        # measured 2.06 TB/chip/step on yi-34b train_4k; see EXPERIMENTS.md
        # §Perf iteration A2)
        q = sharding.constrain(q, "act_batch", None, "act_heads", None)
        k = sharding.constrain(k, "act_batch", None, None, None)
        v = sharding.constrain(v, "act_batch", None, None, None)

    new_cache = cache
    if mode == "decode":
        assert cache is not None and S == 1
        pos = cache["pos"]
        size = cache["k"].shape[1]
        # windowed layers use a ring buffer; keys are pre-RoPEd with absolute
        # positions so softmax is order-invariant (ring alignment assumes any
        # prefill length was a multiple of the window, true for all cells)
        slot = pos % size if window > 0 else jnp.minimum(pos, size - 1)
        # one-hot masked write instead of dynamic_update_slice: elementwise,
        # so GSPMD keeps the cache sharded along seq (a dynamic slice-update
        # at a traced index on a sharded dim triggers involuntary full
        # rematerialization - ~GBs of temp per layer at 32k context)
        hit = (jnp.arange(size) == slot)[None, :, None, None]
        ck = jnp.where(hit, k.astype(cache["k"].dtype), cache["k"])
        cv = jnp.where(hit, v.astype(cache["v"].dtype), cache["v"])
        # anchor: keep the cache seq-sharded through the attention and
        # gather the (tiny) query head dim instead - otherwise GSPMD picks a
        # kv-sharded layout for the einsum and reshards the multi-GB cache
        # every layer ("involuntary full rematerialization"; §Perf C2)
        ck = sharding.constrain(ck, "cache_batch", "cache_seq", "cache_kv",
                                "cache_dim")
        cv = sharding.constrain(cv, "cache_batch", "cache_seq", "cache_kv",
                                "cache_dim")
        q0 = sharding.constrain(q[:, 0], "act_batch", None, None)
        lengths = jnp.minimum(pos + 1, size) * jnp.ones((B,), jnp.int32)
        out = ops.decode_attention(q0, ck, cv, lengths, impl="xla")
        out = out[:, None]                                  # (B,1,H,hd)
        new_cache = {"k": ck, "v": cv, "pos": pos + 1}
    else:
        impl = cfg.attention_impl
        out = ops.attention(q, k, v, causal=True, window=window, impl=impl)
        if mode == "prefill":
            assert cache is not None
            size = cache["k"].shape[1]
            if window > 0 and size < S:
                # ring buffer: slot of absolute position p is p % size, so
                # the tail S-size..S-1 lands rolled by S % size - decode's
                # next write (slot S % size) then overwrites exactly the
                # oldest entry
                kk = jnp.roll(k[:, -size:], S % size, axis=1)
                vv = jnp.roll(v[:, -size:], S % size, axis=1)
            else:
                kk, vv = k, v
            ck = jax.lax.dynamic_update_slice(
                cache["k"], kk.astype(cache["k"].dtype), (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], vv.astype(cache["v"].dtype), (0, 0, 0, 0))
            new_cache = {"k": ck, "v": cv,
                         "pos": jnp.asarray(S, jnp.int32)}
    out = out.reshape(B, S, H * hd)
    out = jnp.einsum("bsq,qd->bsd", out, p["wo"].astype(dt))
    return sharding.constrain(out, "act_batch", "act_seq", "act_embed"), new_cache


# -- SwiGLU MLP ----------------------------------------------------------------

def init_mlp(key, cfg):
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    p = {
        "gate": A(_normal(ks[0], (d, f), pdt(cfg)), "w_embed", "w_mlp"),
        "down": A(_normal(ks[2], (f, d), pdt(cfg)), "w_mlp", "w_embed"),
    }
    if cfg.mlp_variant == "swiglu":
        p["up"] = A(_normal(ks[1], (d, f), pdt(cfg)), "w_embed", "w_mlp")
    return p


def mlp_block(cfg, p, x):
    dt = cdt(cfg)
    g = jnp.einsum("bsd,df->bsf", x, p["gate"].astype(dt))
    if cfg.mlp_variant == "swiglu":
        u = jnp.einsum("bsd,df->bsf", x, p["up"].astype(dt))
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(g)
    h = sharding.constrain(h, "act_batch", "act_seq", "act_mlp")
    y = jnp.einsum("bsf,fd->bsd", h, p["down"].astype(dt))
    return sharding.constrain(y, "act_batch", "act_seq", "act_embed")


# -- standard transformer block (attn [+ local window] + SwiGLU) ---------------

def init_attn_layer(key, cfg):
    ks = jax.random.split(key, 2)
    p = {"ln1": init_rmsnorm(cfg), "attn": init_attention(ks[0], cfg)}
    if cfg.d_ff:
        p["ln2"] = init_rmsnorm(cfg)
        p["mlp"] = init_mlp(ks[1], cfg)
    return p


def attn_layer(cfg, p, x, *, positions, cache=None, mode="train", window=0):
    h, new_cache = attention_block(cfg, p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps),
                                   positions=positions, cache=cache, mode=mode,
                                   window=window)
    x = x + h
    if cfg.d_ff:
        x = x + mlp_block(cfg, p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
    return x, new_cache
