# transformer (the assembler) is imported lazily by users to avoid import
# cycles with the block modules.
from . import layers, moe, rglru, xlstm  # noqa: F401
