"""Analytic FLOP/byte/collective models per (arch x shape) cell, and the
three-term roofline assembly.

Why analytic numbers exist alongside ``compiled.cost_analysis()``: XLA's HLO
cost analysis counts a ``while`` body ONCE, and this framework deliberately
compiles scan-over-layers (plus scanned flash-attention) - so raw
cost_analysis under-reports FLOPs by ~n_layers x.  The dry-run reports both:
HLO numbers for the compiled artifact, analytic numbers (cross-checked
against an unrolled 1-group lowering in tests) for the roofline.

Hardware constants: TPU v5e.
"""
from __future__ import annotations

import dataclasses
import math

from .configs.base import ModelConfig, ShapeConfig

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link (per-chip egress approximation)
DCN_BW = 25e9                # bytes/s / host for the pod axis


@dataclasses.dataclass(frozen=True)
class CellCost:
    """All quantities are PER-CHIP per step unless suffixed otherwise."""
    flops: float                 # compiled-work FLOPs / chip (incl. remat)
    hbm_bytes: float             # HBM traffic / chip
    ici_bytes: float             # ICI egress / chip
    dcn_bytes: float             # DCN egress / chip (pod axis)
    model_flops: float           # useful: 6*N_active*D (train), 2*N_active/tok (serve) / chip
    params_bytes: float          # global parameter bytes (bf16)
    notes: str = ""


def _block_linear_flops(cfg: ModelConfig, kind: str) -> float:
    """Forward MAC*2 FLOPs per token in one block's linear layers."""
    d, hd = cfg.d_model, cfg.head_dim
    qd, kvd = cfg.q_dim, cfg.kv_dim
    mlp_mats = 2 if cfg.mlp_variant == "gelu" else 3
    if kind in ("attn", "local_attn"):
        lin = d * qd + 2 * d * kvd + qd * d
        lin += mlp_mats * d * cfg.d_ff
    elif kind == "moe":
        lin = d * qd + 2 * d * kvd + qd * d
        lin += d * cfg.n_experts + cfg.top_k * mlp_mats * d * cfg.d_ff
    elif kind == "rglru":
        w = cfg.lru_width
        lin = 2 * d * w + cfg.conv_width * w + w * d
        lin += (2 if cfg.mlp_variant == "gelu" else 3) * d * cfg.d_ff
    elif kind == "mlstm":
        inner = 2 * d
        lin = 2 * d * inner + 3 * inner * (inner // cfg.n_heads) \
            + inner * d + 2 * inner * cfg.n_heads
    elif kind == "slstm":
        lin = 8 * d * d + d * d
    else:
        raise ValueError(kind)
    return 2.0 * lin


def _attn_ctx_flops(cfg: ModelConfig, kind: str, S: int, ctx: int) -> float:
    """Attention/recurrence context FLOPs per SEQUENCE (not per token)."""
    hd, H = cfg.head_dim, cfg.n_heads
    if kind in ("attn", "moe"):
        # causal: ~S*ctx/2 scores when ctx == S; S*ctx when decoding (S=1)
        pairs = S * ctx / 2 if S == ctx else S * ctx
        return 2.0 * 2.0 * pairs * H * hd          # QK^T + PV
    if kind == "local_attn":
        w = min(cfg.window or ctx, ctx)
        pairs = S * min(w, ctx) if S == 1 else S * w
        return 2.0 * 2.0 * pairs * H * hd
    if kind == "rglru":
        return 8.0 * S * cfg.lru_width              # gates + scan
    if kind == "mlstm":
        dh = (2 * cfg.d_model) // H
        # chunkwise: intra-chunk quadratic + state update O(dh^2)
        c = min(cfg.mlstm_chunk, S)
        intra = 2.0 * 2.0 * S * c / 2 * H * dh
        state = 2.0 * 2.0 * S * H * dh * dh
        return intra + state
    if kind == "slstm":
        return 16.0 * S * cfg.d_model
    return 0.0


def _layer_kinds(cfg: ModelConfig):
    period = cfg.block_pattern
    return [period[i % len(period)] for i in range(cfg.n_layers)]


def forward_flops(cfg: ModelConfig, B: int, S: int, ctx: int) -> float:
    """Forward pass FLOPs for B sequences of S new tokens vs ctx context."""
    tok = B * S
    total = 0.0
    for kind in _layer_kinds(cfg):
        total += tok * _block_linear_flops(cfg, kind)
        total += B * _attn_ctx_flops(cfg, kind, S, ctx)
    total += 2.0 * tok * cfg.d_model * cfg.vocab_size   # lm head
    return total


def cell_cost(cfg: ModelConfig, shape: ShapeConfig, *, chips: int,
              pods: int = 1, rules: str = "fsdp",
              dtype_bytes: int = 2) -> CellCost:
    """Per-chip analytic cost model for one step of a cell.

    Mesh model: chips = pods x data(16) x tp(16); batch sharded over
    (pod, data), weights 2-D sharded (contraction over data = FSDP, feature
    over tp) under the fsdp rule set, TP-only under baseline.
    """
    B, S = shape.global_batch, shape.seq_len
    data_par, tp = 16, 16
    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    params_bytes = n_params * dtype_bytes
    d = cfg.d_model

    if shape.kind == "train":
        tok_local = B * S / (pods * data_par)   # tokens per chip column
        fwd_flops_tok = forward_flops(cfg, B, S, S) / (B * S)
        fwd = fwd_flops_tok * tok_local / tp
        flops = (4.0 if cfg.remat else 3.0) * fwd
        model_flops = 6.0 * n_active * (B * S) / chips
        # -- HBM / chip: weight shards (fwd+bwd+update reads, update write),
        # optimizer m/v read+write (f32), grads (f32 rw), saved residuals,
        # per-layer activation traffic (fwd+bwd, read+write), logits xent.
        w_local = n_params / (data_par * tp if rules == "fsdp" else tp) * 4
        opt_local = 2 * n_params / (data_par * tp) * 4
        act_layer = 8.0 * tok_local * d * dtype_bytes      # ~8 tensors/layer
        hbm = (4 * w_local + 4 * opt_local
               + 2 * cfg.n_layers * 2 * act_layer
               + 2 * tok_local * cfg.vocab_size / tp * 4)
        # -- ICI / chip:
        #   FSDP: all-gather weights (fwd + bwd recompute) + reduce-scatter
        #   grads, each moving ~the model-shard's bytes through every chip
        w_shard_bf16 = n_params * dtype_bytes / tp
        fsdp_traffic = (2 * w_shard_bf16 + n_params * 4 / tp) \
            if rules == "fsdp" else 2 * n_params * 4 / tp
        #   TP: 2 collectives/layer over the residual stream (fwd) + same in
        #   bwd; seq-parallel turns all-reduce into rs+ag of equal volume
        tp_traffic = 4.0 * cfg.n_layers * tok_local * d * dtype_bytes
        ici = fsdp_traffic + tp_traffic
        # -- DCN / chip: cross-pod grad all-reduce of this chip's grad shard
        dcn = (2.0 * (pods - 1) / pods) * n_params * 4 / (data_par * tp) \
            if pods > 1 else 0.0
        note = (f"accum-agnostic per-step totals; weights 6N={6*n_active/1e9:.0f}G "
                f"useful flops global")
    else:
        new_tok = B * (S if shape.kind == "prefill" else 1)
        batch_shards = min(B, pods * data_par)
        tok_local = new_tok / batch_shards
        fwd_flops_tok = forward_flops(
            cfg, B, S if shape.kind == "prefill" else 1, S) / new_tok
        flops = fwd_flops_tok * tok_local / tp
        model_flops = 2.0 * n_active * new_tok / chips
        cache_local = _cache_bytes(cfg, B, S, dtype_bytes) \
            / (batch_shards * (tp if shape.kind != "prefill" else 1))
        w_local = params_bytes / tp / (data_par if rules == "fsdp" else 1)
        hbm = w_local + cache_local * (2 if shape.kind == "prefill" else 1) \
            + 4.0 * tok_local * d * dtype_bytes * cfg.n_layers / tp
        if rules == "fsdp":
            ici_w = 2 * params_bytes / tp  # gather the FSDP shards
        else:
            ici_w = 0.0
        tp_traffic = 2.0 * cfg.n_layers * tok_local * d * dtype_bytes
        ici = ici_w + tp_traffic
        dcn = 0.0
        note = (f"{shape.kind}: cache "
                f"{_cache_bytes(cfg, B, S, dtype_bytes)/1e9:.1f} GB global")

    return CellCost(flops=flops, hbm_bytes=hbm, ici_bytes=ici, dcn_bytes=dcn,
                    model_flops=model_flops, params_bytes=params_bytes,
                    notes=note)


def _cache_bytes(cfg: ModelConfig, B: int, S: int, dtype_bytes: int) -> float:
    total = 0.0
    for kind in _layer_kinds(cfg):
        if kind in ("attn", "moe"):
            total += 2 * B * S * cfg.kv_dim * dtype_bytes
        elif kind == "local_attn":
            total += 2 * B * min(S, cfg.window or S) * cfg.kv_dim * dtype_bytes
        elif kind == "rglru":
            total += B * cfg.lru_width * (4 + (cfg.conv_width - 1) * dtype_bytes)
        elif kind == "mlstm":
            dh = 2 * cfg.d_model // cfg.n_heads
            total += B * cfg.n_heads * (dh * dh + dh + 1) * 4
        elif kind == "slstm":
            total += 4 * B * cfg.d_model * 4
    return total


def roofline(cost: CellCost, *, chips: int) -> dict:
    """Three-term roofline from PER-CHIP costs.  ``roofline_fraction`` is
    useful-compute time over the binding term: the fraction of the step the
    MXUs would spend on model FLOPs if everything else were perfectly
    overlapped (an MFU-style upper bound)."""
    t_compute = cost.flops / PEAK_FLOPS
    t_memory = cost.hbm_bytes / HBM_BW
    t_coll = cost.ici_bytes / ICI_BW + cost.dcn_bytes / DCN_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    t_useful = cost.model_flops / PEAK_FLOPS
    return {
        **{f"t_{k}": v for k, v in terms.items()},
        "dominant": dom,
        "step_time_est": bound,
        "roofline_fraction": t_useful / bound if bound > 0 else 0.0,
        "model_flops_ratio": cost.model_flops / max(cost.flops, 1.0),
    }
