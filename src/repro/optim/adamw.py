"""AdamW with global-norm clipping and warmup+cosine schedule.

Self-contained pytree implementation (no optax dependency).  Optimizer state
mirrors the parameter tree, so the sharding rules that place parameters also
place m/v (ZeRO-style when the FSDP rule set is active).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree_util.tree_map(zeros, params),
                      nu=jax.tree_util.tree_map(zeros, params))


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def cosine_schedule(step, *, base_lr, warmup_steps, total_steps,
                    min_ratio=0.1):
    # 1-indexed so the very first step takes a (small) non-zero update
    step = step.astype(jnp.float32) + 1.0
    warm = step / jnp.maximum(warmup_steps, 1)
    frac = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
    frac = jnp.clip(frac, 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return base_lr * jnp.where(step < warmup_steps, warm, cos)


def adamw_update(grads, state: AdamWState, params, *, learning_rate,
                 beta1=0.9, beta2=0.95, eps=1e-8, weight_decay=0.1,
                 grad_clip=1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if grad_clip else jnp.asarray(1.0)
    step = state.step + 1
    b1c = 1.0 - beta1 ** step.astype(jnp.float32)
    b2c = 1.0 - beta2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = beta1 * m + (1 - beta1) * g
        v = beta2 * v + (1 - beta2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - learning_rate * delta).astype(p.dtype), m, v

    out = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree_util.tree_map(lambda t: t[2], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), \
        {"grad_norm": gnorm, "lr": jnp.asarray(learning_rate)}
