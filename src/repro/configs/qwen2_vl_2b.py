"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936
- M-RoPE (t/h/w sections), dynamic resolution.  [arXiv:2409.12191]

Backbone only: the vision tower is a STUB - ``input_specs()`` feeds
precomputed patch+text embeddings (B, S, d_model) plus (3, B, S) M-RoPE
position ids.
"""
import dataclasses

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b", family="dense", n_layers=28, d_model=1536,
        n_heads=12, n_kv_heads=2, d_ff=8960, vocab_size=151936,
        pos_type="mrope", mrope_sections=(16, 24, 24), rope_theta=1000000.0,
        embeds_input=True, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="qwen2-vl-2b-smoke", n_layers=2, d_model=96,
        n_heads=3, n_kv_heads=1, d_ff=192, vocab_size=512, head_dim=0,
        mrope_sections=(8, 4, 4))
