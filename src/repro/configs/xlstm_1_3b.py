"""xlstm-1.3b [ssm]: 48L d_model=2048 4H (kv=4) d_ff=0 vocab=50304 - sLSTM +
mLSTM blocks, xLSTM[7:1] layout (7 mLSTM : 1 sLSTM per period).
[arXiv:2405.04517]

Fully recurrent (O(1) state) => runs the long_500k cell.  d_ff=0: mLSTM
blocks carry their own 2x up/down projection instead of a separate FFN.
"""
import dataclasses

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b", family="xlstm", n_layers=48, d_model=2048,
        n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=50304,
        block_pattern=("mlstm",) * 7 + ("slstm",), pos_type="none",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="xlstm-1.3b-smoke", n_layers=4, d_model=32,
        n_heads=2, n_kv_heads=2, vocab_size=256, head_dim=0,
        block_pattern=("mlstm", "slstm"), mlstm_chunk=16)
