"""yi-34b [dense]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
[arXiv:2403.04652]"""
import dataclasses

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-34b", family="dense", n_layers=60, d_model=7168,
        n_heads=56, n_kv_heads=8, d_ff=20480, vocab_size=64000,
        rope_theta=5000000.0,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="yi-34b-smoke", n_layers=2, d_model=56, n_heads=7,
        n_kv_heads=1, d_ff=112, vocab_size=256, head_dim=0)
