"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000 - RG-LRU + local attention, pattern (R, R, A).
[arXiv:2402.19427]

Sub-quadratic (local window 2048 + recurrent state) => runs the long_500k
cell.  26 layers = 8 full (rglru, rglru, local_attn) periods + 2 tail rglru.
"""
import dataclasses

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid_rglru", n_layers=26,
        d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680, vocab_size=256000,
        block_pattern=("rglru", "rglru", "local_attn"), window=2048,
        lru_width=2560, rope_theta=10000.0, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="recurrentgemma-2b-smoke", n_layers=5, d_model=64,
        n_heads=2, n_kv_heads=1, d_ff=128, vocab_size=512, window=16,
        lru_width=64, head_dim=0)
