"""Architecture registry: the 10 assigned architectures (exact public
configs) plus reduced smoke variants.

``get(arch_id)`` returns the full ModelConfig; ``smoke(arch_id)`` a reduced
same-family config for CPU tests.  IDs match the assignment spelling.
"""
from __future__ import annotations

import importlib

from .base import ModelConfig, ShapeConfig, TrainConfig, SHAPES  # noqa: F401

_MODULES = {
    "llama3.2-1b": "llama3_2_1b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "smollm-135m": "smollm_135m",
    "yi-34b": "yi_34b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b_a6_6b",
    "musicgen-medium": "musicgen_medium",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "xlstm-1.3b": "xlstm_1_3b",
}

ARCHS = tuple(_MODULES)


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f".{_MODULES[arch]}", __package__)


def get(arch: str) -> ModelConfig:
    return _mod(arch).config()


def smoke(arch: str) -> ModelConfig:
    return _mod(arch).smoke_config()
