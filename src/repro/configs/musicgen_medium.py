"""musicgen-medium [audio]: 48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048
- decoder-only over EnCodec tokens.  [arXiv:2306.05284]

Backbone only: the EnCodec frontend (4-codebook delay pattern, token
embedding, sinusoidal positions) is a STUB - ``input_specs()`` feeds
precomputed frame embeddings (B, S, d_model); the head predicts one codebook
stream (vocab 2048).
"""
import dataclasses

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", family="dense", n_layers=48, d_model=1536,
        n_heads=24, n_kv_heads=24, d_ff=6144, vocab_size=2048,
        pos_type="none", embeds_input=True, mlp_variant="gelu",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="musicgen-medium-smoke", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=128, head_dim=0)
