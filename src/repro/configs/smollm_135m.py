"""smollm-135m [dense]: 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
[hf:HuggingFaceTB/SmolLM-135M]"""
import dataclasses

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m", family="dense", n_layers=30, d_model=576,
        n_heads=9, n_kv_heads=3, d_ff=1536, vocab_size=49152,
        rope_theta=10000.0, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="smollm-135m-smoke", n_layers=3, d_model=48,
        n_heads=3, n_kv_heads=3, d_ff=96, vocab_size=384, head_dim=0)
