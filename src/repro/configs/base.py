"""Model/run configuration.

One frozen dataclass describes an architecture; ``src/repro/configs/<id>.py``
files instantiate the 10 assigned architectures (plus reduced smoke variants)
and register them in ``repro.configs.registry``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid_rglru | xlstm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # positions
    pos_type: str = "rope"         # rope | mrope | learned | none
    rope_theta: float = 10000.0
    mrope_sections: Sequence[int] = ()   # qwen2-vl t/h/w split of head_dim/2

    # block pattern (period definition); () -> ("attn",) * 1
    # kinds: attn | local_attn | rglru | mlstm | slstm | moe
    block_pattern: Sequence[str] = ()
    window: int = 0                # local attention window
    lru_width: int = 0             # RG-LRU recurrence width (0 -> d_model)
    conv_width: int = 4            # temporal conv in recurrent blocks
    mlstm_chunk: int = 256         # chunk size of the chunkwise mLSTM form

    # modality frontend stub: inputs are precomputed embeddings, not tokens
    embeds_input: bool = False

    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    mlp_variant: str = "swiglu"    # swiglu (3-matrix) | gelu (2-matrix)

    # numerics / compilation
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    attention_impl: str = "auto"   # auto | ref | xla_flash | pallas

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if not self.block_pattern:
            kind = "moe" if self.family == "moe" else "attn"
            object.__setattr__(self, "block_pattern", (kind,))
        object.__setattr__(self, "block_pattern", tuple(self.block_pattern))
        object.__setattr__(self, "mrope_sections", tuple(self.mrope_sections))
        if self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)
        assert self.n_heads % self.n_kv_heads == 0, "GQA group must divide heads"

    # -- derived -------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def n_groups(self) -> int:
        """Number of full pattern periods (scanned)."""
        return self.n_layers // len(self.block_pattern)

    @property
    def n_tail(self) -> int:
        """Layers after the last full period (executed unscanned)."""
        return self.n_layers % len(self.block_pattern)

    @property
    def is_subquadratic(self) -> bool:
        """True if no block attends over unbounded full context ("moe"
        blocks carry full attention too; "local_attn" is windowed)."""
        return "attn" not in self.block_pattern and \
            "moe" not in self.block_pattern

    def param_count(self) -> int:
        """Exact parameter count (embedding + blocks + head)."""
        d, v = self.d_model, self.vocab_size
        total = v * d                      # embedding
        if not self.tie_embeddings:
            total += d * v                 # lm head
        total += d                         # final norm
        per_kind = {}
        for kind in set(self.block_pattern):
            per_kind[kind] = self._block_params(kind)
        for i in range(self.n_layers):
            total += per_kind[self.block_pattern[i % len(self.block_pattern)]]
        return total

    def active_param_count(self) -> int:
        """Params active per token (MoE: top_k of n_experts)."""
        if self.family != "moe" or self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        expert = 3 * d * self.d_ff
        dense = self.param_count() - self.n_layers * self.n_experts * expert
        return dense + self.n_layers * self.top_k * expert

    def _block_params(self, kind: str) -> int:
        d, hd = self.d_model, self.head_dim
        qd, kvd = self.q_dim, self.kv_dim
        norm = d
        mlp_mats = 2 if self.mlp_variant == "gelu" else 3
        if kind in ("attn", "local_attn"):
            attn = d * qd + 2 * d * kvd + qd * d
            mlp = mlp_mats * d * self.d_ff if self.d_ff else 0
            return attn + mlp + 2 * norm
        if kind == "moe":
            attn = d * qd + 2 * d * kvd + qd * d
            router = d * self.n_experts
            experts = self.n_experts * 3 * d * self.d_ff
            return attn + router + experts + 2 * norm
        if kind == "rglru":
            w = self.lru_width
            # in-proj (2 branches) + conv + gate vectors (w_a,b_a,w_i,b_i,lam)
            # + out-proj + mlp + norms
            rec = 2 * d * w + self.conv_width * w + 5 * w + w * d
            mlp = mlp_mats * d * self.d_ff if self.d_ff else 0
            return rec + mlp + 2 * norm
        if kind == "mlstm":
            inner = 2 * d
            up = 2 * d * inner          # up-proj (value + gate branches)
            # block-diagonal per-head q,k,v (the xLSTM implementation choice)
            qkv = 3 * inner * (inner // self.n_heads)
            gates = 2 * (inner * self.n_heads + self.n_heads)
            down = inner * d
            return up + qkv + gates + down + norm
        if kind == "slstm":
            gates = 4 * d * d + 4 * d * d + 4 * d   # w_in, w_rec, bias
            down = d * d
            return gates + down + norm
        raise ValueError(kind)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell of the assigned grid."""
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Training-run substrate settings (optimizer/schedule/fault-tolerance)."""
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    seed: int = 0
    # microbatched gradient accumulation (scan over global-batch slices);
    # bounds activation peak memory at fixed global batch
    grad_accum: int = 1
    # preemption-aware checkpointing (the paper's policies)
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_policy: str = "dp"        # dp | young_daly | fixed | none
    ckpt_cost_hours: float = 1.0 / 60.0
    step_time_hours: float = 1.0 / 3600.0   # measured online; this is the seed
    vm_type: str = "tpu-v5e-pod"
    async_checkpoint: bool = True
