"""deepseek-coder-33b [dense]: 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256.  [arXiv:2401.14196]"""
import dataclasses

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b", family="dense", n_layers=62, d_model=7168,
        n_heads=56, n_kv_heads=8, d_ff=19200, vocab_size=32256,
        rope_theta=100000.0,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="deepseek-coder-33b-smoke", n_layers=3, d_model=56,
        n_heads=7, n_kv_heads=1, d_ff=96, vocab_size=384, head_dim=0)
