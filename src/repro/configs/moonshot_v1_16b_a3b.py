"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (kv=16) d_ff=1408
vocab=163840, MoE 64 experts top-6.  [hf:moonshotai/Moonlight-16B-A3B]"""
import dataclasses

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2048,
        n_heads=16, n_kv_heads=16, d_ff=1408, vocab_size=163840,
        n_experts=64, top_k=6, rope_theta=50000.0,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="moonshot-v1-16b-a3b-smoke", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=32, vocab_size=512, n_experts=8,
        top_k=2, head_dim=0)
