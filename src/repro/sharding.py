"""Logical-axis sharding rules engine (MaxText-style, pjit/GSPMD).

Every parameter and strategic activation carries a tuple of *logical* axis
names.  A rule table maps logical names to mesh axes; ``spec_for`` resolves a
(logical axes, shape) pair to a PartitionSpec, silently dropping mappings that
do not divide the dimension (e.g. 9 attention heads over a 16-way model axis)
or that would reuse a mesh axis twice - this is what lets one rule table
drive all 10 assigned architectures on the fixed (16,16)/(2,16,16) meshes.

Rule sets:
  * RULES_BASELINE  - plain DP(+pod) x TP: batch over data, feature dims over
    model, weights replicated over data (the paper-era default layout).
  * RULES_FSDP      - beyond-paper optimized: 2-D weight sharding (contraction
    dims over data => ZeRO-3), sequence-parallel residual stream, vocab-
    sharded logits.  See EXPERIMENTS.md §Perf.

The active (mesh, rules) pair is installed with ``use(mesh, rules)``;
``constrain(x, *axes)`` is a no-op outside that context so model code runs
unmodified in single-device tests.
"""
from __future__ import annotations

import contextlib
import math
import threading
from typing import NamedTuple, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


class P(NamedTuple):
    """An annotated parameter: value + logical axis names."""
    value: jax.Array
    axes: tuple


def annotate(value, *axes):
    assert len(axes) == len(value.shape), (axes, value.shape)
    return P(value, tuple(axes))


def split_annotated(tree):
    """(params, axes) trees from a tree with P leaves."""
    is_p = lambda x: isinstance(x, P)
    params = jax.tree_util.tree_map(lambda p: p.value, tree, is_leaf=is_p)
    axes = jax.tree_util.tree_map(lambda p: p.axes, tree, is_leaf=is_p)
    return params, axes


RULES_BASELINE = {
    # -- weights (TP only; replicated over data) --
    "w_vocab": "model", "w_mlp": "model", "w_qdim": "model",
    "w_kv_dim": "model", "w_lru": "model", "w_inner": "model",
    "w_embed": None, "w_embed_in": None, "w_experts": "model",
    "w_expert_ff": None, "w_conv": None, "layers": None,
    # -- activations --
    "act_batch": ("pod", "data"), "act_seq": None, "act_embed": None,
    "act_vocab": "model", "act_heads": None, "act_mlp": "model",
    "act_experts": "model", "act_lru": "model", "act_inner": "model",
    # -- kv / recurrent caches --
    "cache_batch": ("pod", "data"), "cache_seq": "model", "cache_kv": None,
    "cache_dim": None,
    # -- solver --
    # the DP solver's (S,) scenario batch axis (repro.core.policies): prefers
    # a dedicated "scenario" mesh axis when the mesh defines one, else splits
    # over the data-parallel axes like any other batch dimension
    "scenario": ("scenario", "pod", "data"),
}

# Beyond-paper optimized layout: ZeRO-3 weight sharding over `data`,
# sequence-parallel residual stream over `model`.
RULES_FSDP = dict(RULES_BASELINE)
RULES_FSDP.update({
    "w_embed": "data", "w_embed_in": "data", "w_expert_ff": "data",
    "act_seq": "model",
})

# ZeRO-1: weights replicated over `data` (TP only, no per-layer gathers);
# optimizer state sharded over `data` via the opt:: aliases.
RULES_ZERO1 = dict(RULES_BASELINE)
RULES_ZERO1.update({
    "act_seq": "model",
    "opt::w_embed": "data", "opt::w_embed_in": "data",
    "opt::w_expert_ff": "data", "opt::w_conv": "data",
})

# Pure data parallelism over all 256(x2) chips: for small models where TP=16
# collective traffic dominates; weights replicated, optimizer ZeRO-1 sharded.
RULES_DP_ZERO1 = {
    **{k: None for k in RULES_BASELINE},
    "act_batch": ("pod", "data", "model"),
    "cache_batch": ("pod", "data", "model"),
    "scenario": ("scenario", "pod", "data", "model"),
    "opt::w_embed": "data", "opt::w_vocab": "model", "opt::w_mlp": "model",
    "opt::w_qdim": "model", "opt::w_kv_dim": "model", "opt::w_lru": "model",
    "opt::w_inner": "model", "opt::w_experts": "model",
}

RULE_SETS = {"baseline": RULES_BASELINE, "fsdp": RULES_FSDP,
             "zero1": RULES_ZERO1, "dp_zero1": RULES_DP_ZERO1}

OPT_PREFIX = "opt::"


def opt_alias(axes: tuple) -> tuple:
    """Rename weight logical axes for optimizer-state leaves: ``opt::name``
    resolves to its own rule when the set defines one, else falls back to
    the plain name."""
    return tuple(None if a is None else
                 (a if a == "layers" else OPT_PREFIX + a) for a in axes)


class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: dict = RULES_BASELINE


_ctx = _Ctx()


@contextlib.contextmanager
def use(mesh: Optional[Mesh], rules=RULES_BASELINE):
    if isinstance(rules, str):
        rules = RULE_SETS[rules]
    prev = (_ctx.mesh, _ctx.rules)
    _ctx.mesh, _ctx.rules = mesh, rules
    try:
        yield
    finally:
        _ctx.mesh, _ctx.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _ctx.mesh


def spec_for(axes: tuple, shape: tuple, mesh: Optional[Mesh] = None,
             rules: Optional[dict] = None) -> PartitionSpec:
    """Resolve logical axes to a PartitionSpec, enforcing divisibility and
    one-use-per-mesh-axis."""
    mesh = mesh or _ctx.mesh
    rules = rules or _ctx.rules
    if mesh is None:
        return PartitionSpec()
    used = set()
    out = []
    for name, dim in zip(axes, shape):
        if name is not None and name.startswith(OPT_PREFIX):
            rule = rules.get(name, rules.get(name[len(OPT_PREFIX):]))
        else:
            rule = rules.get(name)
        if rule is None:
            out.append(None)
            continue
        cand = (rule,) if isinstance(rule, str) else tuple(rule)
        cand = tuple(a for a in cand if a in mesh.shape and a not in used)
        size = math.prod(mesh.shape[a] for a in cand) if cand else 1
        if not cand or dim % size != 0:
            out.append(None)
            continue
        used.update(cand)
        out.append(cand[0] if len(cand) == 1 else cand)
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


def sharding_for(axes: tuple, shape: tuple, mesh: Optional[Mesh] = None,
                 rules: Optional[dict] = None) -> Optional[NamedSharding]:
    mesh = mesh or _ctx.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, spec_for(axes, shape, mesh, rules))


def constrain(x, *axes):
    """with_sharding_constraint by logical names; no-op with no active mesh
    OR when no rule maps (an empty PartitionSpec would *force* replication -
    e.g. 269 GB/chip of gathered logits under the dp_zero1 rules - whereas
    the intent of an unmapped constraint is 'let GSPMD propagate')."""
    mesh = _ctx.mesh
    if mesh is None:
        return x
    spec = spec_for(axes, x.shape, mesh, _ctx.rules)
    if not any(s is not None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def tree_shardings(axes_tree, shape_tree, mesh: Mesh, rules=RULES_BASELINE):
    """NamedSharding pytree for a parameter tree (axes tree + shapes tree)."""
    if isinstance(rules, str):
        rules = RULE_SETS[rules]
    return jax.tree_util.tree_map(
        lambda ax, shp: NamedSharding(mesh, spec_for(ax, shp, mesh, rules)),
        axes_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
