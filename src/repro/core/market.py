"""Spot-market price & capacity dynamics: dollar-denominated policy evaluation.

The paper's headline result is *cost* — model-driven policies cut deployment
cost ~5x on transient VMs — yet a fixed per-VM-type price cannot rank
policies under the moving prices and capacity crunches that break them in
production (the CloudSim-Plus spot-market study and Voorsluys et al.'s
virtual-cluster provisioning both model exactly this dimension; see
PAPERS.md).  This module adds the market layer on the batched substrate:

* :class:`PriceProcess` — a seeded, deterministic mean-reverting OU process
  on *log* price per (zone, vm_type) scenario leaf, with scheduled
  capacity-crunch episodes (a log-price lift over ``[crunch_t0,
  crunch_t1)``, optionally periodic).  It is a ``_dist``-registered frozen
  dataclass pytree, so ``distributions.stack``/``unstack`` put the same
  leading ``(S,)`` scenario axis on its parameter leaves that every other
  batched entry point uses.
* :func:`crunch_effective` — the crunch -> Eq. 1 coupling: a capacity
  crunch scales ``A`` up and ``tau1`` down *through the same properness
  cap* as ``DiurnalConstrained``'s launch-phase modulation
  (``distributions.capped_constrained``), so a crunch-boosted model can
  saturate the cap but never produce an improper CDF.
* :class:`PriceGrid` — the precomputed ``(S, T)`` price grid plus its
  cumulative-dollar grid ``cum[s, k] = integral_0^{k*dt} p_s``, the tensor
  both cost paths gather against.
* :func:`integrate_cost_ref` — the retained serial numpy reference for the
  dollar integral.  Bit-exactness contract (PR-4/PR-7 lineage): the batched
  gather ``engine.accumulate_price_cost`` must reproduce this scalar
  arithmetic bit-for-bit under x64 on shared makespans — same ``cum``
  gather, same ``base + price * frac`` expression tree (enforced by
  ``tests/test_market.py`` / ``tests/test_batched.py``).
* :class:`MarketModel` / :class:`PriceFeed` — the sweep-facing bundle
  (per-scenario processes sharing one horizon/dt/seed) and the closed-loop
  runtime's live ticker (``FleetRuntime(price_feed=...)`` bills every
  streamed lifetime at its launch price).

Billing convention: a VM (or job attempt) starting at wall-clock ``t`` pays
``integral_t^{t+m} p(u) du`` along the trace — discretized on the grid, with
the tail beyond the horizon billed at the last cell's price.  The *service*
loops bill each ``vm_hours`` increment at the owning VM's launch-cell price
(spot-style hour-start billing), which keeps the serial heap loop and the
event-synchronous kernel bit-identical without tracking per-VM price
integrals.  See ``docs/market.md``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from . import distributions as dists
from .distributions import _dist

__all__ = [
    "PriceProcess", "PriceGrid", "MarketModel", "PriceFeed",
    "spot_price_process", "crunch_effective", "crunch_profile",
    "price_trace", "integrate_cost_ref", "MARKET_ZONE_PARAMS",
    "DEFAULT_HORIZON_HOURS", "DEFAULT_PRICE_DT",
]

DEFAULT_HORIZON_HOURS = 48.0
DEFAULT_PRICE_DT = 0.1          # price-grid resolution (hours)

# Zone price levels relative to the type's base preemptible price: a tighter
# market (higher A_scale in scenarios.ZONE_PARAMS) clears at a premium, a
# slacker one at a discount.  us-east1-b is the identity zone, matching the
# paper's fits.
MARKET_ZONE_PARAMS = {
    "us-east1-b": dict(price_scale=1.00),
    "us-central1-a": dict(price_scale=1.12),
    "europe-west1-d": dict(price_scale=0.94),
}


@_dist
class PriceProcess:
    """Mean-reverting OU log-price with scheduled capacity-crunch episodes.

    ``log p`` follows the exact OU discretization ``x_{k+1} = mu + (x_k -
    mu) * e^{-theta*dt} + sd(dt) * z_k`` and the published price is
    ``exp(x + crunch_amp * c(t))`` with ``c(t)`` the crunch intensity —
    strictly positive by construction.  A crunch also couples into the
    Eq. 1 early-hazard through :func:`crunch_effective`: at full intensity
    ``A`` is scaled by ``crunch_A`` and ``tau1`` by ``crunch_tau1``
    (capacity pressure preempts younger VMs faster), capped by
    ``distributions.capped_constrained`` so the fit stays proper.

    All fields are pytree leaves, so ``distributions.stack`` /``unstack``
    give the standard ``(S,)`` leading-axis form.
    """

    mu: jnp.ndarray = -2.0        # long-run mean log price (log USD/h)
    sigma: jnp.ndarray = 0.08     # OU volatility (log-price units)
    theta: jnp.ndarray = 0.35     # mean-reversion rate (1/h)
    p0: jnp.ndarray = 0.135       # initial price (USD/h)
    crunch_t0: jnp.ndarray = 0.0  # crunch window start (h); t1 <= t0 disables
    crunch_t1: jnp.ndarray = 0.0  # crunch window end (h)
    crunch_period: jnp.ndarray = 0.0  # repeat period (h); 0 = single episode
    crunch_amp: jnp.ndarray = 0.9     # log-price lift at full crunch
    crunch_A: jnp.ndarray = 1.6       # Eq. 1 A scale at full crunch
    crunch_tau1: jnp.ndarray = 0.6    # Eq. 1 tau1 scale at full crunch

    def crunch_intensity(self, t):
        """Crunch indicator in [0, 1] at wall-clock hour(s) ``t``."""
        c0, c1, per = map(np.float64, (self.crunch_t0, self.crunch_t1,
                                       self.crunch_period))
        t = np.asarray(t, np.float64)
        if c1 <= c0:
            return np.zeros_like(t)
        tt = np.mod(t, per) if per > 0 else t
        return ((tt >= c0) & (tt < c1)).astype(np.float64)


def crunch_profile(proc: PriceProcess, times) -> np.ndarray:
    """``proc.crunch_intensity`` over an array of wall-clock hours."""
    return proc.crunch_intensity(np.asarray(times, np.float64))


def crunch_effective(dist, proc: PriceProcess, t_launch: float = 0.0):
    """The crunch -> Eq. 1 early-hazard coupling, resolved at VM launch.

    Mirrors ``DiurnalConstrained.effective`` exactly: the crunch intensity
    ``c`` at launch scales ``A`` by ``1 + (crunch_A - 1) * c`` and ``tau1``
    by ``1 - (1 - crunch_tau1) * c``, through the shared
    ``distributions.capped_constrained`` properness cap.  ``c = 0`` passes
    the launch-phase-resolved base model through unchanged, so calm-regime
    tables solved from this function equal plain ``dist.effective()``
    tables.
    """
    base = dist.effective() if hasattr(dist, "effective") else dist
    c = float(proc.crunch_intensity(float(t_launch)))
    A_scale = 1.0 + (float(np.float64(proc.crunch_A)) - 1.0) * c
    tau1_scale = 1.0 - (1.0 - float(np.float64(proc.crunch_tau1))) * c
    return dists.capped_constrained(base, A_scale=A_scale,
                                    tau1_scale=tau1_scale)


def price_trace(proc: PriceProcess, *, horizon: float = DEFAULT_HORIZON_HOURS,
                dt: float = DEFAULT_PRICE_DT, seed: int = 0,
                leaf: int = 0) -> np.ndarray:
    """One deterministic ``(T,)`` price trace (USD/h, float64).

    The noise stream is ``default_rng(SeedSequence([seed, leaf]))`` — one
    independent, reproducible stream per (sweep seed, scenario leaf), so
    re-drawing with the same arguments is bit-identical and leaves never
    share noise.  Host-side numpy float64 throughout: the trace is an
    *input* tensor to both cost paths, so its generation must not depend on
    the session dtype.
    """
    T = int(round(horizon / dt))
    if T < 1:
        raise ValueError(f"horizon/dt gives an empty grid ({horizon}/{dt})")
    mu, sigma, theta = (float(np.float64(proc.mu)),
                        float(np.float64(proc.sigma)),
                        float(np.float64(proc.theta)))
    p0 = float(np.float64(proc.p0))
    if p0 <= 0.0:
        raise ValueError(f"p0 must be positive, got {p0}")
    rng = np.random.default_rng(np.random.SeedSequence([int(seed), int(leaf)]))
    z = rng.standard_normal(T - 1)
    a = np.exp(-theta * dt)
    sd = (sigma * np.sqrt((1.0 - a * a) / (2.0 * theta)) if theta > 0
          else sigma * np.sqrt(dt))
    x = np.empty(T, np.float64)
    x[0] = np.log(p0)
    for k in range(T - 1):
        x[k + 1] = mu + (x[k] - mu) * a + sd * z[k]
    c = crunch_profile(proc, dt * np.arange(T, dtype=np.float64))
    return np.exp(x + float(np.float64(proc.crunch_amp)) * c)


def spot_price_process(zone: str = "us-east1-b",
                       vm_type: str = "n1-highcpu-16",
                       **overrides) -> PriceProcess:
    """The catalog (zone, vm_type) leaf: the 2019 preemptible list price
    scaled by the zone's market level, as both the initial price and the
    OU long-run mean.  ``overrides`` set any :class:`PriceProcess` field
    (schedule a crunch with ``crunch_t0``/``crunch_t1``)."""
    from .service import PRICES_PREEMPTIBLE
    base = (PRICES_PREEMPTIBLE[vm_type]
            * MARKET_ZONE_PARAMS[zone]["price_scale"])
    kw = dict(mu=np.log(base), p0=base)
    kw.update(overrides)
    return PriceProcess(**kw)


@dataclasses.dataclass(frozen=True)
class PriceGrid:
    """The precomputed tensors both cost paths gather against.

    ``prices[s, k]`` is leaf ``s``'s price on ``[k*dt, (k+1)*dt)`` and
    ``cum[s, k] = sum_{i<k} prices[s, i] * dt`` the dollars of running one
    VM over ``[0, k*dt)`` — host numpy float64, computed ONCE and shared by
    the batched kernel and the serial reference so neither re-derives the
    cumulative sum (cumsum order would otherwise be a bit-exactness
    hazard).  ``shift`` re-anchors the grid at a later launch time; tail
    cells beyond the horizon are billed at the last cell's price.
    """
    prices: np.ndarray           # (S, T) float64
    cum: np.ndarray              # (S, T+1) float64
    dt: float

    @staticmethod
    def from_prices(prices, dt: float) -> "PriceGrid":
        prices = np.atleast_2d(np.asarray(prices, np.float64))
        if not np.all(prices > 0.0):
            raise ValueError("price grid must be strictly positive")
        cum = np.zeros((prices.shape[0], prices.shape[1] + 1), np.float64)
        np.cumsum(prices * dt, axis=1, out=cum[:, 1:])
        return PriceGrid(prices=prices, cum=cum, dt=float(dt))

    @property
    def horizon(self) -> float:
        return self.prices.shape[1] * self.dt

    def __len__(self) -> int:
        return self.prices.shape[0]

    def shift(self, t0: float) -> "PriceGrid":
        """The grid as seen from launch time ``t0``: row ``k`` becomes row
        ``k0 + k`` (clamped to the last cell), so integrals from a late
        launch reuse the same from-zero gather kernel."""
        k0 = int(np.floor(float(t0) / self.dt))
        T = self.prices.shape[1]
        idx = np.minimum(np.arange(T) + max(k0, 0), T - 1)
        return PriceGrid.from_prices(self.prices[:, idx], self.dt)

    def price_at(self, t) -> np.ndarray:
        """``(S,)`` prices at wall-clock hour ``t`` (tail-clamped)."""
        k = min(int(np.floor(float(t) / self.dt)), self.prices.shape[1] - 1)
        return self.prices[:, max(k, 0)]


def integrate_cost_ref(prices_row, cum_row, dt: float, makespan) -> float:
    """THE serial dollar integral: ``integral_0^m p`` for one trial.

    Scalar numpy float64 arithmetic — ``cum[k] + prices[k] * (m - k*dt)``
    with ``k = floor(m/dt)`` clamped to the last cell (the tail beyond the
    horizon bills at the final price).  The batched gather
    ``engine.accumulate_price_cost`` must reproduce this expression
    bit-for-bit under x64; NaN makespans (unfinished trials) yield NaN
    dollars in both paths.
    """
    m = float(makespan)
    if np.isnan(m):
        return float("nan")
    T = len(prices_row)
    k = min(max(int(np.floor(m / dt)), 0), T - 1)
    base = np.float64(cum_row[k])
    frac = np.float64(m) - np.float64(k) * np.float64(dt)
    return float(base + np.float64(prices_row[k]) * frac)


@dataclasses.dataclass
class MarketModel:
    """Per-scenario price processes sharing one (horizon, dt, seed) grid.

    ``processes[s]`` prices scenario leaf ``s`` of the sweep it was built
    for; :meth:`grid` materializes (and caches) the ``(S, T)``
    :class:`PriceGrid`.  The leaf order IS the scenario order — keep them
    aligned exactly like ``BatchDPTables``.
    """
    processes: list
    horizon: float = DEFAULT_HORIZON_HOURS
    dt: float = DEFAULT_PRICE_DT
    seed: int = 0
    _grid: Optional[PriceGrid] = dataclasses.field(
        default=None, repr=False, compare=False)

    @classmethod
    def for_scenarios(cls, scenarios: Sequence, *,
                      crunch_zones: Sequence[str] = ("us-central1-a",),
                      crunch_window: tuple = (8.0, 16.0),
                      crunch_amp: float = 0.9, crunch_A: float = 1.6,
                      crunch_tau1: float = 0.6,
                      horizon: float = DEFAULT_HORIZON_HOURS,
                      dt: float = DEFAULT_PRICE_DT, seed: int = 0,
                      **proc_overrides) -> "MarketModel":
        """The default market for a scenario list: one catalog leaf per
        scenario, with a capacity-crunch episode scheduled on every leaf
        whose zone is in ``crunch_zones`` (capacity pressure is zonal —
        the untouched zones are what cost-aware substitution flees to)."""
        procs = []
        for sc in scenarios:
            kw = dict(proc_overrides)
            if sc.zone in crunch_zones:
                kw.update(crunch_t0=crunch_window[0],
                          crunch_t1=crunch_window[1],
                          crunch_amp=crunch_amp, crunch_A=crunch_A,
                          crunch_tau1=crunch_tau1)
            procs.append(spot_price_process(sc.zone, sc.vm_type, **kw))
        return cls(processes=procs, horizon=horizon, dt=dt, seed=seed)

    def __len__(self) -> int:
        return len(self.processes)

    def grid(self) -> PriceGrid:
        if self._grid is None:
            rows = np.stack([
                price_trace(p, horizon=self.horizon, dt=self.dt,
                            seed=self.seed, leaf=i)
                for i, p in enumerate(self.processes)])
            self._grid = PriceGrid.from_prices(rows, self.dt)
        return self._grid

    def launch_time(self, regime: str) -> float:
        """The wall-clock launch hour a regime evaluates at: ``"calm"``
        launches at hour 0 (no default window covers it); ``"crunch"`` at
        the first scheduled episode's start — if no leaf schedules one,
        crunch degenerates to calm."""
        if regime == "calm":
            return 0.0
        if regime == "crunch":
            starts = [float(np.float64(p.crunch_t0)) for p in self.processes
                      if float(np.float64(p.crunch_t1))
                      > float(np.float64(p.crunch_t0))]
            return min(starts) if starts else 0.0
        raise ValueError(f"regime must be 'calm' or 'crunch', got {regime!r}")

    def crunch_dists(self, scenarios: Sequence, t_launch: float) -> list:
        """Per-leaf crunch-coupled Eq. 1 models at launch time (the
        :func:`crunch_effective` coupling, one per scenario)."""
        return [crunch_effective(sc.dist(), p, t_launch)
                for sc, p in zip(scenarios, self.processes)]


class PriceFeed:
    """The closed-loop runtime's live ticker: one :class:`PriceProcess`
    advanced ``tick_hours`` per observation, extending its trace lazily in
    ``block`` cells — deterministic per seed, so a replayed run bills
    identically.  ``FleetRuntime`` calls :meth:`advance` once per streamed
    lifetime and bills the observation at the returned launch price."""

    def __init__(self, process: Optional[PriceProcess] = None, *,
                 seed: int = 0, dt: float = DEFAULT_PRICE_DT,
                 tick_hours: float = 0.05, block: int = 512):
        self.process = process or spot_price_process()
        self.seed = int(seed)
        self.dt = float(dt)
        self.tick_hours = float(tick_hours)
        self.block = int(block)
        self.clock_hours = 0.0
        self._trace = np.empty((0,), np.float64)

    def _ensure(self, k: int) -> None:
        while k >= len(self._trace):
            cells = len(self._trace) + self.block
            # regenerate the whole prefix: price_trace is deterministic per
            # (seed, leaf), so extending never rewrites history
            self._trace = price_trace(self.process,
                                      horizon=cells * self.dt, dt=self.dt,
                                      seed=self.seed, leaf=0)

    def price_at(self, hours: float) -> float:
        k = max(int(np.floor(float(hours) / self.dt)), 0)
        self._ensure(k)
        return float(self._trace[k])

    def grid(self, horizon_hours: float) -> PriceGrid:
        """A one-row :class:`PriceGrid` of the next ``horizon_hours`` as
        seen from the current clock — the forecast the dollar-objective DP
        solves against (``FleetRuntime(dp_objective="dollars")`` refits on
        it).  Deterministic per (seed, clock): the same clock always yields
        the same grid, so refit tables are replayable."""
        n = max(int(np.ceil(float(horizon_hours) / self.dt)), 1)
        k0 = max(int(np.floor(self.clock_hours / self.dt)), 0)
        self._ensure(k0 + n - 1)
        return PriceGrid.from_prices(self._trace[k0:k0 + n][None, :], self.dt)

    def current(self) -> float:
        return self.price_at(self.clock_hours)

    def advance(self) -> float:
        """Price at the current clock, then tick forward one observation."""
        p = self.current()
        self.clock_hours += self.tick_hours
        return p
