"""Least-squares fitting of preemption models to empirical lifetime CDFs.

The paper fits Eq. 1 with scipy's ``optimize.curve_fit`` (dogbox).  Here the
fitter is a self-contained Levenberg-Marquardt loop in pure JAX (``lax`` control
flow, ``jacfwd`` Jacobians) so it can run jitted/vmapped inside the training
runtime (e.g. continuously re-fitting the model from recent fleet preemptions,
as the paper's "detect policy changes" discussion suggests).  Tests cross-check
against scipy.

Families are parametrized by an unconstrained vector theta; ``_TRANSFORMS``
maps theta -> positive/bounded natural parameters.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import distributions as dist_mod
from .distributions import (Constrained, Empirical, Exponential,
                            GompertzMakeham, Weibull, DEADLINE_HOURS)


class FitDiverged(RuntimeError):
    """A fit produced non-finite parameters/loss (NaN residuals at every
    iterate, singular ``JtJ``) and no finite multi-start rescued it.  The
    online refit pipeline (``repro.core.runtime``) catches this and keeps
    serving the last-good model instead of adopting a poisoned one."""


def _softplus(x):
    return jnp.logaddexp(x, 0.0)


def _inv_softplus(y):
    y = jnp.asarray(y, jnp.result_type(float))
    return jnp.log(jnp.expm1(jnp.maximum(y, 1e-6)))


def _sigmoid(x):
    return jax.nn.sigmoid(x)


def _inv_sigmoid(y):
    y = jnp.clip(jnp.asarray(y, jnp.result_type(float)), 1e-6, 1 - 1e-6)
    return jnp.log(y / (1.0 - y))


@dataclasses.dataclass(frozen=True)
class Family:
    name: str
    n_params: int
    build: Callable  # theta (unconstrained) -> distribution
    theta0: Callable  # (t, y, L) -> initial unconstrained theta
    # extra residuals appended to the data residuals (boundary conditions)
    boundary: Callable = lambda d: jnp.zeros((0,))
    # multi-start inits (best final LSE wins)
    extra_theta0: tuple = ()


def _build_constrained(theta, L):
    tau1 = _softplus(theta[0])
    tau2 = _softplus(theta[1])
    b = _softplus(theta[2])
    A = _sigmoid(theta[3])
    return Constrained(tau1=tau1, tau2=tau2, b=b, A=A, L=L)


def _build_exponential(theta, L):
    return Exponential(mttf=_softplus(theta[0]), L=L)


def _build_weibull(theta, L):
    return Weibull(lam=_softplus(theta[0]), k=_softplus(theta[1]), L=L)


def _build_gm(theta, L):
    return GompertzMakeham(lam=_softplus(theta[0]), alpha=1e-3 * _softplus(theta[1]),
                           beta=_softplus(theta[2]), L=L)


FAMILIES = {
    "constrained": Family(
        name="constrained", n_params=4, build=_build_constrained,
        theta0=lambda t, y, L: jnp.stack([
            _inv_softplus(1.0), _inv_softplus(1.0), _inv_softplus(0.95 * L),
            _inv_sigmoid(0.45)]),
        # paper: "combination of the 4 fit parameters ... ensure F(0) ~= 0";
        # weight-3 penalty on the raw (unclipped) Eq. 1 at t=0.
        boundary=lambda d: 3.0 * d.cdf_raw(0.0)[None],
    ),
    "exponential": Family(
        name="exponential", n_params=1, build=_build_exponential,
        theta0=lambda t, y, L: jnp.stack([_inv_softplus(jnp.maximum(jnp.mean(t), 0.5))]),
    ),
    "weibull": Family(
        name="weibull", n_params=2, build=_build_weibull,
        theta0=lambda t, y, L: jnp.stack([
            _inv_softplus(1.0 / jnp.maximum(jnp.mean(t), 0.5)), _inv_softplus(1.0)]),
    ),
    "gompertz_makeham": Family(
        name="gompertz_makeham", n_params=3, build=_build_gm,
        theta0=lambda t, y, L: jnp.stack([
            _inv_softplus(0.1), _inv_softplus(0.1), _inv_softplus(0.3)]),
        extra_theta0=(
            lambda t, y, L: jnp.stack([_inv_softplus(0.05), _inv_softplus(1.0),
                                       _inv_softplus(0.6)]),
            # deadline-wall start: alpha ~ 1e-3*softplus(-14) ~ 1e-9, beta ~ 1
            lambda t, y, L: jnp.stack([_inv_softplus(0.05), jnp.asarray(-14.0),
                                       _inv_softplus(1.0)]),
        ),
    ),
}


def _model_cdf(dist):
    """Fitting target: raw model curve where available (the clip in
    Constrained.cdf would zero gradients at the boundary)."""
    return dist.cdf_raw if hasattr(dist, "cdf_raw") else dist.cdf


@dataclasses.dataclass(frozen=True)
class FitResult:
    dist: object
    theta: jnp.ndarray
    lse: jnp.ndarray           # sum of squared CDF residuals (data terms only)
    iterations: jnp.ndarray
    converged: jnp.ndarray


def levenberg_marquardt(residual_fn, theta0, max_iters: int = 200,
                        mu0: float = 1e-2, tol: float = 1e-9):
    """Classic LM with multiplicative damping; fixed-shape, jit-friendly.

    residual_fn: theta -> residual vector r; minimizes ||r||^2.

    Hardened against degenerate inputs (the online-refit failure modes):

      * a non-finite step (singular ``JtJ``, NaN residuals/Jacobian) is
        replaced by a zero step, so the iterate can never *become*
        non-finite — candidate evaluation simply keeps rejecting;
      * a candidate is accepted only when its loss is FINITE; a finite
        candidate also rescues a non-finite starting loss (the old
        ``accept = new < prev`` was vacuously False forever once ``prev``
        was NaN, silently burning ``max_iters`` and returning
        ``converged`` semantics that lied);
      * the returned ``converged`` flag additionally requires the final
        theta and loss to be finite, so callers can trust
        ``converged=True`` means "a real minimum of a real function".

    Returns ``(theta, loss, iterations, converged)`` with ``theta`` always
    finite (non-finite entries of ``theta0`` itself are zeroed on entry).
    """
    jac = jax.jacfwd(residual_fn)

    def loss(theta):
        r = residual_fn(theta)
        return jnp.sum(r * r)

    def cond(state):
        i, theta, mu, prev, done = state
        return (i < max_iters) & (~done)

    def body(state):
        i, theta, mu, prev, done = state
        r = residual_fn(theta)
        J = jac(theta)
        JtJ = J.T @ J
        g = J.T @ r
        # LM step: (JtJ + mu*diag(JtJ)) delta = -g
        damp = mu * jnp.diag(jnp.maximum(jnp.diag(JtJ), 1e-10))
        delta = jnp.linalg.solve(JtJ + damp, -g)
        # singular JtJ / NaN residuals: never let a non-finite step reach theta
        delta = jnp.where(jnp.all(jnp.isfinite(delta)), delta,
                          jnp.zeros_like(delta))
        cand = theta + delta
        new = loss(cand)
        accept = jnp.isfinite(new) & jnp.where(jnp.isfinite(prev),
                                               new < prev, True)
        theta = jnp.where(accept, cand, theta)
        cur = jnp.where(accept, new, prev)
        mu = jnp.where(accept, jnp.maximum(mu / 3.0, 1e-12), jnp.minimum(mu * 2.0, 1e8))
        done = accept & (jnp.abs(prev - new) < tol * (1.0 + prev))
        return i + 1, theta, mu, cur, done

    theta0 = jnp.asarray(theta0, jnp.result_type(float))
    theta0 = jnp.where(jnp.isfinite(theta0), theta0, jnp.zeros_like(theta0))
    state = (jnp.asarray(0), theta0, jnp.asarray(mu0, theta0.dtype),
             loss(theta0), jnp.asarray(False))
    i, theta, mu, final, done = jax.lax.while_loop(cond, body, state)
    converged = done & jnp.all(jnp.isfinite(theta)) & jnp.isfinite(final)
    return theta, final, i, converged


@functools.partial(jax.jit, static_argnames=("family", "max_iters"))
def _fit_kernel(t, y, L, *, family: str, max_iters: int):
    """One jitted multi-start fit: every init's LM run plus the best-LSE
    selection, cached per ``(family, data shape, max_iters)``.  The online
    refit loop calls :func:`fit_samples` once per ``refit_every``
    observations on a fixed-size window, so after the first trace a refit
    costs only the compiled while_loop — the eager path re-traced the LM
    graph (~1 s) on every single refit.

    Selection matches the historical eager loop: non-finite final losses
    rank last (NaN previously compared False against everything, freezing
    ``best`` on the first init), ties keep the earliest init.
    """
    fam = FAMILIES[family]

    def residual(theta):
        d = fam.build(theta, L)
        r = _model_cdf(d)(t) - y
        return jnp.concatenate([r, fam.boundary(d)])

    runs = [levenberg_marquardt(residual, init(t, y, L), max_iters=max_iters)
            for init in (fam.theta0, *fam.extra_theta0)]
    thetas, losses, iters, convs = (jnp.stack(xs) for xs in zip(*runs))
    best = jnp.argmin(jnp.where(jnp.isfinite(losses), losses, jnp.inf))
    theta = thetas[best]
    d = fam.build(theta, L)
    data_r = _model_cdf(d)(t) - y
    return theta, jnp.sum(data_r * data_r), iters[best], convs[best]


def fit(family: str, t, y, L=DEADLINE_HOURS, max_iters: int = 200) -> FitResult:
    """Fit a family's CDF to points (t, y) by least squares (paper Eq. 1 fit)."""
    fam = FAMILIES[family]
    t = jnp.asarray(t, jnp.result_type(float))
    y = jnp.asarray(y, t.dtype)
    L = jnp.asarray(L, t.dtype)
    theta, lse_v, iters, done = _fit_kernel(t, y, L, family=family,
                                            max_iters=int(max_iters))
    return FitResult(dist=fam.build(theta, L), theta=theta, lse=lse_v,
                     iterations=iters, converged=done)


def fit_samples(family: str, samples, L=DEADLINE_HOURS, **kw) -> FitResult:
    """Fit directly to a lifetime trace via its empirical CDF.

    Degenerate traces are rejected with ``ValueError`` rather than handed to
    the optimizer (whose least-squares target would be meaningless and whose
    iterates used to walk into NaN): an empty trace, any non-finite
    lifetime, a constant trace (zero-spread empirical CDF), and a trace
    whose every lifetime sits at the deadline cap ``L`` (pure provider
    reclamation — nothing for the soft Eq. 1 phases to fit).
    """
    s = np.asarray(samples, np.float64).ravel()
    if s.size == 0:
        raise ValueError("fit_samples: empty lifetime trace")
    if not np.all(np.isfinite(s)):
        raise ValueError(
            f"fit_samples: {int((~np.isfinite(s)).sum())}/{s.size} "
            f"non-finite lifetimes in trace")
    if np.all(s >= float(L) - 1e-9):
        raise ValueError(
            "fit_samples: every lifetime sits at the deadline cap "
            f"L={float(L):g} h; the empirical CDF is a single atom and "
            "Eq. 1's soft phases are unidentifiable")
    if np.ptp(s) == 0.0:
        raise ValueError(
            f"fit_samples: constant trace (all lifetimes == {s[0]:g} h); "
            "a zero-spread empirical CDF cannot constrain the fit")
    emp = Empirical.from_samples(s, L=L)
    return fit(family, emp.knots, emp.values, L=L, **kw)


def fit_all(samples, L=DEADLINE_HOURS, families=("constrained", "exponential",
                                                 "weibull", "gompertz_makeham")):
    """Fit every family to a trace; returns {family: FitResult} (Fig. 1/3)."""
    return {f: fit_samples(f, samples, L=L) for f in families}


# ---------------------------------------------------------------------------
# Goodness of fit
# ---------------------------------------------------------------------------

def ks_statistic(dist, samples):
    """Kolmogorov-Smirnov sup |F_model - F_empirical| over the sample points."""
    s = jnp.sort(jnp.ravel(jnp.asarray(samples, jnp.result_type(float))))
    n = s.shape[0]
    f = dist.cdf(s)
    lo = jnp.arange(n, dtype=f.dtype) / n
    hi = (jnp.arange(n, dtype=f.dtype) + 1.0) / n
    return jnp.maximum(jnp.max(jnp.abs(f - lo)), jnp.max(jnp.abs(f - hi)))


def lse(dist, t, y):
    r = dist.cdf(t) - jnp.asarray(y)
    return jnp.sum(r * r)


def qq_points(dist, samples, n_q: int = 99):
    """QQ plot data (paper Fig. 3): model quantiles vs empirical quantiles."""
    emp = Empirical.from_samples(samples)
    q = (jnp.arange(n_q, dtype=jnp.result_type(float)) + 1.0) / (n_q + 1.0)
    emp_q = emp.quantile(q)
    # invert the model CDF on [0, ~3L] so unconstrained fits can overshoot L
    model_q = dist_mod._bisect_icdf(dist.cdf, jnp.minimum(q, dist.cdf(3.0 * dist.L) - 1e-6),
                                    0.0, 3.0 * dist.L)
    return q, emp_q, model_q
