"""Least-squares fitting of preemption models to empirical lifetime CDFs.

The paper fits Eq. 1 with scipy's ``optimize.curve_fit`` (dogbox).  Here the
fitter is a self-contained Levenberg-Marquardt loop in pure JAX (``lax`` control
flow, ``jacfwd`` Jacobians) so it can run jitted/vmapped inside the training
runtime (e.g. continuously re-fitting the model from recent fleet preemptions,
as the paper's "detect policy changes" discussion suggests).  Tests cross-check
against scipy.

Families are parametrized by an unconstrained vector theta; ``_TRANSFORMS``
maps theta -> positive/bounded natural parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from . import distributions as dist_mod
from .distributions import (Constrained, Empirical, Exponential,
                            GompertzMakeham, Weibull, DEADLINE_HOURS)


def _softplus(x):
    return jnp.logaddexp(x, 0.0)


def _inv_softplus(y):
    y = jnp.asarray(y, jnp.result_type(float))
    return jnp.log(jnp.expm1(jnp.maximum(y, 1e-6)))


def _sigmoid(x):
    return jax.nn.sigmoid(x)


def _inv_sigmoid(y):
    y = jnp.clip(jnp.asarray(y, jnp.result_type(float)), 1e-6, 1 - 1e-6)
    return jnp.log(y / (1.0 - y))


@dataclasses.dataclass(frozen=True)
class Family:
    name: str
    n_params: int
    build: Callable  # theta (unconstrained) -> distribution
    theta0: Callable  # (t, y, L) -> initial unconstrained theta
    # extra residuals appended to the data residuals (boundary conditions)
    boundary: Callable = lambda d: jnp.zeros((0,))
    # multi-start inits (best final LSE wins)
    extra_theta0: tuple = ()


def _build_constrained(theta, L):
    tau1 = _softplus(theta[0])
    tau2 = _softplus(theta[1])
    b = _softplus(theta[2])
    A = _sigmoid(theta[3])
    return Constrained(tau1=tau1, tau2=tau2, b=b, A=A, L=L)


def _build_exponential(theta, L):
    return Exponential(mttf=_softplus(theta[0]), L=L)


def _build_weibull(theta, L):
    return Weibull(lam=_softplus(theta[0]), k=_softplus(theta[1]), L=L)


def _build_gm(theta, L):
    return GompertzMakeham(lam=_softplus(theta[0]), alpha=1e-3 * _softplus(theta[1]),
                           beta=_softplus(theta[2]), L=L)


FAMILIES = {
    "constrained": Family(
        name="constrained", n_params=4, build=_build_constrained,
        theta0=lambda t, y, L: jnp.stack([
            _inv_softplus(1.0), _inv_softplus(1.0), _inv_softplus(0.95 * L),
            _inv_sigmoid(0.45)]),
        # paper: "combination of the 4 fit parameters ... ensure F(0) ~= 0";
        # weight-3 penalty on the raw (unclipped) Eq. 1 at t=0.
        boundary=lambda d: 3.0 * d.cdf_raw(0.0)[None],
    ),
    "exponential": Family(
        name="exponential", n_params=1, build=_build_exponential,
        theta0=lambda t, y, L: jnp.stack([_inv_softplus(jnp.maximum(jnp.mean(t), 0.5))]),
    ),
    "weibull": Family(
        name="weibull", n_params=2, build=_build_weibull,
        theta0=lambda t, y, L: jnp.stack([
            _inv_softplus(1.0 / jnp.maximum(jnp.mean(t), 0.5)), _inv_softplus(1.0)]),
    ),
    "gompertz_makeham": Family(
        name="gompertz_makeham", n_params=3, build=_build_gm,
        theta0=lambda t, y, L: jnp.stack([
            _inv_softplus(0.1), _inv_softplus(0.1), _inv_softplus(0.3)]),
        extra_theta0=(
            lambda t, y, L: jnp.stack([_inv_softplus(0.05), _inv_softplus(1.0),
                                       _inv_softplus(0.6)]),
            # deadline-wall start: alpha ~ 1e-3*softplus(-14) ~ 1e-9, beta ~ 1
            lambda t, y, L: jnp.stack([_inv_softplus(0.05), jnp.asarray(-14.0),
                                       _inv_softplus(1.0)]),
        ),
    ),
}


def _model_cdf(dist):
    """Fitting target: raw model curve where available (the clip in
    Constrained.cdf would zero gradients at the boundary)."""
    return dist.cdf_raw if hasattr(dist, "cdf_raw") else dist.cdf


@dataclasses.dataclass(frozen=True)
class FitResult:
    dist: object
    theta: jnp.ndarray
    lse: jnp.ndarray           # sum of squared CDF residuals (data terms only)
    iterations: jnp.ndarray
    converged: jnp.ndarray


def levenberg_marquardt(residual_fn, theta0, max_iters: int = 200,
                        mu0: float = 1e-2, tol: float = 1e-9):
    """Classic LM with multiplicative damping; fixed-shape, jit-friendly.

    residual_fn: theta -> residual vector r; minimizes ||r||^2.
    """
    jac = jax.jacfwd(residual_fn)

    def loss(theta):
        r = residual_fn(theta)
        return jnp.sum(r * r)

    def cond(state):
        i, theta, mu, prev, done = state
        return (i < max_iters) & (~done)

    def body(state):
        i, theta, mu, prev, done = state
        r = residual_fn(theta)
        J = jac(theta)
        JtJ = J.T @ J
        g = J.T @ r
        # LM step: (JtJ + mu*diag(JtJ)) delta = -g
        damp = mu * jnp.diag(jnp.maximum(jnp.diag(JtJ), 1e-10))
        delta = jnp.linalg.solve(JtJ + damp, -g)
        cand = theta + delta
        new = loss(cand)
        accept = new < prev
        theta = jnp.where(accept, cand, theta)
        cur = jnp.where(accept, new, prev)
        mu = jnp.where(accept, jnp.maximum(mu / 3.0, 1e-12), jnp.minimum(mu * 2.0, 1e8))
        done = accept & (jnp.abs(prev - new) < tol * (1.0 + prev))
        return i + 1, theta, mu, cur, done

    theta0 = jnp.asarray(theta0, jnp.result_type(float))
    state = (jnp.asarray(0), theta0, jnp.asarray(mu0, theta0.dtype),
             loss(theta0), jnp.asarray(False))
    i, theta, mu, final, done = jax.lax.while_loop(cond, body, state)
    return theta, final, i, done


def fit(family: str, t, y, L=DEADLINE_HOURS, max_iters: int = 200) -> FitResult:
    """Fit a family's CDF to points (t, y) by least squares (paper Eq. 1 fit)."""
    fam = FAMILIES[family]
    t = jnp.asarray(t, jnp.result_type(float))
    y = jnp.asarray(y, t.dtype)
    L = jnp.asarray(L, t.dtype)

    def residual(theta):
        d = fam.build(theta, L)
        r = _model_cdf(d)(t) - y
        return jnp.concatenate([r, fam.boundary(d)])

    best = None
    for init in (fam.theta0, *fam.extra_theta0):
        theta, lse_v, iters, done = levenberg_marquardt(residual, init(t, y, L),
                                                        max_iters=max_iters)
        if best is None or float(lse_v) < float(best[1]):
            best = (theta, lse_v, iters, done)
    theta, _, iters, done = best
    d = fam.build(theta, L)
    data_r = _model_cdf(d)(t) - y
    return FitResult(dist=d, theta=theta, lse=jnp.sum(data_r * data_r),
                     iterations=iters, converged=done)


def fit_samples(family: str, samples, L=DEADLINE_HOURS, **kw) -> FitResult:
    """Fit directly to a lifetime trace via its empirical CDF."""
    emp = Empirical.from_samples(samples, L=L)
    return fit(family, emp.knots, emp.values, L=L, **kw)


def fit_all(samples, L=DEADLINE_HOURS, families=("constrained", "exponential",
                                                 "weibull", "gompertz_makeham")):
    """Fit every family to a trace; returns {family: FitResult} (Fig. 1/3)."""
    return {f: fit_samples(f, samples, L=L) for f in families}


# ---------------------------------------------------------------------------
# Goodness of fit
# ---------------------------------------------------------------------------

def ks_statistic(dist, samples):
    """Kolmogorov-Smirnov sup |F_model - F_empirical| over the sample points."""
    s = jnp.sort(jnp.ravel(jnp.asarray(samples, jnp.result_type(float))))
    n = s.shape[0]
    f = dist.cdf(s)
    lo = jnp.arange(n, dtype=f.dtype) / n
    hi = (jnp.arange(n, dtype=f.dtype) + 1.0) / n
    return jnp.maximum(jnp.max(jnp.abs(f - lo)), jnp.max(jnp.abs(f - hi)))


def lse(dist, t, y):
    r = dist.cdf(t) - jnp.asarray(y)
    return jnp.sum(r * r)


def qq_points(dist, samples, n_q: int = 99):
    """QQ plot data (paper Fig. 3): model quantiles vs empirical quantiles."""
    emp = Empirical.from_samples(samples)
    q = (jnp.arange(n_q, dtype=jnp.result_type(float)) + 1.0) / (n_q + 1.0)
    emp_q = emp.quantile(q)
    # invert the model CDF on [0, ~3L] so unconstrained fits can overshoot L
    model_q = dist_mod._bisect_icdf(dist.cdf, jnp.minimum(q, dist.cdf(3.0 * dist.L) - 1e-6),
                                    0.0, 3.0 * dist.L)
    return q, emp_q, model_q
