"""Model-based optimal checkpointing via dynamic programming (Eqs. 11-15).

Discretization follows the paper: a job of J steps, each step one grid unit
``grid_dt`` (hours); a checkpoint costs ``delta_steps`` grid units.  The DP
computes

    V[j, t] = min_{1<=i<=j}  P_succ(t, w) * ( w*dt + V[j-i, t+w] )
                           + P_fail(t, w) * ( E_lost(t, w) + R_j )

where w = i + delta (no trailing checkpoint on the final segment, i == j),
``t`` is the VM age index and R_j the cost of restarting the j remaining
steps on a fresh VM (relaunch overhead + V[j, 0], fixed-pointed over a few
sweeps - the paper's executor likewise recomputes E[M*(J_rem, 0)] after every
failure).

Faithfulness notes (see DESIGN.md §6):
  * P_fail uses the *conditional* form (F~(t+w) - F~(t)) / S~(t) with the
    24 h atom included in F~ (the printed Eq. 12 'F(t+i+d) - F(i+d)' is read
    as a typo for F(t+i+d) - F(t)).
  * E_lost is the conditional expected time-in-segment at failure
    E[x - t | fail in (t, t+w]], which reduces to the paper's memoryless
    approximation (i+delta)/2 under a flat hazard; the printed Eq. 15
    (integral of x f(x) dx, an *absolute-age* moment) is dimensionally a
    makespan, not a lost-work, term.

The solver is one jitted ``lax.fori_loop`` over j (vectorized over VM age and
candidate interval); schedule extraction and the Monte-Carlo executor used by
Fig. 7 live below it.

Bit-exactness contract (what each batched kernel must reproduce)
----------------------------------------------------------------
This module holds both ends of two reference/production pairs; the reference
side is retained forever, and restructuring the production side is only
legal while these matches hold (enforced by ``tests/test_batched.py`` /
``tests/test_sim_engine.py``):

  * :func:`solve_batch` vs the per-scenario :func:`solve` — V *and* K
    bit-identical per scenario slice at the solver's native float32, at any
    session dtype: both build their ``Fc``/``Hc`` grids with the same eager
    ops and the batched kernel keeps the reference expression tree
    (hoisting, column-patching and argmin-restructuring may reorder the
    schedule, never the per-element arithmetic, so XLA's FMA contraction
    stays identical).
  * The vectorized executor ``engine.simulate_makespan_batch`` vs
    :func:`simulate_makespan` (the per-trial Python loop kept at the bottom
    of this file) — bit-identical makespans on a shared pre-drawn pool with
    x64 enabled, ~1e-6-relative in default float32 mode.  The loop body
    works in integer grid units with lifetimes pre-converted OUTSIDE the
    loop, so no multiply-add pattern exists for XLA to contract into an
    FMA; any policy table handed to either executor must yield the same
    interval for the same ``(remaining, age)`` lookup (this is why
    ``engine.stack_policy_tables`` may only *replicate* age-independent
    columns, never resample age-dependent ones).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class DPTables:
    """Solved DP: V[j, t] expected remaining makespan (hours), K[j, t] optimal
    next-checkpoint interval (steps)."""
    V: np.ndarray
    K: np.ndarray
    grid_dt: float
    delta_steps: int
    restart_overhead: float
    horizon_idx: int

    def interval_steps(self, remaining_steps: int, age_idx: int) -> int:
        j = int(np.clip(remaining_steps, 0, self.K.shape[0] - 1))
        t = int(np.clip(age_idx, 0, self.K.shape[1] - 1))
        return int(self.K[j, t])

    def expected_makespan(self, job_steps: int, age_idx: int = 0) -> float:
        return float(self.V[int(job_steps), int(age_idx)])


@dataclasses.dataclass(frozen=True)
class BatchDPTables:
    """Solved DP for a whole scenario batch: V/K carry a leading ``(S,)``
    scenario axis (see the leading-axis convention in ``repro.core.engine``).
    ``tables(s)`` returns a plain per-scenario :class:`DPTables` view for the
    existing single-scenario API."""
    V: np.ndarray                # (S, j_max+1, t_max+1)
    K: np.ndarray                # (S, j_max+1, t_max+1)
    grid_dt: float
    delta_steps: int
    restart_overhead: float
    horizon_idx: int

    def __len__(self) -> int:
        return self.V.shape[0]

    def tables(self, s: int) -> DPTables:
        return DPTables(V=self.V[s], K=self.K[s], grid_dt=self.grid_dt,
                        delta_steps=self.delta_steps,
                        restart_overhead=self.restart_overhead,
                        horizon_idx=self.horizon_idx)

    def expected_makespan(self, s: int, job_steps: int,
                          age_idx: int = 0) -> float:
        return float(self.V[int(s), int(job_steps), int(age_idx)])

    def validate(self) -> "BatchDPTables":
        """Reject half-written / diverged tables before they are served.

        The closed-loop runtime calls this between ``solve_batch`` and the
        atomic table swap: a table passes only if every V entry is finite
        and non-negative and every K row respects the DP's own invariant
        (``0 <= K[j] <= j``, with ``K[j] >= 1`` whenever work remains).
        Raises ``ValueError``; returns ``self`` so calls chain.
        """
        if not np.all(np.isfinite(self.V)):
            raise ValueError("BatchDPTables.validate: non-finite V entries")
        if np.any(self.V < 0.0):
            raise ValueError("BatchDPTables.validate: negative makespans in V")
        j = np.arange(self.K.shape[1])[None, :, None]
        if np.any(self.K < 0) or np.any(self.K > j):
            raise ValueError("BatchDPTables.validate: K outside [0, j]")
        if np.any(self.K[:, 1:, :] < 1):
            raise ValueError("BatchDPTables.validate: K < 1 with work "
                             "remaining (j >= 1)")
        return self


@functools.partial(jax.jit, static_argnames=("j_max", "t_max", "delta_steps",
                                             "n_sweeps"))
def _solve_tables(Fc, Hc, grid_dt, restart_overhead, *, j_max: int, t_max: int,
                  delta_steps: int, n_sweeps: int):
    """Returns (V, K) of shapes (j_max+1, t_max+1)."""
    dt = grid_dt
    t_idx = jnp.arange(t_max + 1)
    i_ax = jnp.arange(1, j_max + 1)                      # candidate intervals
    Sc = 1.0 - Fc
    dead = Sc < 1e-6

    def one_sweep(carry, _):
        V_prev, _ = carry
        # restart cost per remaining length j (uses previous sweep's V[:, 0])
        R = restart_overhead + V_prev[:, 0]              # (j_max+1,)

        def body(j, VK):
            V, K = VK
            valid = i_ax <= j                             # (I,)
            final = i_ax == j                             # no checkpoint on last segment
            w = jnp.where(final, i_ax, i_ax + delta_steps)  # (I,)
            end = jnp.clip(t_idx[:, None] + w[None, :], 0, t_max)  # (T, I)
            Ft = Fc[t_idx][:, None]
            Fe = Fc[end]
            St = jnp.maximum(1.0 - Ft, _EPS)
            p_fail = jnp.clip((Fe - Ft) / St, 0.0, 1.0)
            p_succ = 1.0 - p_fail
            # E[x - t | fail in (t, te]] via H(t) = int_0^t x dF~ (atom incl.)
            dF = jnp.maximum(Fe - Ft, _EPS)
            e_lost = (Hc[end] - Hc[t_idx][:, None]) / dF - t_idx[:, None] * dt
            e_lost = jnp.clip(e_lost, 0.0, w[None, :] * dt)
            v_succ = w[None, :] * dt + V[j - i_ax[None, :], end]
            v_fail = e_lost + R[j]
            cost = p_succ * v_succ + p_fail * v_fail
            cost = jnp.where(valid[None, :], cost, jnp.inf)
            vj = jnp.min(cost, axis=1)
            kj = jnp.argmin(cost, axis=1) + 1
            # dead VM (age >= horizon): must restart
            vj = jnp.where(dead, R[j], vj)
            kj = jnp.where(dead, jnp.minimum(j, j_max), kj)
            V = V.at[j].set(vj.astype(V.dtype))
            K = K.at[j].set(kj.astype(K.dtype))
            return V, K

        V0 = jnp.zeros((j_max + 1, t_max + 1), jnp.float32)
        K0 = jnp.zeros((j_max + 1, t_max + 1), jnp.int32)
        V, K = jax.lax.fori_loop(1, j_max + 1, body, (V0, K0))
        return (V, K), None

    # sweep 0 restart estimate: optimistic j*dt
    V_init = jnp.broadcast_to((jnp.arange(j_max + 1) * dt)[:, None],
                              (j_max + 1, t_max + 1)).astype(jnp.float32)
    (V, K), _ = jax.lax.scan(one_sweep, (V_init, jnp.zeros_like(V_init, jnp.int32)),
                             None, length=n_sweeps)
    return V, K


def solve(dist, job_steps: int, *, grid_dt: float = 1.0 / 60.0,
          delta_steps: int = 1, n_sweeps: int = 3,
          restart_overhead: float = 0.0) -> DPTables:
    """Solve the checkpointing DP for jobs up to ``job_steps`` grid steps on
    VMs following ``dist`` (any repro.core.distributions family)."""
    L = float(dist.L)
    t_max = int(round(L / grid_dt))
    tk = jnp.arange(t_max + 1) * grid_dt
    F_raw = jnp.clip(dist.cdf(tk), 0.0, 1.0)
    atom = jnp.maximum(1.0 - F_raw[-1], 0.0)             # provider kill at L
    Fc = F_raw.at[-1].set(1.0)
    H_raw = dist.partial_expectation(jnp.zeros_like(tk), tk)
    Hc = H_raw.at[-1].add(atom * L)                      # include the L-atom
    # scalars pinned to the solver's native f32: a python float would trace
    # as weak f64 under x64 and shift parts of the DP arithmetic to f64,
    # where the reference and batched kernels round differently — pinning
    # keeps solve/solve_batch bit-identical to each other at any session
    # dtype
    V, K = _solve_tables(Fc.astype(jnp.float32), Hc.astype(jnp.float32),
                         jnp.float32(grid_dt), jnp.float32(restart_overhead),
                         j_max=int(job_steps), t_max=t_max,
                         delta_steps=int(delta_steps), n_sweeps=n_sweeps)
    return DPTables(V=np.asarray(V), K=np.asarray(K), grid_dt=grid_dt,
                    delta_steps=int(delta_steps),
                    restart_overhead=restart_overhead, horizon_idx=t_max)


@functools.partial(jax.jit, static_argnames=("j_max", "t_max", "delta_steps",
                                             "n_sweeps"))
def _solve_tables_batch(Fc, Hc, grid_dt, restart_overhead, v_init=None, *,
                        j_max: int, t_max: int, delta_steps: int,
                        n_sweeps: int):
    """Batched DP solve: ``Fc``/``Hc`` are stacked ``(S, t_max+1)`` grids,
    the result ``(V, K)`` has shapes ``(S, j_max+1, t_max+1)``.

    Per scenario slice this is BIT-IDENTICAL to :func:`_solve_tables` (the
    retained reference kernel) — the per-candidate arithmetic keeps the
    reference expression tree so XLA's FMA contraction matches — while
    restructuring the loop body for throughput:

      * the (VM age x candidate interval) grids ``p_fail``/``e_lost`` are
        j-invariant, so they are hoisted out of the 900-iteration loop (the
        reference recomputes them, with two ``(T, I)`` gathers and three
        divisions, every iteration);
      * only the final-segment candidate ``i == j`` (no trailing checkpoint,
        ``w = i``) differs per j, so it is patched as a single column
        instead of re-selecting full ``w``/``end`` grids;
      * ``argmin`` is computed as a min-reduce plus a first-match max-reduce
        (XLA CPU's variadic argmin reduce was half the body's wall-clock);
      * the j loop runs in three segments (thirds of the remaining-work
        axis) so early rows do not scan the full candidate axis; all
        segments share column-prefix views of one precomputed grid set.
    """
    dt = grid_dt
    T = t_max + 1
    t_idx = jnp.arange(T)
    S = Fc.shape[0]
    Sc = 1.0 - Fc
    dead = Sc < 1e-6                                      # (S, T)
    if j_max >= 24:    # keep every segment SIMD-wide: a very narrow cost
        j1 = (j_max + 1) // 3           # matrix compiles to different (ULP-
        j2 = 2 * (j_max + 1) // 3       # shifting) scalar codegen
        segs = [(j1, 1, j1 + 1), (j2, j1 + 1, j2 + 1),
                (j_max, j2 + 1, j_max + 1)]
    else:
        segs = [(j_max, 1, j_max + 1)]

    i_full = jnp.arange(1, j_max + 1)

    def grids(Fc1, Hc1, w):
        # identical per-element arithmetic to the reference body
        end = jnp.clip(t_idx[:, None] + w[None, :], 0, t_max)
        Ft = Fc1[t_idx][:, None]
        Fe = Fc1[end]
        St = jnp.maximum(1.0 - Ft, _EPS)
        p_fail = jnp.clip((Fe - Ft) / St, 0.0, 1.0)
        dF = jnp.maximum(Fe - Ft, _EPS)
        e_lost = (Hc1[end] - Hc1[t_idx][:, None]) / dF - t_idx[:, None] * dt
        e_lost = jnp.clip(e_lost, 0.0, w[None, :] * dt)
        return p_fail, e_lost, end

    pf_nf_f, el_nf_f, end_nf_f = jax.vmap(
        lambda f, h: grids(f, h, i_full + delta_steps))(Fc, Hc)
    pf_fd_f, el_fd_f, end_fd_f = jax.vmap(
        lambda f, h: grids(f, h, i_full))(Fc, Hc)

    def make_seg_views(I_len):
        # a shorter candidate axis is a column prefix of the full grids
        # (column i's values depend only on i), so segments share one
        # precomputed set; end grids are parameter-independent (one copy)
        return (i_full[:I_len], i_full[:I_len] + delta_steps,
                pf_nf_f[:, :, :I_len], el_nf_f[:, :, :I_len],
                pf_fd_f[:, :, :I_len], el_fd_f[:, :, :I_len],
                end_nf_f[0][:, :I_len], end_fd_f[0][:, :I_len])

    seg_data = [make_seg_views(I) for I, _, _ in segs]

    def body_factory(sd, R):
        i_ax, w_nf, pf_nf, el_nf, pf_fd, el_fd, end_nf, end_fd = sd
        I_len = int(i_ax.shape[0])

        def body(j, VK):
            V, K = VK
            valid = i_ax <= j

            def one(V1, pf1, el1, pffd1, elfd1, Rj1):
                Vg = V1[(j - i_ax)[None, :], end_nf]
                v_succ = w_nf[None, :] * dt + Vg
                v_fail = el1 + Rj1
                cost = (1.0 - pf1) * v_succ + pf1 * v_fail
                # final-segment candidate i == j: w = i, V[j-i] == V[0]
                colV = V1[0, end_fd[:, j - 1]]
                vs_f = jnp.asarray(j, cost.dtype) * dt + colV
                cost_f = (1.0 - pffd1[:, j - 1]) * vs_f \
                    + pffd1[:, j - 1] * (elfd1[:, j - 1] + Rj1)
                cost = jax.lax.dynamic_update_slice(cost, cost_f[:, None],
                                                    (0, j - 1))
                costm = jnp.where(valid[None, :], cost, jnp.inf)
                vj = jnp.min(costm, axis=1)
                # first-match argmin: maximize (I_len - idx) over the minima
                eq = (costm == vj[:, None]) & valid[None, :]
                payload = jnp.where(eq, I_len - jnp.arange(I_len)[None, :], 0)
                kj = (I_len + 1 - jnp.max(payload, axis=1)).astype(jnp.int32)
                return vj, kj

            vj, kj = jax.vmap(one)(V, pf_nf, el_nf, pf_fd, el_fd,
                                   R[:, j][:, None])
            vj = jnp.where(dead, R[:, j][:, None], vj)
            kj = jnp.where(dead, jnp.minimum(j, j_max), kj)
            V = jax.vmap(lambda V1, r: jax.lax.dynamic_update_slice(
                V1, r[None, :], (j, 0)))(V, vj.astype(V.dtype))
            K = jax.vmap(lambda K1, r: jax.lax.dynamic_update_slice(
                K1, r[None, :], (j, 0)))(K, kj)
            return V, K

        return body

    def one_sweep(carry, _):
        V_prev, _ = carry
        R = restart_overhead + V_prev[:, :, 0]            # (S, j_max+1)
        V0 = jnp.zeros((S, j_max + 1, T), jnp.float32)
        K0 = jnp.zeros((S, j_max + 1, T), jnp.int32)
        VK = (V0, K0)
        for sd, (_, lo, hi) in zip(seg_data, segs):
            VK = jax.lax.fori_loop(lo, hi, body_factory(sd, R), VK)
        return VK, None

    if v_init is None:
        # cold start: optimistic j*dt (built inside the jit, exactly as the
        # reference does — the None-vs-array pytree structure gives the warm
        # path its own trace, so this cold graph stays byte-identical to the
        # pre-warm-start kernel and the solve/solve_batch bit contract holds)
        v0 = (jnp.arange(j_max + 1) * dt)[None, :, None]
        V_init = jnp.broadcast_to(v0, (S, j_max + 1, T)).astype(jnp.float32)
    else:
        # warm start: seed the restart-cost fixed point with a previously
        # converged V (the closed-loop runtime hands in the last-good tables
        # after a drift refit — fewer sweeps reach the same fixed point)
        V_init = v_init.astype(jnp.float32)
    (V, K), _ = jax.lax.scan(one_sweep,
                             (V_init, jnp.zeros((S, j_max + 1, T), jnp.int32)),
                             None, length=n_sweeps)
    return V, K


def solve_batch(dists: Sequence, job_steps: int, *, grid_dt: float = 1.0 / 60.0,
                delta_steps: int = 1, n_sweeps: int = 3,
                restart_overhead: float = 0.0,
                v_init=None) -> BatchDPTables:
    """Solve the checkpointing DP for a whole scenario batch in ONE compiled
    call (see :func:`_solve_tables_batch`).

    ``dists`` is a sequence of distributions sharing one deadline ``L``.
    Each scenario's ``Fc``/``Hc`` grid is built exactly as :func:`solve`
    builds it (same eager ops), then the stacked grids go through the
    batched kernel — so every returned slice matches the per-scenario
    :func:`solve` result table-for-table, bit-exactly.

    ``v_init`` optionally warm-starts the restart-cost fixed point from a
    previous solve's ``V`` array of matching shape ``(S, j_max+1, t_max+1)``
    (e.g. ``prev.V`` after a drift refit on the same grid) — the cold path
    (``v_init=None``) is untouched and keeps the bit contract above.
    """
    dists = list(dists)
    if not dists:
        raise ValueError("solve_batch() needs at least one distribution")
    L = float(dists[0].L)
    if any(abs(float(d.L) - L) > 1e-12 for d in dists[1:]):
        raise ValueError("solve_batch() requires a shared deadline L")
    t_max = int(round(L / grid_dt))
    if v_init is not None:
        want = (len(dists), int(job_steps) + 1, t_max + 1)
        v_init = np.asarray(v_init)
        if v_init.shape != want:
            raise ValueError(
                f"solve_batch(v_init=...): shape {v_init.shape} does not "
                f"match this solve's tables {want}; warm starts require the "
                f"same scenario count, job_steps and grid")
        if not np.all(np.isfinite(v_init)):
            raise ValueError("solve_batch(v_init=...): non-finite warm start")
        v_init = jnp.asarray(v_init, jnp.float32)
    tk = jnp.arange(t_max + 1) * grid_dt
    Fcs, Hcs = [], []
    for d in dists:
        F_raw = jnp.clip(d.cdf(tk), 0.0, 1.0)
        atom = jnp.maximum(1.0 - F_raw[-1], 0.0)         # provider kill at L
        Fcs.append(F_raw.at[-1].set(1.0).astype(jnp.float32))
        H_raw = d.partial_expectation(jnp.zeros_like(tk), tk)
        Hcs.append(H_raw.at[-1].add(atom * L).astype(jnp.float32))
    # f32-pinned scalars: see solve() — keeps V/K identical at any dtype
    V, K = _solve_tables_batch(jnp.stack(Fcs), jnp.stack(Hcs),
                               jnp.float32(grid_dt),
                               jnp.float32(restart_overhead), v_init,
                               j_max=int(job_steps), t_max=t_max,
                               delta_steps=int(delta_steps),
                               n_sweeps=n_sweeps)
    return BatchDPTables(V=np.asarray(V), K=np.asarray(K), grid_dt=grid_dt,
                         delta_steps=int(delta_steps),
                         restart_overhead=restart_overhead, horizon_idx=t_max)


def extract_schedule(tables: DPTables, job_steps: int,
                     start_age_idx: int = 0) -> list[int]:
    """Planned checkpoint intervals (steps) assuming no failures - the paper's
    i1, i2, ... sequence (e.g. (15, 28, 38, 59, 128) min for a 5 h job at
    age 0 with a 1-min grid)."""
    out, j, t = [], int(job_steps), int(start_age_idx)
    while j > 0:
        i = tables.interval_steps(j, t)
        i = max(1, min(i, j))
        out.append(i)
        j -= i
        t = min(t + i + (tables.delta_steps if j > 0 else 0), tables.horizon_idx)
    return out


# ---------------------------------------------------------------------------
# Monte-Carlo executor (Fig. 7 evaluation; also used by tests)
#
# This per-trial Python loop is the REFERENCE implementation; the production
# path is the batched lax.while_loop kernel in repro.core.engine, which
# performs the same operations on (n_trials,)-vectors.  Exactness contract:
# lifetimes are pre-converted to grid-step units (minus the initial VM's
# sub-grid age offset) OUTSIDE the hot loop, so the loop body contains no
# multiply-add pattern XLA could contract into an FMA; given a shared pool,
# the kernel run in float64 matches this loop bit-for-bit.
# ---------------------------------------------------------------------------

def simulate_makespan(policy_fn: Callable[[int, int], int], lifetimes_fn,
                      job_steps: int, *, grid_dt: float = 1.0 / 60.0,
                      delta_steps: int = 1, start_age: float = 0.0,
                      n_trials: int = 2000, seed: int = 0,
                      restart_overhead: float = 0.0,
                      max_restarts: int = 64, pool=None, first=None):
    """Execute a job under sampled preemptions.

    policy_fn(remaining_steps, age_idx) -> steps until next checkpoint.
    lifetimes_fn(rng, n, min_age=0.0) -> n sampled VM lifetimes (hours),
    conditioned on survival to ``min_age`` (used for the first VM when the
    job starts on an aged machine).  Alternatively pass pre-drawn ``first``
    (n_trials,) and ``pool`` (n_trials, max_restarts+2) arrays from
    ``engine.draw_lifetime_pool`` — the equivalence tests share one pool
    between this reference and the vectorized kernel.

    Semantics: failure during a work segment or during the checkpoint write
    loses progress back to the last durable checkpoint; the job resumes on a
    fresh VM (age 0) after ``restart_overhead`` hours, recomputing its
    schedule (the paper's resume-event behavior).  Returns makespans (hours),
    shape (n_trials,).
    """
    if pool is None:
        from .. import engine  # local import: engine imports this module too

        first, pool = engine.draw_lifetime_pool(
            lifetimes_fn, n_trials, max_restarts=max_restarts, seed=seed,
            start_age=start_age)
    else:
        first = pool[:, 0] if first is None else first
        n_trials = len(first)
    age0_idx = int(round(start_age / grid_dt))
    off0 = start_age - age0_idx * grid_dt
    # lifetimes in grid-step units, initial VM age offset removed (see the
    # exactness note above: all comparisons are int-vs-precomputed-float)
    first_steps = (np.asarray(first, np.float64) - off0) / grid_dt
    pool_steps = np.asarray(pool, np.float64) / grid_dt
    out = np.empty((n_trials,), np.float64)
    for n in range(n_trials):
        remaining = int(job_steps)
        age_idx = age0_idx
        draw = 0
        life_s = first_steps[n]
        done_steps = 0          # completed work+checkpoint segments (grid units)
        lost_steps = 0.0        # preempted partial segments (grid units)
        restarts = 0
        while remaining > 0 and restarts <= max_restarts:
            i = int(policy_fn(remaining, age_idx))
            i = max(1, min(i, remaining))
            w = i + (delta_steps if i < remaining else 0)
            if age_idx + w <= life_s:
                # segment + checkpoint complete
                done_steps += w
                age_idx += w
                remaining -= i
            else:
                # preempted mid-segment: progress since last checkpoint lost
                lost_steps += max(life_s - age_idx, 0.0)
                draw += 1
                life_s = pool_steps[n, min(draw, max_restarts + 1)]
                age_idx = 0
                restarts += 1
        out[n] = (done_steps + lost_steps) * grid_dt \
            + restarts * restart_overhead
    return out


def dp_policy_fn(tables: DPTables):
    return lambda remaining, age_idx: tables.interval_steps(remaining, age_idx)


def young_daly_policy_fn(tau_hours: float, grid_dt: float):
    tau_steps = max(1, int(round(tau_hours / grid_dt)))
    return lambda remaining, age_idx: min(tau_steps, remaining)


def no_checkpoint_policy_fn():
    return lambda remaining, age_idx: remaining


def model_lifetimes_fn(dist):
    """lifetimes_fn adapter: numpy rng -> inverse-CDF samples from ``dist``,
    optionally conditioned on survival to ``min_age`` (F restricted to
    [F(min_age), 1], with the residual >=F(L) mass preempted at L).

    Draws go through ``engine.capped_icdf_draw``, whose jitted kernel takes
    the distribution as a pytree *argument* — this reference sampler and
    ``engine.draw_lifetime_pool_batch`` therefore share one compiled
    inversion with no parameter constants baked into either graph, which is
    what makes the batched pool reproduce this reference bit-for-bit under
    x64.  Leaves are still normalized to jnp arrays up front so both paths
    present identical leaf dtypes to that cache.
    """
    dist = jax.tree_util.tree_map(
        lambda l: jnp.asarray(l, jnp.result_type(float)), dist)

    def fn_capped(rng, n, min_age: float = 0.0):
        from .. import engine  # local import, matching simulate_makespan

        u = rng.uniform(size=n)
        f_lo = float(dist.cdf(min_age)) if min_age > 0 else 0.0
        u = f_lo + u * (1.0 - f_lo)
        fl = float(dist.cdf(dist.L))
        return engine.capped_icdf_draw(dist, u, fl, float(dist.L))

    return fn_capped
