"""Model-based optimal checkpointing via dynamic programming (Eqs. 11-15).

Discretization follows the paper: a job of J steps, each step one grid unit
``grid_dt`` (hours); a checkpoint costs ``delta_steps`` grid units.  The DP
computes

    V[j, t] = min_{1<=i<=j}  P_succ(t, w) * ( w*dt + V[j-i, t+w] )
                           + P_fail(t, w) * ( E_lost(t, w) + R_j )

where w = i + delta (no trailing checkpoint on the final segment, i == j),
``t`` is the VM age index and R_j the cost of restarting the j remaining
steps on a fresh VM (relaunch overhead + V[j, 0], fixed-pointed over a few
sweeps - the paper's executor likewise recomputes E[M*(J_rem, 0)] after every
failure).

Faithfulness notes (see DESIGN.md §6):
  * P_fail uses the *conditional* form (F~(t+w) - F~(t)) / S~(t) with the
    24 h atom included in F~ (the printed Eq. 12 'F(t+i+d) - F(i+d)' is read
    as a typo for F(t+i+d) - F(t)).
  * E_lost is the conditional expected time-in-segment at failure
    E[x - t | fail in (t, t+w]], which reduces to the paper's memoryless
    approximation (i+delta)/2 under a flat hazard; the printed Eq. 15
    (integral of x f(x) dx, an *absolute-age* moment) is dimensionally a
    makespan, not a lost-work, term.

The solver dispatches to a pluggable backend package
(``repro.core.policies.solver_backends``; see ``docs/solver.md``): the
retained serial reference, the batched XLA kernel, a Pallas VMEM-resident
kernel (``repro.kernels.dp_recurrence``), and a coarse-to-fine refinement
pipeline (``refine=True``), optionally ``shard_map``-sharded over the
``scenario`` logical axis when a ``repro.sharding`` mesh is active.
Schedule extraction and the Monte-Carlo executor used by Fig. 7 live below
the dispatchers.

Bit-exactness contract (what each batched kernel must reproduce)
----------------------------------------------------------------
This module holds both ends of two reference/production pairs; the reference
side is retained forever, and restructuring the production side is only
legal while these matches hold (enforced by ``tests/test_batched.py`` /
``tests/test_sim_engine.py``):

  * :func:`solve_batch` (``backend="xla"``, and the coarse-to-fine pipeline
    when its verification holds) vs the per-scenario :func:`solve` — V
    *and* K bit-identical per scenario slice at the solver's native
    float32, at any session dtype: both build their ``Fc``/``Hc`` grids
    through one shared helper (:func:`_cdf_grids`) and the batched kernel
    keeps the reference expression tree (hoisting, column-patching and
    argmin-restructuring may reorder the schedule, never the per-element
    arithmetic, so XLA's FMA contraction stays identical).  The Pallas
    backend is the deliberate exception: it recomputes the probability
    grids in-kernel and is tolerance-tested instead.
  * The vectorized executor ``engine.simulate_makespan_batch`` vs
    :func:`simulate_makespan` (the per-trial Python loop kept at the bottom
    of this file) — bit-identical makespans on a shared pre-drawn pool with
    x64 enabled, ~1e-6-relative in default float32 mode.  The loop body
    works in integer grid units with lifetimes pre-converted OUTSIDE the
    loop, so no multiply-add pattern exists for XLA to contract into an
    FMA; any policy table handed to either executor must yield the same
    interval for the same ``(remaining, age)`` lookup (this is why
    ``engine.stack_policy_tables`` may only *replicate* age-independent
    columns, never resample age-dependent ones).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import solver_backends
from .solver_backends import refine as _refine
from .solver_backends.grids import (  # noqa: F401
    _EPS, cdf_grids as _cdf_grids, dollar_loss_grids as _dollar_loss_grids,
    price_cum_grids as _price_cum_grids)

OBJECTIVES = ("makespan", "dollars")

# retained names for the two kernels this module used to define inline; the
# implementations moved to the backend package unchanged
_solve_tables = solver_backends.reference.solve_tables
_solve_tables_batch = solver_backends.xla.solve_tables_batch


@dataclasses.dataclass(frozen=True)
class DPTables:
    """Solved DP: V[j, t] expected remaining cost-to-completion, K[j, t]
    optimal next-checkpoint interval (steps).  ``objective`` records the
    unit of V: hours (``"makespan"``, the paper's Eqs. 11-15) or dollars
    (``"dollars"``, price-weighted segments + launch-priced restarts)."""
    V: np.ndarray
    K: np.ndarray
    grid_dt: float
    delta_steps: int
    restart_overhead: float
    horizon_idx: int
    objective: str = "makespan"

    def interval_steps(self, remaining_steps: int, age_idx: int) -> int:
        j = int(np.clip(remaining_steps, 0, self.K.shape[0] - 1))
        t = int(np.clip(age_idx, 0, self.K.shape[1] - 1))
        return int(self.K[j, t])

    def expected_makespan(self, job_steps: int, age_idx: int = 0) -> float:
        """V at (job_steps, age_idx) — expected hours under the makespan
        objective, expected dollars under the dollar objective."""
        return float(self.V[int(job_steps), int(age_idx)])


@dataclasses.dataclass(frozen=True)
class BatchDPTables:
    """Solved DP for a whole scenario batch: V/K carry a leading ``(S,)``
    scenario axis (see the leading-axis convention in ``repro.core.engine``).
    ``tables(s)`` returns a plain per-scenario :class:`DPTables` view for the
    existing single-scenario API."""
    V: np.ndarray                # (S, j_max+1, t_max+1)
    K: np.ndarray                # (S, j_max+1, t_max+1)
    grid_dt: float
    delta_steps: int
    restart_overhead: float
    horizon_idx: int
    # provenance (not part of table identity): which backend produced the
    # tables and, for refine=True, what the refinement pipeline did
    backend: str = "xla"
    refine_info: Optional[dict] = None
    # unit of V: "makespan" (hours, Eqs. 11-15) or "dollars"
    objective: str = "makespan"

    def __len__(self) -> int:
        return self.V.shape[0]

    def tables(self, s: int) -> DPTables:
        return DPTables(V=self.V[s], K=self.K[s], grid_dt=self.grid_dt,
                        delta_steps=self.delta_steps,
                        restart_overhead=self.restart_overhead,
                        horizon_idx=self.horizon_idx,
                        objective=self.objective)

    def expected_makespan(self, s: int, job_steps: int,
                          age_idx: int = 0) -> float:
        """V at (s, job_steps, age_idx) — expected hours under the makespan
        objective, expected dollars under the dollar objective."""
        return float(self.V[int(s), int(job_steps), int(age_idx)])

    def validate(self) -> "BatchDPTables":
        """Reject half-written / diverged tables before they are served.

        The closed-loop runtime calls this between ``solve_batch`` and the
        atomic table swap: a table passes only if every V entry is finite
        and non-negative and every K row respects the DP's own invariant
        (``0 <= K[j] <= j``, with ``K[j] >= 1`` whenever work remains).
        The invariants are objective-independent (dollar V is a price
        integral of non-negative work, so it is non-negative too); only the
        unit named in the error message changes.
        Raises ``ValueError``; returns ``self`` so calls chain.
        """
        unit = "dollars" if self.objective == "dollars" else "makespans"
        if not np.all(np.isfinite(self.V)):
            raise ValueError(
                f"BatchDPTables.validate: non-finite V entries ({unit})")
        if np.any(self.V < 0.0):
            raise ValueError(f"BatchDPTables.validate: negative {unit} in V")
        j = np.arange(self.K.shape[1])[None, :, None]
        if np.any(self.K < 0) or np.any(self.K > j):
            raise ValueError("BatchDPTables.validate: K outside [0, j]")
        if np.any(self.K[:, 1:, :] < 1):
            raise ValueError("BatchDPTables.validate: K < 1 with work "
                             "remaining (j >= 1)")
        return self


def _check_objective(objective: str, price) -> None:
    if objective not in OBJECTIVES:
        raise ValueError(f"objective={objective!r}; expected one of "
                         f"{OBJECTIVES}")
    if objective == "dollars" and price is None:
        raise ValueError("objective='dollars' requires price= (a "
                         "market.PriceGrid)")
    if objective == "makespan" and price is not None:
        raise ValueError("price= is only meaningful with objective='dollars'")


def _dollar_inputs(price, grid_dt: float, t_max: int, job_steps: int,
                   delta_steps: int, restart_overhead: float, S: int):
    """Solver inputs for the dollar objective: the float32 cumulative-dollar
    grid ``Pc`` (``(S, TX)``, extended past the horizon so segment gathers
    never clip) and the per-scenario dollar restart overhead ``ro``
    (``(S,)``, overhead hours billed at the launch-cell price).  A one-row
    ``price`` broadcasts over the scenario axis."""
    rows = np.asarray(price.prices).shape[0]
    if rows not in (1, S):
        raise ValueError(
            f"price= has {rows} rows; expected 1 (broadcast) or S={S}")
    Pc, P0 = _price_cum_grids(price.prices, price.cum, price.dt, grid_dt,
                              t_max, int(job_steps) + int(delta_steps))
    if rows == 1 and S > 1:
        Pc = np.broadcast_to(Pc, (S,) + Pc.shape[1:])
        P0 = np.broadcast_to(P0, (S,))
    ro = (float(restart_overhead) * P0).astype(np.float32)
    return jnp.asarray(Pc), jnp.asarray(ro)


def solve(dist, job_steps: int, *, grid_dt: float = 1.0 / 60.0,
          delta_steps: int = 1, n_sweeps: int = 3,
          restart_overhead: float = 0.0, backend: str = "auto",
          objective: str = "makespan", price=None) -> DPTables:
    """Solve the checkpointing DP for jobs up to ``job_steps`` grid steps on
    VMs following ``dist`` (any repro.core.distributions family).

    ``backend="auto"`` runs the serial reference kernel: the single-scenario
    path IS the reference side of the bit-exactness contract, so rerouting
    it through a production kernel would collapse the very pairing
    ``tests/test_batched.py`` enforces (``REPRO_SOLVER_BACKEND`` therefore
    does not apply here).  An explicit ``"xla"``/``"pallas"`` routes through
    the batched machinery with ``S=1`` and unwraps.

    ``objective="dollars"`` with a ``price`` grid solves for expected
    dollars-to-completion instead of hours (row 0 of a multi-row grid);
    see :func:`solve_batch` for the recurrence.
    """
    _check_objective(objective, price)
    Fc, Hc, t_max = _cdf_grids(dist, grid_dt)
    # scalars pinned to the solver's native f32 (see _cdf_grids): keeps
    # solve/solve_batch bit-identical to each other at any session dtype
    gdt, ro = jnp.float32(grid_dt), jnp.float32(restart_overhead)
    Pc = Elp = None
    if objective == "dollars":
        rows = int(np.asarray(price.prices).shape[0])
        Pc, ro = _dollar_inputs(price, grid_dt, t_max, job_steps,
                                delta_steps, restart_overhead, rows)
        Pc, ro = Pc[:1], ro[:1]          # single scenario: row 0
        Elp = jnp.asarray(_dollar_loss_grids(
            Fc[None], Hc[None], Pc, grid_dt, j_max=int(job_steps),
            t_max=t_max, delta_steps=int(delta_steps)))
    if backend in ("auto", "reference"):
        pc0 = None if Pc is None else Pc[0]
        ro0 = ro if Pc is None else ro[0]
        ep0 = None if Elp is None else Elp[0]
        V, K = _solve_tables(Fc, Hc, gdt, ro0, None, pc0, ep0,
                             j_max=int(job_steps), t_max=t_max,
                             delta_steps=int(delta_steps), n_sweeps=n_sweeps)
    else:
        name = solver_backends.resolve(backend)
        V, K = _dispatch_plain(name, Fc[None], Hc[None], gdt, ro, None, Pc,
                               Elp, j_max=int(job_steps), t_max=t_max,
                               delta_steps=int(delta_steps),
                               n_sweeps=n_sweeps)
        V, K = V[0], K[0]
    return DPTables(V=np.asarray(V), K=np.asarray(K), grid_dt=grid_dt,
                    delta_steps=int(delta_steps),
                    restart_overhead=restart_overhead, horizon_idx=t_max,
                    objective=objective)


def _dispatch_plain(name: str, Fc, Hc, gdt, ro, v_init, Pc=None, Elp=None, *,
                    j_max: int, t_max: int, delta_steps: int, n_sweeps: int):
    """Run one backend on stacked grids, sharding the scenario axis over an
    active ``repro.sharding`` mesh when its rules allow (transparent
    single-device fallback: the unwrapped call is byte-identical to the
    pre-refactor one).

    In dollar mode (``Pc``/``Elp`` given) ``ro`` is the per-scenario ``(S,)``
    dollar overhead and rides the sharded operand list with ``Pc`` and the
    host-precomputed loss grids ``Elp`` — a closure capture would replicate
    them at full length inside each shard."""
    mod = solver_backends.get(name)
    statics = dict(j_max=j_max, t_max=t_max, delta_steps=delta_steps,
                   n_sweeps=n_sweeps)
    if name == "reference":
        # the Python-loop batch adapter: per-scenario dispatches, no shard
        return mod.solve_tables_batch(Fc, Hc, gdt, ro, v_init, Pc, Elp,
                                      **statics)
    if Pc is None:
        if v_init is None:
            kern = lambda fc, hc: mod.solve_tables_batch(
                fc, hc, gdt, ro, None, **statics)
            args = (Fc, Hc)
        else:
            kern = lambda fc, hc, vi: mod.solve_tables_batch(
                fc, hc, gdt, ro, vi, **statics)
            args = (Fc, Hc, v_init)
    else:
        if v_init is None:
            kern = lambda fc, hc, pc, ep, rv: mod.solve_tables_batch(
                fc, hc, gdt, rv, None, pc, ep, **statics)
            args = (Fc, Hc, Pc, Elp, ro)
        else:
            kern = lambda fc, hc, vi, pc, ep, rv: mod.solve_tables_batch(
                fc, hc, gdt, rv, vi, pc, ep, **statics)
            args = (Fc, Hc, v_init, Pc, Elp, ro)
    fn, _ = solver_backends.shard_scenarios(kern, Fc.shape[0], len(args), 2)
    return fn(*args)


def _dispatch_refined(dists, Fc, Hc, grid_dt, gdt, ro, v_init, rplan,
                      refine_check: str, price=None, Pc=None, Elp=None, *,
                      j_max: int, t_max: int, delta_steps: int,
                      n_sweeps: int):
    """The coarse-to-fine pipeline (see ``solver_backends.refine``): coarse
    hint solve at ``factor x grid_dt``, a host round-trip turning its argmin
    table into static per-segment candidate caps, pruned pre-sweeps, one
    full-resolution sweep — falling back to the plain XLA solve whenever the
    column-0 check (or the optional full check) fails.

    Dollar mode (``Pc``/``price`` given): the coarse hint solve runs the
    dollar objective too — a makespan hint would point at the wrong argmin
    in priced windows — on a coarse cumulative-dollar grid built from the
    same ``price``.  The dollar restart overhead ``ro`` is shared between
    levels (same launch cell at either resolution)."""
    statics = dict(j_max=j_max, t_max=t_max, delta_steps=delta_steps,
                   n_sweeps=n_sweeps)
    factor, radius = rplan["factor"], rplan["radius"]
    j_max_c, delta_c = rplan["j_max_c"], rplan["delta_steps_c"]
    Fcs_c, Hcs_c, t_max_c = [], [], None
    for d in dists:
        f, h, t_max_c = _cdf_grids(d, grid_dt * factor)
        Fcs_c.append(f)
        Hcs_c.append(h)
    Fc_c, Hc_c = jnp.stack(Fcs_c), jnp.stack(Hcs_c)
    S = Fc.shape[0]

    if Pc is None:
        coarse = lambda fc, hc: (_refine.coarse_tables(
            fc, hc, jnp.float32(grid_dt * factor), ro, j_max_c=j_max_c,
            t_max_c=t_max_c, delta_steps_c=delta_c, n_sweeps=n_sweeps),)
        cargs = (Fc_c, Hc_c)
    else:
        Pc_c, _ = _dollar_inputs(price, grid_dt * factor, t_max_c, j_max_c,
                                 delta_c, 0.0, S)
        Elp_c = jnp.asarray(_dollar_loss_grids(
            Fc_c, Hc_c, Pc_c, grid_dt * factor, j_max=j_max_c,
            t_max=t_max_c, delta_steps=delta_c))
        coarse = lambda fc, hc, pcc, epc, rv: (_refine.coarse_tables(
            fc, hc, jnp.float32(grid_dt * factor), rv, j_max_c=j_max_c,
            t_max_c=t_max_c, delta_steps_c=delta_c, n_sweeps=n_sweeps,
            Pc_c=pcc, Elp_c=epc),)
        cargs = (Fc_c, Hc_c, Pc_c, Elp_c, ro)
    fn_c, _ = solver_backends.shard_scenarios(coarse, S, len(cargs), 1)
    (Kc,) = fn_c(*cargs)

    # host round-trip: the coarse argmin becomes STATIC candidate caps (the
    # bit-safe prefix-slice form of "refine near the argmin"); retraces are
    # cached per cap tuple, which a sweep over one workload reuses
    cone_segs = _refine.cone_segments(j_max, t_max, delta_steps)
    caps = _refine.candidate_caps(Kc, cone_segs, factor=factor,
                                  radius=radius, j_max_c=j_max_c,
                                  t_max_c=t_max_c)

    rstatics = dict(statics, caps=caps)
    c0 = None if v_init is None else v_init[:, :, 0]
    if Pc is None:
        if c0 is None:
            kern = lambda fc, hc: _refine.refined_solve(
                fc, hc, gdt, ro, None, **rstatics)
            args = (Fc, Hc)
        else:
            kern = lambda fc, hc, c0: _refine.refined_solve(
                fc, hc, gdt, ro, c0, **rstatics)
            args = (Fc, Hc, c0)
    else:
        if c0 is None:
            kern = lambda fc, hc, pc, ep, rv: _refine.refined_solve(
                fc, hc, gdt, rv, None, pc, ep, **rstatics)
            args = (Fc, Hc, Pc, Elp, ro)
        else:
            kern = lambda fc, hc, c0, pc, ep, rv: _refine.refined_solve(
                fc, hc, gdt, rv, c0, pc, ep, **rstatics)
            args = (Fc, Hc, c0, Pc, Elp, ro)
    fn, _ = solver_backends.shard_scenarios(kern, S, len(args), 3)
    V, K, ok = fn(*args)

    info = dict(rplan, applied=True, t_max_c=t_max_c, caps=list(caps),
                verified_col0=bool(np.asarray(ok).all()), fallback=False)
    if not info["verified_col0"]:
        # a cap cut off an argmin on the restart-cost chain: the refined
        # tables are not trustworthy — serve the plain solve instead
        V, K = _dispatch_plain("xla", Fc, Hc, gdt, ro, v_init, Pc, Elp,
                               **statics)
        info["fallback"] = True
        return V, K, info
    if refine_check == "full":
        # debug/CI harness: compare the whole refined table against the
        # plain solve (costs more than the solve it checks)
        Vf, Kf = _dispatch_plain("xla", Fc, Hc, gdt, ro, v_init, Pc, Elp,
                                 **statics)
        match = bool(np.array_equal(np.asarray(V), np.asarray(Vf))
                     and np.array_equal(np.asarray(K), np.asarray(Kf)))
        info["full_check_match"] = match
        if not match:
            V, K = Vf, Kf
            info["fallback"] = True
    return V, K, info


def solve_batch(dists: Sequence, job_steps: int, *, grid_dt: float = 1.0 / 60.0,
                delta_steps: int = 1, n_sweeps: int = 3,
                restart_overhead: float = 0.0, v_init=None,
                backend: str = "auto", refine: bool = False,
                refine_factor: int = 4, refine_radius: Optional[int] = None,
                refine_check: str = "col0", objective: str = "makespan",
                price=None) -> BatchDPTables:
    """Solve the checkpointing DP for a whole scenario batch in ONE compiled
    call (see ``solver_backends`` and ``docs/solver.md``).

    ``dists`` is a sequence of distributions sharing one deadline ``L``.
    Each scenario's ``Fc``/``Hc`` grid is built by the shared
    :func:`_cdf_grids` helper (the same eager ops :func:`solve` uses), then
    the stacked grids go through the selected backend — for ``"xla"`` (the
    ``"auto"`` default off-TPU) every returned slice matches the
    per-scenario :func:`solve` result table-for-table, bit-exactly.

    ``backend`` selects the kernel (``"auto"``/``"reference"``/``"xla"``/
    ``"pallas"``; ``"auto"`` honors the ``REPRO_SOLVER_BACKEND`` env var).
    ``refine=True`` runs the coarse-to-fine pipeline on the XLA machinery:
    a coarse solve at ``refine_factor x grid_dt`` supplies argmin hints that
    cap the pre-sweeps' candidate axis (to ``factor*K_c + refine_radius``
    per segment) inside the column-0 dependency cone, and the final sweep
    runs at full resolution;
    a bit-level column-0 verification guards every pre-sweep, falling back
    to the plain solve on failure (``refine_check="full"`` additionally
    compares the whole table in-process; ``"off"`` is not available — the
    column check is always on).

    ``v_init`` optionally warm-starts the restart-cost fixed point from a
    previous solve's ``V`` array of matching shape ``(S, j_max+1, t_max+1)``
    (e.g. ``prev.V`` after a drift refit on the same grid) — the cold path
    (``v_init=None``) is untouched and keeps the bit contract above.  A warm
    start must come from tables solved under the SAME objective (V's unit is
    the seed's unit; the shapes cannot tell them apart, so this is the
    caller's contract — ``FleetRuntime`` guards it).

    ``objective="dollars"`` with ``price=`` (a ``market.PriceGrid``; one row
    broadcasts, otherwise one row per scenario) switches V to expected
    dollars-to-completion:

        V[j, t] = min_i  P_succ * ( dP(t, w) + V[j-i, t+w] )
                       + P_fail * ( E_lost * pbar(t, w) + R_j )

    where ``dP(t, w) = Pc(t+w) - Pc(t)`` is the integrated price over the
    segment's age window (``grids.price_cum_grids``, ages beyond the price
    horizon billed at the final cell), ``pbar = dP / (w*dt)`` its average
    $/hour, and ``R_j = restart_overhead x launch price + V[j, 0]``.  The
    failure branches' probabilities and expected lost time are unchanged —
    only the pricing of time changes — so K stretches checkpoint intervals
    exactly where the price makes lost work cheap or checkpoint overhead
    expensive.  On a flat grid at p $/h every cost term is p x the makespan
    term, so V reduces to ``p x V_makespan`` (up to float32 rounding; the
    property tests pin this).  All backends, warm starts, ``refine=True``
    and scenario sharding work identically under either objective, and the
    reference<->xla bit-identity contract covers both.
    """
    _check_objective(objective, price)
    dists = list(dists)
    if not dists:
        raise ValueError("solve_batch() needs at least one distribution")
    L = float(dists[0].L)
    if any(abs(float(d.L) - L) > 1e-12 for d in dists[1:]):
        raise ValueError("solve_batch() requires a shared deadline L")
    t_max = int(round(L / grid_dt))
    if v_init is not None:
        want = (len(dists), int(job_steps) + 1, t_max + 1)
        v_init = np.asarray(v_init)
        if v_init.shape != want:
            raise ValueError(
                f"solve_batch(v_init=...): shape {v_init.shape} does not "
                f"match this solve's tables {want}; warm starts require the "
                f"same scenario count, job_steps and grid")
        if not np.all(np.isfinite(v_init)):
            raise ValueError("solve_batch(v_init=...): non-finite warm start")
        v_init = jnp.asarray(v_init, jnp.float32)
    grids_fh = [_cdf_grids(d, grid_dt) for d in dists]
    Fc = jnp.stack([g[0] for g in grids_fh])
    Hc = jnp.stack([g[1] for g in grids_fh])
    # f32-pinned scalars: see _cdf_grids — keeps V/K identical at any dtype
    gdt, ro = jnp.float32(grid_dt), jnp.float32(restart_overhead)
    Pc = Elp = None
    if objective == "dollars":
        Pc, ro = _dollar_inputs(price, grid_dt, t_max, job_steps,
                                delta_steps, restart_overhead, len(dists))
        Elp = jnp.asarray(_dollar_loss_grids(
            Fc, Hc, Pc, grid_dt, j_max=int(job_steps), t_max=t_max,
            delta_steps=int(delta_steps)))
    statics = dict(j_max=int(job_steps), t_max=t_max,
                   delta_steps=int(delta_steps), n_sweeps=n_sweeps)
    refine_info = None
    if refine:
        if backend not in ("auto", "xla"):
            raise ValueError(
                f"solve_batch(refine=True) runs on the XLA machinery; "
                f"backend={backend!r} is contradictory")
        name = "xla"
        rplan = _refine.plan(int(job_steps), t_max, int(delta_steps),
                             n_sweeps, refine_factor, refine_radius)
        if rplan is None:
            # grid too small to refine (or single sweep): plain solve
            V, K = _dispatch_plain(name, Fc, Hc, gdt, ro, v_init, Pc, Elp,
                                   **statics)
            refine_info = {"applied": False, "reason": "degenerate"}
        else:
            V, K, refine_info = _dispatch_refined(
                dists, Fc, Hc, grid_dt, gdt, ro, v_init, rplan,
                refine_check, price, Pc, Elp, **statics)
    else:
        name = solver_backends.resolve(backend)
        V, K = _dispatch_plain(name, Fc, Hc, gdt, ro, v_init, Pc, Elp,
                               **statics)
    return BatchDPTables(V=np.asarray(V), K=np.asarray(K), grid_dt=grid_dt,
                         delta_steps=int(delta_steps),
                         restart_overhead=restart_overhead, horizon_idx=t_max,
                         backend=name + ("+refine" if refine else ""),
                         refine_info=refine_info, objective=objective)


def extract_schedule(tables: DPTables, job_steps: int,
                     start_age_idx: int = 0) -> list[int]:
    """Planned checkpoint intervals (steps) assuming no failures - the paper's
    i1, i2, ... sequence (e.g. (15, 28, 38, 59, 128) min for a 5 h job at
    age 0 with a 1-min grid)."""
    out, j, t = [], int(job_steps), int(start_age_idx)
    while j > 0:
        i = tables.interval_steps(j, t)
        i = max(1, min(i, j))
        out.append(i)
        j -= i
        t = min(t + i + (tables.delta_steps if j > 0 else 0), tables.horizon_idx)
    return out


def evaluate_policy_dollars(K, dists: Sequence, price, *, grid_dt: float,
                            delta_steps: int = 1, n_sweeps: int = 3,
                            restart_overhead: float = 0.0) -> np.ndarray:
    """Expected dollars-to-completion of executing FIXED policy tables ``K``
    under the dollar objective's own model.

    A float64 host mirror of the dollar recurrence with the min over
    candidate intervals replaced by K's choice (clipped to ``[1, j]``), run
    through the same restart-cost fixed point and row order as the solver.
    Because the solver minimizes over every candidate the evaluator merely
    follows, ``solve_batch(objective="dollars").V <= evaluate(K_any)``
    pointwise per sweep by induction — which is what lets the market
    benchmark compare a makespan-optimal K against a dollar-optimal K in
    the same currency without Monte-Carlo noise (the solver's float32
    argmin leaves ~1e-6-relative slack against this float64 evaluation).

    ``K``: ``(S, j_max+1, t_max+1)`` int tables (e.g. ``BatchDPTables.K``);
    ``dists``: the S lifetime distributions; ``price``: a PriceGrid (one
    row broadcasts).  Returns float64 ``(S, j_max+1, t_max+1)`` dollar
    tables; entry ``[s, J, 0]`` is the expected cost of a fresh J-step job.
    """
    K = np.asarray(K)
    S, J1, T = K.shape
    j_max, t_max = J1 - 1, T - 1
    prices = np.asarray(price.prices, np.float64)
    cum = np.asarray(price.cum, np.float64)
    if prices.shape[0] == 1 and S > 1:
        prices = np.broadcast_to(prices, (S, prices.shape[1]))
        cum = np.broadcast_to(cum, (S, cum.shape[1]))
    pdt = float(price.dt)
    TX = t_max + 1 + j_max + int(delta_steps)
    tau = np.arange(TX, dtype=np.float64) * grid_dt
    kc = np.clip(np.floor(tau / pdt).astype(np.int64), 0, prices.shape[1] - 1)
    Pc = cum[:, kc] + prices[:, kc] * (tau[None, :] - kc[None, :] * pdt)
    t = np.arange(t_max + 1)
    out = np.empty((S, J1, T), np.float64)
    for s in range(S):
        d = dists[s]
        tk = np.arange(t_max + 1, dtype=np.float64) * grid_dt
        F = np.clip(np.array(d.cdf(tk), np.float64), 0.0, 1.0)
        atom = max(1.0 - F[-1], 0.0)
        F[-1] = 1.0
        H = np.array(d.partial_expectation(np.zeros_like(tk), tk),
                     np.float64)
        H[-1] += atom * float(d.L)
        dead = (1.0 - F) < 1e-6
        V = np.broadcast_to(Pc[s, :J1, None], (J1, T)).copy()
        for _ in range(n_sweeps):
            R = float(restart_overhead) * prices[s, 0] + V[:, 0].copy()
            for j in range(1, J1):
                i = np.clip(K[s, j], 1, j)
                w = np.where(i == j, i, i + int(delta_steps))
                end = np.minimum(t + w, t_max)
                endx = t + w
                Ft, Fe = F[t], F[end]
                p_fail = np.clip((Fe - Ft) / np.maximum(1.0 - Ft, _EPS),
                                 0.0, 1.0)
                dF = np.maximum(Fe - Ft, _EPS)
                e_lost = np.clip((H[end] - H[t]) / dF - t * grid_dt,
                                 0.0, w * grid_dt)
                dP = Pc[s, endx] - Pc[s, t]
                pb = dP / (w * grid_dt)
                v_succ = dP + V[j - i, end]
                v_fail = e_lost * pb + R[j]
                vj = (1.0 - p_fail) * v_succ + p_fail * v_fail
                V[j] = np.where(dead, R[j], vj)
        out[s] = V
    return out


# ---------------------------------------------------------------------------
# Monte-Carlo executor (Fig. 7 evaluation; also used by tests)
#
# This per-trial Python loop is the REFERENCE implementation; the production
# path is the batched lax.while_loop kernel in repro.core.engine, which
# performs the same operations on (n_trials,)-vectors.  Exactness contract:
# lifetimes are pre-converted to grid-step units (minus the initial VM's
# sub-grid age offset) OUTSIDE the hot loop, so the loop body contains no
# multiply-add pattern XLA could contract into an FMA; given a shared pool,
# the kernel run in float64 matches this loop bit-for-bit.
# ---------------------------------------------------------------------------

def simulate_makespan(policy_fn: Callable[[int, int], int], lifetimes_fn,
                      job_steps: int, *, grid_dt: float = 1.0 / 60.0,
                      delta_steps: int = 1, start_age: float = 0.0,
                      n_trials: int = 2000, seed: int = 0,
                      restart_overhead: float = 0.0,
                      max_restarts: int = 64, pool=None, first=None):
    """Execute a job under sampled preemptions.

    policy_fn(remaining_steps, age_idx) -> steps until next checkpoint.
    lifetimes_fn(rng, n, min_age=0.0) -> n sampled VM lifetimes (hours),
    conditioned on survival to ``min_age`` (used for the first VM when the
    job starts on an aged machine).  Alternatively pass pre-drawn ``first``
    (n_trials,) and ``pool`` (n_trials, max_restarts+2) arrays from
    ``engine.draw_lifetime_pool`` — the equivalence tests share one pool
    between this reference and the vectorized kernel.

    Semantics: failure during a work segment or during the checkpoint write
    loses progress back to the last durable checkpoint; the job resumes on a
    fresh VM (age 0) after ``restart_overhead`` hours, recomputing its
    schedule (the paper's resume-event behavior).  Returns makespans (hours),
    shape (n_trials,).
    """
    if pool is None:
        from .. import engine  # local import: engine imports this module too

        first, pool = engine.draw_lifetime_pool(
            lifetimes_fn, n_trials, max_restarts=max_restarts, seed=seed,
            start_age=start_age)
    else:
        first = pool[:, 0] if first is None else first
        n_trials = len(first)
    age0_idx = int(round(start_age / grid_dt))
    off0 = start_age - age0_idx * grid_dt
    # lifetimes in grid-step units, initial VM age offset removed (see the
    # exactness note above: all comparisons are int-vs-precomputed-float)
    first_steps = (np.asarray(first, np.float64) - off0) / grid_dt
    pool_steps = np.asarray(pool, np.float64) / grid_dt
    out = np.empty((n_trials,), np.float64)
    for n in range(n_trials):
        remaining = int(job_steps)
        age_idx = age0_idx
        draw = 0
        life_s = first_steps[n]
        done_steps = 0          # completed work+checkpoint segments (grid units)
        lost_steps = 0.0        # preempted partial segments (grid units)
        restarts = 0
        while remaining > 0 and restarts <= max_restarts:
            i = int(policy_fn(remaining, age_idx))
            i = max(1, min(i, remaining))
            w = i + (delta_steps if i < remaining else 0)
            if age_idx + w <= life_s:
                # segment + checkpoint complete
                done_steps += w
                age_idx += w
                remaining -= i
            else:
                # preempted mid-segment: progress since last checkpoint lost
                lost_steps += max(life_s - age_idx, 0.0)
                draw += 1
                life_s = pool_steps[n, min(draw, max_restarts + 1)]
                age_idx = 0
                restarts += 1
        out[n] = (done_steps + lost_steps) * grid_dt \
            + restarts * restart_overhead
    return out


def dp_policy_fn(tables: DPTables):
    return lambda remaining, age_idx: tables.interval_steps(remaining, age_idx)


def young_daly_policy_fn(tau_hours: float, grid_dt: float):
    tau_steps = max(1, int(round(tau_hours / grid_dt)))
    return lambda remaining, age_idx: min(tau_steps, remaining)


def no_checkpoint_policy_fn():
    return lambda remaining, age_idx: remaining


def model_lifetimes_fn(dist):
    """lifetimes_fn adapter: numpy rng -> inverse-CDF samples from ``dist``,
    optionally conditioned on survival to ``min_age`` (F restricted to
    [F(min_age), 1], with the residual >=F(L) mass preempted at L).

    Draws go through ``engine.capped_icdf_draw``, whose jitted kernel takes
    the distribution as a pytree *argument* — this reference sampler and
    ``engine.draw_lifetime_pool_batch`` therefore share one compiled
    inversion with no parameter constants baked into either graph, which is
    what makes the batched pool reproduce this reference bit-for-bit under
    x64.  Leaves are still normalized to jnp arrays up front so both paths
    present identical leaf dtypes to that cache.
    """
    dist = jax.tree_util.tree_map(
        lambda l: jnp.asarray(l, jnp.result_type(float)), dist)

    def fn_capped(rng, n, min_age: float = 0.0):
        from .. import engine  # local import, matching simulate_makespan

        u = rng.uniform(size=n)
        f_lo = float(dist.cdf(min_age)) if min_age > 0 else 0.0
        u = f_lo + u * (1.0 - f_lo)
        fl = float(dist.cdf(dist.L))
        return engine.capped_icdf_draw(dist, u, fl, float(dist.L))

    return fn_capped
