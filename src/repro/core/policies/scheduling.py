"""Model-driven job scheduling & VM-reuse policy (paper Eqs. 6-10, Fig. 6).

All quantities are pure functions of a distribution object from
``repro.core.distributions`` and broadcast over ``T`` (job length) and ``s``
(VM age at job start); everything is jit/vmap-compatible and reused verbatim
by the pod-reuse logic in ``repro.fault``.

The provider's hard 24 h cap means a VM alive at age s is *certainly* gone by
L, so we work with the capped CDF  F~(t) = 1 for t >= L.
"""
from __future__ import annotations

import jax.numpy as jnp

_EPS = 1e-9


def _f32(x):
    return jnp.asarray(x, jnp.result_type(float))


def capped_cdf(dist, t):
    """F~(t): the model CDF with the deterministic deadline mass at L."""
    t = _f32(t)
    return jnp.where(t >= dist.L, 1.0, dist.cdf(t))


def expected_wasted_work(dist, T):
    """Eq. 7: E[W1(T)] = (1/F(T)) * integral_0^T t f(t) dt, the expected work
    lost to a single preemption during a length-T job on a fresh VM."""
    T = _f32(T)
    return dist.partial_expectation(0.0, T) / jnp.maximum(dist.cdf(T), _EPS)


def expected_makespan_new(dist, T):
    """Eq. 9: E[T] = T + integral_0^T t f(t) dt (single-failure model, fresh VM)."""
    T = _f32(T)
    return T + dist.partial_expectation(0.0, T)


def expected_makespan_at_age(dist, T, s):
    """Eq. 10: E[T_s] = T + integral_s^{s+T} t f(t) dt, job started at VM age s.

    Jobs whose window crosses the deadline cannot complete on this VM
    (the provider kills it at L), so the makespan is +inf there.
    """
    T, s = _f32(T), _f32(s)
    m = T + dist.partial_expectation(s, s + T)
    return jnp.where(s + T >= dist.L, jnp.inf, m)


def p_fail_existing_paper(dist, T, s):
    """The paper's printed P_Existing = max(1, F(T+s) - F(T)).

    Kept verbatim for reference; the printed 'max' and 'F(T)' are read as
    typos - see :func:`p_fail_existing` for the corrected conditional form
    used by the runtime.
    """
    return jnp.maximum(1.0, dist.cdf(_f32(T) + s) - dist.cdf(_f32(T)))


def p_fail_existing(dist, T, s):
    """P(preempted during (s, s+T] | alive at s), with the hard-cap rule:
    windows crossing L always fail."""
    T, s = _f32(T), _f32(s)
    num = capped_cdf(dist, s + T) - capped_cdf(dist, s)
    den = jnp.maximum(1.0 - capped_cdf(dist, s), _EPS)
    return jnp.clip(jnp.where(s + T >= dist.L, 1.0, num / den), 0.0, 1.0)


def p_fail_new(dist, T):
    """Failure probability of a length-T job on a freshly launched VM."""
    return jnp.clip(capped_cdf(dist, _f32(T)), 0.0, 1.0)


def reuse_decision(dist, T, s, relaunch_overhead=0.0):
    """True -> run on the existing (age-s) VM; False -> relinquish and launch
    a new one.  Decided by comparing Eq. 10 against Eq. 9 (lower expected
    makespan wins), exactly as in the paper.  ``relaunch_overhead`` (hours)
    optionally charges the fresh VM its provisioning time - the paper's
    analysis ignores it (0.0 default keeps the paper-verbatim criterion)."""
    return expected_makespan_at_age(dist, T, s) < \
        expected_makespan_new(dist, T) + relaunch_overhead


def job_failure_prob_memoryless(dist, T, s):
    """Baseline (SpotOn-style): always reuse the running VM (Fig. 6a grey)."""
    return p_fail_existing(dist, T, s)


def job_failure_prob_policy(dist, T, s):
    """Our policy (Fig. 6a): failure probability after the reuse decision."""
    reuse = reuse_decision(dist, T, s)
    return jnp.where(reuse, p_fail_existing(dist, T, s), p_fail_new(dist, T))


def mean_failure_prob_over_starts(dist, T, n_starts: int = 241, policy: bool = True):
    """Fig. 6b: failure probability averaged over job start ages s in [0, L)."""
    T = _f32(T)
    s = jnp.linspace(0.0, float(dist.L) * (1.0 - 1e-3), n_starts)
    fn = job_failure_prob_policy if policy else job_failure_prob_memoryless
    probs = fn(dist, T[..., None], s)
    return jnp.mean(probs, axis=-1)


def expected_runtime_increase(dist, T):
    """Fig. 5b: P(failure) * E[W1(T)] = integral_0^T t f(t) dt, the expected
    increase in running time of a length-T job (single-failure model)."""
    return dist.partial_expectation(0.0, _f32(T))
