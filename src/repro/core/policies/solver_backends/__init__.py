"""Pluggable DP solver backends for the checkpointing DP (Eqs. 11-15).

``checkpointing.solve`` / ``solve_batch`` dispatch here.  Every backend
module implements one contract:

    solve_tables_batch(Fc, Hc, grid_dt, restart_overhead, v_init=None,
                       Pc=None, *, j_max, t_max, delta_steps, n_sweeps)
        -> (V, K)

with stacked ``(S, t_max+1)`` float32 grids (built once by
``grids.cdf_grids``) in and ``(S, j_max+1, t_max+1)`` tables out, and the
``v_init`` warm-start seeding the restart-cost fixed point.  ``Pc=None``
selects the makespan objective; a stacked ``(S, TX)`` cumulative-dollar
grid (``grids.price_cum_grids``, ``TX = t_max+1+j_max+delta_steps``)
selects the dollar objective, in which case ``restart_overhead`` is the
per-scenario ``(S,)`` dollar overhead (hours x launch-cell price, folded
by the dispatcher) so sharding can split it with the other operands.
Backends:

  reference  the retained serial kernel — the bit-exactness anchor;
             batch = a Python loop over scenarios.
  xla        the batched production kernel (hoisted grids, segmented j
             loop); bit-identical to the reference per scenario slice.
  pallas     ``repro.kernels.dp_recurrence`` — VMEM-resident blocked scan;
             tolerance-tested, interpret mode off-TPU.

plus ``refine`` (coarse-to-fine pruning around the coarse argmin), which is
an orchestration over the ``xla`` machinery rather than a fourth contract
implementation — ``checkpointing.solve_batch(refine=True)`` drives it.

Selection: an explicit ``backend=`` name always wins; ``"auto"`` consults
the ``REPRO_SOLVER_BACKEND`` env var and otherwise picks Pallas on TPU and
XLA everywhere else.

Scenario sharding: ``shard_scenarios`` wraps a backend call in ``shard_map``
over the ``"scenario"`` logical axis when a ``repro.sharding`` mesh context
is active and its rules map that axis onto mesh axes dividing ``S``; in
every other case the call runs unwrapped — the exact single-device path, so
sharding is transparent (identical tables, enforced by
``tests/test_solver_backends.py``).
"""
from __future__ import annotations

import os

import jax
from jax.sharding import PartitionSpec

from .... import sharding as _sharding
from . import grids, reference, refine, xla
from . import pallas as pallas_backend

BACKENDS = ("reference", "xla", "pallas")
ENV_VAR = "REPRO_SOLVER_BACKEND"

_MODULES = {"reference": reference, "xla": xla, "pallas": pallas_backend}


def resolve(backend: str = "auto") -> str:
    """Resolve a ``backend=`` argument to a concrete backend name.

    The ``REPRO_SOLVER_BACKEND`` env override applies ONLY to ``"auto"`` —
    code that asks for a backend by name gets that backend (the CI matrix
    steers default-selection tests without silently rewiring the
    bit-contract tests, which pin their backends explicitly).
    """
    if backend == "auto":
        env = os.environ.get(ENV_VAR, "").strip().lower()
        if env:
            backend = env
        else:
            backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown solver backend {backend!r}; expected one of "
            f"{('auto',) + BACKENDS} (or REPRO_SOLVER_BACKEND in {BACKENDS})")
    return backend


def get(name: str):
    """The backend module for a resolved name."""
    return _MODULES[name]


def scenario_partition(n_scenarios: int):
    """(mesh, PartitionSpec) for the ``scenario`` logical axis under the
    active ``repro.sharding`` context, or ``(None, None)`` when there is no
    mesh, no rule maps the axis, or the mapped axes do not divide S —
    every such case takes the unwrapped single-device path."""
    mesh = _sharding.active_mesh()
    if mesh is None:
        return None, None
    spec = _sharding.spec_for(("scenario",), (int(n_scenarios),))
    if len(spec) == 0 or spec[0] is None:
        return None, None
    return mesh, PartitionSpec(spec[0])


def shard_scenarios(fn, n_scenarios: int, n_args: int, n_out: int):
    """Wrap ``fn(*arrays) -> tuple`` (all inputs and outputs carrying a
    leading ``(S,)`` axis) in ``shard_map`` over the scenario axis.

    Returns ``(wrapped_fn, sharded)``; when no mesh/rule applies the
    original ``fn`` comes back untouched (``sharded=False``) so the
    single-device call path stays byte-identical to the unsharded one.
    Per-scenario DP solves are independent, so the sharded tables match the
    unsharded ones bit-for-bit.
    """
    mesh, pspec = scenario_partition(n_scenarios)
    if mesh is None:
        return fn, False
    from jax.experimental.shard_map import shard_map

    wrapped = shard_map(fn, mesh=mesh, in_specs=(pspec,) * n_args,
                        out_specs=(pspec,) * n_out, check_rep=False)
    return wrapped, True
