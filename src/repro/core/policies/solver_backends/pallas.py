"""Pallas DP backend: adapter from the backend contract to the
``repro.kernels.dp_recurrence`` kernel.

Selected by ``backend="auto"`` on TPU; on CPU it runs the kernel in
interpret mode (``backend="pallas"`` explicitly, or the
``REPRO_SOLVER_BACKEND=pallas`` env override), which is how the CI matrix
validates it without TPU hardware.  Tolerance-tested against the reference —
the kernel recomputes the probability grids on the fly under a different
fusion schedule, so it is NOT part of the bit-exactness contract (see
``docs/solver.md``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ....kernels.dp_recurrence import dp_recurrence


def _interpret_default() -> bool:
    # lower natively on TPU; emulate anywhere else
    return jax.default_backend() != "tpu"


def solve_tables_batch(Fc, Hc, grid_dt, restart_overhead, v_init=None,
                       Pc=None, Elp=None, *, j_max: int, t_max: int,
                       delta_steps: int, n_sweeps: int, interpret=None):
    """Backend contract entry (see ``solver_backends.__init__``): stacked
    ``(S, t_max+1)`` grids in, ``(S, j_max+1, t_max+1)`` tables out.

    The kernel carries the restart-cost fixed point through a column-0 VMEM
    scratch, so the warm start enters as the seed column ``v_init[:, :, 0]``
    — same semantics as the full-array seed of the other backends, because
    sweeps couple only through that column.

    Dollar objective: ``Pc`` is the ``(S, TX)`` cumulative-dollar grid and
    ``restart_overhead`` the per-scenario ``(S,)`` dollar overhead, both
    forwarded to the kernel's price mode.  The host-precomputed ``Elp``
    loss grids are accepted for contract uniformity but IGNORED: the Pallas
    kernel recomputes the expected-lost-dollars term in-lane, which is
    exactly why this backend sits under the tolerance contract rather than
    the bit-identity one.
    """
    S = Fc.shape[0]
    if v_init is None:
        if Pc is None:
            col0 = jnp.broadcast_to(
                (jnp.arange(j_max + 1) * grid_dt)[None, :],
                (S, j_max + 1)).astype(jnp.float32)
        else:
            col0 = jnp.asarray(Pc, jnp.float32)[:, :j_max + 1]
    else:
        col0 = v_init[:, :, 0].astype(jnp.float32)
    if interpret is None:
        interpret = _interpret_default()
    if Pc is None:
        return dp_recurrence(
            Fc, Hc, col0, grid_dt=float(grid_dt),
            restart_overhead=float(restart_overhead), j_max=j_max,
            t_max=t_max, delta_steps=delta_steps, n_sweeps=n_sweeps,
            interpret=bool(interpret))
    return dp_recurrence(
        Fc, Hc, col0, grid_dt=float(grid_dt), restart_overhead=0.0,
        j_max=j_max, t_max=t_max, delta_steps=delta_steps, n_sweeps=n_sweeps,
        interpret=bool(interpret), Pc=Pc, Ro=restart_overhead)
