"""Shared DP grid construction: the discretized lifetime CDF and its
partial-expectation companion.

Every solver backend consumes the same pair of per-scenario grids:

  ``Fc[t]``  the lifetime CDF on the age grid, with the provider-kill atom
             at the deadline ``L`` folded into the last cell (``Fc[-1] = 1``);
  ``Hc[t]``  the partial expectation ``H(t) = int_0^t x dF~(x)`` including
             the same atom (``Hc[-1] += atom * L``) — the numerator of the
             conditional expected-loss term E[x - t | fail in (t, t+w]].

This module is the single source of those grids (PR 7 deduplicated the
copies that ``solve`` and ``solve_batch`` used to carry): the eager op
sequence below is the bit-exactness anchor — every backend receives float32
grids built by exactly these ops at any session dtype, which is what lets
the batched/XLA/Pallas kernels be compared table-for-table against the
serial reference.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Shared guard against zero survival/failure mass in the conditional forms;
# all backends must use this same constant so their per-element arithmetic
# stays comparable.
_EPS = 1e-9


def cdf_grids(dist, grid_dt: float):
    """Build the (Fc, Hc) solver grids for one distribution.

    Returns ``(Fc, Hc, t_max)`` where the grids have ``t_max + 1`` cells
    (``t_max = round(L / grid_dt)``) and are pinned to the solver's native
    float32: a python-float scalar would trace as weak f64 under x64 and
    shift parts of the DP arithmetic to f64, where the reference and batched
    kernels round differently — pinning keeps every backend bit-comparable
    at any session dtype.
    """
    L = float(dist.L)
    t_max = int(round(L / grid_dt))
    tk = jnp.arange(t_max + 1) * grid_dt
    F_raw = jnp.clip(dist.cdf(tk), 0.0, 1.0)
    atom = jnp.maximum(1.0 - F_raw[-1], 0.0)             # provider kill at L
    Fc = F_raw.at[-1].set(1.0).astype(jnp.float32)
    H_raw = dist.partial_expectation(jnp.zeros_like(tk), tk)
    Hc = H_raw.at[-1].add(atom * L).astype(jnp.float32)
    return Fc, Hc, t_max


def price_cum_grids(prices, cum, price_dt: float, grid_dt: float,
                    t_max: int, ext: int):
    """Cumulative-dollar grid on the DP age axis, for the dollar objective.

    ``prices``/``cum``/``price_dt`` are a ``market.PriceGrid``'s fields (duck
    typed so this module stays free of a market import): ``prices`` is
    ``(S, T_price)`` $/hour cells, ``cum[s, k]`` the dollars accrued through
    the first ``k`` cells.  Returns ``(Pc, P0)`` where

      ``Pc[s, m]``  float32 ``(S, t_max + 1 + ext)`` — dollars accrued by a VM
                    of age ``m * grid_dt`` hours, evaluated with exactly the
                    semantics of ``market.integrate_cost_ref`` (piecewise
                    linear between cell edges; ages beyond the price horizon
                    billed at the final cell's price);
      ``P0[s]``     float64 ``(S,)`` — the launch-cell price, used to bill the
                    restart overhead.

    The ``ext`` extra cells extend the axis past the DP horizon so the
    recurrence's ``t + w`` segment-cost gathers never clip: segment dollars
    are ``Pc[t + w] - Pc[t]`` with ``t <= t_max`` and ``w <= ext``.  All
    arithmetic is host float64 (matching ``integrate_cost_ref``) with one
    final float32 cast — the bit-exactness anchor for the dollar objective,
    mirroring the float32 pin in :func:`cdf_grids`.
    """
    prices = np.asarray(prices, np.float64)
    cum = np.asarray(cum, np.float64)
    pdt = float(price_dt)
    tau = np.arange(t_max + 1 + ext, dtype=np.float64) * float(grid_dt)
    k = np.clip(np.floor(tau / pdt).astype(np.int64), 0, prices.shape[1] - 1)
    Pc = cum[:, k] + prices[:, k] * (tau[None, :] - k[None, :] * pdt)
    return Pc.astype(np.float32), prices[:, 0].copy()


def dollar_loss_grids(Fc, Hc, Pc, grid_dt: float, *, j_max: int, t_max: int,
                      delta_steps: int):
    """Expected-lost-dollars grids for the dollar objective, computed on the
    host in plain float32 numpy and fed to every backend as an OPERAND.

    Returns ``Elp`` of shape ``(S, 2, t_max + 1, j_max)``: ``Elp[:, 0]`` the
    non-final-segment variant (``w = i + delta``) and ``Elp[:, 1]`` the
    final-segment variant (``w = i``) of

        E[lost $ | fail in (t, t+w]] = e_lost(t, w) * dP(t, w) / (w * dt)

    (the conditional expected lost hours times the window's average $/hour).
    This is deliberately NOT computed inside the kernels: the expression
    contains mul-feeding-add/sub pairs that XLA:CPU FMA-contracts at the
    LLVM level, and its contraction choices differ between the serial
    reference program (one fused loop body) and the batched kernels (hoisted
    grid fusions) — 1-ulp divergences that ``jax.lax.optimization_barrier``
    does not survive to prevent (the barrier is elided before codegen).
    A single host-side numpy evaluation, consumed bit-for-bit by all
    backends, removes the compiler from the equation entirely — the same
    pattern as ``Pc`` itself.  Every op below is float32 with explicit
    casts so NumPy's promotion rules cannot silently widen to f64.
    """
    Fc = np.asarray(Fc, np.float32)
    Hc = np.asarray(Hc, np.float32)
    Pc = np.asarray(Pc, np.float32)
    dt = np.float32(grid_dt)
    eps = np.float32(_EPS)
    t = np.arange(t_max + 1)
    i = np.arange(1, j_max + 1)
    tdt = t.astype(np.float32) * dt                       # (T,)
    out = []
    for w in (i + delta_steps, i):
        end = np.clip(t[:, None] + w[None, :], 0, t_max)  # (T, I)
        endx = t[:, None] + w[None, :]                    # unclipped
        wdt = w.astype(np.float32) * dt                   # (I,)
        Ft = Fc[:, t][:, :, None]
        Fe = Fc[:, end]
        dF = np.maximum(Fe - Ft, eps)
        el = (Hc[:, end] - Hc[:, t][:, :, None]) / dF - tdt[None, :, None]
        el = np.clip(el, np.float32(0.0), wdt[None, None, :])
        dP = Pc[:, endx] - Pc[:, t][:, :, None]
        pb = dP / wdt[None, None, :]
        out.append(el * pb)
    return np.stack(out, axis=1)
