"""Shared DP grid construction: the discretized lifetime CDF and its
partial-expectation companion.

Every solver backend consumes the same pair of per-scenario grids:

  ``Fc[t]``  the lifetime CDF on the age grid, with the provider-kill atom
             at the deadline ``L`` folded into the last cell (``Fc[-1] = 1``);
  ``Hc[t]``  the partial expectation ``H(t) = int_0^t x dF~(x)`` including
             the same atom (``Hc[-1] += atom * L``) — the numerator of the
             conditional expected-loss term E[x - t | fail in (t, t+w]].

This module is the single source of those grids (PR 7 deduplicated the
copies that ``solve`` and ``solve_batch`` used to carry): the eager op
sequence below is the bit-exactness anchor — every backend receives float32
grids built by exactly these ops at any session dtype, which is what lets
the batched/XLA/Pallas kernels be compared table-for-table against the
serial reference.
"""
from __future__ import annotations

import jax.numpy as jnp

# Shared guard against zero survival/failure mass in the conditional forms;
# all backends must use this same constant so their per-element arithmetic
# stays comparable.
_EPS = 1e-9


def cdf_grids(dist, grid_dt: float):
    """Build the (Fc, Hc) solver grids for one distribution.

    Returns ``(Fc, Hc, t_max)`` where the grids have ``t_max + 1`` cells
    (``t_max = round(L / grid_dt)``) and are pinned to the solver's native
    float32: a python-float scalar would trace as weak f64 under x64 and
    shift parts of the DP arithmetic to f64, where the reference and batched
    kernels round differently — pinning keeps every backend bit-comparable
    at any session dtype.
    """
    L = float(dist.L)
    t_max = int(round(L / grid_dt))
    tk = jnp.arange(t_max + 1) * grid_dt
    F_raw = jnp.clip(dist.cdf(tk), 0.0, 1.0)
    atom = jnp.maximum(1.0 - F_raw[-1], 0.0)             # provider kill at L
    Fc = F_raw.at[-1].set(1.0).astype(jnp.float32)
    H_raw = dist.partial_expectation(jnp.zeros_like(tk), tk)
    Hc = H_raw.at[-1].add(atom * L).astype(jnp.float32)
    return Fc, Hc, t_max
