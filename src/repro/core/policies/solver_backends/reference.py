"""Serial reference DP kernel — the bit-exactness anchor.

This is the original per-scenario ``_solve_tables`` kernel, retained forever
per the contract in ``checkpointing.py``: every production backend (XLA,
Pallas, coarse-to-fine) is measured against the tables this kernel produces.
It is deliberately unclever — the (age x candidate) grids are recomputed in
every j iteration and the batch path is a plain Python loop over scenarios —
because its job is to be obviously faithful to Eqs. 11-15, not fast.

Objectives.  With ``Pc=None`` the recurrence minimizes expected *makespan*
(hours); with a cumulative-dollar grid ``Pc`` (see ``grids.price_cum_grids``)
it minimizes expected *dollars-to-completion*: segment work is billed at the
integrated price over the VM's age window (``dP = Pc[t+w] - Pc[t]``), lost
work on failure at the window's average price, and the restart overhead at
the launch-cell price (folded into ``restart_overhead`` by the dispatcher,
which passes the per-scenario dollar overhead in dollar mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .grids import _EPS


@functools.partial(jax.jit, static_argnames=("j_max", "t_max", "delta_steps",
                                             "n_sweeps"))
def solve_tables(Fc, Hc, grid_dt, restart_overhead, v_init=None, Pc=None,
                 Elp=None, *, j_max: int, t_max: int, delta_steps: int,
                 n_sweeps: int):
    """Returns (V, K) of shapes (j_max+1, t_max+1) for ONE scenario.

    ``v_init`` optionally seeds the restart-cost fixed point (same warm-start
    semantics as the batched kernels, one scenario at a time); the cold path
    (``v_init=None``) builds the optimistic ``j*dt`` seed inside the jit and
    stays byte-identical to the pre-refactor kernel.

    ``Pc`` (``(t_max + 1 + j_max + delta_steps,)`` float32) switches the
    recurrence to the dollar objective.  ``restart_overhead`` must then
    already be dollar-denominated (hours x launch price), and ``Elp``
    (``(2, t_max + 1, j_max)`` float32, ``grids.dollar_loss_grids``) carries
    the expected-lost-dollars grids — precomputed on the host because XLA:CPU
    FMA-contracts that expression differently in this fused loop body than
    in the batched kernels' hoisted grids (see ``dollar_loss_grids``).  The
    extended ``Pc`` tail lets the ``t + w`` segment-cost gathers run past the
    horizon unclipped, which is what makes a flat price reduce exactly to
    ``p x makespan``.
    """
    dt = grid_dt
    t_idx = jnp.arange(t_max + 1)
    i_ax = jnp.arange(1, j_max + 1)                      # candidate intervals
    Sc = 1.0 - Fc
    dead = Sc < 1e-6

    def one_sweep(carry, _):
        V_prev, _ = carry
        # restart cost per remaining length j (uses previous sweep's V[:, 0])
        R = restart_overhead + V_prev[:, 0]              # (j_max+1,)

        def body(j, VK):
            V, K = VK
            valid = i_ax <= j                             # (I,)
            final = i_ax == j                             # no checkpoint on last segment
            w = jnp.where(final, i_ax, i_ax + delta_steps)  # (I,)
            end = jnp.clip(t_idx[:, None] + w[None, :], 0, t_max)  # (T, I)
            Ft = Fc[t_idx][:, None]
            Fe = Fc[end]
            St = jnp.maximum(1.0 - Ft, _EPS)
            p_fail = jnp.clip((Fe - Ft) / St, 0.0, 1.0)
            p_succ = 1.0 - p_fail
            if Pc is None:
                # E[x-t | fail in (t, te]] via H(t) = int_0^t x dF~ (atom
                # incl.)
                dF = jnp.maximum(Fe - Ft, _EPS)
                e_lost = (Hc[end] - Hc[t_idx][:, None]) / dF \
                    - t_idx[:, None] * dt
                e_lost = jnp.clip(e_lost, 0.0, w[None, :] * dt)
                v_succ = w[None, :] * dt + V[j - i_ax[None, :], end]
                v_fail = e_lost + R[j]
            else:
                # dollars: segment billed at integrated price over the age
                # window (unclipped gather on the extended Pc axis); the
                # expected lost dollars come from the host-precomputed Elp
                # grids — in-kernel only gathers, adds and subs remain, all
                # FMA-contraction-free (see grids.dollar_loss_grids)
                endx = t_idx[:, None] + w[None, :]        # (T, I), unclipped
                dP = Pc[endx] - Pc[t_idx][:, None]
                elp = jnp.where(final[None, :], Elp[1], Elp[0])
                v_succ = dP + V[j - i_ax[None, :], end]
                v_fail = elp + R[j]
            cost = p_succ * v_succ + p_fail * v_fail
            cost = jnp.where(valid[None, :], cost, jnp.inf)
            vj = jnp.min(cost, axis=1)
            kj = jnp.argmin(cost, axis=1) + 1
            # dead VM (age >= horizon): must restart
            vj = jnp.where(dead, R[j], vj)
            kj = jnp.where(dead, jnp.minimum(j, j_max), kj)
            V = V.at[j].set(vj.astype(V.dtype))
            K = K.at[j].set(kj.astype(K.dtype))
            return V, K

        V0 = jnp.zeros((j_max + 1, t_max + 1), jnp.float32)
        K0 = jnp.zeros((j_max + 1, t_max + 1), jnp.int32)
        V, K = jax.lax.fori_loop(1, j_max + 1, body, (V0, K0))
        return (V, K), None

    if v_init is None:
        if Pc is None:
            # sweep 0 restart estimate: optimistic j*dt
            seed_col = (jnp.arange(j_max + 1) * dt).astype(jnp.float32)
        else:
            # dollar seed: dollars to run j steps from launch, a pure gather
            # (no arithmetic) so every backend's cold seed is bit-identical
            seed_col = Pc[:j_max + 1]
        V_init = jnp.broadcast_to(seed_col[:, None],
                                  (j_max + 1, t_max + 1)).astype(jnp.float32)
    else:
        V_init = v_init.astype(jnp.float32)
    (V, K), _ = jax.lax.scan(one_sweep,
                             (V_init, jnp.zeros_like(V_init, jnp.int32)),
                             None, length=n_sweeps)
    return V, K


def solve_tables_batch(Fc, Hc, grid_dt, restart_overhead, v_init=None,
                       Pc=None, Elp=None, *, j_max: int, t_max: int,
                       delta_steps: int, n_sweeps: int):
    """Batch adapter for the reference kernel: a plain Python loop over the
    scenario axis (one compiled per-scenario solve, S dispatches).  This is
    the ``backend="reference"`` path of ``solve_batch`` — slow on purpose,
    and the yardstick the equivalence tests hold the fast backends to.

    In dollar mode (``Pc`` an ``(S, TX)`` batch, ``Elp`` an ``(S, 2, T, I)``
    batch) ``restart_overhead`` is the per-scenario ``(S,)`` dollar
    overhead; each scenario gets its own slice.
    """
    outs = []
    for s in range(Fc.shape[0]):
        vi = None if v_init is None else v_init[s]
        pcs = None if Pc is None else Pc[s]
        eps = None if Elp is None else Elp[s]
        ro = restart_overhead if Pc is None else restart_overhead[s]
        outs.append(solve_tables(Fc[s], Hc[s], grid_dt, ro, vi, pcs, eps,
                                 j_max=j_max, t_max=t_max,
                                 delta_steps=delta_steps, n_sweeps=n_sweeps))
    V = jnp.stack([o[0] for o in outs])
    K = jnp.stack([o[1] for o in outs])
    return V, K
