"""Coarse-to-fine DP refinement: solve at ``factor x grid_dt``, prune the
pre-sweeps to the coarse argmin's neighborhood and the restart-cost
dependency cone, verify, then run one full-resolution production sweep.

Why this is sound
-----------------
The sweep structure couples sweeps ONLY through the restart-cost column
``V[:, :, 0]`` (``R_j = overhead + V_prev[j, 0]``; the warm-start test in
``tests/test_runtime.py`` pins this: one warm sweep from a 3-sweep ``V``
equals the 4-sweep cold solve bit-for-bit).  So only the FINAL sweep has to
run at full resolution over the full candidate axis to produce the output
``V``/``K`` with the exact first-match argmin; the ``n_sweeps - 1`` sweeps
before it exist solely to reproduce the restart-cost trajectory
``R^(1) .. R^(n-1)``, and for those we can prune aggressively:

  * **the column-0 dependency cone** — ``R`` needs ``V[j, 0]`` only, and
    ``V[j, 0]`` transitively reads row ``j'`` at ages ``t <= M(j') =
    (1 + delta) * (j_max - j')`` (induction: from ``(j, t)`` with
    ``t <= M(j)`` the body reads ``(j - i, t + i + delta)`` and
    ``M(j) + i + delta <= M(j - i)``).  Pre-sweeps compute each j-segment
    only out to its cone extent; ages beyond a row's own cone may absorb
    unwritten zeros from deeper rows, but by the same induction nothing
    inside the cone ever reads them, and the final full sweep reads only
    ``R``.
  * **candidate-prefix caps near the coarse argmin** — a coarse solve at
    ``factor x grid_dt`` gives argmin hints ``K_c``; per j-segment the fine
    candidate axis is capped at ``factor * max(K_c over the segment's cone)
    + radius`` (the run-to-completion candidate ``i == j`` is always kept).
    The cap is a STATIC column-prefix slice of the hoisted grids — the same
    mechanism ``xla.seg_views`` already uses — because a min over a
    candidate prefix equals the full min whenever the prefix contains a
    minimizer.  Gather-based per-(j, t) windows are deliberately NOT used:
    gathered operands change XLA's fusion context and shift results by
    1 ulp, breaking bit-exactness even when the window covers the argmin.

Both prunings reuse ``xla.body_factory``'s exact per-candidate expression on
sliced views of ``xla.candidate_grids`` (minus the argmin payload — pre-sweep
``K`` is never observed), so every computed element rounds identically to
the plain solve's.

Verification: after each pre-sweep, column 0 is recomputed at FULL candidate
width (same expression, age extent 1) from the pre-sweep table and compared
bit-for-bit.  A mismatch means a cap cut off an argmin where the
restart-cost chain reads; the per-scenario ``ok`` flag goes False and the
dispatcher falls back to the plain full-resolution solve.  The check is
necessary-not-sufficient (a capped miss in the cone interior that happens
not to move column 0 escapes it), so the equivalence tests additionally pin
the whole refined table against the plain solve on every workload they
cover; ``refine_check="full"`` in ``solve_batch`` runs that comparison
in-process.

The final sweep itself is ``xla.sweep_from_R`` — the production kernel's own
full-resolution sweep — so a verified refined solve IS the plain solve's
last sweep, fed an identically-valued ``R``.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from . import xla

# pre-sweeps split the j axis finer than the full sweep's thirds so the
# per-segment age extent hugs the dependency cone
_N_CONE_SEGS = 6


def plan(j_max: int, t_max: int, delta_steps: int, n_sweeps: int,
         factor: int, radius):
    """Static refinement plan, or None when refinement cannot help (grid too
    small for a meaningful coarse level, or nothing to prune: with
    ``n_sweeps == 1`` there are no pre-sweeps)."""
    factor = int(factor)
    if radius is None:
        # the coarse argmin locates the fine argmin to ~factor steps; pad x3
        # so hint error from the coarser delta/deadline rounding stays inside
        radius = 3 * factor
    radius = int(radius)
    if (factor < 2 or n_sweeps < 2 or j_max < 4 * factor
            or t_max < 4 * factor):
        return None
    return {
        "factor": factor,
        "radius": radius,
        "j_max_c": max(1, (j_max + factor // 2) // factor),
        "delta_steps_c": max(1, (delta_steps + factor // 2) // factor),
    }


def cone_segments(j_max: int, t_max: int, delta_steps: int):
    """(lo, hi, age_extent) segments covering rows 1..j_max, each clipped to
    the column-0 dependency cone ``ages <= (1+delta)*(j_max - lo)``."""
    n_seg = _N_CONE_SEGS if j_max >= 8 * _N_CONE_SEGS else 1
    bounds = [1 + (k * j_max) // n_seg for k in range(n_seg)] + [j_max + 1]
    segs = []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        if lo >= hi:
            continue
        A = min(t_max + 1, (1 + delta_steps) * (j_max - lo) + 1)
        segs.append((lo, hi, max(A, 1)))
    return segs


def candidate_caps(Kc, segs, *, factor: int, radius: int, j_max_c: int,
                   t_max_c: int):
    """Per-segment static candidate-axis caps from the coarse argmin table.

    Host-side (numpy): the caps become static jit arguments, turning "near
    the argmin" into bit-safe column-prefix slicing.  Per segment the cap
    covers ``factor * K_c + radius`` over every (scenario, row, cone age)
    the segment touches — conservative, so spread-out argmins (e.g. a
    decreasing-hazard Weibull going run-to-completion at old ages) simply
    degrade the cap toward the full axis instead of going wrong.
    """
    Kc = np.asarray(Kc)
    caps = []
    for lo, hi, A in segs:
        jlo_c = min(max((lo + factor // 2) // factor, 0), j_max_c)
        jhi_c = min(max((hi - 1 + factor // 2) // factor, 0), j_max_c)
        thi_c = min(max((A - 1 + factor // 2) // factor, 0), t_max_c)
        kmax = int(Kc[:, jlo_c:jhi_c + 1, :thi_c + 1].max())
        cap = min(hi - 1, factor * kmax + radius)
        caps.append(max(cap, 1))
    return tuple(caps)


def cone_views(gp, delta_steps, I_len, A):
    """Slice the hoisted grids to a segment's (cone ages x candidate cap)
    block.  Age and candidate-prefix slices are static, so the body compiles
    to the same per-element codegen as the full-extent sweep (bit-safety);
    the final-segment (``i == j``) grids stay full candidate width because
    row j always reads their column ``j - 1``."""
    pf_nf_f, el_nf_f, end_nf_f, pf_fd_f, el_fd_f, end_fd_f, i_full = gp[:7]
    sd = (i_full[:I_len], i_full[:I_len] + delta_steps,
          pf_nf_f[:, :A, :I_len], el_nf_f[:, :A, :I_len],
          pf_fd_f[:, :A, :], el_fd_f[:, :A, :],
          end_nf_f[0][:A, :I_len], end_fd_f[0][:A, :])
    if len(gp) > 7:
        dp_nf_f, elp_nf_f, dp_fd_f, elp_fd_f = gp[7:]
        sd = sd + (dp_nf_f[:, :A, :I_len], elp_nf_f[:, :A, :I_len],
                   dp_fd_f[:, :A, :], elp_fd_f[:, :A, :])
    return sd


def _row_values(sd, V, R, dead_a, dt, j):
    """Value row j over a cone segment's sliced views — ``xla.body_factory``'s
    exact expression minus the argmin payload, with the ``i == j`` candidate
    folded in by an (exact) two-way min instead of the column patch."""
    dollar = len(sd) > 8
    if dollar:
        (i_ax, w_nf, pf_nf, el_nf, pf_fd, el_fd, end_nf, end_fd,
         dp_nf, elp_nf, dp_fd, elp_fd) = sd
    else:
        i_ax, w_nf, pf_nf, el_nf, pf_fd, el_fd, end_nf, end_fd = sd
    valid = i_ax < j                      # i == j is the fd candidate below

    def one(V1, pf1, el1, pffd1, elfd1, Rj1):
        Vg = V1[(j - i_ax)[None, :], end_nf]
        v_succ = w_nf[None, :] * dt + Vg
        v_fail = el1 + Rj1
        cost = (1.0 - pf1) * v_succ + pf1 * v_fail
        costm = jnp.where(valid[None, :], cost, jnp.inf)
        m_nf = jnp.min(costm, axis=1)
        # final-segment candidate i == j: w = i, V[j-i] == V[0]
        colV = V1[0, end_fd[:, j - 1]]
        vs_f = jnp.asarray(j, cost.dtype) * dt + colV
        cost_f = (1.0 - pffd1[:, j - 1]) * vs_f \
            + pffd1[:, j - 1] * (elfd1[:, j - 1] + Rj1)
        return jnp.minimum(m_nf, cost_f)

    def one_dollar(V1, pf1, pffd1, dp1, elp1, dpfd1, elpfd1, Rj1):
        Vg = V1[(j - i_ax)[None, :], end_nf]
        v_succ = dp1 + Vg
        v_fail = elp1 + Rj1
        cost = (1.0 - pf1) * v_succ + pf1 * v_fail
        costm = jnp.where(valid[None, :], cost, jnp.inf)
        m_nf = jnp.min(costm, axis=1)
        # final-segment candidate i == j: w = i, V[j-i] == V[0]
        colV = V1[0, end_fd[:, j - 1]]
        vs_f = dpfd1[:, j - 1] + colV
        cost_f = (1.0 - pffd1[:, j - 1]) * vs_f \
            + pffd1[:, j - 1] * (elpfd1[:, j - 1] + Rj1)
        return jnp.minimum(m_nf, cost_f)

    if dollar:
        vj = jax.vmap(one_dollar)(V, pf_nf, pf_fd,
                                  dp_nf, elp_nf, dp_fd, elp_fd,
                                  R[:, j][:, None])
    else:
        vj = jax.vmap(one)(V, pf_nf, el_nf, pf_fd, el_fd, R[:, j][:, None])
    return jnp.where(dead_a, R[:, j][:, None], vj)


def _cone_presweep(gp, cone_segs, caps, col0, dead, dt, restart_overhead, *,
                   j_max, t_max, delta_steps):
    """One pruned value-only sweep.  Returns (new column 0, ok flags)."""
    S = col0.shape[0]
    R = restart_overhead + col0                           # (S, j_max+1)
    V = jnp.zeros((S, j_max + 1, t_max + 1), jnp.float32)
    for (lo, hi, A), cap in zip(cone_segs, caps):
        sd = cone_views(gp, delta_steps, cap, A)
        dead_a = dead[:, :A]

        def body(j, V, sd=sd, dead_a=dead_a):
            vj = _row_values(sd, V, R, dead_a, dt, j)
            return jax.vmap(lambda V1, r: jax.lax.dynamic_update_slice(
                V1, r[None, :], (j, 0)))(V, vj.astype(V.dtype))

        V = jax.lax.fori_loop(lo, hi, body, V)
    ok = _col0_check(gp, cone_segs, V, R, dead, dt, delta_steps=delta_steps)
    return V[:, :, 0], ok


def _col0_check(gp, cone_segs, V, R, dead, dt, *, delta_steps):
    """Recompute column 0 over the FULL candidate axis (age extent 1, same
    expression) from the pre-sweep table and compare bit-for-bit — the cheap
    necessary condition that no cap cut off an argmin where the restart-cost
    chain reads."""
    dead_0 = dead[:, :1]

    def check_seg(lo, hi):
        sd = cone_views(gp, delta_steps, hi - 1, 1)

        def body(j, ok):
            vj = _row_values(sd, V, R, dead_0, dt, j)
            return ok & (vj[:, 0] == V[:, j, 0])

        return lo, hi, body

    ok = jnp.ones((V.shape[0],), bool)
    for lo, hi, _A in cone_segs:
        lo, hi, body = check_seg(lo, hi)
        ok = jax.lax.fori_loop(lo, hi, body, ok)
    return ok


def _refined_impl(Fc, Hc, grid_dt, restart_overhead, v_init_col0=None,
                  Pc=None, Elp=None, *, j_max: int, t_max: int,
                  delta_steps: int, n_sweeps: int, caps: tuple):
    """The fine-level pipeline: pruned pre-sweeps, then ONE full-resolution
    sweep through the production kernel's own machinery.  Returns
    ``(V, K, ok)`` with ``ok`` a per-scenario verification mask.

    The dollar objective (``Pc``/``Elp`` given) changes only the hoisted
    grid set and the cost expression inside ``_row_values`` — the cone
    geometry, caps mechanism and column-0 verification are
    objective-independent because the sweeps still couple only through
    ``V[:, :, 0]``.  In that mode ``restart_overhead`` is the per-scenario
    ``(S,)`` dollar overhead.
    """
    dt = grid_dt
    S = Fc.shape[0]
    dead = (1.0 - Fc) < 1e-6
    segs = xla.seg_plan(j_max)
    gp = xla.candidate_grids(Fc, Hc, dt, j_max=j_max, t_max=t_max,
                             delta_steps=delta_steps, Pc=Pc, Elp=Elp)
    seg_data = [xla.seg_views(gp, delta_steps, I) for I, _, _ in segs]
    cone_segs = cone_segments(j_max, t_max, delta_steps)
    # pre-shape so `restart_overhead + col0` broadcasts identically whether
    # ro is the makespan scalar or the (S,) dollar vector
    ro_b = restart_overhead if Pc is None else restart_overhead[:, None]

    if v_init_col0 is None:
        if Pc is None:
            # cold start: the optimistic j*dt seed's column 0 (matches the
            # plain kernels' cold V_init exactly)
            col0 = jnp.broadcast_to((jnp.arange(j_max + 1) * dt)[None, :],
                                    (S, j_max + 1)).astype(jnp.float32)
        else:
            # dollar seed: Pc prefix gather, matches the plain kernels
            col0 = Pc[:, :j_max + 1].astype(jnp.float32)
    else:
        col0 = v_init_col0.astype(jnp.float32)

    ok = jnp.ones((S,), bool)
    for _ in range(n_sweeps - 1):
        col0, ok_k = _cone_presweep(
            gp, cone_segs, caps, col0, dead, dt, ro_b,
            j_max=j_max, t_max=t_max, delta_steps=delta_steps)
        ok = ok & ok_k

    R = ro_b + col0
    V, K = xla.sweep_from_R(gp, seg_data, segs, R, dead, dt,
                            j_max=j_max, t_max=t_max)
    return V, K, ok


refined_solve = jax.jit(
    _refined_impl,
    static_argnames=("j_max", "t_max", "delta_steps", "n_sweeps", "caps"))


def coarse_tables(Fc_c, Hc_c, grid_dt_c, restart_overhead, *, j_max_c,
                  t_max_c, delta_steps_c, n_sweeps, Pc_c=None, Elp_c=None):
    """The coarse hint solve: a plain XLA solve on the ``factor x`` grid.
    Only ``K`` is used (argmin hints); cost is ~``factor**-3`` of the fine
    solve."""
    _, Kc = xla.solve_tables_batch(
        Fc_c, Hc_c, grid_dt_c, restart_overhead, None, Pc_c, Elp_c,
        j_max=j_max_c, t_max=t_max_c, delta_steps=delta_steps_c,
        n_sweeps=n_sweeps)
    return Kc
