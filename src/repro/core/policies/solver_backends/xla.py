"""Batched XLA DP kernel — the CPU/GPU production backend.

This is the PR-3/PR-4 ``_solve_tables_batch`` kernel moved out of
``checkpointing.py`` and factored into reusable pieces (``candidate_grids``,
``seg_plan``, ``seg_views``, ``sweep_from_R``) so the coarse-to-fine
refinement backend (``refine.py``) can compose the *same* expression tree:
the hoisted grids and the full-resolution sweep it runs are these functions,
not copies, which is what keeps the refined tables bit-comparable.

Per scenario slice the solve is BIT-IDENTICAL to the serial reference kernel
(``reference.solve_tables``) — the per-candidate arithmetic keeps the
reference expression tree so XLA's FMA contraction matches — while
restructuring the loop body for throughput:

  * the (VM age x candidate interval) grids ``p_fail``/``e_lost`` are
    j-invariant, so they are hoisted out of the 900-iteration loop (the
    reference recomputes them, with two ``(T, I)`` gathers and three
    divisions, every iteration);
  * only the final-segment candidate ``i == j`` (no trailing checkpoint,
    ``w = i``) differs per j, so it is patched as a single column instead
    of re-selecting full ``w``/``end`` grids;
  * ``argmin`` is computed as a min-reduce plus a first-match max-reduce
    (XLA CPU's variadic argmin reduce was half the body's wall-clock);
  * the j loop runs in three segments (thirds of the remaining-work axis)
    so early rows do not scan the full candidate axis; all segments share
    column-prefix views of one precomputed grid set.

The dollar objective (``Pc`` a cumulative-dollar grid, see
``grids.price_cum_grids``) rides the same structure: the per-segment dollar
cost ``dP`` and average price ``pb`` are j-invariant too, so they join the
hoisted grid set (7-tuple -> 11-tuple) and the loop body swaps the two cost
expressions — same gathers, same argmin, bit-identical to the reference's
dollar branch per scenario slice.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .grids import _EPS


def seg_plan(j_max: int):
    """The j-axis segmentation: thirds of the remaining-work axis when wide
    enough to keep every segment SIMD-wide (a very narrow cost matrix
    compiles to different, ULP-shifting, scalar codegen)."""
    if j_max >= 24:
        j1 = (j_max + 1) // 3
        j2 = 2 * (j_max + 1) // 3
        return [(j1, 1, j1 + 1), (j2, j1 + 1, j2 + 1),
                (j_max, j2 + 1, j_max + 1)]
    return [(j_max, 1, j_max + 1)]


def candidate_grids(Fc, Hc, dt, *, j_max, t_max, delta_steps, Pc=None,
                    Elp=None):
    """Hoist the j-invariant (VM age x candidate) grids, vmapped over the
    scenario axis.  Identical per-element arithmetic to the reference body.

    Returns ``(pf_nf_f, el_nf_f, end_nf_f, pf_fd_f, el_fd_f, end_fd_f,
    i_full)`` — the non-final (``w = i + delta``) and final-segment
    (``w = i``) probability/loss/end grids plus the full candidate axis.
    With ``Pc``/``Elp`` (dollar objective) the tuple gains ``(dp_nf_f,
    elp_nf_f, dp_fd_f, elp_fd_f)`` — segment dollars ``dP`` (gathered on
    the extended, unclipped price axis; a contraction-free sub of gathers)
    and the host-precomputed expected-lost-dollars grids from
    ``grids.dollar_loss_grids``, passed through untouched so the reference
    kernel consumes the very same bits.
    """
    t_idx = jnp.arange(t_max + 1)
    i_full = jnp.arange(1, j_max + 1)

    def grids(Fc1, Hc1, w):
        end = jnp.clip(t_idx[:, None] + w[None, :], 0, t_max)
        Ft = Fc1[t_idx][:, None]
        Fe = Fc1[end]
        St = jnp.maximum(1.0 - Ft, _EPS)
        p_fail = jnp.clip((Fe - Ft) / St, 0.0, 1.0)
        dF = jnp.maximum(Fe - Ft, _EPS)
        e_lost = (Hc1[end] - Hc1[t_idx][:, None]) / dF - t_idx[:, None] * dt
        e_lost = jnp.clip(e_lost, 0.0, w[None, :] * dt)
        return p_fail, e_lost, end

    pf_nf_f, el_nf_f, end_nf_f = jax.vmap(
        lambda f, h: grids(f, h, i_full + delta_steps))(Fc, Hc)
    pf_fd_f, el_fd_f, end_fd_f = jax.vmap(
        lambda f, h: grids(f, h, i_full))(Fc, Hc)
    base = (pf_nf_f, el_nf_f, end_nf_f, pf_fd_f, el_fd_f, end_fd_f, i_full)
    if Pc is None:
        return base

    def dgrids(Pc1, w):
        endx = t_idx[:, None] + w[None, :]                # unclipped
        return Pc1[endx] - Pc1[t_idx][:, None]

    dp_nf_f = jax.vmap(lambda p: dgrids(p, i_full + delta_steps))(Pc)
    dp_fd_f = jax.vmap(lambda p: dgrids(p, i_full))(Pc)
    return base + (dp_nf_f, Elp[:, 0], dp_fd_f, Elp[:, 1])


def seg_views(gp, delta_steps, I_len):
    """A shorter candidate axis is a column prefix of the full grids (column
    i's values depend only on i), so segments share one precomputed set;
    end grids are parameter-independent (one copy)."""
    pf_nf_f, el_nf_f, end_nf_f, pf_fd_f, el_fd_f, end_fd_f, i_full = gp[:7]
    sd = (i_full[:I_len], i_full[:I_len] + delta_steps,
          pf_nf_f[:, :, :I_len], el_nf_f[:, :, :I_len],
          pf_fd_f[:, :, :I_len], el_fd_f[:, :, :I_len],
          end_nf_f[0][:, :I_len], end_fd_f[0][:, :I_len])
    if len(gp) > 7:
        dp_nf_f, elp_nf_f, dp_fd_f, elp_fd_f = gp[7:]
        sd = sd + (dp_nf_f[:, :, :I_len], elp_nf_f[:, :, :I_len],
                   dp_fd_f[:, :, :I_len], elp_fd_f[:, :, :I_len])
    return sd


def body_factory(sd, R, dead, dt, j_max):
    """One j-row update over a segment's candidate prefix (see module
    docstring for the restructurings vs the reference body)."""
    dollar = len(sd) > 8
    if dollar:
        (i_ax, w_nf, pf_nf, el_nf, pf_fd, el_fd, end_nf, end_fd,
         dp_nf, elp_nf, dp_fd, elp_fd) = sd
    else:
        i_ax, w_nf, pf_nf, el_nf, pf_fd, el_fd, end_nf, end_fd = sd
    I_len = int(i_ax.shape[0])

    def _minimize(cost, valid):
        costm = jnp.where(valid[None, :], cost, jnp.inf)
        vj = jnp.min(costm, axis=1)
        # first-match argmin: maximize (I_len - idx) over the minima
        eq = (costm == vj[:, None]) & valid[None, :]
        payload = jnp.where(eq, I_len - jnp.arange(I_len)[None, :], 0)
        kj = (I_len + 1 - jnp.max(payload, axis=1)).astype(jnp.int32)
        return vj, kj

    def body(j, VK):
        V, K = VK
        valid = i_ax <= j

        def one(V1, pf1, el1, pffd1, elfd1, Rj1):
            Vg = V1[(j - i_ax)[None, :], end_nf]
            v_succ = w_nf[None, :] * dt + Vg
            v_fail = el1 + Rj1
            cost = (1.0 - pf1) * v_succ + pf1 * v_fail
            # final-segment candidate i == j: w = i, V[j-i] == V[0]
            colV = V1[0, end_fd[:, j - 1]]
            vs_f = jnp.asarray(j, cost.dtype) * dt + colV
            cost_f = (1.0 - pffd1[:, j - 1]) * vs_f \
                + pffd1[:, j - 1] * (elfd1[:, j - 1] + Rj1)
            cost = jax.lax.dynamic_update_slice(cost, cost_f[:, None],
                                                (0, j - 1))
            return _minimize(cost, valid)

        def one_dollar(V1, pf1, pffd1, dp1, elp1, dpfd1, elpfd1, Rj1):
            Vg = V1[(j - i_ax)[None, :], end_nf]
            v_succ = dp1 + Vg
            v_fail = elp1 + Rj1
            cost = (1.0 - pf1) * v_succ + pf1 * v_fail
            # final-segment candidate i == j: w = i, V[j-i] == V[0]
            colV = V1[0, end_fd[:, j - 1]]
            vs_f = dpfd1[:, j - 1] + colV
            cost_f = (1.0 - pffd1[:, j - 1]) * vs_f \
                + pffd1[:, j - 1] * (elpfd1[:, j - 1] + Rj1)
            cost = jax.lax.dynamic_update_slice(cost, cost_f[:, None],
                                                (0, j - 1))
            return _minimize(cost, valid)

        if dollar:
            vj, kj = jax.vmap(one_dollar)(V, pf_nf, pf_fd,
                                          dp_nf, elp_nf, dp_fd, elp_fd,
                                          R[:, j][:, None])
        else:
            vj, kj = jax.vmap(one)(V, pf_nf, el_nf, pf_fd, el_fd,
                                   R[:, j][:, None])
        vj = jnp.where(dead, R[:, j][:, None], vj)
        kj = jnp.where(dead, jnp.minimum(j, j_max), kj)
        V = jax.vmap(lambda V1, r: jax.lax.dynamic_update_slice(
            V1, r[None, :], (j, 0)))(V, vj.astype(V.dtype))
        K = jax.vmap(lambda K1, r: jax.lax.dynamic_update_slice(
            K1, r[None, :], (j, 0)))(K, kj)
        return V, K

    return body


def sweep_from_R(gp, seg_data, segs, R, dead, dt, *, j_max, t_max):
    """One full-resolution DP sweep from a given restart-cost vector
    ``R`` of shape ``(S, j_max+1)``.  Returns fresh ``(V, K)``."""
    S = R.shape[0]
    V0 = jnp.zeros((S, j_max + 1, t_max + 1), jnp.float32)
    K0 = jnp.zeros((S, j_max + 1, t_max + 1), jnp.int32)
    VK = (V0, K0)
    for sd, (_, lo, hi) in zip(seg_data, segs):
        VK = jax.lax.fori_loop(lo, hi, body_factory(sd, R, dead, dt, j_max),
                               VK)
    return VK


def _impl(Fc, Hc, grid_dt, restart_overhead, v_init=None, Pc=None, Elp=None,
          *, j_max: int, t_max: int, delta_steps: int, n_sweeps: int):
    dt = grid_dt
    T = t_max + 1
    S = Fc.shape[0]
    Sc = 1.0 - Fc
    dead = Sc < 1e-6                                      # (S, T)
    segs = seg_plan(j_max)
    gp = candidate_grids(Fc, Hc, dt, j_max=j_max, t_max=t_max,
                         delta_steps=delta_steps, Pc=Pc, Elp=Elp)
    seg_data = [seg_views(gp, delta_steps, I) for I, _, _ in segs]

    def one_sweep(carry, _):
        V_prev, _ = carry
        if Pc is None:
            R = restart_overhead + V_prev[:, :, 0]        # (S, j_max+1)
        else:
            # dollar mode: restart_overhead is the per-scenario (S,) dollar
            # overhead (hours x launch price, folded by the dispatcher)
            R = restart_overhead[:, None] + V_prev[:, :, 0]
        VK = sweep_from_R(gp, seg_data, segs, R, dead, dt,
                          j_max=j_max, t_max=t_max)
        return VK, None

    if v_init is None:
        if Pc is None:
            # cold start: optimistic j*dt (built inside the jit, exactly as
            # the reference does — the None-vs-array pytree structure gives
            # the warm path its own trace, so this cold graph stays
            # byte-identical to the pre-warm-start kernel and the
            # solve/solve_batch bit contract holds)
            v0 = (jnp.arange(j_max + 1) * dt)[None, :, None]
        else:
            # dollar seed: Pc prefix gather, bit-identical across backends
            v0 = Pc[:, :j_max + 1, None]
        V_init = jnp.broadcast_to(v0, (S, j_max + 1, T)).astype(jnp.float32)
    else:
        # warm start: seed the restart-cost fixed point with a previously
        # converged V (the closed-loop runtime hands in the last-good tables
        # after a drift refit — fewer sweeps reach the same fixed point)
        V_init = v_init.astype(jnp.float32)
    (V, K), _ = jax.lax.scan(one_sweep,
                             (V_init, jnp.zeros((S, j_max + 1, T), jnp.int32)),
                             None, length=n_sweeps)
    return V, K


solve_tables_batch = jax.jit(
    _impl, static_argnames=("j_max", "t_max", "delta_steps", "n_sweeps"))
