"""Young-Daly periodic checkpointing - the memoryless baseline.

Checkpoint every tau = sqrt(2 * delta * MTTF) time units, optimal when
failures are exponentially distributed.  The paper evaluates it with the MTTF
implied by the VM's *initial* failure rate (~1 h), which over-checkpoints
massively once the VM enters its stable phase (Fig. 7: ~25 % overhead vs <5 %
for the model-based DP schedule).
"""
from __future__ import annotations

import jax.numpy as jnp


def interval(delta, mttf):
    """tau = sqrt(2 * delta * MTTF) (hours)."""
    return jnp.sqrt(2.0 * jnp.asarray(delta, jnp.result_type(float)) * mttf)


def schedule(job_hours, delta, mttf):
    """Uniform checkpoint times (hours of work) for a job of given length."""
    tau = float(interval(delta, mttf))
    if tau <= 0:
        raise ValueError("non-positive Young-Daly interval")
    n = int(job_hours / tau)
    pts = [tau * (i + 1) for i in range(n)]
    return [p for p in pts if p < job_hours]


def mttf_from_initial_rate(dist):
    """MTTF implied by the hazard at t=0 (the paper's Fig. 7 baseline setup)."""
    return 1.0 / float(dist.hazard(1e-3))


def expected_overhead(delta, mttf, restart_overhead: float = 0.0):
    """First-order expected running-time overhead fraction under the
    exponential-failure assumption Young-Daly itself makes:

        delta/tau  (checkpoint writes)  +  tau/(2*MTTF)  (mean recompute)
        +  restart_overhead/MTTF        (relaunch per failure)

    The paper's Fig. 7 "more than 25%" Young-Daly number corresponds to this
    *model-predicted* overhead at MTTF = 1 h; the bathtub reality has a far
    lower stable-phase rate, so simulated actuals are lower - both are
    reported by benchmarks/fig7_checkpointing.py.
    """
    tau = float(interval(delta, mttf))
    return delta / tau + tau / (2.0 * mttf) + restart_overhead / mttf
