from . import checkpointing, scheduling, young_daly  # noqa: F401
