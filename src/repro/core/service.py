"""Batch-computing-service simulation (the paper's prototype, Figs. 4 & 8).

Event-driven discrete simulator of the paper's service: a centralized
controller manages a cluster of preemptible VMs, schedules a *bag of jobs*
onto them using the model-driven policies, keeps stable VMs as hot spares
(<= 1 h), and accounts cost at preemptible vs on-demand prices.

This is also the harness the training framework's pod-level fault-injection
tests reuse (a "job" = a training segment between checkpoints; a "VM" = a
preemptible TPU pod reservation).

The event loop itself is numpy-only; all JAX work is batched up front via
``repro.core.engine``: lifetime sampling goes through a pooled inverse-CDF
draw (one dispatch per ~4096 lifetimes) and the model policy's per-candidate
reuse decisions are looked up in a precomputed :class:`engine.ReuseTable`
(one jitted grid evaluation per distribution, shareable across runs).
``run_bag_grid`` sweeps (policy x vm_type x cluster_size x seed) in one
call, amortizing that vectorized setup across the whole grid.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Optional

import numpy as np

from . import distributions as dists
from . import engine
from .policies import scheduling as sched_policy

# Google Cloud n1-highcpu pricing (2019, us-central1, USD/hour) - the ~4.9x
# preemptible discount behind the paper's Fig. 8 "5x cheaper" result.
PRICES_ON_DEMAND = {
    "n1-highcpu-2": 0.0709 * 1.0, "n1-highcpu-4": 0.1418, "n1-highcpu-8": 0.2836,
    "n1-highcpu-16": 0.5672, "n1-highcpu-32": 1.1344, "tpu-v5e-pod": 307.2,
}
PRICES_PREEMPTIBLE = {
    "n1-highcpu-2": 0.0145, "n1-highcpu-4": 0.0289, "n1-highcpu-8": 0.0578,
    "n1-highcpu-16": 0.1156, "n1-highcpu-32": 0.2312, "tpu-v5e-pod": 62.0,
}
HOT_SPARE_HOURS = 1.0         # paper: keep stable VMs for one hour
RELAUNCH_OVERHEAD = 2.0 / 60.0  # VM provisioning time


def _normalize_dist(dist):
    """Leaf-normalize a distribution (jnp arrays of the default float dtype)
    so every sampler presents identical leaf dtypes to the shared
    ``capped_icdf_draw`` jit cache — the same convention as
    ``checkpointing.model_lifetimes_fn``, and a precondition for the batched
    pools of ``service_kernel.draw_service_pool_batch`` reproducing the
    serial stream bit-for-bit under x64."""
    import jax
    import jax.numpy as jnp
    return jax.tree_util.tree_map(
        lambda l: jnp.asarray(l, jnp.result_type(float)), dist)


def draw_service_pool(dist, *, seed: Optional[int] = None, rng=None,
                      size: int = 4096) -> np.ndarray:
    """One up-front pooled lifetime draw for a service grid cell.

    Consumes ``size`` uniforms from ``default_rng(seed)`` (or a caller's
    ``rng``, advancing it) and inverts them through the shared
    ``engine.capped_icdf_draw`` kernel — exactly the stream
    ``BatchService._model_sampler`` consumes, so a pool drawn here and
    passed as ``lifetime_pool=`` leaves the serial results unchanged while
    letting many cells share one dispatch (see
    ``service_kernel.draw_service_pool_batch`` for the deduplicated batch
    form).
    """
    if rng is None:
        rng = np.random.default_rng(seed)
    dist = _normalize_dist(dist)
    u = rng.uniform(size=size)
    fl = float(dist.cdf(dist.L))
    return np.asarray(engine.capped_icdf_draw(dist, u, fl, float(dist.L)))


@dataclasses.dataclass
class Job:
    job_id: int
    length: float               # uninterrupted running time (hours)
    submitted: float = 0.0
    started: Optional[float] = None
    attempt_started: Optional[float] = None
    finished: Optional[float] = None
    attempts: int = 0
    failures: int = 0
    done_work: float = 0.0      # checkpointed progress (hours)


@dataclasses.dataclass
class VM:
    vm_id: int
    vm_type: str
    launched: float
    lifetime: float             # sampled preemption age (hours)
    job: Optional[int] = None   # running job id
    idle_since: Optional[float] = None
    terminated: Optional[float] = None

    def age(self, now: float) -> float:
        return now - self.launched

    @property
    def preempt_at(self) -> float:
        return self.launched + self.lifetime


@dataclasses.dataclass
class ServiceResult:
    makespan: float             # bag completion wall-time (hours)
    vm_hours: float
    cost: float
    on_demand_cost: float       # same bag on non-preemptible VMs, no failures
    n_preemptions: int          # preemptions that hit a running job
    n_job_failures: int
    jobs: list = dataclasses.field(default_factory=list)
    n_deflations: int = 0       # preemptions absorbed as capacity degradation
    n_rejected: int = 0         # jobs denied admission (deadline misses)
    dollars: float = 0.0        # market-priced cost (== ``cost`` when the
    #                             service was run without a price trace)

    @property
    def cost_reduction(self) -> float:
        return self.on_demand_cost / max(self.cost, 1e-9)


class BatchService:
    """The controller: launches VMs, schedules jobs, reacts to preemptions.

    policy = "model"      : paper's reuse policy (Eq. 9 vs Eq. 10) + hot spares
    policy = "memoryless" : always reuse any idle VM; never relinquish early
    """

    def __init__(self, dist, *, vm_type: str = "n1-highcpu-32",
                 cluster_size: int = 32, policy: str = "model",
                 lifetimes_fn=None, seed: int = 0,
                 checkpointing: bool = False, ckpt_interval: float = 0.5,
                 ckpt_cost: float = 1.0 / 60.0,
                 reuse_table: Optional[engine.ReuseTable] = None,
                 vectorized_reuse: bool = True,
                 lifetime_pool: Optional[np.ndarray] = None,
                 pool_size: int = 4096,
                 price_trace: Optional[np.ndarray] = None,
                 price_dt: float = 1.0):
        self.dist = dist
        self.vm_type = vm_type
        self.cluster_size = cluster_size
        self.policy = policy
        self.rng = np.random.default_rng(seed)
        self.lifetimes_fn = lifetimes_fn or self._model_sampler
        self.checkpointing = checkpointing
        self.ckpt_interval = ckpt_interval
        self.ckpt_cost = ckpt_cost
        # vectorized reuse decisions: one jitted grid evaluation up front
        # (shareable across runs/seeds via ``reuse_table``) instead of one
        # JAX dispatch per idle-VM candidate inside the event loop
        self.reuse_table = reuse_table
        self.vectorized_reuse = vectorized_reuse
        self._run_reuse_table: Optional[engine.ReuseTable] = None
        # up-front pooled lifetime stream: an externally drawn pool (from
        # draw_service_pool[_batch] with THIS seed) is consumed first; the
        # stream stays bit-identical to lazy in-loop draws because the
        # sampler only ever takes n=1 and PCG64 uniforms are call-size
        # invariant (two 4096-draws == one 8192-draw)
        self.pool_size = int(pool_size)
        if self.pool_size < 1:
            raise ValueError("pool_size must be >= 1")
        # market billing: each VM is billed for ALL its vm-hours at the spot
        # price in force at its launch cell, ``price_trace[floor(launched /
        # price_dt)]`` (tail-clamped) — the spot convention of locking the
        # bid price at acquisition.  The accumulation sites mirror the four
        # ``vm_hours`` increments one-for-one, which is what lets
        # ``service_kernel`` reproduce ``dollars`` bit-for-bit under x64 on
        # shared pools (the PR-7 equivalence contract extended to dollars).
        if price_trace is not None:
            self._price_row = np.asarray(price_trace, np.float64)
            if self._price_row.ndim != 1 or self._price_row.size == 0:
                raise ValueError("price_trace must be a 1-D row of prices")
            if not np.all(self._price_row > 0):
                raise ValueError("price_trace must be strictly positive")
            self.price_dt = float(price_dt)
            if not self.price_dt > 0:
                raise ValueError("price_dt must be > 0")
        else:
            self._price_row = None
            self.price_dt = float(price_dt)
        if lifetime_pool is not None:
            self._pool = np.asarray(lifetime_pool, np.float64)
            self._pool_pos = 0
            self._pool_skip = len(self._pool)

    def _candidate_rem_values(self, lengths):
        """Every remaining-work value a job can present to the reuse policy:
        its full length, minus whole checkpoint intervals when checkpointing
        is on (progress is only banked at checkpoint boundaries)."""
        vals = list(map(float, lengths))
        if self.checkpointing:
            for l in map(float, lengths):
                k = 1
                while l - k * self.ckpt_interval > 0:
                    vals.append(l - k * self.ckpt_interval)
                    k += 1
        return np.asarray(vals)

    _pool: Optional[np.ndarray] = None
    _pool_pos: int = 0
    _pool_skip: int = 0   # uniforms an externally drawn pool consumed

    def _model_sampler(self, rng, n):
        # batched inverse-CDF pool: one JAX dispatch per ``pool_size`` draws
        # (or zero when ``lifetime_pool`` was drawn up front), through the
        # engine's shared (jit-cached) capped-draw kernel
        if n > self.pool_size:
            raise ValueError(f"sampler asked for {n} lifetimes at once; "
                             f"pool_size is {self.pool_size}")
        if self._pool is None or self._pool_pos + n > len(self._pool):
            if self._pool_skip:
                # realign this service's rng past the uniforms its external
                # pool consumed, keeping the refill stream-continuous
                rng.uniform(size=self._pool_skip)
                self._pool_skip = 0
            self._pool = draw_service_pool(self.dist, rng=rng,
                                           size=self.pool_size)
            self._pool_pos = 0
        out = self._pool[self._pool_pos:self._pool_pos + n]
        self._pool_pos += n
        return out

    # -- policy hooks -------------------------------------------------------
    def _approve_reuse(self, vm: VM, job: Job, now: float) -> bool:
        if self.policy == "memoryless":
            return True
        rem = job.length - job.done_work
        if self._run_reuse_table is not None:
            return self._run_reuse_table.decide(rem, vm.age(now))
        return bool(sched_policy.reuse_decision(self.dist, rem, vm.age(now)))

    # -- simulation ---------------------------------------------------------
    def run(self, job_lengths) -> ServiceResult:
        # per-run table: a user-supplied reuse_table is trusted to cover the
        # bag; otherwise build one from THIS bag's lengths (a table cached
        # from a previous run could miss the new remaining-work values)
        if self.policy != "model":
            self._run_reuse_table = None
        elif self.reuse_table is not None:
            self._run_reuse_table = self.reuse_table
        elif self.vectorized_reuse:
            self._run_reuse_table = engine.ReuseTable(
                self.dist, self._candidate_rem_values(job_lengths))
        else:
            self._run_reuse_table = None
        jobs = [Job(i, float(l)) for i, l in enumerate(job_lengths)]
        queue = list(range(len(jobs)))
        vms: dict[int, VM] = {}
        events: list = []   # (time, seq, kind, vm_id)
        seq = 0
        now = 0.0
        vm_hours = 0.0
        dollars = 0.0
        n_preempt = 0
        n_fail = 0
        next_vm_id = 0

        def launch_price(vm: VM) -> float:
            # the VM's locked-in spot price: its launch cell on the trace
            row = self._price_row
            k = min(int(vm.launched / self.price_dt), len(row) - 1)
            return float(row[max(k, 0)])

        def bill(vm: VM, inc: float) -> float:
            """Dollar increment for ``inc`` vm-hours on ``vm`` — one product
            per vm_hours increment, in the same order, so the batched kernel
            can reproduce the accumulation bit-for-bit."""
            if self._price_row is None:
                return 0.0
            return inc * launch_price(vm)

        def launch_vm(t):
            nonlocal next_vm_id, seq
            life = float(self.lifetimes_fn(self.rng, 1)[0])
            vm = VM(next_vm_id, self.vm_type, t, life)
            vms[vm.vm_id] = vm
            next_vm_id += 1
            heapq.heappush(events, (vm.preempt_at, seq, "preempt", vm.vm_id))
            seq += 1
            return vm

        def segment_time(job: Job) -> float:
            """Wall time for the job's next run-to-completion attempt,
            including checkpoint writes if enabled."""
            rem = job.length - job.done_work
            if not self.checkpointing:
                return rem
            n_ck = int(rem / self.ckpt_interval)
            return rem + n_ck * self.ckpt_cost

        def start_job(vm: VM, job: Job, t):
            nonlocal seq
            vm.job = job.job_id
            vm.idle_since = None
            job.attempts += 1
            job.attempt_started = t
            if job.started is None:
                job.started = t
            # no relaunch overhead here: fresh VMs are launched (and billed)
            # RELAUNCH_OVERHEAD later in assign(); reused hot spares are
            # already provisioned
            finish_at = t + segment_time(job)
            heapq.heappush(events, (finish_at, seq, "finish", vm.vm_id))
            seq += 1

        def assign(t):
            """Greedy scheduling loop at time t."""
            nonlocal seq, vm_hours, dollars
            if not queue:
                # bag-of-jobs abstraction: the controller knows no further
                # work is coming, so idle spares are released immediately
                for vm in vms.values():
                    if vm.job is None and vm.terminated is None:
                        vm.terminated = t
                        vm_hours += t - vm.launched
                        dollars += bill(vm, t - vm.launched)
                return
            while queue:
                job = jobs[queue[0]]
                # prefer an idle (hot-spare) VM the policy approves of
                cand = None
                for vm in vms.values():
                    if vm.job is None and vm.terminated is None:
                        if self._approve_reuse(vm, job, t):
                            cand = vm
                            break
                if cand is None:
                    active = sum(1 for v in vms.values() if v.terminated is None)
                    if active < self.cluster_size:
                        cand = launch_vm(t + RELAUNCH_OVERHEAD)
                        queue.pop(0)
                        start_job(cand, job, t + RELAUNCH_OVERHEAD)
                        continue
                    break  # cluster full; wait for a finish/preempt event
                queue.pop(0)
                start_job(cand, job, t)

        assign(0.0)
        while events:
            now, _, kind, vm_id = heapq.heappop(events)
            vm = vms[vm_id]
            if vm.terminated is not None:
                continue
            if kind == "finish":
                if vm.job is None:
                    continue
                job = jobs[vm.job]
                # stale finish event (job was preempted and restarted)?
                if job.finished is not None or now > vm.preempt_at:
                    continue
                job.finished = now
                job.done_work = job.length
                vm.job = None
                vm.idle_since = now
                # the global seq counter keeps heap keys unique: the old
                # ``len(jobs) + vm_id`` tiebreaker could collide with early
                # seq values, ordering same-timestamp expire events
                # nondeterministically against finish/preempt events
                heapq.heappush(events, (now + HOT_SPARE_HOURS, seq,
                                        "expire", vm_id))
                seq += 1
                assign(now)
            elif kind == "preempt":
                vm.terminated = now
                vm_hours += min(now - vm.launched, vm.lifetime)
                dollars += bill(vm, min(now - vm.launched, vm.lifetime))
                if vm.job is not None:
                    job = jobs[vm.job]
                    if job.finished is None:
                        n_preempt += 1
                        job.failures += 1
                        n_fail += 1
                        if self.checkpointing:
                            # progress up to the last completed checkpoint
                            # of THIS attempt (earlier attempts only count
                            # through the done_work they already banked)
                            ran = max(now - (job.attempt_started or now), 0.0)
                            k = int(ran / (self.ckpt_interval + self.ckpt_cost))
                            job.done_work = min(job.done_work
                                                + k * self.ckpt_interval,
                                                job.length)
                        queue.insert(0, job.job_id)
                    vm.job = None
                assign(now)
            elif kind == "expire":
                if vm.job is None and vm.terminated is None and \
                        vm.idle_since is not None and \
                        now - vm.idle_since >= HOT_SPARE_HOURS - 1e-9:
                    vm.terminated = now
                    vm_hours += now - vm.launched
                    dollars += bill(vm, now - vm.launched)
                    # the expired spare freed cluster capacity: jobs whose
                    # reuse was denied while the cluster was full can now
                    # get a fresh VM (otherwise they starve once the event
                    # queue drains)
                    assign(now)
            if all(j.finished is not None for j in jobs):
                break

        # account still-running VMs
        for vm in vms.values():
            if vm.terminated is None:
                vm_hours += now - vm.launched
                dollars += bill(vm, now - vm.launched)
        makespan = max((j.finished or now) for j in jobs)
        price = PRICES_PREEMPTIBLE[self.vm_type]
        od_price = PRICES_ON_DEMAND[self.vm_type]
        # on-demand reference: same bag, no preemptions, perfect packing
        total_work = float(np.sum([j.length for j in jobs]))
        on_demand_cost = total_work * od_price
        cost = vm_hours * price
        return ServiceResult(makespan=makespan, vm_hours=vm_hours,
                             cost=cost,
                             on_demand_cost=on_demand_cost,
                             n_preemptions=n_preempt, n_job_failures=n_fail,
                             jobs=jobs,
                             dollars=dollars if self._price_row is not None
                             else cost)


def _bag_lengths(n_jobs: int, job_hours: float, jitter: float, seed: int):
    rng = np.random.default_rng(seed + 1)
    return job_hours * (1.0 + jitter * (rng.uniform(size=n_jobs) - 0.5))


def grid_reuse_values(dist, *, seeds, n_jobs: int, job_hours: float,
                      jitter: float, **kw) -> np.ndarray:
    """Every remaining-work value a ``run_bag_grid`` call with these
    parameters can present to the reuse policy (the union of all seeds'
    bag lengths, expanded for checkpoint banking).  Single source of truth
    for both ``run_bag_grid``'s own table and callers that precompute
    tables for it (``scenarios.sweep_service``)."""
    lengths = np.concatenate([_bag_lengths(n_jobs, job_hours, jitter, s)
                              for s in seeds])
    probe = BatchService(dist, **kw)
    return probe._candidate_rem_values(lengths)


def run_bag(dist, *, n_jobs: int = 100, job_hours: float = 2.0,
            jitter: float = 0.1, cluster_size: int = 32,
            vm_type: str = "n1-highcpu-32", policy: str = "model",
            seed: int = 0, lifetimes_fn=None, **kw) -> ServiceResult:
    """Paper Fig. 8 setup: a bag of ~uniform-length jobs on a 32-VM cluster."""
    lengths = _bag_lengths(n_jobs, job_hours, jitter, seed)
    svc = BatchService(dist, vm_type=vm_type, cluster_size=cluster_size,
                       policy=policy, seed=seed, lifetimes_fn=lifetimes_fn, **kw)
    return svc.run(lengths)


def run_bag_grid(*, vm_types=("n1-highcpu-32",), policies=("model",),
                 cluster_sizes=(32,), seeds=(0,), n_jobs: int = 100,
                 job_hours: float = 2.0, jitter: float = 0.1, dist_for=None,
                 reuse_table: Optional[engine.ReuseTable] = None,
                 mode: str = "serial", pool_size: int = 4096,
                 deadline_hours: Optional[float] = None,
                 deflate_factor: float = 0.5, **kw) -> list:
    """Sweep ``run_bag`` over the (policy x vm_type x cluster_size x seed)
    grid in one call, sharing the vectorized per-distribution work.

    The model policy's reuse decisions for the WHOLE grid are evaluated in
    a single vmapped grid call — one :class:`engine.ReuseTables` tensor
    over the union of every seed's job lengths, shared across all cluster
    sizes, seeds and VM types (their distributions share the deadline
    ``L``).  Lifetime pools are likewise drawn once per unique
    ``(vm_type, seed)`` pair (``draw_service_pool_batch``) and handed to
    each cell, so the serial event loops run entirely in numpy and both
    sweep modes consume identical streams.  A caller that already holds a
    table (e.g. ``scenarios.sweep_service``) can pass it as
    ``reuse_table``; it is trusted to cover the grid's remaining-work
    values and must come from the same distribution ``dist_for`` resolves
    (single-vm_type grids only).

    ``mode="batched"`` routes every cell through ONE jitted
    ``service_kernel`` dispatch (bit-identical rows under x64); it also
    unlocks the kernel-only policy branches — ``deadline_hours`` admission
    control and ``"+deflate"``-suffixed policies (VM deflation at
    ``deflate_factor``).  Returns a list of dict rows with the grid
    coordinates and the :class:`ServiceResult`.
    """
    from . import service_kernel  # deferred: service_kernel imports us
    dist_for = dist_for or dists.constrained_for
    vm_types = tuple(vm_types)
    policies, cluster_sizes = tuple(policies), tuple(cluster_sizes)
    seeds = tuple(seeds)
    if mode not in ("serial", "batched"):
        raise ValueError(f"unknown mode {mode!r}")
    bases = [service_kernel.split_policy(p)[0] for p in policies]
    if mode == "serial":
        if deadline_hours is not None:
            raise ValueError("deadline admission control needs "
                             "mode='batched'")
        if any(service_kernel.split_policy(p)[1] for p in policies):
            raise ValueError("'+deflate' policies need mode='batched'")
    if reuse_table is not None and len(vm_types) != 1:
        raise ValueError("a shared reuse_table implies a single-distribution "
                         "grid; pass one vm_type")
    lengths = {s: _bag_lengths(n_jobs, job_hours, jitter, s) for s in seeds}
    dist_list = [dist_for(vt) for vt in vm_types]

    # one ReuseTables build for the WHOLE grid (all cluster sizes, seeds
    # and VM types), not one table per vm_type — their dists share L
    tables = None
    table_views = None
    if reuse_table is not None:
        tables = _tables_from_view(reuse_table)
        table_views = [reuse_table]
    elif "model" in bases and kw.get("vectorized_reuse", True):
        values = grid_reuse_values(
            dist_list[0], seeds=seeds, n_jobs=n_jobs, job_hours=job_hours,
            jitter=jitter, vm_type=vm_types[0], **kw)
        Ls = [float(np.asarray(d.L).reshape(-1)[0]) for d in dist_list]
        if max(Ls) - min(Ls) <= 1e-12:
            tables = engine.ReuseTables(dist_list, values)
            table_views = [tables.view(ti) for ti in range(len(vm_types))]
        elif mode == "batched":
            raise ValueError("mode='batched' folds all vm_types into one "
                             "reuse tensor and needs a shared deadline L")
        else:
            table_views = [engine.ReuseTable(d, values) for d in dist_list]

    if mode == "batched":
        unsupported = set(kw) - {"checkpointing", "ckpt_interval",
                                 "ckpt_cost", "vectorized_reuse"}
        if unsupported:
            raise ValueError(f"mode='batched' does not support "
                             f"{sorted(unsupported)}")
        if tables is None and "model" in bases:
            raise ValueError("mode='batched' model cells need vectorized "
                             "reuse tables (vectorized_reuse=True)")
        cells = [dict(dist_index=di, vm_type=vt, policy=policy,
                      cluster_size=cs, seed=seed)
                 for di, vt in enumerate(vm_types)
                 for policy, cs, seed in itertools.product(
                     policies, cluster_sizes, seeds)]
        return service_kernel.run_cells_batched(
            cells=cells, dists=dist_list, lengths_by_seed=lengths,
            reuse_tables=tables, pool_size=pool_size,
            deadline_hours=deadline_hours, deflate_factor=deflate_factor,
            checkpointing=kw.get("checkpointing", False),
            ckpt_interval=kw.get("ckpt_interval", 0.5),
            ckpt_cost=kw.get("ckpt_cost", 1.0 / 60.0),
            return_jobs=n_jobs <= 2048)

    pools = None
    if "lifetimes_fn" not in kw:
        pairs = [(ti, s) for ti in range(len(vm_types)) for s in seeds]
        pool_mat = service_kernel.draw_service_pool_batch(
            [dist_list[ti] for ti, _ in pairs], [s for _, s in pairs],
            size=pool_size)
        pools = {(vm_types[ti], s): pool_mat[i]
                 for i, (ti, s) in enumerate(pairs)}
    rows = []
    for ti, vm_type in enumerate(vm_types):
        dist = dist_list[ti]
        table = table_views[ti] if table_views is not None else None
        for policy, cs, seed in itertools.product(policies, cluster_sizes,
                                                  seeds):
            svc = BatchService(
                dist, vm_type=vm_type, cluster_size=cs, policy=policy,
                seed=seed, reuse_table=table if policy == "model" else None,
                pool_size=pool_size,
                lifetime_pool=(None if pools is None
                               else pools[(vm_type, seed)]), **kw)
            rows.append(dict(vm_type=vm_type, policy=policy, cluster_size=cs,
                             seed=seed, result=svc.run(lengths[seed])))
    return rows


def _tables_from_view(table: engine.ReuseTable) -> engine.ReuseTables:
    """Lift a single :class:`engine.ReuseTable` view into a one-entry
    :class:`engine.ReuseTables`-shaped batch (shared backing array)."""
    out = engine.ReuseTables.__new__(engine.ReuseTables)
    out._dists = [None]
    out.T_values = table.T_values
    out.L = table.L
    out.n_age = table.n_age
    out.tables = np.asarray(table.table)[None]
    return out
