"""Vectorized JAX Monte-Carlo engine for policy evaluation.

This module is the batched/jitted counterpart of the pure-Python simulation
hot paths used by the paper's headline figures:

  * :func:`simulate_makespan_batch` — the Fig. 7 checkpointing executor
    (``repro.core.policies.checkpointing.simulate_makespan``) rewritten as a
    single ``lax.while_loop`` over *events*, operating on ``(n_trials,)``
    state vectors with the policy table and the pre-drawn lifetime pool
    resident on device.  One event = one work segment attempt (success or
    preemption) for every still-running trial; the loop exits as soon as all
    trials have finished, so the wall-clock cost is the *slowest* trial's
    event count, not ``n_trials`` Python iterations.
  * :func:`reuse_decision_table` — the scheduling policy's Eq. 9-vs-Eq. 10
    reuse decision evaluated for a whole ``(remaining-work x VM-age)`` grid
    in one jitted call, so the batch-service event loop never dispatches to
    JAX per idle-VM candidate.
  * :func:`draw_lifetime_pool` — the shared pre-drawn lifetime pool.  The
    Python reference executor and the vectorized kernel both consume pools
    drawn by this helper, which is what makes exact (same-seed, same-pool)
    equivalence testable.

Policies are represented as integer *tables* ``P[j, t] -> interval`` (steps
until the next checkpoint given ``j`` remaining steps and VM age index
``t``); :func:`dp_policy_table`, :func:`young_daly_policy_table` and
:func:`no_checkpoint_policy_table` build them for the three Fig. 7 policies.
Age-independent policies use a ``(j_max+1, 1)`` table — the kernel clips the
age index into the table's second dimension.

Exactness contract: with a float64 pool and x64 enabled (e.g. under
``jax.experimental.enable_x64``), the kernel performs the *same* IEEE
operations in the same order as the Python reference, so makespans match
bit-for-bit.  In default float32 mode results agree to ~1e-6 relative, which
is far below Monte-Carlo noise.

Leading-axis convention (scenario batching): every batched entry point
treats an optional leading axis as the *scenario* axis ``S``, threaded
end-to-end from the distribution layer up:

  * ``distributions.stack(dists)`` stacks a scenario list into one pytree
    whose parameter leaves carry a leading ``(S,)`` axis;
  * ``checkpointing.solve_batch`` returns ``(S, j_max+1, t_max+1)`` V/K
    tables from one compiled call;
  * :func:`draw_lifetime_pool_batch` draws ``(S, n_trials, max_restarts+2)``
    pools on-device in one shot;
  * :func:`simulate_makespan_batch` accepts the leading axis on
    ``policy_table`` (optional — a 2-D table is shared), ``first`` and
    ``pool``, vmapping the event kernel and returning ``(S, n_trials)``
    makespans.  The float64 bit-exactness contract holds per scenario
    slice: on a shared pool each slice equals the corresponding unbatched
    run bit-for-bit;
  * :meth:`ReuseTable.batch` evaluates all scenarios' reuse grids in one
    vmapped call.

Typical use (Fig. 7 workload)::

    tables = checkpointing.solve(dist, 720)
    table = engine.dp_policy_table(tables)
    first, pool = engine.draw_lifetime_pool(
        checkpointing.model_lifetimes_fn(dist), n_trials=5000,
        max_restarts=64, seed=0)
    makespans = engine.simulate_makespan_batch(table, 720, first=first,
                                               pool=pool)
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import distributions as dists_mod
from .policies import scheduling as sched_policy

__all__ = [
    "dp_policy_table", "young_daly_policy_table", "no_checkpoint_policy_table",
    "draw_lifetime_pool", "draw_lifetime_pool_batch",
    "simulate_makespan_batch", "simulate_makespan_engine",
    "ReuseTable",
]


# ---------------------------------------------------------------------------
# policy tables
# ---------------------------------------------------------------------------

def dp_policy_table(tables) -> np.ndarray:
    """The DP's optimal-interval table ``K[j, t]`` (see checkpointing.solve)."""
    return np.asarray(tables.K, np.int32)


def young_daly_policy_table(tau_steps: int, job_steps: int) -> np.ndarray:
    """Fixed-interval policy ``min(tau, remaining)`` as a (j_max+1, 1) table."""
    j = np.arange(job_steps + 1, dtype=np.int32)
    return np.minimum(np.maximum(int(tau_steps), 1), j)[:, None].astype(np.int32)


def no_checkpoint_policy_table(job_steps: int) -> np.ndarray:
    """Run-to-completion: the next 'segment' is the whole remaining job."""
    return np.arange(job_steps + 1, dtype=np.int32)[:, None]


# ---------------------------------------------------------------------------
# lifetime pools
# ---------------------------------------------------------------------------

def draw_lifetime_pool(lifetimes_fn: Callable, n_trials: int, *,
                       max_restarts: int = 64, seed: int = 0,
                       start_age: float = 0.0):
    """Pre-draw the `(first, pool)` lifetimes consumed by one executor run.

    ``pool`` has shape ``(n_trials, max_restarts + 2)``; draw ``k`` (k >= 1)
    after the k-th preemption of trial ``n`` is ``pool[n, min(k, max_restarts
    + 1)]``.  ``first`` is the initial VM's lifetime, conditioned on survival
    to ``start_age`` when the sampler supports ``min_age`` (falls back to
    ``pool[:, 0]`` otherwise).  Draw order matches the historical reference
    executor, so a given ``seed`` yields the same lifetimes in both engines.
    """
    rng = np.random.default_rng(seed)
    pool = np.asarray(lifetimes_fn(rng, n_trials * (max_restarts + 2)),
                      np.float64).reshape(n_trials, max_restarts + 2)
    try:
        first = np.asarray(lifetimes_fn(rng, n_trials, min_age=start_age),
                           np.float64)
    except TypeError:  # sampler without conditioning support
        first = pool[:, 0].copy()
    return first, pool


def capped_icdf_draw(dist, u, fl, L):
    """The capped inverse-CDF draw both samplers share: lifetimes
    ``icdf(min(u, fl * (1 - 1e-6)))`` with the residual ``u >= fl`` mass
    preempted AT the deadline ``L``.  Broadcasts over scalar parameters
    (``checkpointing.model_lifetimes_fn``, the numpy reference) and
    ``(S, 1)``-stacked ones (:func:`draw_lifetime_pool_batch`) — keeping
    this contract in ONE place is what keeps the two paths bit-identical
    under x64."""
    t = np.asarray(dist.icdf(jnp.minimum(jnp.asarray(u),
                                         jnp.asarray(fl * (1.0 - 1e-6)))),
                   np.float64)
    return np.where(u >= fl, L, t)


def draw_lifetime_pool_batch(dists, n_trials: int, *, max_restarts: int = 64,
                             seed: int = 0, start_age: float = 0.0):
    """Batched :func:`draw_lifetime_pool` for a scenario list: ``first`` has
    shape ``(S, n_trials)`` and ``pool`` ``(S, n_trials, max_restarts + 2)``.

    The uniforms come from ONE ``np.random.default_rng(seed)`` stream in the
    reference draw order (pool first, then the conditioned first draw), so
    every scenario sees exactly the uniforms the serial per-scenario path
    would see for that seed.  The inverse CDF then runs as one on-device
    bisection over all ``S * n_trials * (max_restarts + 2)`` lifetimes —
    replacing S per-scenario numpy round-trips — by stacking each
    scenario's launch-phase-resolved parameters to ``(S, 1)`` so the
    distribution methods broadcast over the trailing draw axis.

    Exactness: per-scenario parameters are resolved with the same scalar
    eager ops as ``checkpointing.model_lifetimes_fn`` (``effective()`` for
    the diurnal family), so under x64 every scenario slice reproduces the
    numpy-reference pool bit-for-bit; in default float32 mode slices agree
    to float32 precision (~1e-6), far below Monte-Carlo noise.
    """
    dists = list(dists)
    dtype = jnp.result_type(float)
    # normalize leaves first (as model_lifetimes_fn does), then resolve any
    # launch-phase modulation with the same scalar eager ops the reference
    # sampler performs at trace time; finally stack to (S, 1)
    norm = [jax.tree_util.tree_map(lambda l: jnp.asarray(l, dtype), d)
            for d in dists]
    eff = [d.effective() if hasattr(d, "effective") else d for d in norm]
    d_b = jax.tree_util.tree_map(
        lambda *ls: jnp.stack(ls)[:, None], *eff)
    S = len(dists)
    rng = np.random.default_rng(seed)
    u_pool = rng.uniform(size=n_trials * (max_restarts + 2))
    u_first = rng.uniform(size=n_trials)
    # scalar pre/post quantities, per scenario, as the numpy reference
    fl = np.array([float(d.cdf(d.L)) for d in eff])[:, None]
    L = np.array([float(d.L) for d in eff])[:, None]
    pool = capped_icdf_draw(d_b, np.broadcast_to(u_pool, (S, u_pool.size)),
                            fl, L)
    if start_age > 0:
        f_lo = np.array([float(d.cdf(start_age)) for d in eff])[:, None]
    else:
        f_lo = np.zeros((S, 1))
    first = capped_icdf_draw(d_b, f_lo + u_first[None, :] * (1.0 - f_lo),
                             fl, L)
    return first, pool.reshape(S, n_trials, max_restarts + 2)


# ---------------------------------------------------------------------------
# the event kernel
# ---------------------------------------------------------------------------

@jax.jit
def _makespan_kernel(policy, first_steps, pool_steps, job_steps, age0_idx,
                     delta_steps, max_restarts, max_events):
    """One ``lax.while_loop`` over events; all state is (n_trials,) vectors.

    Works entirely in grid-step units: lifetimes arrive pre-converted to
    steps (initial sub-grid age offset already removed), VM age is an integer
    grid index, and the only float accumulation is the sum of preempted
    partial segments.  The loop body therefore contains no multiply-add
    pattern XLA could contract into an FMA — given a shared pool, a float64
    run matches the Python reference loop bit-for-bit.  Returns
    ``(done_steps, lost_steps, restarts, finished)`` — ``finished`` marks
    trials that completed all their work; the caller converts to hours.
    """
    n = first_steps.shape[0]
    fdt = first_steps.dtype
    j_hi = policy.shape[0] - 1
    t_hi = policy.shape[1] - 1

    state = dict(
        remaining=jnp.full((n,), job_steps, jnp.int32),
        age_idx=jnp.full((n,), age0_idx, jnp.int32),
        draw=jnp.zeros((n,), jnp.int32),
        life_s=first_steps,
        done_steps=jnp.zeros((n,), jnp.int32),
        lost_steps=jnp.zeros((n,), fdt),
        restarts=jnp.zeros((n,), jnp.int32),
        events=jnp.zeros((), jnp.int32),
    )

    def active(s):
        return (s["remaining"] > 0) & (s["restarts"] <= max_restarts)

    def cond(s):
        return jnp.any(active(s)) & (s["events"] < max_events)

    def body(s):
        act = active(s)
        rem, age = s["remaining"], s["age_idx"]
        i = policy[jnp.clip(rem, 0, j_hi), jnp.clip(age, 0, t_hi)]
        i = jnp.clip(i, 1, jnp.maximum(rem, 1))
        w = jnp.where(i < rem, i + delta_steps, i)
        survive = (age + w).astype(fdt) <= s["life_s"]
        # preemption: time since VM start minus checkpointed prefix is lost
        loss = jnp.maximum(s["life_s"] - age.astype(fdt), 0.0)
        nxt_draw = s["draw"] + 1
        nxt_life = pool_steps[jnp.arange(n),
                              jnp.minimum(nxt_draw, max_restarts + 1)]

        def upd(old, succ_val, fail_val):
            return jnp.where(act, jnp.where(survive, succ_val, fail_val), old)

        return dict(
            remaining=upd(rem, rem - i, rem),
            age_idx=upd(age, age + w, jnp.zeros((), jnp.int32)),
            draw=upd(s["draw"], s["draw"], nxt_draw),
            life_s=upd(s["life_s"], s["life_s"], nxt_life),
            done_steps=upd(s["done_steps"], s["done_steps"] + w,
                           s["done_steps"]),
            lost_steps=upd(s["lost_steps"], s["lost_steps"],
                           s["lost_steps"] + loss),
            restarts=upd(s["restarts"], s["restarts"], s["restarts"] + 1),
            events=s["events"] + 1,
        )

    out = jax.lax.while_loop(cond, body, state)
    return (out["done_steps"], out["lost_steps"], out["restarts"],
            out["remaining"] == 0)


# scenario-batched kernels: vmap the event loop over the leading (S,) axis.
# The while_loop batching rule freezes finished slices with selects, so each
# scenario slice performs the reference IEEE operations — on a shared pool a
# float64 slice is bit-identical to the unbatched kernel.
_KERNEL_SCALARS = (None,) * 5
_makespan_kernel_batch = jax.jit(jax.vmap(
    _makespan_kernel.__wrapped__, in_axes=(0, 0, 0) + _KERNEL_SCALARS))
_makespan_kernel_batch_shared = jax.jit(jax.vmap(
    _makespan_kernel.__wrapped__, in_axes=(None, 0, 0) + _KERNEL_SCALARS))


def simulate_makespan_batch(policy_table, job_steps: int, *, first, pool,
                            grid_dt: float = 1.0 / 60.0, delta_steps: int = 1,
                            start_age: float = 0.0,
                            restart_overhead: float = 0.0,
                            max_restarts: int = 64,
                            max_events: int | None = None,
                            unfinished: str = "nan",
                            return_finished: bool = False):
    """Vectorized executor over a shared pre-drawn lifetime pool.

    Semantics are identical to the Python reference
    ``checkpointing.simulate_makespan``: a preemption mid-segment (work or
    checkpoint write) loses progress back to the last durable checkpoint and
    the job resumes on a fresh VM after ``restart_overhead`` hours.  Returns
    makespans (hours), shape ``(n_trials,)``.

    Scenario batching (leading-axis convention): when ``pool`` has a
    leading scenario axis — shape ``(S, n_trials, max_restarts + 2)``, with
    ``first`` of shape ``(S, n_trials)`` — the event kernel is vmapped over
    it and the result is ``(S, n_trials)``.  ``policy_table`` may then be
    either per-scenario ``(S, j_max+1, t_axis)`` or a shared 2-D table.
    Each scenario slice keeps the bit-exactness contract above.

    Trials can exit the event loop *unfinished* — either their ``max_restarts``
    budget is exhausted or the whole batch hits the ``max_events`` safety cap.
    ``unfinished`` selects how those trials are reported:

    * ``"nan"`` (default) — the makespan is NaN, so a truncated trial can
      never silently pass for a completed one in downstream statistics;
    * ``"partial"`` — the accumulated ``done + lost`` time is returned, which
      is exactly what the Python reference loop yields on restart exhaustion;
    * ``"raise"`` — a ``RuntimeError`` naming the count of unfinished trials.

    ``return_finished=True`` additionally returns the boolean completion mask
    (shape ``(n_trials,)``), regardless of ``unfinished`` mode.
    """
    if unfinished not in ("nan", "partial", "raise"):
        raise ValueError(f"unfinished must be 'nan', 'partial' or 'raise', "
                         f"got {unfinished!r}")
    dtype = jnp.result_type(float)  # float64 under enable_x64, else float32
    if max_events is None:
        max_events = int(job_steps) + int(max_restarts) + 2
    age0_idx = int(round(start_age / grid_dt))
    off0 = start_age - age0_idx * grid_dt
    # unit conversion in float64 numpy, identical to the reference loop
    first_steps = (np.asarray(first, np.float64) - off0) / grid_dt
    pool_steps = np.asarray(pool, np.float64) / grid_dt
    table = np.asarray(policy_table, np.int32)
    if pool_steps.ndim == 3:                 # leading scenario axis
        if first_steps.shape != pool_steps.shape[:2]:
            raise ValueError(
                f"scenario-batched pool {pool_steps.shape} needs first of "
                f"shape {pool_steps.shape[:2]}, got {first_steps.shape}")
        kernel = (_makespan_kernel_batch if table.ndim == 3
                  else _makespan_kernel_batch_shared)
    elif table.ndim == 3:
        raise ValueError("per-scenario policy_table needs a scenario-batched "
                         "pool (S, n_trials, max_restarts + 2)")
    else:
        kernel = _makespan_kernel
    done, lost, restarts, finished = kernel(
        jnp.asarray(table),
        jnp.asarray(first_steps, dtype), jnp.asarray(pool_steps, dtype),
        jnp.int32(job_steps), jnp.int32(age0_idx), jnp.int32(delta_steps),
        jnp.int32(max_restarts), jnp.int32(max_events))
    done = np.asarray(done, np.float64)
    lost = np.asarray(lost, np.float64)
    restarts = np.asarray(restarts, np.float64)
    finished = np.asarray(finished, bool)
    out = (done + lost) * grid_dt + restarts * restart_overhead
    if not finished.all():
        if unfinished == "raise":
            raise RuntimeError(
                f"{int((~finished).sum())}/{finished.size} trials exited "
                f"unfinished (max_restarts={max_restarts}, "
                f"max_events={max_events})")
        if unfinished == "nan":
            out = np.where(finished, out, np.nan)
    if return_finished:
        return out, finished
    return out


def simulate_makespan_engine(policy_table, lifetimes_fn, job_steps: int, *,
                             grid_dt: float = 1.0 / 60.0, delta_steps: int = 1,
                             start_age: float = 0.0, n_trials: int = 2000,
                             seed: int = 0, restart_overhead: float = 0.0,
                             max_restarts: int = 64, **kw):
    """Drop-in vectorized replacement for ``checkpointing.simulate_makespan``
    (same sampler protocol, same seed -> same lifetime draws).  Extra
    keywords (``unfinished``, ``return_finished``, ``max_events``) pass
    through to :func:`simulate_makespan_batch`; with
    ``return_finished=True`` the result is a ``(makespans, finished)``
    tuple instead of a bare array."""
    first, pool = draw_lifetime_pool(lifetimes_fn, n_trials,
                                     max_restarts=max_restarts, seed=seed,
                                     start_age=start_age)
    return simulate_makespan_batch(policy_table, job_steps, first=first,
                                   pool=pool, grid_dt=grid_dt,
                                   delta_steps=delta_steps,
                                   start_age=start_age,
                                   restart_overhead=restart_overhead,
                                   max_restarts=max_restarts, **kw)


# ---------------------------------------------------------------------------
# batched reuse decisions for the service simulator
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_age",))
def _reuse_grid(dist, T_values, L, n_age):
    age = jnp.linspace(0.0, L, n_age)
    return sched_policy.reuse_decision(dist, T_values[:, None], age[None, :])


@functools.partial(jax.jit, static_argnames=("n_age",))
def _reuse_grid_batch(dist, T_values, L, n_age):
    """(S,)-stacked distribution -> (S, len(T_values), n_age) decisions in
    one compiled call (vmap of the per-scenario grid)."""
    return jax.vmap(
        lambda d: _reuse_grid.__wrapped__(d, T_values, L, n_age))(dist)


class ReuseTable:
    """Precomputed reuse decisions over (remaining work x VM age).

    One jitted call evaluates Eq. 10 < Eq. 9 for every grid point; lookups
    from the service's event loop are then pure numpy indexing.  ``T_values``
    is exact in the remaining-work axis (pass the actual job lengths when
    they are known, e.g. a non-checkpointing bag); ages are quantized to
    ``n_age`` points over [0, L] (nearest), 1-min resolution by default.
    """

    def __init__(self, dist, T_values, *, n_age: int = 1441, _table=None):
        self.T_values = np.asarray(np.sort(np.unique(T_values)), np.float64)
        self.L = float(np.asarray(dist.L).reshape(-1)[0])
        self.n_age = int(n_age)
        self.table = np.asarray(_reuse_grid(
            dist, jnp.asarray(self.T_values), self.L, self.n_age)) \
            if _table is None else np.asarray(_table)

    @classmethod
    def batch(cls, dists, T_values, *, n_age: int = 1441) -> list:
        """Build one table per scenario from a SINGLE vmapped grid call
        (leading-axis convention; the scenarios must share ``L``).  Returns
        a list of per-scenario :class:`ReuseTable` views, interchangeable
        with individually constructed ones."""
        dists = list(dists)
        L = float(dists[0].L)
        if any(abs(float(d.L) - L) > 1e-12 for d in dists[1:]):
            raise ValueError("ReuseTable.batch() requires a shared L")
        T_values = np.asarray(np.sort(np.unique(T_values)), np.float64)
        grids = np.asarray(_reuse_grid_batch(
            dists_mod.stack(dists), jnp.asarray(T_values), L, int(n_age)))
        return [cls(d, T_values, n_age=n_age, _table=grids[i])
                for i, d in enumerate(dists)]

    def decide(self, remaining_work: float, vm_age: float) -> bool:
        ti = int(np.searchsorted(self.T_values, remaining_work))
        if ti >= len(self.T_values) or (
                ti > 0 and remaining_work - self.T_values[ti - 1]
                < self.T_values[ti] - remaining_work):
            ti -= 1
        ai = int(round(vm_age / self.L * (self.n_age - 1)))
        return bool(self.table[ti, min(max(ai, 0), self.n_age - 1)])
