"""Vectorized JAX Monte-Carlo engine for policy evaluation.

This module is the batched/jitted counterpart of the pure-Python simulation
hot paths used by the paper's headline figures:

  * :func:`simulate_makespan_batch` — the Fig. 7 checkpointing executor
    (``repro.core.policies.checkpointing.simulate_makespan``) rewritten as a
    single ``lax.while_loop`` over *events*, operating on ``(n_trials,)``
    state vectors with the policy table and the pre-drawn lifetime pool
    resident on device.  One event = one work segment attempt (success or
    preemption) for every still-running trial; the loop exits as soon as all
    trials have finished, so the wall-clock cost is the *slowest* trial's
    event count, not ``n_trials`` Python iterations.
  * :func:`reuse_decision_table` — the scheduling policy's Eq. 9-vs-Eq. 10
    reuse decision evaluated for a whole ``(remaining-work x VM-age)`` grid
    in one jitted call, so the batch-service event loop never dispatches to
    JAX per idle-VM candidate.
  * :func:`draw_lifetime_pool` — the shared pre-drawn lifetime pool.  The
    Python reference executor and the vectorized kernel both consume pools
    drawn by this helper, which is what makes exact (same-seed, same-pool)
    equivalence testable.

Policies are represented as integer *tables* ``P[j, t] -> interval`` (steps
until the next checkpoint given ``j`` remaining steps and VM age index
``t``); :func:`dp_policy_table`, :func:`young_daly_policy_table` and
:func:`no_checkpoint_policy_table` build them for the three Fig. 7 policies.
Age-independent policies use a ``(j_max+1, 1)`` table — the kernel clips the
age index into the table's second dimension.

Bit-exactness contract (the PR-1 equivalence discipline)
---------------------------------------------------------
Every batched kernel in this module has a retained reference implementation
it must reproduce, and the dtype under which the match is *bit-exact* is part
of the contract:

  * :func:`simulate_makespan_batch` vs the per-trial Python loop
    ``checkpointing.simulate_makespan`` — on a shared pre-drawn pool with x64
    enabled (``jax.experimental.enable_x64``), makespans match bit-for-bit:
    the kernel works in integer grid-step units with the only float
    accumulation (lost partial segments) ordered exactly as the reference,
    so XLA cannot contract a multiply-add into an FMA.  In default float32
    mode results agree to ~1e-6 relative, far below Monte-Carlo noise.
  * Batched (leading-axis) kernels vs their own unbatched form — per slice,
    same dtype rule: the ``lax.while_loop`` batching rule freezes finished
    lanes with selects, so each lane performs the reference IEEE operations.
  * :func:`draw_lifetime_pool_batch` vs the numpy-reference
    :func:`draw_lifetime_pool` — per (entry, seed) slice, bit-exact under
    x64 (both paths share :func:`capped_icdf_draw` and compile the same
    array-constant bisection graph), float32-close (~1e-6) otherwise.

``tests/test_sim_engine.py`` and ``tests/test_batched.py`` enforce all
three; any kernel restructuring must keep them green.

Leading-axis convention (batching scenarios — or whole sweep grids)
-------------------------------------------------------------------
Every batched entry point treats an optional leading axis as a *batch of
independent cells*.  In the simplest use the axis is the scenario axis
``S``, threaded end-to-end from the distribution layer up; since PR 4 the
same axis folds the full (scenario x policy x seed) sweep grid as a
flattened cell axis ``B = S*P*R`` — the executor does not care what the
axis means, only that lane ``b`` carries that cell's table, first lifetime
and pool:

  * ``distributions.stack(dists)`` stacks a scenario list into one pytree
    whose parameter leaves carry a leading ``(S,)`` axis;
  * ``checkpointing.solve_batch`` returns ``(S, j_max+1, t_max+1)`` V/K
    tables from one compiled call;
  * :func:`draw_lifetime_pool_batch` draws ``(S, n_trials, max_restarts+2)``
    pools on-device in one shot; ``seed`` may be a per-entry sequence, so a
    flattened (scenario x seed) cell list draws every cell's pool — each
    from its own seed's reference rng stream — in the same single call;
  * :func:`stack_policy_tables` stacks per-cell policy tables of differing
    provenance (age-dependent DP tables next to age-independent
    Young-Daly/no-checkpoint columns) into one ``(B, j_max+1, t_max+1)``
    tensor without changing any lookup result;
  * :func:`simulate_makespan_batch` accepts the leading axis on
    ``policy_table`` (optional — a 2-D table is shared), ``first`` and
    ``pool``, vmapping the event kernel and returning ``(B, n_trials)``
    makespans.  The bit-exactness contract above holds per lane;
  * :meth:`ReuseTable.batch` / :class:`ReuseTables` evaluate all scenarios'
    reuse grids in one vmapped call, sharing one backing tensor.

``scenarios.sweep_checkpointing(mode="batched")`` composes these into ONE
executor dispatch for an entire sweep; see its docstring for the
cell-index/unflattening bookkeeping.

Typical use (Fig. 7 workload)::

    tables = checkpointing.solve(dist, 720)
    table = engine.dp_policy_table(tables)
    first, pool = engine.draw_lifetime_pool(
        checkpointing.model_lifetimes_fn(dist), n_trials=5000,
        max_restarts=64, seed=0)
    makespans = engine.simulate_makespan_batch(table, 720, first=first,
                                               pool=pool)
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import distributions as dists_mod
from .policies import scheduling as sched_policy

__all__ = [
    "dp_policy_table", "young_daly_policy_table", "no_checkpoint_policy_table",
    "stack_policy_tables",
    "draw_lifetime_pool", "draw_lifetime_pool_batch",
    "simulate_makespan_batch", "simulate_makespan_engine",
    "ReuseTable", "ReuseTables",
]


# ---------------------------------------------------------------------------
# policy tables
# ---------------------------------------------------------------------------

def dp_policy_table(tables) -> np.ndarray:
    """The DP's optimal-interval table ``K[j, t]`` (see checkpointing.solve)."""
    return np.asarray(tables.K, np.int32)


def young_daly_policy_table(tau_steps: int, job_steps: int) -> np.ndarray:
    """Fixed-interval policy ``min(tau, remaining)`` as a (j_max+1, 1) table."""
    j = np.arange(job_steps + 1, dtype=np.int32)
    return np.minimum(np.maximum(int(tau_steps), 1), j)[:, None].astype(np.int32)


def no_checkpoint_policy_table(job_steps: int) -> np.ndarray:
    """Run-to-completion: the next 'segment' is the whole remaining job."""
    return np.arange(job_steps + 1, dtype=np.int32)[:, None]


def validate_policy_table(table) -> np.ndarray:
    """Reject a policy table the executor must never serve from: NaN/inf
    (a half-written or diverged solve) or intervals outside ``[0, j]`` /
    zero with work remaining (which would wedge the executor's progress
    loop at ``max(1, min(i, remaining))`` in ways the solve never
    intended).  Returns the table as int32 on success; raises ValueError.

    The closed-loop runtime calls this on every candidate table before the
    atomic hot-swap — validation failures degrade to the last-good table.
    """
    raw = np.asarray(table)
    if not np.all(np.isfinite(raw)):
        raise ValueError("validate_policy_table: non-finite entries")
    t = raw.astype(np.int32)
    if t.ndim != 2:
        raise ValueError(f"validate_policy_table: expected a 2-D (j, t) "
                         f"table, got shape {raw.shape}")
    j = np.arange(t.shape[0], dtype=np.int32)[:, None]
    if np.any(t < 0) or np.any(t > j):
        raise ValueError("validate_policy_table: intervals outside [0, j]")
    if t.shape[0] > 1 and np.any(t[1:] < 1):
        raise ValueError("validate_policy_table: zero interval with work "
                         "remaining (j >= 1)")
    return t


def stack_policy_tables(tables, t_axis: int | None = None) -> np.ndarray:
    """Stack per-cell 2-D policy tables into one ``(B, j_max+1, t_axis)``
    int32 tensor for the one-kernel executor.

    The three Fig. 7 policy families produce tables of differing provenance
    and age-axis width: the DP's ``K[j, t]`` is fully age-dependent
    (``t_axis = t_max+1``) while Young-Daly and no-checkpoint tables are
    age-independent ``(j_max+1, 1)`` columns.  An age-independent column is
    widened by replication, which cannot change any lookup: the kernel reads
    ``table[clip(j), clip(age)]`` and every age column holds the same
    interval the 1-wide table would have produced via its age clip.  Tables
    must share the remaining-work axis; a table that is neither 1-wide nor
    ``t_axis``-wide is rejected rather than resampled.
    """
    tables = [np.asarray(t, np.int32) for t in tables]
    if not tables:
        raise ValueError("stack_policy_tables() needs at least one table")
    if any(t.ndim != 2 for t in tables):
        raise ValueError("stack_policy_tables() stacks 2-D (j, t) tables")
    j_axis = tables[0].shape[0]
    if any(t.shape[0] != j_axis for t in tables):
        raise ValueError("policy tables must share the remaining-work axis; "
                         f"got {sorted({t.shape[0] for t in tables})}")
    if t_axis is None:
        t_axis = max(t.shape[1] for t in tables)
    out = np.empty((len(tables), j_axis, int(t_axis)), np.int32)
    for b, t in enumerate(tables):
        if t.shape[1] == t_axis:
            out[b] = t
        elif t.shape[1] == 1:
            out[b] = np.broadcast_to(t, (j_axis, int(t_axis)))
        else:
            raise ValueError(
                f"table {b} has age axis {t.shape[1]}; expected 1 (age-"
                f"independent) or {t_axis} — widening an age-dependent "
                f"table would need resampling, not replication")
    return out


# ---------------------------------------------------------------------------
# lifetime pools
# ---------------------------------------------------------------------------

def draw_lifetime_pool(lifetimes_fn: Callable, n_trials: int, *,
                       max_restarts: int = 64, seed: int = 0,
                       start_age: float = 0.0):
    """Pre-draw the `(first, pool)` lifetimes consumed by one executor run.

    ``pool`` has shape ``(n_trials, max_restarts + 2)``; draw ``k`` (k >= 1)
    after the k-th preemption of trial ``n`` is ``pool[n, min(k, max_restarts
    + 1)]``.  ``first`` is the initial VM's lifetime, conditioned on survival
    to ``start_age`` when the sampler supports ``min_age`` (falls back to
    ``pool[:, 0]`` otherwise).  Draw order matches the historical reference
    executor, so a given ``seed`` yields the same lifetimes in both engines.
    """
    rng = np.random.default_rng(seed)
    pool = np.asarray(lifetimes_fn(rng, n_trials * (max_restarts + 2)),
                      np.float64).reshape(n_trials, max_restarts + 2)
    try:
        first = np.asarray(lifetimes_fn(rng, n_trials, min_age=start_age),
                           np.float64)
    except TypeError:  # sampler without conditioning support
        first = pool[:, 0].copy()
    return first, pool


@jax.jit
def _capped_icdf_kernel(dist, u, fl, L):
    t = dist.icdf(jnp.minimum(u, fl * (1.0 - 1e-6)))
    return jnp.where(u >= fl, jnp.asarray(L, t.dtype), t)


def capped_icdf_draw(dist, u, fl, L):
    """The capped inverse-CDF draw both samplers share: lifetimes
    ``icdf(min(u, fl * (1 - 1e-6)))`` with the residual ``u >= fl`` mass
    preempted AT the deadline ``L``.  Broadcasts over scalar parameters
    (``checkpointing.model_lifetimes_fn``, the numpy reference) and
    ``(S, 1)``-stacked ones (:func:`draw_lifetime_pool_batch`) — keeping
    this contract in ONE place is what keeps the two paths bit-identical
    under x64.

    The whole draw — inversion and deadline cap — runs through one
    module-level jitted kernel that takes the distribution as a pytree
    *argument*: the compiled bisection is cached per (family, shape,
    dtype) instead of being re-traced through each fresh distribution
    instance's closure, neither path can bake parameter constants into
    its graph — both see literally the same executable — and the capped
    result crosses the device boundary exactly once."""
    return np.asarray(_capped_icdf_kernel(dist, jnp.asarray(u),
                                          jnp.asarray(fl), jnp.asarray(L)),
                      np.float64)


def draw_lifetime_pool_batch(dists, n_trials: int, *, max_restarts: int = 64,
                             seed=0, start_age: float = 0.0):
    """Batched :func:`draw_lifetime_pool` for a list of cells: ``first`` has
    shape ``(S, n_trials)`` and ``pool`` ``(S, n_trials, max_restarts + 2)``.

    ``seed`` is either one integer — a scenario batch, every entry sharing
    that seed's uniforms — or a sequence of ``len(dists)`` per-entry seeds,
    which is how a flattened (scenario x seed) sweep cell list draws every
    cell's pool in ONE call.  Either way each entry's uniforms come from its
    own ``np.random.default_rng(seed)`` stream in the reference draw order
    (pool first, then the conditioned first draw), so entry ``i`` sees
    exactly the uniforms the serial per-scenario path would see for
    ``(dists[i], seed_i)``.  The inverse CDF then runs as one on-device
    bisection over all ``S * n_trials * (max_restarts + 2)`` lifetimes —
    replacing S per-scenario numpy round-trips — by stacking each
    entry's launch-phase-resolved parameters to ``(S, 1)`` so the
    distribution methods broadcast over the trailing draw axis.

    Exactness: per-entry parameters are resolved with the same scalar
    eager ops as ``checkpointing.model_lifetimes_fn`` (``effective()`` for
    the diurnal family), so under x64 every slice reproduces the
    numpy-reference pool bit-for-bit; in default float32 mode slices agree
    to float32 precision (~1e-6), far below Monte-Carlo noise.
    """
    dists = list(dists)
    dtype = jnp.result_type(float)
    # normalize leaves first (as model_lifetimes_fn does), then resolve any
    # launch-phase modulation with the same scalar eager ops the reference
    # sampler performs at trace time; finally stack to (S, 1)
    norm = [jax.tree_util.tree_map(lambda l: jnp.asarray(l, dtype), d)
            for d in dists]
    eff = [d.effective() if hasattr(d, "effective") else d for d in norm]
    d_b = jax.tree_util.tree_map(
        lambda *ls: jnp.stack(ls)[:, None], *eff)
    S = len(dists)
    n_pool = n_trials * (max_restarts + 2)
    if np.ndim(seed) == 0:
        rng = np.random.default_rng(seed)
        u_pool = np.broadcast_to(rng.uniform(size=n_pool), (S, n_pool))
        u_first = np.broadcast_to(rng.uniform(size=n_trials), (S, n_trials))
    else:
        seed = list(seed)
        if len(seed) != S:
            raise ValueError(f"per-entry seeds need one seed per entry: got "
                             f"{len(seed)} seeds for {S} distributions")
        # entries sharing a seed see the same reference stream — draw each
        # unique seed's uniforms once; the big pool block is fanned out to
        # the S entries on DEVICE (upload unique rows + take) instead of
        # materializing an S-times-duplicated host copy.  take is an exact
        # copy, so entry i's uniforms are bit-identical to its stream's.
        draws, order = {}, []
        for s in seed:
            if s not in draws:
                r = np.random.default_rng(s)
                draws[s] = (len(order), r.uniform(size=n_pool),
                            r.uniform(size=n_trials))
                order.append(s)
        u_pool = jnp.take(
            jnp.asarray(np.stack([draws[s][1] for s in order])),
            jnp.asarray([draws[s][0] for s in seed]), axis=0)
        u_first = np.stack([draws[s][2] for s in seed])
    # scalar pre/post quantities, per entry, as the numpy reference
    fl = np.array([float(d.cdf(d.L)) for d in eff])[:, None]
    L = np.array([float(d.L) for d in eff])[:, None]
    pool = capped_icdf_draw(d_b, u_pool, fl, L)
    if start_age > 0:
        f_lo = np.array([float(d.cdf(start_age)) for d in eff])[:, None]
    else:
        f_lo = np.zeros((S, 1))
    first = capped_icdf_draw(d_b, f_lo + u_first * (1.0 - f_lo), fl, L)
    return first, pool.reshape(S, n_trials, max_restarts + 2)


# ---------------------------------------------------------------------------
# market dollars: the price-grid gather
# ---------------------------------------------------------------------------

@jax.jit
def _price_cost_kernel(prices, cum, sidx, m, dt):
    """Batched gather for ``integral_0^m p`` against a precomputed ``(S, T)``
    price grid: ``k = floor(m/dt)`` (tail-clamped) per trial, returning
    ``(cum[s, k], prices[s, k], k)``.  The kernel deliberately stops at the
    gathers — the partial-cell arithmetic ``cum + prices * (m - k*dt)`` runs
    in host float64 (``accumulate_price_cost``): inside the fused kernel
    XLA:CPU contracts the multiply-subtract / multiply-add pairs into FMAs
    that round once where the serial reference
    ``market.integrate_cost_ref`` rounds twice — 1-ulp mismatches that break
    the x64 bit-identity contract (``lax.optimization_barrier`` does not
    reliably stop the contraction).  Like ``_capped_icdf_kernel``, this is
    ONE module-level jitted kernel taking its tensors as arguments: the
    compiled gather is cached per shape/dtype, never re-traced per sweep
    (``tests/test_market.py`` spies on it)."""
    Tn = prices.shape[1]
    m0 = jnp.where(jnp.isnan(m), 0.0, m)
    k = jnp.clip(jnp.floor(m0 / dt).astype(jnp.int32), 0, Tn - 1)
    s = sidx[:, None]
    return cum[s, k], prices[s, k], k


def accumulate_price_cost(grid, makespans, price_index=None) -> np.ndarray:
    """Dollars per trial for ``(B, n_trials)`` makespans billed against a
    ``market.PriceGrid``: lane ``b`` integrates price row
    ``price_index[b]`` (identity when omitted) over ``[0, m)``.  NaN
    makespans (unfinished trials) stay NaN.  The whole batch is one jitted
    gather dispatch; under x64 every element is bit-identical to the
    retained serial reference ``market.integrate_cost_ref`` — the
    established reference/production contract (see the module docstring).
    """
    m = np.atleast_2d(np.asarray(makespans, np.float64))
    B = m.shape[0]
    if price_index is None:
        price_index = np.arange(B, dtype=np.int32)
    sidx = np.broadcast_to(np.asarray(price_index, np.int32), (B,))
    if sidx.size and (sidx.min() < 0 or sidx.max() >= len(grid.prices)):
        raise ValueError("price_index out of range for the price grid")
    dtype = jnp.result_type(float)
    base, pk, k = _price_cost_kernel(
        jnp.asarray(grid.prices, dtype), jnp.asarray(grid.cum, dtype),
        jnp.asarray(sidx), jnp.asarray(m, dtype),
        jnp.asarray(float(grid.dt), dtype))
    # partial-cell arithmetic in host float64 — the same IEEE rounding
    # sequence as the serial reference's
    # ``cum[k] + prices[k] * (m - k*dt)`` (see the kernel docstring)
    base = np.asarray(base, np.float64)
    pk = np.asarray(pk, np.float64)
    kf = np.asarray(k, np.int64).astype(np.float64)
    frac = m - kf * np.float64(grid.dt)
    out = base + pk * frac
    out[np.isnan(m)] = np.nan
    return out if np.ndim(makespans) > 1 else out[0]


# ---------------------------------------------------------------------------
# the event kernel
# ---------------------------------------------------------------------------

def _event_loop(policy_lookup, pool_lookup, first_steps, job_steps, age0_idx,
                delta_steps, max_restarts, max_events):
    """THE makespan event loop — one ``lax.while_loop`` over events, all
    state in (n_trials,) vectors; every executor kernel is this loop with a
    different pair of lookups (``policy_lookup(rem, age) -> interval``,
    ``pool_lookup(draw) -> next lifetime``), so the traced operations per
    trial — the bit-exactness contract — live in exactly one place.

    Works entirely in grid-step units: lifetimes arrive pre-converted to
    steps (initial sub-grid age offset already removed), VM age is an integer
    grid index, and the only float accumulation is the sum of preempted
    partial segments.  The loop body therefore contains no multiply-add
    pattern XLA could contract into an FMA — given a shared pool, a float64
    run matches the Python reference loop bit-for-bit.  Returns
    ``(done_steps, lost_steps, restarts, finished)`` — ``finished`` marks
    trials that completed all their work; the caller converts to hours.
    """
    n = first_steps.shape[0]
    fdt = first_steps.dtype

    state = dict(
        remaining=jnp.full((n,), job_steps, jnp.int32),
        age_idx=jnp.full((n,), age0_idx, jnp.int32),
        draw=jnp.zeros((n,), jnp.int32),
        life_s=first_steps,
        done_steps=jnp.zeros((n,), jnp.int32),
        lost_steps=jnp.zeros((n,), fdt),
        restarts=jnp.zeros((n,), jnp.int32),
        events=jnp.zeros((), jnp.int32),
    )

    def active(s):
        return (s["remaining"] > 0) & (s["restarts"] <= max_restarts)

    def cond(s):
        return jnp.any(active(s)) & (s["events"] < max_events)

    def body(s):
        act = active(s)
        rem, age = s["remaining"], s["age_idx"]
        i = policy_lookup(rem, age)
        i = jnp.clip(i, 1, jnp.maximum(rem, 1))
        w = jnp.where(i < rem, i + delta_steps, i)
        survive = (age + w).astype(fdt) <= s["life_s"]
        # preemption: time since VM start minus checkpointed prefix is lost
        loss = jnp.maximum(s["life_s"] - age.astype(fdt), 0.0)
        nxt_draw = s["draw"] + 1
        nxt_life = pool_lookup(nxt_draw)

        def upd(old, succ_val, fail_val):
            return jnp.where(act, jnp.where(survive, succ_val, fail_val), old)

        return dict(
            remaining=upd(rem, rem - i, rem),
            age_idx=upd(age, age + w, jnp.zeros((), jnp.int32)),
            draw=upd(s["draw"], s["draw"], nxt_draw),
            life_s=upd(s["life_s"], s["life_s"], nxt_life),
            done_steps=upd(s["done_steps"], s["done_steps"] + w,
                           s["done_steps"]),
            lost_steps=upd(s["lost_steps"], s["lost_steps"],
                           s["lost_steps"] + loss),
            restarts=upd(s["restarts"], s["restarts"], s["restarts"] + 1),
            events=s["events"] + 1,
        )

    out = jax.lax.while_loop(cond, body, state)
    return (out["done_steps"], out["lost_steps"], out["restarts"],
            out["remaining"] == 0)


@jax.jit
def _makespan_kernel(policy, first_steps, pool_steps, job_steps, age0_idx,
                     delta_steps, max_restarts, max_events):
    """:func:`_event_loop` with direct per-call table/pool lookups."""
    n = first_steps.shape[0]
    j_hi = policy.shape[0] - 1
    t_hi = policy.shape[1] - 1
    return _event_loop(
        lambda rem, age: policy[jnp.clip(rem, 0, j_hi),
                                jnp.clip(age, 0, t_hi)],
        lambda draw: pool_steps[jnp.arange(n),
                                jnp.minimum(draw, max_restarts + 1)],
        first_steps, job_steps, age0_idx, delta_steps, max_restarts,
        max_events)


# cell-batched kernels: vmap the event loop over the leading (B,) axis.
# The while_loop batching rule freezes finished slices with selects, so each
# cell slice performs the reference IEEE operations — on a shared pool a
# float64 slice is bit-identical to the unbatched kernel.
_KERNEL_SCALARS = (None,) * 5
_makespan_kernel_batch = jax.jit(jax.vmap(
    _makespan_kernel.__wrapped__, in_axes=(0, 0, 0) + _KERNEL_SCALARS))
_makespan_kernel_batch_shared = jax.jit(jax.vmap(
    _makespan_kernel.__wrapped__, in_axes=(None, 0, 0) + _KERNEL_SCALARS))


def _makespan_kernel_cell(policy_u, tidx, pool_all, pidx, first_steps,
                          job_steps, age0_idx, delta_steps, max_restarts,
                          max_events):
    """One lane of the deduplicated one-kernel fold: the :func:`_event_loop`
    reading the policy via ``policy_u[tidx]`` and the pool via
    ``pool_all[pidx]`` instead of materialized per-lane copies.

    Vmapped over ``(tidx, pidx, first_steps)`` with the unique-table tensor
    ``(U, j_max+1, t_max+1)`` and the unique-pool tensor ``(Q, n_trials,
    max_restarts+2)`` UNBATCHED, the whole sweep's gathers hit tens of MB
    instead of the ``B``-times-replicated tensors — the difference between
    the fold being faster or slower than the grouped dispatch it replaces.
    Per lane the lookups return the very same integers/floats, so the
    bit-exactness contract is untouched.
    """
    n = first_steps.shape[0]
    j_hi = policy_u.shape[1] - 1
    t_hi = policy_u.shape[2] - 1
    return _event_loop(
        lambda rem, age: policy_u[tidx, jnp.clip(rem, 0, j_hi),
                                  jnp.clip(age, 0, t_hi)],
        lambda draw: pool_all[pidx, jnp.arange(n),
                              jnp.minimum(draw, max_restarts + 1)],
        first_steps, job_steps, age0_idx, delta_steps, max_restarts,
        max_events)


_makespan_kernel_indexed = jax.jit(jax.vmap(
    _makespan_kernel_cell,
    in_axes=(None, 0, None, 0, 0) + _KERNEL_SCALARS))


def simulate_makespan_batch(policy_table, job_steps: int, *, first, pool,
                            grid_dt: float = 1.0 / 60.0, delta_steps: int = 1,
                            start_age: float = 0.0,
                            restart_overhead: float = 0.0,
                            max_restarts: int = 64,
                            max_events: int | None = None,
                            unfinished: str = "nan",
                            return_finished: bool = False,
                            table_index=None, pool_index=None,
                            price=None, price_index=None):
    """Vectorized executor over a shared pre-drawn lifetime pool.

    Semantics are identical to the Python reference
    ``checkpointing.simulate_makespan``: a preemption mid-segment (work or
    checkpoint write) loses progress back to the last durable checkpoint and
    the job resumes on a fresh VM after ``restart_overhead`` hours.  Returns
    makespans (hours), shape ``(n_trials,)``.

    Cell batching (leading-axis convention): when ``pool`` has a leading
    cell axis — shape ``(B, n_trials, max_restarts + 2)``, with ``first``
    of shape ``(B, n_trials)`` — the event kernel is vmapped over it and
    the result is ``(B, n_trials)``.  ``policy_table`` may then be either
    per-cell ``(B, j_max+1, t_axis)`` (see :func:`stack_policy_tables`) or
    a shared 2-D table.  The axis can be a scenario batch or a flattened
    (scenario x policy x seed) sweep grid — each lane keeps the
    bit-exactness contract in the module docstring either way.

    Deduplicated fold (``table_index``/``pool_index``): a sweep grid
    replicates tables across seeds and pools across policies.  Passing
    ``table_index`` (shape ``(B,)`` into a ``(U, j_max+1, t_axis)``
    ``policy_table`` of *unique* tables) and ``pool_index`` (shape ``(B,)``
    into a ``(Q, n_trials, max_restarts + 2)`` ``pool`` of *unique* pools,
    with ``first`` still per-cell ``(B, n_trials)``) runs the same B lanes
    while the kernel gathers from the compact tensors — avoiding both the
    host-side replication and the cache-hostile reads of B-times-duplicated
    data.  Lane ``b`` computes bit-identically to the materialized
    ``policy_table[table_index[b]]`` / ``pool[pool_index[b]]`` call.

    Trials can exit the event loop *unfinished* — either their ``max_restarts``
    budget is exhausted or the whole batch hits the ``max_events`` safety cap.
    ``unfinished`` selects how those trials are reported:

    * ``"nan"`` (default) — the makespan is NaN, so a truncated trial can
      never silently pass for a completed one in downstream statistics;
    * ``"partial"`` — the accumulated ``done + lost`` time is returned, which
      is exactly what the Python reference loop yields on restart exhaustion;
    * ``"raise"`` — a ``RuntimeError`` naming the count of unfinished trials.

    ``return_finished=True`` additionally returns the boolean completion mask
    (shape ``(n_trials,)``), regardless of ``unfinished`` mode.

    Market dollars (``price=``): a ``market.PriceGrid`` bills every trial's
    makespan — the checkpointing executor runs one VM at a time, so a
    trial's vm_hours IS its makespan — by integrating its price row over
    ``[0, m)`` through :func:`accumulate_price_cost` (one batched gather
    against the precomputed grid; ``price_index`` maps cells to grid rows,
    identity when omitted).  The dollars array is appended to the return
    value: ``(mk, dollars)``, or ``(mk, finished, dollars)`` with
    ``return_finished=True``.  NaN-flagged trials cost NaN.
    """
    if unfinished not in ("nan", "partial", "raise"):
        raise ValueError(f"unfinished must be 'nan', 'partial' or 'raise', "
                         f"got {unfinished!r}")
    dtype = jnp.result_type(float)  # float64 under enable_x64, else float32
    if max_events is None:
        max_events = int(job_steps) + int(max_restarts) + 2
    age0_idx = int(round(start_age / grid_dt))
    off0 = start_age - age0_idx * grid_dt
    # unit conversion in float64 numpy, identical to the reference loop
    first_steps = (np.asarray(first, np.float64) - off0) / grid_dt
    pool_steps = np.asarray(pool, np.float64) / grid_dt
    table = np.asarray(policy_table, np.int32)
    scalars = (jnp.int32(job_steps), jnp.int32(age0_idx),
               jnp.int32(delta_steps), jnp.int32(max_restarts),
               jnp.int32(max_events))
    if (table_index is None) != (pool_index is None):
        raise ValueError("table_index and pool_index must be passed together")
    if table_index is not None:
        tix = np.asarray(table_index, np.int32)
        pix = np.asarray(pool_index, np.int32)
        if table.ndim != 3 or pool_steps.ndim != 3:
            raise ValueError("the indexed fold needs a (U, j, t) policy_table "
                             "and a (Q, n_trials, max_restarts + 2) pool")
        if first_steps.ndim != 2 \
                or not (tix.shape == pix.shape == first_steps.shape[:1]) \
                or first_steps.shape[1] != pool_steps.shape[1]:
            raise ValueError(
                f"indexed fold needs first of shape (B, n_trials) with "
                f"(B,) table_index/pool_index and a matching pool trial "
                f"axis; got first {first_steps.shape}, pool "
                f"{pool_steps.shape}, table_index {tix.shape}, "
                f"pool_index {pix.shape}")
        if tix.size and (tix.min() < 0 or tix.max() >= table.shape[0]):
            raise ValueError("table_index out of range")
        if pix.size and (pix.min() < 0 or pix.max() >= pool_steps.shape[0]):
            raise ValueError("pool_index out of range")
        done, lost, restarts, finished = _makespan_kernel_indexed(
            jnp.asarray(table), jnp.asarray(tix),
            jnp.asarray(pool_steps, dtype), jnp.asarray(pix),
            jnp.asarray(first_steps, dtype), *scalars)
    else:
        if pool_steps.ndim == 3:             # leading cell axis
            if first_steps.shape != pool_steps.shape[:2]:
                raise ValueError(
                    f"scenario-batched pool {pool_steps.shape} needs first of "
                    f"shape {pool_steps.shape[:2]}, got {first_steps.shape}")
            kernel = (_makespan_kernel_batch if table.ndim == 3
                      else _makespan_kernel_batch_shared)
        elif table.ndim == 3:
            raise ValueError("per-scenario policy_table needs a "
                             "scenario-batched pool "
                             "(S, n_trials, max_restarts + 2)")
        else:
            kernel = _makespan_kernel
        done, lost, restarts, finished = kernel(
            jnp.asarray(table),
            jnp.asarray(first_steps, dtype), jnp.asarray(pool_steps, dtype),
            *scalars)
    done = np.asarray(done, np.float64)
    lost = np.asarray(lost, np.float64)
    restarts = np.asarray(restarts, np.float64)
    finished = np.asarray(finished, bool)
    out = (done + lost) * grid_dt + restarts * restart_overhead
    if not finished.all():
        if unfinished == "raise":
            raise RuntimeError(
                f"{int((~finished).sum())}/{finished.size} trials exited "
                f"unfinished (max_restarts={max_restarts}, "
                f"max_events={max_events})")
        if unfinished == "nan":
            out = np.where(finished, out, np.nan)
    if price is None:
        if price_index is not None:
            raise ValueError("price_index needs price= (a market.PriceGrid)")
        if return_finished:
            return out, finished
        return out
    dollars = accumulate_price_cost(price, out, price_index)
    if return_finished:
        return out, finished, dollars
    return out, dollars


def simulate_makespan_engine(policy_table, lifetimes_fn, job_steps: int, *,
                             grid_dt: float = 1.0 / 60.0, delta_steps: int = 1,
                             start_age: float = 0.0, n_trials: int = 2000,
                             seed: int = 0, restart_overhead: float = 0.0,
                             max_restarts: int = 64, **kw):
    """Drop-in vectorized replacement for ``checkpointing.simulate_makespan``
    (same sampler protocol, same seed -> same lifetime draws).  Extra
    keywords (``unfinished``, ``return_finished``, ``max_events``) pass
    through to :func:`simulate_makespan_batch`; with
    ``return_finished=True`` the result is a ``(makespans, finished)``
    tuple instead of a bare array."""
    first, pool = draw_lifetime_pool(lifetimes_fn, n_trials,
                                     max_restarts=max_restarts, seed=seed,
                                     start_age=start_age)
    return simulate_makespan_batch(policy_table, job_steps, first=first,
                                   pool=pool, grid_dt=grid_dt,
                                   delta_steps=delta_steps,
                                   start_age=start_age,
                                   restart_overhead=restart_overhead,
                                   max_restarts=max_restarts, **kw)


# ---------------------------------------------------------------------------
# batched reuse decisions for the service simulator
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_age",))
def _reuse_grid(dist, T_values, L, n_age):
    age = jnp.linspace(0.0, L, n_age)
    return sched_policy.reuse_decision(dist, T_values[:, None], age[None, :])


@functools.partial(jax.jit, static_argnames=("n_age",))
def _reuse_grid_batch(dist, T_values, L, n_age):
    """(S,)-stacked distribution -> (S, len(T_values), n_age) decisions in
    one compiled call (vmap of the per-scenario grid)."""
    return jax.vmap(
        lambda d: _reuse_grid.__wrapped__(d, T_values, L, n_age))(dist)


class ReuseTable:
    """Precomputed reuse decisions over (remaining work x VM age).

    One jitted call evaluates Eq. 10 < Eq. 9 for every grid point; lookups
    from the service's event loop are then pure numpy indexing.  ``T_values``
    is exact in the remaining-work axis (pass the actual job lengths when
    they are known, e.g. a non-checkpointing bag); ages are quantized to
    ``n_age`` points over [0, L] (nearest), 1-min resolution by default.
    """

    def __init__(self, dist, T_values, *, n_age: int = 1441, _table=None):
        self.T_values = np.asarray(np.sort(np.unique(T_values)), np.float64)
        self.L = float(np.asarray(dist.L).reshape(-1)[0])
        self.n_age = int(n_age)
        self.table = np.asarray(_reuse_grid(
            dist, jnp.asarray(self.T_values), self.L, self.n_age)) \
            if _table is None else np.asarray(_table)

    @classmethod
    def batch(cls, dists, T_values, *, n_age: int = 1441) -> list:
        """Build one table per scenario from a SINGLE vmapped grid call
        (leading-axis convention; the scenarios must share ``L``).  Returns
        a list of per-scenario :class:`ReuseTable` views, interchangeable
        with individually constructed ones.  The views share one backing
        tensor — see :class:`ReuseTables`, which this wraps."""
        return list(ReuseTables(dists, T_values, n_age=n_age))

    def decide(self, remaining_work: float, vm_age: float) -> bool:
        ti = int(np.searchsorted(self.T_values, remaining_work))
        if ti >= len(self.T_values) or (
                ti > 0 and remaining_work - self.T_values[ti - 1]
                < self.T_values[ti] - remaining_work):
            ti -= 1
        ai = int(round(vm_age / self.L * (self.n_age - 1)))
        return bool(self.table[ti, min(max(ai, 0), self.n_age - 1)])


class ReuseTables:
    """The folded scenario batch of reuse-decision grids.

    ONE vmapped grid call evaluates every scenario's (remaining-work x
    VM-age) Eq. 10-vs-Eq. 9 decisions into a single ``(S, len(T_values),
    n_age)`` boolean tensor; :meth:`view` (or indexing/iteration) returns
    per-scenario :class:`ReuseTable` views that *share* that backing tensor,
    so a whole service sweep costs one JAX dispatch and one allocation no
    matter how many (policy x cluster x seed) cells later consume each
    scenario's grid.  All scenarios must share the deadline ``L``.
    """

    def __init__(self, dists, T_values, *, n_age: int = 1441):
        self._dists = list(dists)
        if not self._dists:
            raise ValueError("ReuseTables needs at least one distribution")
        L = float(self._dists[0].L)
        if any(abs(float(d.L) - L) > 1e-12 for d in self._dists[1:]):
            raise ValueError("ReuseTables requires a shared L")
        self.T_values = np.asarray(np.sort(np.unique(T_values)), np.float64)
        self.L = L
        self.n_age = int(n_age)
        self.tables = np.asarray(_reuse_grid_batch(
            dists_mod.stack(self._dists), jnp.asarray(self.T_values), L,
            self.n_age))

    def __len__(self) -> int:
        return len(self._dists)

    def view(self, s: int) -> ReuseTable:
        """A per-scenario :class:`ReuseTable` over the shared tensor."""
        return ReuseTable(self._dists[s], self.T_values, n_age=self.n_age,
                          _table=self.tables[s])

    def __getitem__(self, s: int) -> ReuseTable:
        return self.view(s)

    def __iter__(self):
        return (self.view(s) for s in range(len(self)))
