"""Synthetic preemption-trace generation for constrained transient VMs/pods.

The paper's raw 1,516-preemption trace is not public, so benchmarks and tests
draw lifetimes from a *ground-truth hazard process* that reproduces the
empirical phenomenology of Figs. 1-2 (steep early preemptions, long stable
phase, deadline wall, hard 24 h cap, diurnal + VM-size modulation).

Crucially the ground truth is a DIFFERENT functional family from the paper's
Eq. 1 model - a three-term hazard

    lambda(t) = h0 * exp(-t / d0)  +  h_s * diurnal(clock)  +  k / (L - t + s)^4

so that "our model fits the data better than exponential/Weibull/GM" is a real
statement about model capacity, not the generator fitting itself.

Sampling goes through a dense cumulative-hazard grid (inverse transform), all
jit/vmap-friendly.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .distributions import DEADLINE_HOURS

_GRID_N = 4096

# Cumulative-hazard grids are pure functions of the process parameters, so
# concrete (non-traced) GroundTruth instances share them through this cache
# instead of re-integrating 4096 hazard points on every cdf/sample call.
_GRID_CACHE: dict = {}
_GRID_CACHE_MAX = 128


def _dc(cls):
    cls = dataclasses.dataclass(frozen=True, eq=False)(cls)
    return jax.tree_util.register_dataclass(cls)


@_dc
class GroundTruth:
    """Ground-truth constrained-preemption process (NOT the paper's model)."""

    h0: jnp.ndarray = 0.45        # initial-phase hazard amplitude (1/h)
    d0: jnp.ndarray = 1.4         # initial-phase decay (h)
    h_stable: jnp.ndarray = 0.008  # stable-phase hazard floor (1/h)
    k_wall: jnp.ndarray = 2.0     # deadline-wall strength
    s_wall: jnp.ndarray = 0.6     # deadline-wall softening (h)
    diurnal_amp: jnp.ndarray = 0.5   # Obs. 5: day/night modulation of h_stable
    launch_clock: jnp.ndarray = 12.0  # wall-clock hour-of-day at VM launch
    L: jnp.ndarray = DEADLINE_HOURS

    def hazard(self, t):
        t = jnp.asarray(t, jnp.result_type(float))
        clock = self.launch_clock + t
        # day (8-20h) busier than night: smooth +-amp modulation
        diurnal = 1.0 + self.diurnal_amp * jnp.sin(2.0 * jnp.pi * (clock - 14.0) / 24.0)
        gap = self.L - jnp.minimum(t, self.L - 1e-3) + self.s_wall
        wall = self.k_wall / jnp.square(jnp.square(gap))
        return self.h0 * jnp.exp(-t / self.d0) + self.h_stable * diurnal + wall

    def _grid_key(self):
        """Hashable parameter tuple, or None when any field is a tracer (or
        non-scalar), in which case the grid cannot be cached.  The active
        float width is part of the key: a float32 grid must not be served
        under enable_x64 (or vice versa)."""
        try:
            return (jnp.result_type(float).name,) + tuple(
                float(getattr(self, f.name))
                for f in dataclasses.fields(self))
        except (TypeError, jax.errors.TracerArrayConversionError,
                jax.errors.ConcretizationTypeError):
            return None

    def _grid_compute(self):
        t = jnp.linspace(0.0, self.L, _GRID_N)
        dt = t[1] - t[0]
        lam = self.hazard(t)
        cum = jnp.concatenate([jnp.zeros((1,), lam.dtype),
                               jnp.cumsum(0.5 * (lam[1:] + lam[:-1]) * dt)])
        return t, 1.0 - jnp.exp(-cum)  # grid CDF

    def _grid(self):
        key = self._grid_key()
        if key is None:
            return self._grid_compute()
        hit = _GRID_CACHE.get(key)
        if hit is None:
            if len(_GRID_CACHE) >= _GRID_CACHE_MAX:
                _GRID_CACHE.pop(next(iter(_GRID_CACHE)))
            hit = _GRID_CACHE[key] = self._grid_compute()
        return hit

    def cdf(self, x):
        t, F = self._grid()
        return jnp.interp(jnp.asarray(x, t.dtype), t, F, left=0.0, right=F[-1])

    def sample(self, key, shape=()):
        """Lifetimes in (0, L]; survivors of the soft process are reclaimed at
        exactly L (the provider's hard 24 h cap)."""
        t, F = self._grid()
        u = jax.random.uniform(key, shape, minval=1e-6, maxval=1.0 - 1e-9)
        capped = u >= F[-1]
        # invert the grid CDF
        x = jnp.interp(jnp.minimum(u, F[-1] - 1e-7), F, t)
        return jnp.where(capped, self.L, x)


# Ground-truth processes per VM type, consistent with Obs. 4 (larger VMs are
# preempted more) and calibrated so fitted Eq.-1 parameters land in the
# paper's quoted ranges (tau1 in [0.5,1.5], tau2~0.8, b~24, A in [0.4,0.5]).
_TYPE_SCALE = {
    "n1-highcpu-2": 0.55,
    "n1-highcpu-4": 0.70,
    "n1-highcpu-8": 0.85,
    "n1-highcpu-16": 1.00,
    "n1-highcpu-32": 1.45,
    "tpu-v5e-pod": 1.00,
}


def ground_truth_for(vm_type: str = "n1-highcpu-16",
                     launch_clock: float = 12.0,
                     idle: bool = False) -> GroundTruth:
    scale = _TYPE_SCALE[vm_type]
    # Obs. 5: idle VMs live longer (lower stable hazard)
    h_stable = 0.008 * (0.5 if idle else 1.0)
    return GroundTruth(h0=0.45 * scale, h_stable=h_stable * scale,
                       launch_clock=launch_clock)


class FleetTrace(NamedTuple):
    """A fleet-wide synthetic preemption study (the paper's 1,516-VM study)."""
    vm_type_idx: jnp.ndarray   # (n,) int - index into vm_types list
    launch_clock: jnp.ndarray  # (n,) wall-clock launch hour
    lifetime: jnp.ndarray      # (n,) hours in (0, 24]


def generate_fleet_trace(key, n_vms: int = 1516,
                         vm_types=("n1-highcpu-2", "n1-highcpu-4", "n1-highcpu-8",
                                   "n1-highcpu-16", "n1-highcpu-32")) -> FleetTrace:
    """Reproduce the shape of the paper's empirical study: n_vms launches
    across VM types, launch times spread over day/night.

    Each VM samples from ONE batched ``GroundTruth`` whose parameter fields
    are (n_vms,) vectors gathered from its own type — a single ``vmap`` that
    builds one cumulative-hazard grid per VM, instead of the old per-VM path
    that built grids for all five types and then selected one.  Per-VM draws
    use the same (key, process) pairs as before, so the trace is unchanged.
    """
    k1, k2, k3 = jax.random.split(key, 3)
    type_idx = jax.random.randint(k1, (n_vms,), 0, len(vm_types))
    clock = jax.random.uniform(k2, (n_vms,), minval=0.0, maxval=24.0)
    keys = jax.random.split(k3, n_vms)

    # parameter vectors in float64 numpy first, so each VM's parameters are
    # bit-identical to the python-float fields ground_truth_for would set;
    # only the type- and clock-dependent fields are batched
    scale = np.asarray([_TYPE_SCALE[v] for v in vm_types],
                       np.float64)[np.asarray(type_idx)]
    batched = GroundTruth(h0=jnp.asarray(0.45 * scale),
                          h_stable=jnp.asarray(0.008 * scale),
                          launch_clock=clock)
    axes = GroundTruth(h0=0, d0=None, h_stable=0, k_wall=None, s_wall=None,
                       diurnal_amp=None, launch_clock=0, L=None)
    life = jax.vmap(lambda g, k: g.sample(k), in_axes=(axes, 0))(batched, keys)
    return FleetTrace(vm_type_idx=type_idx, launch_clock=clock, lifetime=life)


def trace_for(key, vm_type: str = "n1-highcpu-16", n: int = 300,
              launch_clock: float = 12.0, idle: bool = False):
    """Single-type lifetime trace (one CDF curve of Fig. 1 / Fig. 2)."""
    return ground_truth_for(vm_type, launch_clock, idle).sample(key, (n,))
