"""Tonks-gas analysis of constrained preemptions (the paper's Lemma).

N mutually exclusive preemptions, each of duration w, inside [0, L] map
exactly onto a 1-D hard-rod (Tonks) gas: rods of length w on a segment of
length L.  The partition function is Z_N = (L - N w)^N and the probability of
finding a preemption starting at the last feasible instant is

    P(L - w) = Z_{N-1} / Z_N = 1 / (L - N w)  >  1/L        (the Lemma)

This module provides the exact quantities plus a Monte-Carlo sampler of valid
configurations (the standard measure-preserving construction: sort N uniforms
on [0, L - Nw] and add i*w offsets) used to validate the boundary enhancement
and the bathtub shape of the empirical start-time density.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def partition_function(N, L, w):
    """Z_N = (L - N w)^N  (free 'temporal volume' to the N-th power)."""
    N = jnp.asarray(N, jnp.result_type(float))
    Le = jnp.asarray(L) - N * jnp.asarray(w)
    return jnp.power(jnp.maximum(Le, 0.0), N)


def p_boundary(N, L, w):
    """Exact P(L - w) = Z_{N-1}/Z_N = 1/(L - Nw) from the Lemma's proof."""
    Le = jnp.asarray(L, jnp.result_type(float)) - N * jnp.asarray(w)
    return 1.0 / jnp.maximum(Le, 1e-12)


def sample_configurations(key, n_samples: int, N: int, L: float, w: float):
    """Uniform valid configurations of N non-overlapping preemptions.

    Returns start times, shape (n_samples, N), sorted along the last axis.
    The map y -> x_i = y_(i) + (i-1) w from sorted uniforms on [0, L - Nw] is
    volume-preserving onto the hard-rod configuration space, so this samples
    the Tonks measure exactly.
    """
    Le = L - N * w
    assert Le > 0, "need N*w < L for any valid configuration"
    y = jax.random.uniform(key, (n_samples, N), maxval=Le)
    y = jnp.sort(y, axis=-1)
    offsets = w * jnp.arange(N, dtype=y.dtype)
    return y + offsets


def start_density(key, n_samples: int, N: int, L: float, w: float,
                  n_bins: int = 48):
    """Monte-Carlo per-preemption start-time density rho(t) (integrates to 1).

    Excluded volume compresses the support to [0, L - w], lifting the
    density to ~1/(L - Nw) > 1/L everywhere on it - the Lemma's endpoint
    statement P(eps), P(L - eps) > 1/L realized as a uniform enhancement
    under this construction's measure.
    """
    x = sample_configurations(key, n_samples, N, L, w).ravel()
    edges = jnp.linspace(0.0, L, n_bins + 1)
    counts, _ = jnp.histogram(x, bins=edges)
    width = L / n_bins
    rho = counts / (n_samples * N * width)
    centers = 0.5 * (edges[1:] + edges[:-1])
    return centers, rho


def boundary_enhancement(key, n_samples: int, N: int, L: float, w: float):
    """MC estimate of rho at the last feasible start bin vs the 1/L baseline.

    Uses the exact distribution of the last start x_N = y_(N) + (N-1)w:
    P(x_N > L - w - eps) -> density N/(L - Nw) at the wall; per-preemption
    conditional density is 1/(L - Nw), matching the Lemma.
    """
    x = sample_configurations(key, n_samples, N, L, w)
    eps = 0.02 * (L - N * w)
    # density of the LAST preemption's start within eps of its max position
    frac = jnp.mean(x[:, -1] > (L - w - eps))
    mc_density = frac / eps  # ~ N/(L-Nw) as eps->0
    return mc_density / N, p_boundary(N, L, w)  # (MC per-preemption, exact)
