"""Closed-loop fleet runtime: stream -> track -> refit -> re-solve -> swap.

This is the paper's Discussion section made executable: "a long-running
cloud service can continuously update the model based on recent preemption
behavior".  The pieces have existed since PRs 1-4 — ``simulator`` generates
fleet lifetimes, ``fitting`` refits Eq. 1, ``OnlineModelTracker`` detects
change points, ``scenarios`` names regimes and ``solve_batch`` +
``sweep_checkpointing(tables=...)`` evaluate policies from pre-solved
tables — and :class:`FleetRuntime` closes the loop:

::

    FleetStream / FaultInjector                 (lifetime observations)
          |
          v
    OnlineModelTracker.observe()                (rolling window, KS drift)
          |  confirmed change point
          v
    fitting.fit_samples (Eq. 1 refit)  --fail-> retry w/ backoff, keep model
          |  finite theta
          v
    checkpointing.solve_batch          --fail-> retry w/ backoff, keep tables
      (warm-started from last V)
          |  validate() + validate_policy_table
          v
    atomic hot-swap of BatchDPTables + live-scenario dist_override
          |
          v
    sweep_checkpointing(..., tables=live)       (fleet keeps serving)

Robustness envelope
-------------------
Every stage is guarded so the fleet NEVER serves from a half-written or
NaN table:

* fit stage — ``FitDiverged`` / degenerate-window ``ValueError`` leaves the
  last-good model in place; bounded retry-with-backoff via
  ``tracker.defer_refit`` (doubling, ``retry_backoff_obs * 2**k``).
* solve stage — wall-clock budget (``SolveTimeout``), the injector's
  artificial timeouts, and table validation (``BatchDPTables.validate`` +
  ``engine.validate_policy_table``) all degrade to the last-good tables;
  a staleness counter runs from change-point confirmation to the swap.
* instrumentation — adaptation lag (observations between an *injected*
  drift and the table swap that answers it) and stale-table makespan
  regret (paired pools: same lifetime draws, stale K vs fresh K), written
  to ``BENCH_runtime.json`` by ``benchmarks/runtime_bench.py``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax
import numpy as np

from . import engine, fitting, online
from . import scenarios as SC
from . import simulator
from .policies import checkpointing as ckpt

FLEET_VM_TYPES = ("n1-highcpu-2", "n1-highcpu-4", "n1-highcpu-8",
                  "n1-highcpu-16", "n1-highcpu-32")


class SolveTimeout(RuntimeError):
    """A DP re-solve exceeded its wall-clock budget (real or injected)."""


@dataclasses.dataclass
class FleetStream:
    """Block-buffered lifetime stream over ``simulator.generate_fleet_trace``.

    The trace generator is a batched kernel (one ``vmap`` over the whole
    block), so the stream draws ``block`` lifetimes per refill and pops them
    one observation at a time.  ``set_regime`` switches the fleet's VM-type
    mix mid-stream — the injected-drift mechanism — and drops any buffered
    draws from the old regime so the change is immediate.
    """
    seed: int = 0
    block: int = 256
    vm_types: tuple = FLEET_VM_TYPES

    def __post_init__(self):
        self._key = jax.random.PRNGKey(self.seed)
        self._buf: list = []

    def set_regime(self, vm_types: Sequence[str]):
        self.vm_types = tuple(vm_types)
        self._buf = []

    def _refill(self):
        self._key, k = jax.random.split(self._key)
        tr = simulator.generate_fleet_trace(k, n_vms=self.block,
                                            vm_types=self.vm_types)
        self._buf = list(np.asarray(tr.lifetime, np.float64))

    def next(self) -> float:
        if not self._buf:
            self._refill()
        return float(self._buf.pop())


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    # evaluation workload (shared by the DP solve and the regret probe)
    base_scenarios: tuple = ()          # names/Scenarios solved alongside live
    job_steps: int = 60
    grid_dt: float = 0.1
    delta_steps: int = 1
    restart_overhead: float = 0.0
    n_sweeps: int = 3
    warm_sweeps: int = 2                # sweeps when warm-started from last V
    warm_start: bool = True
    max_restarts: int = 64
    # solver backend (see docs/solver.md): passed straight through to
    # checkpointing.solve_batch; "auto" keeps the platform default and the
    # REPRO_SOLVER_BACKEND env override
    solver_backend: str = "auto"
    solver_refine: bool = False         # coarse-to-fine pre-sweep pruning
    # DP objective (see docs/solver.md): "makespan" optimises expected
    # hours-to-completion; "dollars" prices every segment off the live
    # ticker and optimises expected dollars-to-completion.  Dollars
    # requires a price_feed at construction time.
    dp_objective: str = "makespan"
    # tracker
    window: int = 256
    refit_every: int = 64
    min_samples: int = 64
    # robustness envelope
    retry_backoff_obs: int = 16         # doubles per consecutive failure
    max_retries: int = 3
    solve_budget_s: float = 60.0
    # regret probe
    regret_trials: int = 256
    regret_seed: int = 123
    # stream
    stream_seed: int = 0
    stream_block: int = 256
    stream_vm_types: tuple = FLEET_VM_TYPES
    live_name: str = "live/fleet"


@dataclasses.dataclass(frozen=True)
class SwapRecord:
    obs: int                            # observation index of the swap
    reason: str                         # "initial-fit" | "change-point"
    warm: bool                          # warm-started from the previous V
    solve_seconds: float
    stale_obs: int                      # observations served stale before it
    lag_from_drift: Optional[int]       # obs since last injected drift
    regret_hours: Optional[float] = None  # what serving stale K was costing
    regret_frac: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class RuntimeReport:
    n_obs: int
    n_refits: int
    change_points: int
    swaps: tuple
    events: tuple                       # (obs, kind, detail)
    retries: dict                       # {"fit": n, "solve": n}
    degraded: bool                      # serving last-good past retry budget
    stale_obs_total: int
    adaptation_lag_obs: Optional[int]   # first injected drift -> its swap
    regret_hours: Optional[float]       # stale-K minus fresh-K mean makespan
    regret_frac: Optional[float]
    # market billing (populated when the runtime has a price_feed): every
    # streamed lifetime is billed at its launch price off the live ticker
    vm_hours_streamed: float = 0.0
    dollars_streamed: float = 0.0
    mean_price: Optional[float] = None  # dollars / vm-hours


class FleetRuntime:
    """The closed loop.  ``run(n_obs)`` streams observations through the
    pipeline and returns a :class:`RuntimeReport`; ``evaluate()`` re-runs
    the standing policy sweep from the CURRENT live tables at any time
    (this is what "the fleet keeps serving" means operationally)."""

    def __init__(self, config: Optional[RuntimeConfig] = None, *,
                 injector=None, stream: Optional[FleetStream] = None,
                 price_feed=None):
        self.cfg = cfg = config or RuntimeConfig()
        self.injector = injector
        # live market ticker (a market.PriceFeed): each streamed lifetime
        # is billed at the price the feed shows when the VM launches —
        # the same launch-cell convention as the service billing
        self.price_feed = price_feed
        if cfg.dp_objective == "dollars" and price_feed is None:
            raise ValueError("dp_objective='dollars' requires a price_feed: "
                             "the dollar DP prices segments off the live "
                             "ticker")
        self.vm_hours_streamed = 0.0
        self.dollars_streamed = 0.0
        self.stream = stream or FleetStream(seed=cfg.stream_seed,
                                            block=cfg.stream_block,
                                            vm_types=cfg.stream_vm_types)
        self.tracker = online.OnlineModelTracker(
            window=cfg.window, refit_every=cfg.refit_every,
            min_samples=cfg.min_samples, fit_fn=self._guarded_fit)
        base = SC._resolve(cfg.base_scenarios)
        self.live_sc = SC.register(
            SC.Scenario(name=cfg.live_name,
                        description="online-fitted fleet model (closed loop)",
                        dist_override=self.tracker.model),
            overwrite=True)
        self.scenario_names = tuple(s.name for s in base) + (cfg.live_name,)
        # telemetry / envelope state
        self.obs = 0
        self.events: list = []
        self.retries = {"fit": 0, "solve": 0}
        self.swaps: list = []
        self.degraded = False
        self.stale_obs_total = 0
        self._stale_since: Optional[int] = None
        self._seen_change_points = 0
        self._fit_attempts = 0
        self._solve_attempts = 0
        self._next_solve_retry = 0
        self._pending_swap: Optional[str] = None   # reason awaiting a solve
        self._last_drift_injected: Optional[int] = None
        self._adaptation_lags: list = []
        self._stale_tables: Optional[ckpt.BatchDPTables] = None
        # cold solve so the fleet serves validated tables from observation 0
        # (bootstrap precedes the stream, so the injector — whose schedule
        # is indexed by observation — does not apply yet)
        self.live_tables: Optional[ckpt.BatchDPTables] = None
        self.live_tables = self._solve(warm=False, inject=False)

    # -- scenario/dist plumbing -------------------------------------------
    def _dists(self) -> list:
        out = [SC.get(n).dist() for n in self.scenario_names[:-1]]
        out.append(self.tracker.model)
        return out

    def _guarded_fit(self, family, data, **kw):
        """The tracker's fit hook: lets the injector fault the fit stage
        with the exact non-finite result a diverged LM would produce, so
        the tracker's own validation path (not a mock) rejects it."""
        if self.injector is not None \
                and self.injector.take("fit_divergence", self.obs):
            import jax.numpy as jnp
            return fitting.FitResult(
                dist=self.tracker.model, theta=jnp.full((3,), jnp.nan),
                lse=jnp.asarray(jnp.nan), iterations=jnp.asarray(0),
                converged=jnp.asarray(False))
        return fitting.fit_samples(family, data, **kw)

    # -- solve stage -------------------------------------------------------
    def _solve(self, *, warm: bool, inject: bool = True) -> ckpt.BatchDPTables:
        cfg = self.cfg
        dists = self._dists()
        t_max = int(round(float(dists[-1].L) / cfg.grid_dt))
        want = (len(dists), cfg.job_steps + 1, t_max + 1)
        warm = (warm and cfg.warm_start and self.live_tables is not None
                and self.live_tables.V.shape == want
                and getattr(self.live_tables, "objective", "makespan")
                == cfg.dp_objective)
        if inject and self.injector is not None \
                and self.injector.take("solve_timeout", self.obs):
            raise SolveTimeout("injected solve timeout")
        # dollar objective: snapshot the live ticker from the market clock
        # forward over the solve horizon; one row broadcasts over scenarios
        price = (self.price_feed.grid(float(dists[-1].L))
                 if cfg.dp_objective == "dollars" else None)
        t0 = time.perf_counter()
        tab = ckpt.solve_batch(
            dists, cfg.job_steps, grid_dt=cfg.grid_dt,
            delta_steps=cfg.delta_steps,
            n_sweeps=cfg.warm_sweeps if warm else cfg.n_sweeps,
            restart_overhead=cfg.restart_overhead,
            v_init=self.live_tables.V if warm else None,
            backend=cfg.solver_backend, refine=cfg.solver_refine,
            objective=cfg.dp_objective, price=price)
        dt = time.perf_counter() - t0
        if dt > cfg.solve_budget_s:
            raise SolveTimeout(f"solve took {dt:.2f}s "
                               f"(budget {cfg.solve_budget_s}s)")
        tab.validate()
        for s in range(len(tab)):
            engine.validate_policy_table(tab.K[s])
        self._last_solve_warm = warm
        self._last_solve_seconds = dt
        return tab

    def _try_swap(self, reason: str):
        """Solve + validate + atomically publish; on failure keep last-good
        tables and schedule a bounded backoff retry."""
        try:
            tab = self._solve(warm=True)
        except (SolveTimeout, ValueError) as e:
            self.retries["solve"] += 1
            self._solve_attempts += 1
            self._pending_swap = reason
            self.events.append((self.obs, "solve-failure", str(e)))
            if self._solve_attempts <= self.cfg.max_retries:
                back = self.cfg.retry_backoff_obs * 2 ** (self._solve_attempts - 1)
                self._next_solve_retry = self.obs + back
                self.events.append((self.obs, "solve-retry-scheduled",
                                    f"in {back} obs"))
            else:
                # degraded: last-good tables keep serving; the next burst
                # of attempts waits a full refit period and gets its own
                # bounded budget (mirrors the fit stage)
                self.degraded = True
                self._next_solve_retry = self.obs + self.cfg.refit_every
                self._solve_attempts = 0
                self.events.append((self.obs, "solve-degraded",
                                    "retry budget exhausted; serving "
                                    "last-good tables"))
            return
        # swap: publish tables and the live scenario's dist in one go —
        # nothing downstream can observe a half-updated pair
        self._stale_tables = self.live_tables
        self.live_tables = tab
        self.live_sc = SC.register(
            dataclasses.replace(self.live_sc,
                                dist_override=self.tracker.model),
            overwrite=True)
        stale = (self.obs - self._stale_since
                 if self._stale_since is not None else 0)
        lag = (self.obs - self._last_drift_injected
               if self._last_drift_injected is not None else None)
        regret = None
        if reason == "change-point":
            # what the displaced (now-stale) table was costing, measured on
            # the model the fleet just adapted to; instrumentation must
            # never take the loop down, so probe failures record as None
            try:
                regret = self.measure_regret()
            except Exception:
                regret = None
        self.swaps.append(SwapRecord(
            obs=self.obs, reason=reason, warm=self._last_solve_warm,
            solve_seconds=self._last_solve_seconds, stale_obs=stale,
            lag_from_drift=lag,
            regret_hours=None if regret is None else regret[0],
            regret_frac=None if regret is None else regret[1]))
        if reason == "change-point" and lag is not None \
                and not self._adaptation_lags:
            self._adaptation_lags.append(lag)
        self.events.append((self.obs, "table-swap",
                            f"{reason}, warm={self._last_solve_warm}, "
                            f"stale_obs={stale}"))
        self._stale_since = None
        self._pending_swap = None
        self._solve_attempts = 0
        self.degraded = False

    # -- fit stage ---------------------------------------------------------
    def _on_fit_failure(self, exc: Exception):
        self.retries["fit"] += 1
        self._fit_attempts += 1
        self.events.append((self.obs, "fit-failure",
                            f"{type(exc).__name__}: {exc}"))
        if self._fit_attempts <= self.cfg.max_retries:
            back = self.cfg.retry_backoff_obs * 2 ** (self._fit_attempts - 1)
            self.tracker.defer_refit(back)
            self.events.append((self.obs, "fit-retry-scheduled",
                                f"in {back} obs"))
        else:
            # degraded: last-good model keeps serving; the next attempt
            # waits a full refit period (and the attempt counter resets so
            # a later burst gets its own bounded budget)
            self.degraded = True
            self.tracker.defer_refit(self.cfg.refit_every)
            self._fit_attempts = 0
            self.events.append((self.obs, "fit-degraded",
                                "retry budget exhausted; serving last-good "
                                "model"))

    # -- the loop ----------------------------------------------------------
    def step(self) -> None:
        """One observation through the whole pipeline."""
        inj = self.injector
        if self._stale_since is not None:
            self.stale_obs_total += 1
        # stream faults
        storm = None
        if inj is not None:
            ev = inj.drift_event(self.obs)
            if ev is not None:
                p = ev.param or {}
                if "vm_types" in p:
                    self.stream.set_regime(p["vm_types"])
                self._last_drift_injected = self.obs
                self.events.append((self.obs, "drift-injected", str(p)))
            storm = inj.storm_active(self.obs)
        life = (inj.storm_lifetime(storm) if storm is not None
                else self.stream.next())
        if self.price_feed is not None:
            # bill the observed VM life at its launch price, then tick the
            # market clock — deterministic per feed seed, so replays match
            self.vm_hours_streamed += life
            self.dollars_streamed += life * self.price_feed.advance()
        # fit stage (tracker validates the refit; failures keep last-good)
        try:
            refit = self.tracker.observe(life)
            if refit:
                self._fit_attempts = 0
        except (fitting.FitDiverged, ValueError) as e:
            refit = False
            self._on_fit_failure(e)
        # change-point bookkeeping survives a failed fit: the window was
        # already trimmed, and the tables are stale from this moment on
        if self.tracker.change_points > self._seen_change_points:
            self._seen_change_points = self.tracker.change_points
            if self._stale_since is None:
                self._stale_since = self.obs
            self.events.append((self.obs, "change-point",
                                f"ks={self.tracker.last_ks:.3f} > "
                                f"cut={self.tracker.last_cut:.3f}"))
            if refit:
                self._try_swap("change-point")
        elif refit and self.tracker.n_refits == 1:
            # first real fit replaces the prior model in the tables
            self._try_swap("initial-fit")
        elif self._pending_swap is not None \
                and self.obs >= self._next_solve_retry:
            self._try_swap(self._pending_swap)
        self.obs += 1

    def run(self, n_obs: int) -> RuntimeReport:
        for _ in range(int(n_obs)):
            self.step()
        return self.report()

    # -- instrumentation ---------------------------------------------------
    def measure_regret(self, *, n_trials: Optional[int] = None,
                       seed: Optional[int] = None):
        """Stale-table makespan regret on the live scenario, as a PAIRED
        comparison: one lifetime pool drawn from the current live model,
        executed under the pre-swap (stale) K and the current (fresh) K.
        Sharing the pool removes the Monte-Carlo variance between the two
        arms, so small regrets resolve at modest trial counts.  Returns
        ``(regret_hours, regret_frac)`` or ``None`` before the first swap.
        """
        if self._stale_tables is None:
            return None
        cfg = self.cfg
        n = int(n_trials or cfg.regret_trials)
        dist = self.live_sc.dist_override
        first, pool = engine.draw_lifetime_pool_batch(
            [dist], n, max_restarts=cfg.max_restarts,
            seed=cfg.regret_seed if seed is None else seed)
        s = len(self.live_tables) - 1          # live slice is last
        kw = dict(first=first, pool=pool, grid_dt=cfg.grid_dt,
                  delta_steps=cfg.delta_steps,
                  restart_overhead=cfg.restart_overhead,
                  max_restarts=cfg.max_restarts, unfinished="nan")
        mk_fresh = engine.simulate_makespan_batch(
            self.live_tables.K[s], cfg.job_steps, **kw)
        mk_stale = engine.simulate_makespan_batch(
            self._stale_tables.K[s], cfg.job_steps, **kw)
        # a storm-era model can leave EVERY trial unfinished (NaN-flagged);
        # an arm with no finished trials makes the probe unmeasurable
        if not (np.isfinite(mk_fresh).any() and np.isfinite(mk_stale).any()):
            return None
        fresh = float(np.nanmean(mk_fresh))
        stale = float(np.nanmean(mk_stale))
        return stale - fresh, (stale - fresh) / fresh

    def report(self) -> RuntimeReport:
        # the headline regret is the FIRST post-drift adaptation: the cost
        # of the table that served through the staleness window, measured
        # on the model the fleet adapted to at that swap
        regret = next(((s.regret_hours, s.regret_frac) for s in self.swaps
                       if s.reason == "change-point"
                       and s.regret_hours is not None), None)
        return RuntimeReport(
            n_obs=self.obs, n_refits=self.tracker.n_refits,
            change_points=self.tracker.change_points,
            swaps=tuple(self.swaps), events=tuple(self.events),
            retries=dict(self.retries), degraded=self.degraded,
            stale_obs_total=self.stale_obs_total,
            adaptation_lag_obs=(self._adaptation_lags[0]
                                if self._adaptation_lags else None),
            regret_hours=None if regret is None else regret[0],
            regret_frac=None if regret is None else regret[1],
            vm_hours_streamed=self.vm_hours_streamed,
            dollars_streamed=self.dollars_streamed,
            mean_price=(self.dollars_streamed / self.vm_hours_streamed
                        if self.vm_hours_streamed > 0 else None))

    def evaluate(self, **kw) -> list:
        """Re-run the standing policy sweep from the CURRENT live tables —
        one executor dispatch, no re-solve (the PR-4 ``tables=`` hook)."""
        cfg = self.cfg
        kw.setdefault("job_steps", cfg.job_steps)
        kw.setdefault("grid_dt", cfg.grid_dt)
        kw.setdefault("delta_steps", cfg.delta_steps)
        kw.setdefault("restart_overhead", cfg.restart_overhead)
        kw.setdefault("max_restarts", cfg.max_restarts)
        return SC.sweep_checkpointing(self.scenario_names,
                                      tables=self.live_tables, **kw)
