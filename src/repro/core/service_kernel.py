"""Batched event-synchronous service kernel (the Fig. 8 loop in JAX).

``service.BatchService`` replays the paper's batch-computing service one
heap event at a time in Python, which caps bag sizes at ~10^2 jobs.  This
module re-expresses that exact event loop as a single jitted
``lax.while_loop`` over fixed-shape state vectors:

  * ``(J,)`` job state — done work, finish time, failure/attempt counts,
    admission verdicts;
  * ``(V,)`` VM-slot state — launch time, sampled lifetime, running job,
    hot-spare expiry, per-event sequence numbers, fractional capacity.

Each loop iteration advances the simulation by ONE logical step: either a
*scheduling step* (one iteration of the serial loop's greedy ``assign``:
reuse an approved hot spare / launch a fresh VM / reject on a missed
deadline / release an idle spare / block head-of-line) or an *event step*
(the next finish / preempt / expire, chosen as the lexicographic
``(time, seq)`` minimum over per-slot candidates — the same global-seq
tiebreaker that orders the serial loop's heap keys).  All per-event work is
O(V) gathers/scatters, so the wall-clock per event is flat in J; a leading
``(B,)`` batch axis vmaps whole (scenario x policy x cluster_size x seed)
grids into one dispatch, with per-lane ``table_index`` / ``pool_index`` /
``bag_index`` gathers into deduplicated tensors (the PR-4 leading-axis
convention of ``engine.simulate_makespan_batch``).

Bit-exactness contract
----------------------
Under ``jax.experimental.enable_x64`` and a shared lifetime pool, a lane is
bit-identical to ``service.BatchService.run`` — per-job completion times,
failure/attempt counts, ``vm_hours`` and the cost accounting all match the
serial heap loop float-for-float.  This holds because every arithmetic
expression (segment times, checkpoint banking, ``ReuseTable.decide``'s
index arithmetic, the VM-hours accumulation *order*) is mirrored exactly,
and because the event order is: the serial heap pops by ``(time, seq)``;
the kernel takes the same minimum over *live* candidates.  Stale heap
entries (a finish event of a preempted job, an expire event of a re-used
spare) are no-ops in the serial loop and simply never become candidates
here, with one documented exception: a hot spare that is re-used and
becomes idle again within 1e-9 h of its previous idle period would, in the
serial loop, be expired by the *older* event; the kernel only tracks the
latest expiry.  No such schedule is reachable with positive job lengths.

New policy branches (kernel-only)
---------------------------------
* Deadline admission control: a job whose estimated completion
  (``start + segment/capacity``) misses its deadline is *rejected* at
  scheduling time — before a lifetime is drawn or a VM launched.
* VM deflation (arXiv:2006.00508): with ``deflate=True`` a lane converts
  the first preemption of a *running* VM into a capacity degradation to
  ``deflate_factor`` (the remaining segment stretches by ``1/factor`` and a
  fresh lifetime is drawn for the survivor) instead of a kill; checkpoint
  banking on a later real preemption counts work-equivalent progress
  ``att_w0 + (now - att_start) * capacity``.  Idle spares are never
  deflated — reclaiming an idle VM costs no work.

Sequence-number semantics (what makes ties serial-exact): launching pushes
``seq_p`` then starting the job pushes ``seq_f = seq_p + 1``, so a VM whose
lifetime exactly equals its segment is preempted first, exactly like the
serial heap.  Finishing allocates ``seq_e`` for the hot-spare expiry;
deflation allocates a fresh ``seq_p`` for the survivor's next preemption.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import distributions as dists_mod
from . import engine
from .service import (HOT_SPARE_HOURS, PRICES_ON_DEMAND, PRICES_PREEMPTIBLE,
                      RELAUNCH_OVERHEAD, Job, ServiceResult)

POLICY_MODEL = 0
POLICY_MEMORYLESS = 1
POLICY_CODES = {"model": POLICY_MODEL, "memoryless": POLICY_MEMORYLESS}

_BIG = 2 ** 30  # int sentinel > any seq/ord the loop can allocate


def split_policy(name: str) -> tuple[str, bool]:
    """``"model+deflate"`` -> ``("model", True)``; validates the base."""
    base, _, mod = name.partition("+")
    if base not in POLICY_CODES or mod not in ("", "deflate"):
        raise ValueError(f"unknown service policy {name!r}; expected "
                         f"{sorted(POLICY_CODES)} with optional '+deflate'")
    return base, mod == "deflate"


# ---------------------------------------------------------------------------
# pooled lifetime streams
# ---------------------------------------------------------------------------

def draw_service_pool_batch(dists, seeds, *, size: int = 4096) -> np.ndarray:
    """One ``(Q, size)`` tensor of service lifetime pools in ONE device call.

    Entry ``q`` is bit-identical (x64) to ``service.draw_service_pool(
    dists[q], seed=seeds[q], size=size)`` — the uniforms come from the same
    per-seed ``default_rng(seed).uniform(size)`` reference streams (drawn
    once per *unique* seed, fanned out with a device-side gather, exactly
    like ``engine.draw_lifetime_pool_batch``) and the inversion goes through
    the same shared ``engine.capped_icdf_draw`` kernel on leaf-normalized
    parameters.
    """
    dists = list(dists)
    seeds = [int(s) for s in seeds]
    if len(dists) != len(seeds):
        raise ValueError(f"dists ({len(dists)}) and seeds ({len(seeds)}) "
                         "must align")
    dtype = jnp.result_type(float)
    norm = [jax.tree_util.tree_map(lambda l: jnp.asarray(l, dtype), d)
            for d in dists]
    eff = [d.effective() if hasattr(d, "effective") else d for d in norm]
    # uniforms per unique seed, gathered per entry on device
    uniq: dict[int, int] = {}
    blocks = []
    for s in seeds:
        if s not in uniq:
            uniq[s] = len(blocks)
            blocks.append(np.random.default_rng(s).uniform(size=size))
    u = jnp.take(jnp.asarray(np.stack(blocks), dtype),
                 jnp.asarray([uniq[s] for s in seeds]), axis=0)
    d_b = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls)[:, None], *eff)
    fl = jnp.asarray(np.array([[float(d.cdf(d.L))] for d in eff]), dtype)
    L = jnp.asarray(np.array([[float(d.L)] for d in eff]), dtype)
    return np.asarray(engine.capped_icdf_draw(d_b, u, fl, L))


# ---------------------------------------------------------------------------
# the single-lane kernel (vmapped over the (B,) lane axis)
# ---------------------------------------------------------------------------

def _setv(arr, idx, val, flag):
    """Masked scatter: ``arr[idx] = val`` iff ``flag`` (lane-select safe).

    The masking redirects the index out of bounds and relies on scatter's
    ``mode="drop"`` instead of re-reading ``arr[idx]``, and the scatter
    promises ``unique_indices`` (one update per call, and vmap keeps lanes
    on distinct rows).  Both matter: without them XLA CPU lowers the
    batched scatter to a copy-then-write, and the loop's per-step cost
    scales with J instead of staying O(1) — fatal for 10^5-job bags."""
    i = jnp.where(flag, idx, arr.shape[0])
    return arr.at[i].set(jnp.asarray(val, arr.dtype), mode="drop",
                         unique_indices=True)


def _init_state(B, J, V):
    """Batched initial carry: ``B`` independent lanes of zeroed sim state."""
    ft = jnp.result_type(float)
    it = jnp.int32

    def sc(v, dt):
        return jnp.full((B,), v, dt)

    return dict(
        now=sc(0.0, ft), seq=sc(0, it), cursor=sc(0, it),
        n_launch=sc(0, it), n_active=sc(0, it), n_done=sc(0, it),
        n_preempt=sc(0, it), n_fail=sc(0, it), n_defl=sc(0, it),
        n_rej=sc(0, it), n_events=sc(0, it), steps=sc(0, it),
        vm_hours=sc(0.0, ft), dollars=sc(0.0, ft),
        pending=sc(True, bool), halt=sc(False, bool),
        exhausted=sc(False, bool), rel_mode=sc(False, bool),
        stack=jnp.zeros((B, V), it), stack_len=sc(0, it),
        next_fresh=sc(0, it),
        alive=jnp.zeros((B, V), bool), launched=jnp.zeros((B, V), ft),
        life=jnp.zeros((B, V), ft), pre_at=jnp.full((B, V), np.inf, ft),
        seq_p=jnp.zeros((B, V), it),
        job=jnp.full((B, V), -1, it), fin_at=jnp.full((B, V), np.inf, ft),
        seq_f=jnp.zeros((B, V), it),
        has_exp=jnp.zeros((B, V), bool), exp_at=jnp.full((B, V), np.inf, ft),
        seq_e=jnp.zeros((B, V), it),
        ordv=jnp.zeros((B, V), it), cap=jnp.ones((B, V), ft),
        defl=jnp.zeros((B, V), bool), att_start=jnp.zeros((B, V), ft),
        att_w0=jnp.zeros((B, V), ft), att_done=jnp.zeros((B, V), ft),
        stack_done=jnp.zeros((B, V), ft),
        done=jnp.zeros((B, J), ft), fin_t=jnp.full((B, J), np.nan, ft),
        failures=jnp.zeros((B, J), it), attempts=jnp.zeros((B, J), it),
        rejected=jnp.zeros((B, J), bool),
    )


def _lane_step(lane, shared, s, *, n_slots: int):
    """ONE per-lane simulation step (unbatched; vmapped by the kernel)."""
    ft = jnp.result_type(float)
    it = jnp.int32
    lengths_all = shared["lengths"]     # (R, J)
    deadline_all = shared["deadlines"]  # (R, J)
    pool_all = shared["pools"]          # (Q, P)
    table_all = shared["tables"]        # (U, T, A) bool
    T_values = shared["T_values"]       # (T,)
    l_reuse = shared["reuse_L"]
    ro, hot = shared["relaunch_overhead"], shared["hot_spare_hours"]
    ckpt_on, ck_i, ck_c = (shared["ckpt_on"], shared["ckpt_interval"],
                           shared["ckpt_cost"])
    max_steps = shared["max_steps"]
    bidx, pidx, tidx = lane["bag_index"], lane["pool_index"], lane["table_index"]
    policy, cluster = lane["policy"], lane["cluster_size"]
    deflate_on, dfac = lane["deflate"], lane["deflate_factor"]
    price_row, price_dt = lane["price"], shared["price_dt"]

    V = n_slots
    J = lengths_all.shape[1]
    P = pool_all.shape[1]
    Tn = T_values.shape[0]
    A = table_all.shape[2]
    BIGI = jnp.asarray(_BIG, it)
    inf = jnp.asarray(np.inf, ft)
    zero = jnp.asarray(0.0, ft)
    slot_ids = jnp.arange(V, dtype=it)
    Tp = price_row.shape[0]

    def launch_price(launched):
        # the VM's locked-in spot price: its launch cell on the lane's
        # price row — the exact index arithmetic of the serial
        # ``BatchService.run``'s ``launch_price`` (floor == int-trunc for
        # launched >= 0, tail-clamped)
        k = jnp.clip(jnp.floor(launched / price_dt).astype(it), 0, Tp - 1)
        return price_row[k]

    # Each step function returns (scalar updates, per-array scatter deltas)
    # instead of a full next-state: every (V,)/(J,) array changes in at most
    # two slots per step, so the loop body's WRITES are O(1) and the branch
    # merge only touches scalars.  A naive ``jnp.where(pending, sa[k],
    # se[k])`` tree-merge would copy every array every iteration, making the
    # per-step cost scale with J — fatal for 10^5-job bags.

    def assign_step(s):
        """ONE iteration of the serial loop's greedy ``assign(t)``."""
        now, seqv = s["now"], s["seq"]
        q_empty = (s["stack_len"] == 0) & (s["next_fresh"] >= J)
        idle = s["alive"] & (s["job"] < 0)
        any_idle = jnp.any(idle)
        # release idle spares one per step, in vm_id (launch) order, so the
        # float accumulation into vm_hours happens in the serial order.
        # ``rel_mode`` mirrors the serial assign(t)'s entry check exactly:
        # spares are released only when the cascade STARTED with an empty
        # queue; a queue that empties mid-cascade leaves denied spares
        # alive until the next event's assign (they may yet be reused)
        rel = jnp.argmin(jnp.where(idle, s["ordv"], BIGI))
        rel_mode = s["rel_mode"]
        b_release = rel_mode & any_idle
        b_stop = (rel_mode & ~any_idle) | (~rel_mode & q_empty)

        top = jnp.maximum(s["stack_len"] - 1, 0)
        from_stack = s["stack_len"] > 0
        head = jnp.where(from_stack, s["stack"][top],
                         jnp.minimum(s["next_fresh"], J - 1))
        length_h = lengths_all[bidx, head]
        # the head's banked progress rides on the stack (pushed at preempt
        # time) instead of being gathered from the (J,) ``done`` array:
        # keeping ``done`` WRITE-ONLY inside the loop is what lets XLA
        # alias the (B, J) carry in place (a gather whose value feeds
        # another array's scatter forces a full per-step copy on CPU)
        done_h = jnp.where(from_stack, s["stack_done"][top], zero)
        rem = length_h - done_h
        n_ck = jnp.floor(rem / ck_i).astype(it).astype(ft)
        seg = jnp.where(ckpt_on, rem + n_ck * ck_c, rem)

        # model-policy approval: the exact index arithmetic of
        # engine.ReuseTable.decide, vectorized over the V candidate slots
        age = now - s["launched"]
        ti = jnp.searchsorted(T_values, rem).astype(it)
        t_lo = T_values[jnp.maximum(ti - 1, 0)]
        t_hi = T_values[jnp.minimum(ti, Tn - 1)]
        adj = (ti >= Tn) | ((ti > 0) & (rem - t_lo < t_hi - rem))
        ti = jnp.clip(ti - adj.astype(it), 0, Tn - 1)
        ai = jnp.clip(jnp.round(age / l_reuse * (A - 1)).astype(it), 0, A - 1)
        appr = jnp.where(policy == POLICY_MEMORYLESS, True,
                         table_all[tidx, ti, ai])
        approved = idle & appr
        any_appr = jnp.any(approved)
        cand = jnp.argmin(jnp.where(approved, s["ordv"], BIGI))

        can_launch = s["n_active"] < cluster
        free = jnp.argmin(jnp.where(s["alive"], BIGI, slot_ids))
        cap_c = s["cap"][cand]
        start_l = now + ro
        est_reuse = now + seg / cap_c
        est_launch = start_l + seg
        dl = deadline_all[bidx, head]
        rej_reuse = est_reuse > dl
        rej_launch = est_launch > dl

        b_reuse = ~q_empty & any_appr & ~rej_reuse
        b_rejct = ~q_empty & ((any_appr & rej_reuse) |
                              (~any_appr & can_launch & rej_launch))
        b_launch = ~q_empty & ~any_appr & can_launch & ~rej_launch
        b_block = ~q_empty & ~any_appr & ~can_launch
        pop = b_reuse | b_rejct | b_launch
        b_start = b_reuse | b_launch
        slot = jnp.where(b_reuse, cand, free)
        start_t = jnp.where(b_reuse, now, start_l)
        life_new = pool_all[pidx, jnp.minimum(s["cursor"], P - 1)]

        pop_stack = pop & (s["stack_len"] > 0)
        fin_val = jnp.where(b_reuse, now + seg / cap_c, start_l + seg)
        up = dict(
            now=now, halt=s["halt"], n_events=s["n_events"],
            n_preempt=s["n_preempt"], n_fail=s["n_fail"],
            n_defl=s["n_defl"], rel_mode=s["rel_mode"],
            vm_hours=s["vm_hours"] + jnp.where(
                b_release, now - s["launched"][rel], zero),
            # dollars mirrors every vm_hours increment: the same wall-clock
            # delta times the slot's launch-cell price (serial ``bill``)
            dollars=s["dollars"] + jnp.where(
                b_release,
                (now - s["launched"][rel]) * launch_price(s["launched"][rel]),
                zero),
            pending=~(b_stop | b_block),
            stack_len=s["stack_len"] - pop_stack.astype(it),
            next_fresh=s["next_fresh"] + (pop & ~pop_stack).astype(it),
            n_rej=s["n_rej"] + b_rejct.astype(it),
            n_done=s["n_done"] + b_rejct.astype(it),
            cursor=s["cursor"] + b_launch.astype(it),
            exhausted=s["exhausted"] | (b_launch & (s["cursor"] >= P)),
            n_launch=s["n_launch"] + b_launch.astype(it),
            n_active=(s["n_active"] + b_launch.astype(it)
                      - b_release.astype(it)),
            seq=seqv + jnp.where(b_launch, 2,
                                 jnp.where(b_reuse, 1, 0)).astype(it))
        deltas = dict(
            alive=[(rel, False, b_release), (free, True, b_launch)],
            rejected=[(head, True, b_rejct)],
            # fresh launch at now + relaunch_overhead
            launched=[(free, start_l, b_launch)],
            life=[(free, life_new, b_launch)],
            pre_at=[(free, start_l + life_new, b_launch)],
            seq_p=[(free, seqv, b_launch)],
            ordv=[(free, s["n_launch"], b_launch)],
            cap=[(free, jnp.asarray(1.0, ft), b_launch)],
            defl=[(free, False, b_launch)],
            # start the job (reused spare at now, fresh VM at start_l)
            job=[(slot, head, b_start)],
            att_start=[(slot, start_t, b_start)],
            att_w0=[(slot, zero, b_start)],
            att_done=[(slot, done_h, b_start)],
            fin_at=[(slot, fin_val, b_start)],
            seq_f=[(slot, jnp.where(b_reuse, seqv, seqv + 1), b_start)],
            has_exp=[(slot, False, b_start)],
            attempts=[(head, s["attempts"][head] + 1, b_start)])
        return up, deltas

    def event_step(s):
        """Advance to the next (time, seq)-minimal finish/preempt/expire."""
        times = jnp.stack([s["pre_at"], s["fin_at"], s["exp_at"]])
        valid = jnp.stack([s["alive"],
                           s["alive"] & (s["job"] >= 0),
                           s["alive"] & (s["job"] < 0) & s["has_exp"]])
        seqs = jnp.stack([s["seq_p"], s["seq_f"], s["seq_e"]])
        tt = jnp.where(valid, times, inf)
        t_min = jnp.min(tt)
        live = jnp.isfinite(t_min)
        sq = jnp.where(valid & (tt == t_min), seqs, BIGI)
        flat = jnp.argmin(sq.reshape(-1)).astype(it)
        kind = flat // V
        v = flat % V
        now = jnp.where(live, t_min, s["now"])
        j = s["job"][v]
        j0 = jnp.clip(j, 0, J - 1)

        k_pre = live & (kind == 0)
        k_fin = live & (kind == 1)
        k_exp = live & (kind == 2)
        defl_now = k_pre & deflate_on & (j >= 0) & ~s["defl"][v]
        kill = k_pre & ~defl_now
        # a slot with job >= 0 always holds an UNFINISHED job (finishing
        # clears vm.job in the same event), so j >= 0 alone decides this —
        # no fin_t read needed (keeping fin_t write-only in the loop lets
        # XLA alias it in place instead of copying (B, J) per step)
        job_running = kill & (j >= 0)

        dvh_kill = jnp.minimum(now - s["launched"][v], s["life"][v])
        dvh_exp = now - s["launched"][v]
        # checkpoint banking: whole (interval + cost) blocks of this
        # attempt's work-equivalent progress (serial: ran with capacity 1)
        ran = jnp.maximum(now - s["att_start"][v], zero)
        w = s["att_w0"][v] + ran * s["cap"][v]
        kck = jnp.floor(w / (ck_i + ck_c)).astype(it).astype(ft)
        len_j = lengths_all[bidx, j0]
        # banked progress comes from the slot's attempt snapshot, not from
        # a ``done`` gather (see the write-only note in assign_step)
        bank = jnp.minimum(s["att_done"][v] + kck * ck_i, len_j)
        sl = jnp.clip(s["stack_len"], 0, V - 1)
        stack_len = s["stack_len"] + job_running.astype(it)
        # deflation: survivor draws a fresh lifetime at the pool cursor
        life_new = pool_all[pidx, jnp.minimum(s["cursor"], P - 1)]
        w0 = s["att_w0"][v] + (now - s["att_start"][v]) * s["cap"][v]
        fin2 = now + (s["fin_at"][v] - now) * s["cap"][v] / dfac
        up = dict(
            now=now, halt=~live,
            pending=k_fin | kill | k_exp,
            n_events=s["n_events"] + live.astype(it),
            n_done=s["n_done"] + k_fin.astype(it),
            seq=s["seq"] + (k_fin | defl_now).astype(it),
            vm_hours=(s["vm_hours"] + jnp.where(kill, dvh_kill, zero)
                      + jnp.where(k_exp, dvh_exp, zero)),
            # kill and expire are mutually exclusive, so exactly one product
            # is billed (the other add is +0.0, exact on non-negative sums)
            dollars=(s["dollars"]
                     + jnp.where(kill,
                                 dvh_kill * launch_price(s["launched"][v]),
                                 zero)
                     + jnp.where(k_exp,
                                 dvh_exp * launch_price(s["launched"][v]),
                                 zero)),
            n_active=s["n_active"] - (kill | k_exp).astype(it),
            n_preempt=s["n_preempt"] + job_running.astype(it),
            n_fail=s["n_fail"] + job_running.astype(it),
            stack_len=stack_len,
            # the serial assign(now) releases idle spares only when ENTERED
            # with an empty queue — snapshot that entry condition per event
            rel_mode=(stack_len == 0) & (s["next_fresh"] >= J),
            cursor=s["cursor"] + defl_now.astype(it),
            exhausted=s["exhausted"] | (defl_now & (s["cursor"] >= P)),
            n_defl=s["n_defl"] + defl_now.astype(it),
            n_launch=s["n_launch"], n_rej=s["n_rej"],
            next_fresh=s["next_fresh"])
        deltas = dict(
            # finish: job completes, VM becomes a hot spare (k_fin and the
            # kill/ckpt-banking flags are mutually exclusive, so the merged
            # ``done`` write picks the branch by flag)
            fin_t=[(j0, now, k_fin)],
            done=[(j0, jnp.where(k_fin, len_j, bank),
                   k_fin | (job_running & ckpt_on))],
            job=[(v, -1, k_fin | kill)],
            exp_at=[(v, now + hot, k_fin)],
            seq_e=[(v, s["seq"], k_fin)],
            has_exp=[(v, k_fin, k_fin | k_exp)],
            # preempt (kill) / expire: slot dies, wall-clock is billed
            alive=[(v, False, kill | k_exp)],
            failures=[(j0, s["failures"][j0] + 1, job_running)],
            # preempted job goes to the FRONT of the queue (serial
            # ``queue.insert(0, .)``), carrying its done-work so the next
            # assign never reads the (J,) ``done`` array
            stack=[(sl, j0, job_running)],
            stack_done=[(sl, jnp.where(ckpt_on, bank, s["att_done"][v]),
                         job_running)],
            # deflation: capacity degrades, segment stretches, survivor
            # draws a fresh lifetime (one deflation per VM life)
            att_w0=[(v, w0, defl_now)],
            att_start=[(v, now, defl_now)],
            fin_at=[(v, fin2, defl_now)],
            cap=[(v, dfac, defl_now)],
            defl=[(v, True, defl_now)],
            pre_at=[(v, now + life_new, defl_now)],
            life=[(v, now + life_new - s["launched"][v], defl_now)],
            seq_p=[(v, s["seq"], defl_now)])
        return up, deltas

    # a lane that has finished (or halted / hit max_steps) freezes: its
    # scalar updates are where'd back to the old value and `active` is
    # AND-ed into every scatter mask, so the shared while_loop below can
    # keep iterating for the stragglers without touching done lanes
    active = (s["n_done"] < J) & ~s["halt"] & (s["steps"] < max_steps)
    sa, da = assign_step(s)
    se, de = event_step(s)
    p = s["pending"]
    out = dict(s)
    out.update({k: jnp.where(active, jnp.where(p, sa[k], se[k]), s[k])
                for k in sa})
    for k in set(da) | set(de):
        arr = s[k]
        for idx, val, flag in da.get(k, ()):
            arr = _setv(arr, idx, val, flag & p & active)
        for idx, val, flag in de.get(k, ()):
            arr = _setv(arr, idx, val, flag & ~p & active)
        out[k] = arr
    out["steps"] = s["steps"] + active.astype(it)
    return out


def _epilogue(s, price_row, price_dt, max_steps):
    """Per-lane exit accounting (vmapped over the final carry)."""
    ft = jnp.result_type(float)
    zero = jnp.asarray(0.0, ft)
    BIGI = jnp.asarray(_BIG, jnp.int32)
    V = s["alive"].shape[0]
    J = s["fin_t"].shape[0]
    Tp = price_row.shape[0]
    # bill still-running VMs in launch (vm_id) order so the sequential
    # float accumulation matches the serial epilogue exactly
    order = jnp.argsort(jnp.where(s["alive"], s["ordv"], BIGI))

    def acc(i, hd):
        h, d = hd
        v = order[i]
        alive = s["alive"][v]
        inc = s["now"] - s["launched"][v]
        k = jnp.clip(jnp.floor(s["launched"][v] / price_dt).astype(jnp.int32),
                     0, Tp - 1)
        return (h + jnp.where(alive, inc, zero),
                d + jnp.where(alive, inc * price_row[k], zero))

    vm_hours, dollars = jax.lax.fori_loop(0, V, acc,
                                          (s["vm_hours"], s["dollars"]))
    makespan = jnp.max(jnp.where(jnp.isnan(s["fin_t"]), s["now"],
                                 s["fin_t"]))
    return dict(
        makespan=makespan, vm_hours=vm_hours, dollars=dollars,
        final_time=s["now"],
        n_preemptions=s["n_preempt"], n_job_failures=s["n_fail"],
        n_deflations=s["n_defl"], n_rejected=s["n_rej"],
        n_launches=s["n_launch"], n_events=s["n_events"],
        steps=s["steps"], n_done=s["n_done"],
        pool_exhausted=s["exhausted"],
        deadlocked=s["halt"] & (s["n_done"] < J),
        truncated=(s["steps"] >= max_steps) & (s["n_done"] < J),
        finished_time=s["fin_t"], failures=s["failures"],
        attempts=s["attempts"], done_work=s["done"],
        rejected=s["rejected"])


@functools.partial(jax.jit, static_argnames=("n_slots",))
def _service_kernel(lane, shared, n_slots):
    # vmap the STEP, not the while_loop.  A vmapped ``lax.while_loop`` runs
    # until every lane's cond is false and re-selects EVERY carry leaf with
    # a full-array ``where(lane_active, new, old)`` each iteration — that
    # select copies the (B, J) job state per step, making the loop O(J) per
    # event.  One un-vmapped loop whose cond is ``any(lane active)`` and
    # whose body is the vmapped per-lane step (each lane gating its own
    # updates, see ``_lane_step``) has the same semantics but lets XLA
    # alias every carry buffer in place: per-step cost stays O(1) in J.
    B = lane["policy"].shape[0]
    J = shared["lengths"].shape[1]
    max_steps = shared["max_steps"]
    step = functools.partial(_lane_step, n_slots=n_slots)

    def body(s):
        return jax.vmap(step, in_axes=(0, None, 0))(lane, shared, s)

    def cond(s):
        return jnp.any((s["n_done"] < J) & ~s["halt"]
                       & (s["steps"] < max_steps))

    out = jax.lax.while_loop(cond, body, _init_state(B, J, n_slots))
    ep = functools.partial(_epilogue, max_steps=max_steps)
    return jax.vmap(ep, in_axes=(0, 0, None))(out, lane["price"],
                                              shared["price_dt"])


# ---------------------------------------------------------------------------
# public batched entry point
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServiceBatchResult:
    """Per-lane outputs of one batched service dispatch (numpy, host-side)."""
    makespan: np.ndarray          # (B,)
    vm_hours: np.ndarray          # (B,)
    dollars: np.ndarray           # (B,) market-priced cost (== vm_hours when
    #                               run without price_rows: unit price rows)
    final_time: np.ndarray        # (B,) last processed event time
    n_preemptions: np.ndarray     # (B,)
    n_job_failures: np.ndarray    # (B,)
    n_deflations: np.ndarray      # (B,)
    n_rejected: np.ndarray        # (B,)
    n_launches: np.ndarray        # (B,)
    n_events: np.ndarray          # (B,) finish+preempt+expire events
    steps: np.ndarray             # (B,) while_loop iterations (incl. assigns)
    pool_exhausted: np.ndarray    # (B,) bool
    deadlocked: np.ndarray        # (B,) bool
    truncated: np.ndarray         # (B,) bool
    finished_time: np.ndarray     # (B, J) NaN = never finished
    failures: np.ndarray          # (B, J)
    attempts: np.ndarray          # (B, J)
    done_work: np.ndarray         # (B, J)
    rejected: np.ndarray          # (B, J) bool
    priced: bool = False          # True when real price_rows were supplied

    def __len__(self) -> int:
        return len(self.makespan)


def simulate_service_batch(
        *, lengths, pools, bag_index, pool_index, policy, cluster_size,
        tables=None, T_values=None, reuse_L: float = 1.0, table_index=None,
        deadlines=None, deflate=None, deflate_factor=0.5,
        checkpointing: bool = False, ckpt_interval: float = 0.5,
        ckpt_cost: float = 1.0 / 60.0,
        relaunch_overhead: float = RELAUNCH_OVERHEAD,
        hot_spare_hours: float = HOT_SPARE_HOURS,
        max_slots: Optional[int] = None, max_steps: Optional[int] = None,
        price_rows=None, price_dt: float = 1.0,
        on_exhausted: str = "raise") -> ServiceBatchResult:
    """Run B service lanes event-synchronously in ONE jitted dispatch.

    Deduplicated inputs (the PR-4 leading-axis convention): ``lengths`` is
    ``(R, J)`` unique bags, ``pools`` ``(Q, P)`` unique lifetime streams,
    ``tables`` ``(U, T, A)`` unique reuse-decision grids (from
    ``engine.ReuseTables.tables``; ``T_values``/``reuse_L`` are its shared
    remaining-work axis and deadline); per-lane ``bag_index`` /
    ``pool_index`` / ``table_index`` gather a lane's slice of each.

    ``policy`` is per-lane int codes (``POLICY_CODES``) or strings;
    ``deadlines`` an optional ``(R, J)`` per-job completion deadline (jobs
    whose estimated completion misses it are rejected at scheduling time);
    ``deflate``/``deflate_factor`` enable the per-lane VM-deflation branch.
    ``price_rows`` is an optional ``(B, Tp)`` (or broadcastable ``(Tp,)``)
    per-lane spot-price trace sampled every ``price_dt`` hours: each VM is
    billed for ALL its vm-hours at its launch-cell price (the serial
    ``BatchService(price_trace=...)`` convention), accumulating a per-lane
    ``dollars`` total bit-identical to the serial loop under x64 on shared
    pools.  Without ``price_rows`` the kernel bills unit prices, so
    ``dollars == vm_hours`` and ``priced`` is False.
    ``on_exhausted="raise"`` fails loudly when any lane consumes its whole
    lifetime pool or exceeds ``max_steps``; ``"flag"`` returns the per-lane
    flags instead.
    """
    lengths = np.atleast_2d(np.asarray(lengths, np.float64))
    pools = np.atleast_2d(np.asarray(pools, np.float64))
    if isinstance(policy, (str, int, np.integer)):
        policy = [policy]
    policy = np.asarray([POLICY_CODES[p] if isinstance(p, str) else int(p)
                         for p in np.atleast_1d(np.asarray(policy, object))],
                        np.int32)
    B = len(policy)
    bag_index = np.broadcast_to(np.asarray(bag_index, np.int32), (B,))
    pool_index = np.broadcast_to(np.asarray(pool_index, np.int32), (B,))
    cluster_size = np.broadcast_to(np.asarray(cluster_size, np.int32), (B,))
    if np.any(bag_index < 0) or np.any(bag_index >= len(lengths)):
        raise ValueError("bag_index out of range")
    if np.any(pool_index < 0) or np.any(pool_index >= len(pools)):
        raise ValueError("pool_index out of range")
    if np.any(cluster_size < 1):
        raise ValueError("cluster_size must be >= 1")
    if tables is None:
        if np.any(policy == POLICY_MODEL):
            raise ValueError("model-policy lanes need tables= (an "
                             "engine.ReuseTables tensor) and T_values=")
        tables = np.zeros((1, 1, 1), bool)
        T_values = np.zeros((1,), np.float64)
        table_index = np.zeros((B,), np.int32)
    else:
        tables = np.asarray(tables, bool)
        T_values = np.asarray(T_values, np.float64)
        if tables.ndim != 3 or tables.shape[1] != len(T_values):
            raise ValueError("tables must be (U, len(T_values), n_age)")
        table_index = (np.zeros((B,), np.int32) if table_index is None
                       else np.broadcast_to(
                           np.asarray(table_index, np.int32), (B,)))
        if np.any(table_index < 0) or np.any(table_index >= len(tables)):
            raise ValueError("table_index out of range")
    if deadlines is None:
        deadlines = np.full(lengths.shape, np.inf)
    else:
        deadlines = np.broadcast_to(
            np.asarray(deadlines, np.float64), lengths.shape)
    deflate = (np.zeros((B,), bool) if deflate is None
               else np.broadcast_to(np.asarray(deflate, bool), (B,)))
    dfac = np.broadcast_to(np.asarray(deflate_factor, np.float64), (B,))
    if np.any(deflate & ((dfac <= 0.0) | (dfac > 1.0))):
        raise ValueError("deflate_factor must be in (0, 1] on deflate lanes")
    if checkpointing and ckpt_interval <= 0:
        raise ValueError("ckpt_interval must be positive")
    priced = price_rows is not None
    if priced:
        price_rows = np.atleast_2d(np.asarray(price_rows, np.float64))
        if price_rows.shape[0] == 1:
            price_rows = np.broadcast_to(price_rows, (B, price_rows.shape[1]))
        if price_rows.shape[0] != B or price_rows.shape[1] == 0:
            raise ValueError("price_rows must be (B, Tp) or (Tp,)")
        if not np.all(price_rows > 0):
            raise ValueError("price_rows must be strictly positive")
        if not float(price_dt) > 0:
            raise ValueError("price_dt must be > 0")
    else:
        price_rows = np.ones((B, 1), np.float64)

    V = int(max_slots) if max_slots is not None else int(cluster_size.max())
    if V < int(cluster_size.max()):
        raise ValueError("max_slots must cover the largest cluster_size")
    J, P = lengths.shape[1], pools.shape[1]
    if max_steps is None:
        max_steps = 8 * (J + P) + 16 * V + 64

    ft = jnp.result_type(float)
    lane = dict(
        bag_index=jnp.asarray(bag_index), pool_index=jnp.asarray(pool_index),
        table_index=jnp.asarray(table_index), policy=jnp.asarray(policy),
        cluster_size=jnp.asarray(cluster_size), deflate=jnp.asarray(deflate),
        deflate_factor=jnp.asarray(dfac, ft),
        price=jnp.asarray(price_rows, ft))
    shared = dict(
        lengths=jnp.asarray(lengths, ft), deadlines=jnp.asarray(deadlines, ft),
        pools=jnp.asarray(pools, ft), tables=jnp.asarray(tables),
        T_values=jnp.asarray(T_values, ft),
        reuse_L=jnp.asarray(float(reuse_L), ft),
        relaunch_overhead=jnp.asarray(float(relaunch_overhead), ft),
        hot_spare_hours=jnp.asarray(float(hot_spare_hours), ft),
        ckpt_on=jnp.asarray(bool(checkpointing)),
        ckpt_interval=jnp.asarray(float(ckpt_interval), ft),
        ckpt_cost=jnp.asarray(float(ckpt_cost), ft),
        price_dt=jnp.asarray(float(price_dt), ft),
        max_steps=jnp.asarray(int(max_steps), jnp.int32))
    out = {k: np.asarray(v) for k, v in
           _service_kernel(lane, shared, V).items()}
    res = ServiceBatchResult(
        makespan=out["makespan"], vm_hours=out["vm_hours"],
        dollars=out["dollars"], priced=priced,
        final_time=out["final_time"], n_preemptions=out["n_preemptions"],
        n_job_failures=out["n_job_failures"], n_deflations=out["n_deflations"],
        n_rejected=out["n_rejected"], n_launches=out["n_launches"],
        n_events=out["n_events"], steps=out["steps"],
        pool_exhausted=out["pool_exhausted"], deadlocked=out["deadlocked"],
        truncated=out["truncated"], finished_time=out["finished_time"],
        failures=out["failures"], attempts=out["attempts"],
        done_work=out["done_work"], rejected=out["rejected"])
    if on_exhausted == "raise":
        if res.pool_exhausted.any():
            raise RuntimeError(
                f"service lifetime pool exhausted on lanes "
                f"{np.flatnonzero(res.pool_exhausted).tolist()}; increase "
                f"pool_size (P={P})")
        if res.truncated.any():
            raise RuntimeError(
                f"service kernel hit max_steps={max_steps} on lanes "
                f"{np.flatnonzero(res.truncated).tolist()}")
    elif on_exhausted != "flag":
        raise ValueError("on_exhausted must be 'raise' or 'flag'")
    return res


# ---------------------------------------------------------------------------
# grid-cell driver shared by service.run_bag_grid and scenarios.sweep_service
# ---------------------------------------------------------------------------

def run_cells_batched(*, cells: Sequence[dict], dists: Sequence,
                      lengths_by_seed: dict, reuse_tables=None,
                      pool_size: int = 4096, deadline_hours=None,
                      deflate_factor: float = 0.5,
                      checkpointing: bool = False, ckpt_interval: float = 0.5,
                      ckpt_cost: float = 1.0 / 60.0,
                      return_jobs: bool = False,
                      price_rows=None, price_dt: float = 1.0,
                      on_exhausted: str = "raise") -> list:
    """Run a list of grid cells through ONE batched kernel dispatch.

    Each cell is ``dict(dist_index, vm_type, policy, cluster_size, seed)``
    (policy may carry a ``"+deflate"`` suffix).  ``dists[dist_index]`` is
    the cell's lifetime model, ``lengths_by_seed[seed]`` its bag;
    ``reuse_tables`` an :class:`engine.ReuseTables` aligned with ``dists``
    (required iff any cell runs the model policy).  Lifetime pools are
    drawn once per unique ``(dist_index, seed)`` pair — the same per-seed
    reference streams the serial ``BatchService`` consumes, which is what
    makes serial-vs-batched comparisons bit-identical under x64.  Returns
    ``run_bag_grid``-style rows (cell coords + :class:`ServiceResult`).
    """
    cells = list(cells)
    if not cells:
        return []
    dists = list(dists)
    seeds_order = list(dict.fromkeys(c["seed"] for c in cells))
    bag_pos = {s: i for i, s in enumerate(seeds_order)}
    lengths = np.stack([np.asarray(lengths_by_seed[s], np.float64)
                        for s in seeds_order])
    pairs = list(dict.fromkeys((c["dist_index"], c["seed"]) for c in cells))
    pool_pos = {p: i for i, p in enumerate(pairs)}
    pool_mat = draw_service_pool_batch([dists[di] for di, _ in pairs],
                                       [s for _, s in pairs], size=pool_size)
    parsed = [split_policy(c["policy"]) for c in cells]
    tables = T_values = None
    reuse_L = 1.0
    if any(base == "model" for base, _ in parsed):
        if reuse_tables is None:
            raise ValueError("model-policy cells need reuse_tables=")
        tables, T_values = reuse_tables.tables, reuse_tables.T_values
        reuse_L = reuse_tables.L
    deadlines = (None if deadline_hours is None
                 else np.full(lengths.shape, float(deadline_hours)))
    res = simulate_service_batch(
        lengths=lengths, pools=pool_mat,
        bag_index=[bag_pos[c["seed"]] for c in cells],
        pool_index=[pool_pos[(c["dist_index"], c["seed"])] for c in cells],
        policy=[base for base, _ in parsed],
        cluster_size=[c["cluster_size"] for c in cells],
        tables=tables, T_values=T_values, reuse_L=reuse_L,
        table_index=[c["dist_index"] for c in cells],
        deadlines=deadlines, deflate=[d for _, d in parsed],
        deflate_factor=deflate_factor, checkpointing=checkpointing,
        ckpt_interval=ckpt_interval, ckpt_cost=ckpt_cost,
        price_rows=price_rows, price_dt=price_dt,
        on_exhausted=on_exhausted)
    rows = []
    for i, cell in enumerate(cells):
        bag = lengths[bag_pos[cell["seed"]]]
        rows.append(dict(vm_type=cell["vm_type"], policy=cell["policy"],
                         cluster_size=cell["cluster_size"], seed=cell["seed"],
                         result=lane_result(res, i, bag, cell["vm_type"],
                                            jobs=return_jobs)))
    return rows


def lane_result(res: ServiceBatchResult, i: int, bag_lengths, vm_type: str,
                *, jobs: bool = False) -> ServiceResult:
    """Package lane ``i`` as a serial-compatible :class:`ServiceResult`.

    The cost expressions mirror ``BatchService.run``'s epilogue exactly
    (same numpy float64 host arithmetic), so under x64 the whole row is
    bit-identical to the serial loop on a shared pool.  ``jobs=True``
    additionally materializes per-job :class:`Job` records (``started`` /
    ``attempt_started`` are not tracked by the kernel and stay ``None``).
    """
    vm_hours = float(res.vm_hours[i])
    price = PRICES_PREEMPTIBLE[vm_type]
    od_price = PRICES_ON_DEMAND[vm_type]
    cost = vm_hours * price
    # market dollars: the kernel's accumulated launch-cell billing when a
    # price trace was supplied, else the flat-price cost — the same
    # fallback as the serial epilogue
    dollars = float(res.dollars[i]) if res.priced else cost
    total_work = float(np.sum([float(l) for l in bag_lengths]))
    job_list = []
    if jobs:
        for j, l in enumerate(bag_lengths):
            fin = res.finished_time[i, j]
            job_list.append(Job(
                j, float(l), finished=None if np.isnan(fin) else float(fin),
                attempts=int(res.attempts[i, j]),
                failures=int(res.failures[i, j]),
                done_work=float(res.done_work[i, j])))
    return ServiceResult(
        makespan=float(res.makespan[i]), vm_hours=vm_hours,
        cost=cost, on_demand_cost=total_work * od_price,
        n_preemptions=int(res.n_preemptions[i]),
        n_job_failures=int(res.n_job_failures[i]), jobs=job_list,
        n_deflations=int(res.n_deflations[i]),
        n_rejected=int(res.n_rejected[i]), dollars=dollars)
