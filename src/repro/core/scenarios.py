"""Scenario registry + vectorized sweep runner on top of the engine.

PR 1 removed the simulation bottleneck; this module turns the single static
per-VM-type evaluation into *scenario diversity*: a scenario names a market
condition — VM type x diurnal launch phase (paper Obs. 5), with optional
parameter overrides — and resolves to a :class:`~repro.core.distributions.
DiurnalConstrained` model.  The sweep runners expand

    (scenario x policy x seed)                 checkpointing executor grids
    (scenario x policy x cluster_size x seed)  batch-service grids

and drive ``engine.simulate_makespan_batch`` / ``service.run_bag_grid`` with
the expensive per-distribution setup shared across each scenario's cell
group: one DP solve + one policy table set + one pre-drawn lifetime pool per
(scenario, seed) for the executor, one jitted :class:`engine.ReuseTable`
grid call per scenario for the service.

Adding a scenario is one :func:`register` call (see ROADMAP "Scenario
sweeps"); ``benchmarks/scenario_sweep.py`` turns the default grid into the
machine-readable ``BENCH_scenarios.json`` perf artifact.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterable, Mapping, Optional, Sequence

import numpy as np

from . import distributions as dists
from . import engine
from . import service as service_mod
from .policies import checkpointing as ckpt
from .policies import young_daly as yd

__all__ = [
    "Scenario", "register", "get", "names", "default_grid",
    "sweep_checkpointing", "sweep_service", "PHASE_CLOCKS",
]

# Wall-clock launch hour per diurnal phase label.  "day" is the busiest
# launch hour (the DiurnalConstrained peak), "night" the quietest, 12 h
# away; "shoulder" sits at the zero crossing (= the static fit).
PHASE_CLOCKS: Dict[str, float] = {"day": 20.0, "night": 8.0, "shoulder": 14.0}


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One named market condition the policies are evaluated against."""

    name: str
    vm_type: str = "n1-highcpu-16"
    phase: str = "shoulder"            # diurnal label (see PHASE_CLOCKS)
    launch_clock: Optional[float] = None  # overrides the phase's clock
    dist_kwargs: Mapping = dataclasses.field(default_factory=dict)
    description: str = ""

    @property
    def clock(self) -> float:
        if self.launch_clock is not None:
            return float(self.launch_clock)
        return PHASE_CLOCKS[self.phase]

    def dist(self) -> dists.DiurnalConstrained:
        """The scenario's resolved lifetime model (full pytree contract, so
        the DP solver, ReuseTable and lifetime pools work unchanged)."""
        return dists.diurnal_for(self.vm_type, self.clock,
                                 **dict(self.dist_kwargs))

    def coords(self) -> dict:
        """Grid coordinates every sweep row is tagged with."""
        return dict(scenario=self.name, vm_type=self.vm_type,
                    phase=self.phase, launch_clock=self.clock)


_REGISTRY: Dict[str, Scenario] = {}


def register(scenario: Scenario, *, replace: bool = False) -> Scenario:
    if not replace and scenario.name in _REGISTRY:
        raise ValueError(f"scenario {scenario.name!r} already registered")
    _REGISTRY[scenario.name] = scenario
    return scenario


def get(name: str) -> Scenario:
    return _REGISTRY[name]


def names() -> list:
    return sorted(_REGISTRY)


def default_grid(vm_types: Sequence[str] = ("n1-highcpu-16", "n1-highcpu-32"),
                 phases: Sequence[str] = ("day", "night")) -> list:
    """The (vm_type x diurnal phase) product as a list of scenarios (shared
    with the registry; repeated calls return the same objects)."""
    out = []
    for vm_type, phase in itertools.product(vm_types, phases):
        name = f"{phase}/{vm_type}"
        if name not in _REGISTRY:
            register(Scenario(
                name=name, vm_type=vm_type, phase=phase,
                description=f"{vm_type} launched at the {phase} clock "
                            f"({PHASE_CLOCKS[phase]:.0f}h)"))
        out.append(_REGISTRY[name])
    return out


def _resolve(scenarios) -> list:
    return [get(s) if isinstance(s, str) else s for s in scenarios]


# ---------------------------------------------------------------------------
# checkpointing-executor sweep
# ---------------------------------------------------------------------------

_CKPT_POLICY_BUILDERS = ("dp", "young_daly", "none")


def _policy_tables(policy: str, tables: ckpt.DPTables, job_steps: int,
                   grid_dt: float, delta_steps: int, dist):
    if policy == "dp":
        return engine.dp_policy_table(tables)
    if policy == "young_daly":
        # paper Fig. 7 baseline setup, per scenario: the MTTF implied by
        # THIS distribution's initial failure rate (a day-phase launch has
        # a faster initial phase and therefore a shorter YD interval), with
        # the sweep's actual checkpoint-write cost delta
        tau = float(yd.interval(delta_steps * grid_dt,
                                yd.mttf_from_initial_rate(dist)))
        tau_steps = max(1, int(round(tau / grid_dt)))
        return engine.young_daly_policy_table(tau_steps, job_steps)
    if policy == "none":
        return engine.no_checkpoint_policy_table(job_steps)
    raise ValueError(f"unknown checkpointing policy {policy!r}; "
                     f"choose from {_CKPT_POLICY_BUILDERS}")


def sweep_checkpointing(scenarios: Iterable, *,
                        policies: Sequence[str] = ("dp", "young_daly", "none"),
                        seeds: Sequence[int] = (0,), job_steps: int = 300,
                        n_trials: int = 1000, grid_dt: float = 1.0 / 60.0,
                        delta_steps: int = 1, max_restarts: int = 64,
                        restart_overhead: float = 0.0,
                        n_sweeps: int = 3) -> list:
    """Expand (scenario x policy x seed) over the vectorized executor.

    Per scenario: ONE DP solve, one table per policy and one pre-drawn
    device lifetime pool per seed, shared by every policy — so the grid cost
    is dominated by the batched kernel runs, not per-cell setup.  Returns a
    list of dict rows (one per cell) with makespan statistics and the
    unfinished-trial fraction (truncated trials are NaN-flagged by the
    engine, never silently averaged in).
    """
    rows = []
    for sc in _resolve(scenarios):
        dist = sc.dist()
        tables = ckpt.solve(dist, job_steps, grid_dt=grid_dt,
                            delta_steps=delta_steps, n_sweeps=n_sweeps,
                            restart_overhead=restart_overhead)
        ptables = {p: _policy_tables(p, tables, job_steps, grid_dt,
                                     delta_steps, dist)
                   for p in policies}
        lifetimes_fn = ckpt.model_lifetimes_fn(dist)
        # single-attempt failure probability of the whole job on a fresh VM —
        # the scenario's Obs. 5 "how gentle is this launch phase" scalar
        p_fail_fresh = float(dist.cdf(job_steps * grid_dt))
        for seed in seeds:
            first, pool = engine.draw_lifetime_pool(
                lifetimes_fn, n_trials, max_restarts=max_restarts, seed=seed)
            for policy in policies:
                mk, finished = engine.simulate_makespan_batch(
                    ptables[policy], job_steps, first=first, pool=pool,
                    grid_dt=grid_dt, delta_steps=delta_steps,
                    restart_overhead=restart_overhead,
                    max_restarts=max_restarts, unfinished="nan",
                    return_finished=True)
                ok = mk[finished]
                rows.append(dict(
                    sc.coords(), policy=policy, seed=seed,
                    n_trials=n_trials, job_steps=job_steps,
                    p_fail_fresh=p_fail_fresh,
                    expected_makespan_dp=tables.expected_makespan(job_steps),
                    makespan_mean=float(ok.mean()) if ok.size else float("nan"),
                    makespan_p50=float(np.median(ok)) if ok.size else float("nan"),
                    makespan_p95=float(np.percentile(ok, 95)) if ok.size else float("nan"),
                    unfinished_frac=float(1.0 - finished.mean())))
    return rows


# ---------------------------------------------------------------------------
# batch-service sweep
# ---------------------------------------------------------------------------

def sweep_service(scenarios: Iterable, *,
                  policies: Sequence[str] = ("model", "memoryless"),
                  cluster_sizes: Sequence[int] = (16,),
                  seeds: Sequence[int] = (0,), n_jobs: int = 40,
                  job_hours: float = 2.0, jitter: float = 0.1, **kw) -> list:
    """Expand (scenario x policy x cluster_size x seed) over the batch
    service.  Each scenario's cell group goes through ``service.
    run_bag_grid``, which evaluates the model policy's reuse decisions in a
    single jitted ReuseTable grid call shared across all of that scenario's
    cells.  Returns flat dict rows with the headline service metrics.
    """
    rows = []
    for sc in _resolve(scenarios):
        dist = sc.dist()
        grid = service_mod.run_bag_grid(
            vm_types=(sc.vm_type,), policies=tuple(policies),
            cluster_sizes=tuple(cluster_sizes), seeds=tuple(seeds),
            n_jobs=n_jobs, job_hours=job_hours, jitter=jitter,
            dist_for=lambda _vm_type: dist, **kw)
        for cell in grid:
            r = cell["result"]
            rows.append(dict(
                sc.coords(), policy=cell["policy"],
                cluster_size=cell["cluster_size"], seed=cell["seed"],
                n_jobs=n_jobs, job_hours=job_hours,
                makespan=r.makespan, vm_hours=r.vm_hours, cost=r.cost,
                on_demand_cost=r.on_demand_cost,
                cost_reduction=r.cost_reduction,
                n_preemptions=r.n_preemptions,
                n_job_failures=r.n_job_failures,
                job_failure_rate=r.n_job_failures / max(n_jobs, 1)))
    return rows
