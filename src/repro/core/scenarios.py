"""Scenario registry + vectorized sweep runner on top of the engine.

PR 1 removed the simulation bottleneck; this module turns the single static
per-VM-type evaluation into *scenario diversity*: a scenario names a market
condition — zone x diurnal launch phase x VM type (paper Obs. 5 plus the
ZONE_PARAMS capacity-pressure regimes), with optional parameter overrides —
and resolves to a :class:`~repro.core.distributions.DiurnalConstrained`
model.  The sweep runners expand

    (scenario x policy x seed)                 checkpointing executor grids
    (scenario x policy x cluster_size x seed)  batch-service grids

over the batched engine entry points.

Sweep execution modes and their equivalence contract
----------------------------------------------------
:func:`sweep_checkpointing` runs the same grid three ways, orderable by how
much of it is folded into the leading batch axis (the engine's leading-axis
convention):

  * ``mode="batched"`` (default, PR 4) — the ONE-KERNEL path: the whole
    (scenario x policy x seed) grid is flattened to a cell axis
    ``B = S*P*R``; one ``checkpointing.solve_batch`` call solves every DP,
    one ``engine.draw_lifetime_pool_batch`` call draws every (scenario,
    seed) pool from per-cell seeds, policy tables of differing provenance
    are stacked by ``engine.stack_policy_tables``, and a SINGLE
    scenario-batched executor dispatch produces every cell's makespans,
    which are then unflattened back to labeled rows.
  * ``mode="grouped"`` — the PR-3 path: scenario axis batched, but the
    (seed x policy) cell groups still loop in Python (P*R executor
    dispatches).  Retained as the timed reference the one-kernel fold is
    benchmarked against.
  * ``mode="serial"`` — the per-scenario reference path (one DP solve + one
    numpy pool round-trip + one executor call per cell group, scenario by
    scenario).  This is the semantic ground truth.

All three modes emit identical row order and schema.  Equivalence contract
(enforced by ``tests/test_batched.py`` / ``tests/test_scenarios.py``): DP
tables and derived scalars (``expected_makespan_dp``, ``p_fail_fresh``) are
bit-exact across modes at any dtype; with x64 enabled the makespan
statistics are bit-identical row-for-row too, because each folded lane then
reproduces the serial cell's IEEE operations exactly (see the engine module
docstring).  In default float32 mode rows agree to the pool's float32
inverse-CDF rounding, far below Monte-Carlo noise.  Truncated trials are
NaN-flagged by the engine and excluded from row statistics, never silently
averaged in; ``unfinished_frac`` records them per row in every mode.

Adding a scenario is one :func:`register` call (see ROADMAP "Scenario
sweeps"); ``benchmarks/scenario_sweep.py`` turns the default grid into the
machine-readable ``BENCH_scenarios.json`` perf artifact (see
``docs/bench_schemas.md``).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterable, Mapping, Optional, Sequence

import numpy as np

from . import distributions as dists
from . import engine
from . import service as service_mod
from .policies import checkpointing as ckpt
from .policies import young_daly as yd

__all__ = [
    "Scenario", "register", "get", "names", "default_grid",
    "sweep_checkpointing", "sweep_service", "sweep_market",
    "solve_market_tables", "PHASE_CLOCKS", "ZONE_PARAMS",
]

# Wall-clock launch hour per diurnal phase label.  "day" is the busiest
# launch hour (the DiurnalConstrained peak), "night" the quietest, 12 h
# away; "shoulder" sits at the zero crossing (= the static fit).
PHASE_CLOCKS: Dict[str, float] = {"day": 20.0, "night": 8.0, "shoulder": 14.0}

# Per-zone parameter regimes (CloudSim-Plus-style market diversity): zones
# differ in capacity pressure, scaling the Eq. 1 initial-phase severity.
# ``A_scale`` multiplies the type's fitted A (more pressure -> more
# preemptions), ``tau1_scale`` the initial-phase time constant (more
# pressure -> faster decay onto the young-VM wall).  The paper's fits are
# from us-east1-b, which is therefore the identity zone.
ZONE_PARAMS: Dict[str, Dict[str, float]] = {
    "us-east1-b": dict(A_scale=1.0, tau1_scale=1.0),
    "us-central1-a": dict(A_scale=1.08, tau1_scale=0.85),   # tighter market
    "europe-west1-d": dict(A_scale=0.92, tau1_scale=1.20),  # slacker market
}


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One named market condition the policies are evaluated against."""

    name: str
    vm_type: str = "n1-highcpu-16"
    phase: str = "shoulder"            # diurnal label (see PHASE_CLOCKS)
    zone: str = "us-east1-b"           # parameter regime (see ZONE_PARAMS)
    launch_clock: Optional[float] = None  # overrides the phase's clock
    dist_kwargs: Mapping = dataclasses.field(default_factory=dict)
    description: str = ""
    # a live fitted distribution (e.g. the closed-loop runtime's latest
    # Eq. 1 refit) served verbatim instead of the catalog resolution —
    # lets an online model participate in sweeps alongside catalog regimes
    dist_override: Optional[object] = None

    @property
    def clock(self) -> float:
        if self.launch_clock is not None:
            return float(self.launch_clock)
        return PHASE_CLOCKS[self.phase]

    def dist(self):
        """The scenario's resolved lifetime model (full pytree contract, so
        the DP solver, ReuseTable and lifetime pools work unchanged).  The
        zone's capacity-pressure scaling is applied to the type's base
        Eq. 1 fit before any explicit ``dist_kwargs`` overrides; a
        ``dist_override`` (a live fitted model) short-circuits all of it."""
        if self.dist_override is not None:
            return self.dist_override
        zone = ZONE_PARAMS[self.zone]
        base = dists.VM_TYPE_PARAMS[self.vm_type]
        kw = dict(A=base["A"] * zone["A_scale"],
                  tau1=base["tau1"] * zone["tau1_scale"])
        kw.update(self.dist_kwargs)
        return dists.diurnal_for(self.vm_type, self.clock, **kw)

    def coords(self) -> dict:
        """Grid coordinates every sweep row is tagged with."""
        return dict(scenario=self.name, vm_type=self.vm_type,
                    phase=self.phase, zone=self.zone, launch_clock=self.clock)


_REGISTRY: Dict[str, Scenario] = {}


def register(scenario: Scenario, *, overwrite: bool = False,
             replace: Optional[bool] = None) -> Scenario:
    """Add a scenario to the global registry.  Re-registering a taken name
    raises unless ``overwrite=True`` — a silent clobber would invalidate
    any grid that already resolved the old definition.  ``replace`` is the
    deprecated pre-PR-3 spelling of the same flag."""
    if replace is not None:
        overwrite = replace
    if not overwrite and scenario.name in _REGISTRY:
        raise ValueError(f"scenario {scenario.name!r} already registered "
                         f"(pass overwrite=True to replace it)")
    _REGISTRY[scenario.name] = scenario
    return scenario


def get(name: str) -> Scenario:
    return _REGISTRY[name]


def names() -> list:
    return sorted(_REGISTRY)


def default_grid(vm_types: Sequence[str] = ("n1-highcpu-16", "n1-highcpu-32"),
                 phases: Sequence[str] = ("day", "night"),
                 zones: Sequence[str] = ("us-east1-b", "us-central1-a"),
                 ) -> list:
    """The (zone x diurnal phase x vm_type) product as a list of scenarios
    (shared with the registry; repeated calls return the same objects).
    The default product is 2 x 2 x 2 = 8 scenarios."""
    out = []
    for zone, phase, vm_type in itertools.product(zones, phases, vm_types):
        name = f"{zone}/{phase}/{vm_type}"
        if name not in _REGISTRY:
            register(Scenario(
                name=name, vm_type=vm_type, phase=phase, zone=zone,
                description=f"{vm_type} in {zone} launched at the {phase} "
                            f"clock ({PHASE_CLOCKS[phase]:.0f}h)"))
        out.append(_REGISTRY[name])
    return out


def _resolve(scenarios) -> list:
    return [get(s) if isinstance(s, str) else s for s in scenarios]


# ---------------------------------------------------------------------------
# checkpointing-executor sweep
# ---------------------------------------------------------------------------

_CKPT_POLICY_BUILDERS = ("dp", "young_daly", "none")


def _policy_tables(policy: str, tables: ckpt.DPTables, job_steps: int,
                   grid_dt: float, delta_steps: int, dist):
    if policy == "dp":
        return engine.dp_policy_table(tables)
    if policy == "young_daly":
        # paper Fig. 7 baseline setup, per scenario: the MTTF implied by
        # THIS distribution's initial failure rate (a day-phase launch has
        # a faster initial phase and therefore a shorter YD interval), with
        # the sweep's actual checkpoint-write cost delta
        tau = float(yd.interval(delta_steps * grid_dt,
                                yd.mttf_from_initial_rate(dist)))
        tau_steps = max(1, int(round(tau / grid_dt)))
        return engine.young_daly_policy_table(tau_steps, job_steps)
    if policy == "none":
        return engine.no_checkpoint_policy_table(job_steps)
    raise ValueError(f"unknown checkpointing policy {policy!r}; "
                     f"choose from {_CKPT_POLICY_BUILDERS}")


def _policy_tables_batch(policy: str, batch: "ckpt.BatchDPTables",
                         job_steps: int, grid_dt: float, delta_steps: int,
                         dist_list):
    """Scenario-stacked policy tables for the batched executor: (S, ...) for
    per-scenario policies, a plain 2-D table for scenario-independent ones
    (the executor broadcasts it)."""
    if policy == "dp":
        return np.asarray(batch.K, np.int32)
    if policy == "young_daly":
        # per scenario, as in the serial path: the YD interval implied by
        # THIS scenario's initial failure rate
        tabs = []
        for dist in dist_list:
            tau = float(yd.interval(delta_steps * grid_dt,
                                    yd.mttf_from_initial_rate(dist)))
            tau_steps = max(1, int(round(tau / grid_dt)))
            tabs.append(engine.young_daly_policy_table(tau_steps, job_steps))
        return np.stack(tabs)
    if policy == "none":
        return engine.no_checkpoint_policy_table(job_steps)   # shared 2-D
    raise ValueError(f"unknown checkpointing policy {policy!r}; "
                     f"choose from {_CKPT_POLICY_BUILDERS}")


def _ckpt_row(sc, policy, seed, mk, finished, *, n_trials, job_steps,
              p_fail_fresh, expected_makespan_dp):
    ok = mk[finished]
    return dict(
        sc.coords(), policy=policy, seed=seed,
        n_trials=n_trials, job_steps=job_steps,
        p_fail_fresh=p_fail_fresh,
        expected_makespan_dp=expected_makespan_dp,
        makespan_mean=float(ok.mean()) if ok.size else float("nan"),
        makespan_p50=float(np.median(ok)) if ok.size else float("nan"),
        makespan_p95=float(np.percentile(ok, 95)) if ok.size else float("nan"),
        unfinished_frac=float(1.0 - finished.mean()))


def sweep_checkpointing(scenarios: Iterable, *,
                        policies: Sequence[str] = ("dp", "young_daly", "none"),
                        seeds: Sequence[int] = (0,), job_steps: int = 300,
                        n_trials: int = 1000, grid_dt: float = 1.0 / 60.0,
                        delta_steps: int = 1, max_restarts: int = 64,
                        restart_overhead: float = 0.0,
                        n_sweeps: int = 3, mode: str = "batched",
                        tables: Optional["ckpt.BatchDPTables"] = None,
                        solver_backend: str = "auto",
                        solver_refine: bool = False) -> list:
    """Expand (scenario x policy x seed) over the vectorized executor.

    ``mode="batched"`` (default) folds the WHOLE grid into the engine's
    leading batch axis and dispatches one compiled executor call for all
    ``B = S*P*R`` cells: one ``checkpointing.solve_batch`` DP call, one
    ``engine.draw_lifetime_pool_batch`` call drawing every (scenario, seed)
    pool from per-cell seeds, one ``engine.stack_policy_tables`` stack of
    the per-cell policy tables, one kernel dispatch, then unflattening back
    to labeled rows.  Cell ``b`` of the flat axis is the row-order index
    ``(s*R + r)*P + p`` (scenario outer, seed, policy inner), and its pool
    is shared across the P policies of the same (scenario, seed) — exactly
    the sharing the serial path expresses with its nested loops.

    ``mode="grouped"`` is the PR-3 path this replaced — scenario axis
    batched, (seed x policy) cell groups looped in Python — retained as the
    timed comparison point for ``benchmarks/scenario_sweep.py``.
    ``mode="serial"`` is the per-scenario reference path (one solve + one
    numpy pool round-trip per scenario): the semantic ground truth.

    Row order and schema are identical in all modes; the equivalence
    contract between them (bit-exact DP scalars always; bit-identical rows
    under x64; float32-rounding-close otherwise) is stated in the module
    docstring and enforced by the test suite.  Truncated trials are
    NaN-flagged by the engine, never silently averaged in.

    ``tables`` (batched/grouped modes) reuses a previously solved
    ``checkpointing.BatchDPTables`` for this scenario list, skipping the DP
    solve entirely — the whole-grid *re-evaluation* path (fresh seeds,
    trial counts or policies against fixed market models) then costs only
    the pool draw and the single executor dispatch.

    ``solver_backend``/``solver_refine`` pass straight through to
    ``checkpointing.solve_batch`` (batched/grouped modes; the serial
    reference path always runs the reference kernel) — see
    ``docs/solver.md``.
    """
    if mode not in ("batched", "grouped", "serial"):
        raise ValueError(f"mode must be 'batched', 'grouped' or 'serial', "
                         f"got {mode!r}")
    scs = _resolve(scenarios)          # once: scenarios may be a generator
    if tables is not None:
        if mode == "serial":
            raise ValueError("tables= reuse is for the batched/grouped "
                             "modes; the serial reference path always "
                             "re-solves")
        if len(tables) != len(scs) or tables.K.shape[1] != job_steps + 1:
            raise ValueError(
                f"tables has {len(tables)} scenarios x j_max "
                f"{tables.K.shape[1] - 1}; this sweep needs "
                f"{len(scs)} x {job_steps}")
        if tables.delta_steps != delta_steps \
                or abs(tables.grid_dt - grid_dt) > 1e-12 \
                or tables.restart_overhead != restart_overhead:
            raise ValueError("tables was solved for a different "
                             "(grid_dt, delta_steps, restart_overhead) "
                             "workload")
    rows = []
    if mode == "serial":
        for sc in scs:
            dist = sc.dist()
            tables = ckpt.solve(dist, job_steps, grid_dt=grid_dt,
                                delta_steps=delta_steps, n_sweeps=n_sweeps,
                                restart_overhead=restart_overhead)
            ptables = {p: _policy_tables(p, tables, job_steps, grid_dt,
                                         delta_steps, dist)
                       for p in policies}
            lifetimes_fn = ckpt.model_lifetimes_fn(dist)
            # single-attempt failure probability of the whole job on a fresh
            # VM — the scenario's Obs. 5 "how gentle is this phase" scalar
            p_fail_fresh = float(dist.cdf(job_steps * grid_dt))
            for seed in seeds:
                first, pool = engine.draw_lifetime_pool(
                    lifetimes_fn, n_trials, max_restarts=max_restarts,
                    seed=seed)
                for policy in policies:
                    mk, finished = engine.simulate_makespan_batch(
                        ptables[policy], job_steps, first=first, pool=pool,
                        grid_dt=grid_dt, delta_steps=delta_steps,
                        restart_overhead=restart_overhead,
                        max_restarts=max_restarts, unfinished="nan",
                        return_finished=True)
                    rows.append(_ckpt_row(
                        sc, policy, seed, mk, finished, n_trials=n_trials,
                        job_steps=job_steps, p_fail_fresh=p_fail_fresh,
                        expected_makespan_dp=tables.expected_makespan(job_steps)))
        return rows

    dist_list = [sc.dist() for sc in scs]
    batch = tables if tables is not None else ckpt.solve_batch(
        dist_list, job_steps, grid_dt=grid_dt, delta_steps=delta_steps,
        n_sweeps=n_sweeps, restart_overhead=restart_overhead,
        backend=solver_backend, refine=solver_refine)
    ptables = {p: _policy_tables_batch(p, batch, job_steps, grid_dt,
                                       delta_steps, dist_list)
               for p in policies}
    p_fail_fresh = [float(d.cdf(job_steps * grid_dt)) for d in dist_list]
    S, P, R = len(scs), len(policies), len(seeds)

    if mode == "grouped":
        cells = {}
        for seed in seeds:
            first, pool = engine.draw_lifetime_pool_batch(
                dist_list, n_trials, max_restarts=max_restarts, seed=seed)
            for policy in policies:
                mk, finished = engine.simulate_makespan_batch(
                    ptables[policy], job_steps, first=first, pool=pool,
                    grid_dt=grid_dt, delta_steps=delta_steps,
                    restart_overhead=restart_overhead,
                    max_restarts=max_restarts, unfinished="nan",
                    return_finished=True)
                cells[seed, policy] = (mk, finished)
        for s, sc in enumerate(scs):             # serial-compatible row order
            for seed in seeds:
                for policy in policies:
                    mk, finished = cells[seed, policy]
                    rows.append(_ckpt_row(
                        sc, policy, seed, mk[s], finished[s],
                        n_trials=n_trials, job_steps=job_steps,
                        p_fail_fresh=p_fail_fresh[s],
                        expected_makespan_dp=batch.expected_makespan(
                            s, job_steps)))
        return rows

    # one-kernel fold: flat cell axis b = (s*R + r)*P + p, i.e. row order.
    # Pools depend on (scenario, seed) only, so the S*R unique pools are
    # drawn in one per-cell-seeded call; tables depend on (policy,
    # scenario) only.  Both stay deduplicated on device — the executor
    # fans them out to the B lanes through table_index/pool_index gathers
    # (see the engine's "deduplicated fold" notes), which is what keeps
    # the single dispatch faster than the grouped loop it replaces.
    first_sr, pool_sr = engine.draw_lifetime_pool_batch(
        [d for d in dist_list for _ in seeds], n_trials,
        max_restarts=max_restarts,
        seed=[seed for _ in dist_list for seed in seeds])
    uniq, keys = [], {}
    table_ix = np.empty(S * R * P, np.int32)
    pool_ix = np.repeat(np.arange(S * R), P)
    for b, (s, _seed, policy) in enumerate(
            itertools.product(range(S), seeds, policies)):
        key = (policy, s if np.asarray(ptables[policy]).ndim == 3 else -1)
        if key not in keys:
            keys[key] = len(uniq)
            uniq.append(ptables[policy][s] if key[1] >= 0
                        else ptables[policy])
        table_ix[b] = keys[key]
    table_u = engine.stack_policy_tables(uniq, t_axis=batch.K.shape[2])
    mk_b, fin_b = engine.simulate_makespan_batch(
        table_u, job_steps, first=first_sr[pool_ix], pool=pool_sr,
        grid_dt=grid_dt, delta_steps=delta_steps,
        restart_overhead=restart_overhead, max_restarts=max_restarts,
        unfinished="nan", return_finished=True,
        table_index=table_ix, pool_index=pool_ix)
    for b, (s, seed, policy) in enumerate(
            itertools.product(range(S), seeds, policies)):
        rows.append(_ckpt_row(
            scs[s], policy, seed, mk_b[b], fin_b[b], n_trials=n_trials,
            job_steps=job_steps, p_fail_fresh=p_fail_fresh[s],
            expected_makespan_dp=batch.expected_makespan(s, job_steps)))
    return rows


# ---------------------------------------------------------------------------
# batch-service sweep
# ---------------------------------------------------------------------------

def sweep_service(scenarios: Iterable, *,
                  policies: Sequence[str] = ("model", "memoryless"),
                  cluster_sizes: Sequence[int] = (16,),
                  seeds: Sequence[int] = (0,), n_jobs: int = 40,
                  job_hours: float = 2.0, jitter: float = 0.1,
                  mode: str = "serial", pool_size: int = 4096,
                  deadline_hours=None, deflate_factor: float = 0.5,
                  **kw) -> list:
    """Expand (scenario x policy x cluster_size x seed) over the batch
    service.  The model policy's reuse grids for ALL scenarios are folded
    into one :class:`engine.ReuseTables` tensor up front — a single vmapped
    grid call, one backing allocation shared by every cluster size (the bag
    lengths depend only on the seeds, so every scenario shares one
    remaining-work axis).

    ``mode="serial"`` (ground truth) routes each scenario's cell group
    through ``service.run_bag_grid`` with its shared view of that tensor,
    keeping the event loops numpy-only; ``mode="batched"`` folds EVERY
    (scenario x policy x cluster_size x seed) cell into ONE jitted
    ``service_kernel`` dispatch — rows bit-identical to serial under x64
    on the shared per-seed lifetime streams — and additionally supports
    ``deadline_hours`` admission control and ``"+deflate"`` policies.
    Returns flat dict rows with the headline service metrics.
    """
    from . import service_kernel
    if mode not in ("serial", "batched"):
        raise ValueError(f"unknown mode {mode!r}")
    if mode == "serial" and deadline_hours is not None:
        raise ValueError("deadline admission control needs mode='batched'")
    scs = _resolve(scenarios)
    policies = tuple(policies)
    dist_list = [sc.dist() for sc in scs]
    bases = [service_kernel.split_policy(p)[0] for p in policies]
    tables = None
    if "model" in bases and kw.get("vectorized_reuse", True):
        tables = engine.ReuseTables(
            dist_list,
            service_mod.grid_reuse_values(dist_list[0], seeds=tuple(seeds),
                                          n_jobs=n_jobs, job_hours=job_hours,
                                          jitter=jitter, **kw))

    def _row(sc, cell):
        r = cell["result"]
        return dict(
            sc.coords(), policy=cell["policy"],
            cluster_size=cell["cluster_size"], seed=cell["seed"],
            n_jobs=n_jobs, job_hours=job_hours,
            makespan=r.makespan, vm_hours=r.vm_hours, cost=r.cost,
            on_demand_cost=r.on_demand_cost,
            cost_reduction=r.cost_reduction,
            n_preemptions=r.n_preemptions,
            n_job_failures=r.n_job_failures,
            n_deflations=r.n_deflations, n_rejected=r.n_rejected,
            job_failure_rate=r.n_job_failures / max(n_jobs, 1))

    if mode == "batched":
        lengths = {s: service_mod._bag_lengths(n_jobs, job_hours, jitter, s)
                   for s in seeds}
        cells = [dict(dist_index=si, vm_type=sc.vm_type, policy=policy,
                      cluster_size=cs, seed=seed)
                 for si, sc in enumerate(scs)
                 for policy, cs, seed in itertools.product(
                     policies, tuple(cluster_sizes), tuple(seeds))]
        grid = service_kernel.run_cells_batched(
            cells=cells, dists=dist_list, lengths_by_seed=lengths,
            reuse_tables=tables, pool_size=pool_size,
            deadline_hours=deadline_hours, deflate_factor=deflate_factor,
            checkpointing=kw.get("checkpointing", False),
            ckpt_interval=kw.get("ckpt_interval", 0.5),
            ckpt_cost=kw.get("ckpt_cost", 1.0 / 60.0),
            return_jobs=False)
        per_sc = len(grid) // max(len(scs), 1)
        return [_row(scs[i // per_sc], cell) for i, cell in enumerate(grid)]

    rows = []
    for si, sc in enumerate(scs):
        dist = dist_list[si]
        grid = service_mod.run_bag_grid(
            vm_types=(sc.vm_type,), policies=policies,
            cluster_sizes=tuple(cluster_sizes), seeds=tuple(seeds),
            n_jobs=n_jobs, job_hours=job_hours, jitter=jitter,
            dist_for=lambda _vm_type: dist, pool_size=pool_size,
            reuse_table=tables.view(si) if tables is not None else None,
            **kw)
        rows.extend(_row(sc, cell) for cell in grid)
    return rows


# ---------------------------------------------------------------------------
# spot-market sweep (dollar-denominated policy evaluation)
# ---------------------------------------------------------------------------

_MARKET_POLICIES = ("fixed", "cheapest", "migrate")


def solve_market_tables(scenarios: Iterable, market, *,
                        regimes: Sequence[str] = ("calm", "crunch"),
                        job_steps: int = 300, grid_dt: float = 1.0 / 60.0,
                        delta_steps: int = 1, n_sweeps: int = 3,
                        restart_overhead: float = 0.0,
                        solver_backend: str = "auto",
                        solver_refine: bool = False,
                        dp_objective: str = "makespan") -> dict:
    """Solve one ``BatchDPTables`` per market regime, for ``tables=`` reuse.

    Each regime's tables are solved against the CRUNCH-COUPLED Eq. 1 models
    at that regime's launch time (``market.crunch_dists``): calm tables
    equal the plain per-scenario tables (zero crunch intensity passes the
    base fit through unchanged), crunch tables price in the boosted early
    hazard.  Feed the result to :func:`sweep_market` ``tables=`` to
    re-evaluate fresh seeds/trial counts/policies without re-solving — the
    same whole-grid reuse contract as ``sweep_checkpointing``.

    ``dp_objective="dollars"`` solves each regime under the dollar
    objective against the market's own price grid as seen from that
    regime's launch time (``market.grid().shift(launch_time)``) — V becomes
    expected dollars-to-completion and K stretches checkpoint intervals
    through priced windows.
    """
    scs = _resolve(scenarios)
    grid0 = market.grid() if dp_objective == "dollars" else None
    out = {}
    for regime in regimes:
        t0 = market.launch_time(regime)
        dist_list = market.crunch_dists(scs, t0)
        price = None if grid0 is None else grid0.shift(t0)
        out[regime] = ckpt.solve_batch(
            dist_list, job_steps, grid_dt=grid_dt, delta_steps=delta_steps,
            n_sweeps=n_sweeps, restart_overhead=restart_overhead,
            backend=solver_backend, refine=solver_refine,
            objective=dp_objective, price=price)
    return out


def _market_row(sc, regime, policy, seed, chosen, launch_price, dollars,
                mk_row, fin_row, *, n_trials, job_steps, crunch):
    ok = np.asarray(fin_row, bool)
    d_ok = np.asarray(dollars)[ok]
    m_ok = np.asarray(mk_row)[ok]
    return dict(
        sc.coords(), regime=regime, policy=policy, seed=seed,
        chosen=chosen, launch_price=float(launch_price),
        n_trials=n_trials, job_steps=job_steps, crunch=bool(crunch),
        expected_dollars=float(d_ok.mean()) if d_ok.size else float("nan"),
        dollars_p50=float(np.median(d_ok)) if d_ok.size else float("nan"),
        makespan_mean=float(m_ok.mean()) if m_ok.size else float("nan"),
        unfinished_frac=float(1.0 - ok.mean()))


def sweep_market(scenarios: Iterable, *, market=None,
                 regimes: Sequence[str] = ("calm", "crunch"),
                 policies: Sequence[str] = _MARKET_POLICIES,
                 seeds: Sequence[int] = (0,), job_steps: int = 300,
                 n_trials: int = 400, grid_dt: float = 1.0 / 60.0,
                 delta_steps: int = 1, max_restarts: int = 64,
                 restart_overhead: float = 0.0, n_sweeps: int = 3,
                 tables: Optional[dict] = None,
                 feasible_slack: float = 1.25,
                 migrate_threshold: float = 1.15,
                 migrate_overhead_hours: float = 2.0 / 60.0,
                 cost_path: str = "kernel",
                 solver_backend: str = "auto",
                 solver_refine: bool = False,
                 dp_objective: str = "makespan") -> list:
    """Expand (scenario x regime x cost-policy x seed) in dollars.

    The market layer on the checkpointing sweep: each regime launches the
    whole scenario grid at ``market.launch_time(regime)`` against the
    crunch-coupled Eq. 1 models (``market.crunch_dists``), runs ONE batched
    executor dispatch per (regime, seed), and bills every trial's makespan
    against the (launch-shifted) ``(S, T)`` price grid through
    ``engine.accumulate_price_cost`` — one jit-cached gather for every
    policy (``tests/test_market.py`` asserts zero retracing).

    Cost policies are *selection* policies over the scenario leaves (the
    checkpoint schedule is always the DP table):

    * ``"fixed"`` — run and bill the scenario's own leaf (the repo's
      pre-market behavior, now in moving dollars).
    * ``"cheapest"`` — cheapest-feasible substitution at launch: run and
      bill the same-vm_type leaf with the lowest launch price among those
      whose DP expected makespan is within ``feasible_slack`` of the own
      leaf's.  Falls back to the own leaf when nothing cheaper qualifies.
    * ``"migrate"`` — migrate-on-price-signal: start on the own leaf; at
      the first grid cell where the own price exceeds ``migrate_threshold``
      times the substitute's, the remaining trace is billed at the
      substitute's prices, and trials still running at the crossing pay
      ``migrate_overhead_hours`` at the substitute's crossing-cell price.
      No crossing (or no substitute) degrades to ``"fixed"``.

    ``tables=`` takes the dict of per-regime ``BatchDPTables`` from
    :func:`solve_market_tables`, skipping every DP solve.
    ``cost_path="reference"`` bills through the serial
    ``market.integrate_cost_ref`` loop instead of the batched gather — the
    bit-exactness cross-check used by ``benchmarks/market_bench.py``.

    ``dp_objective="dollars"`` solves (or expects, with ``tables=``) the
    dollar-objective tables against each regime's launch-shifted price
    grid: the checkpoint schedule itself then minimizes expected dollars.
    With dollar tables the ``feasible_slack`` gate for ``"cheapest"``/
    ``"migrate"`` substitution compares expected *dollars* instead of
    expected makespans — the slack becomes dollar-denominated, which is
    the natural reading of "feasible" under a cost objective.  Supplied
    ``tables=`` must match: a makespan table under
    ``dp_objective="dollars"`` (or vice versa) raises.
    """
    from . import market as market_mod
    scs = _resolve(scenarios)
    S = len(scs)
    if market is None:
        market = market_mod.MarketModel.for_scenarios(scs)
    if len(market) != S:
        raise ValueError(f"market has {len(market)} leaves for {S} scenarios")
    if cost_path not in ("kernel", "reference"):
        raise ValueError(f"cost_path must be 'kernel' or 'reference', "
                         f"got {cost_path!r}")
    unknown = set(policies) - set(_MARKET_POLICIES)
    if unknown:
        raise ValueError(f"unknown market policies {sorted(unknown)}; "
                         f"choose from {_MARKET_POLICIES}")

    def bill(grid, mk, price_index):
        if cost_path == "kernel":
            return engine.accumulate_price_cost(grid, mk, price_index)
        return np.array([
            [market_mod.integrate_cost_ref(grid.prices[price_index[s]],
                                           grid.cum[price_index[s]],
                                           grid.dt, m)
             for m in mk[s]] for s in range(S)])

    grid0 = market.grid()
    T = grid0.prices.shape[1]
    rows = []
    for regime in regimes:
        t0 = market.launch_time(regime)
        dist_list = market.crunch_dists(scs, t0)
        g = grid0.shift(t0)
        if tables is not None:
            if regime not in tables:
                raise ValueError(f"tables= has no entry for regime "
                                 f"{regime!r}")
            batch = tables[regime]
            if len(batch) != S or batch.K.shape[1] != job_steps + 1:
                raise ValueError(
                    f"tables[{regime!r}] has {len(batch)} scenarios x "
                    f"j_max {batch.K.shape[1] - 1}; this sweep needs "
                    f"{S} x {job_steps}")
            if batch.delta_steps != delta_steps \
                    or abs(batch.grid_dt - grid_dt) > 1e-12 \
                    or batch.restart_overhead != restart_overhead:
                raise ValueError("tables was solved for a different "
                                 "(grid_dt, delta_steps, restart_overhead) "
                                 "workload")
            got = getattr(batch, "objective", "makespan")
            if got != dp_objective:
                raise ValueError(
                    f"tables[{regime!r}] was solved with objective={got!r}; "
                    f"this sweep requested dp_objective={dp_objective!r}")
        else:
            batch = ckpt.solve_batch(
                dist_list, job_steps, grid_dt=grid_dt,
                delta_steps=delta_steps, n_sweeps=n_sweeps,
                restart_overhead=restart_overhead, backend=solver_backend,
                refine=solver_refine, objective=dp_objective,
                price=g if dp_objective == "dollars" else None)
        # per-leaf expected cost of a fresh job (hours, or dollars under the
        # dollar objective) — the substitution policies' feasibility signal
        exp_mk = np.array([batch.expected_makespan(s, job_steps)
                           for s in range(S)])
        launch_p = g.prices[:, 0]
        crunch_on = [regime == "crunch"
                     and float(np.float64(p.crunch_t1))
                     > float(np.float64(p.crunch_t0))
                     for p in market.processes]
        # cheapest-feasible substitute per leaf, resolved at launch: same
        # vm_type, DP expected makespan within the slack, lowest launch
        # price (ties keep the own leaf — substitution must strictly win)
        target = np.arange(S)
        for s in range(S):
            cands = [j for j in range(S)
                     if scs[j].vm_type == scs[s].vm_type
                     and exp_mk[j] <= feasible_slack * exp_mk[s]
                     and launch_p[j] < launch_p[s]]
            if cands:
                target[s] = min(cands, key=lambda j: launch_p[j])
        # migrate-on-price-signal: first cell where own price exceeds
        # threshold x substitute price; compose the billed row from the
        # own prefix and the substitute suffix
        composed = g.prices.copy()
        kc = np.full(S, T, np.int64)
        for s in range(S):
            j = target[s]
            if j == s:
                continue
            hit = np.flatnonzero(g.prices[s]
                                 > migrate_threshold * g.prices[j])
            if hit.size:
                kc[s] = hit[0]
                composed[s, hit[0]:] = g.prices[j, hit[0]:]
        g_migrate = market_mod.PriceGrid.from_prices(composed, g.dt)

        ptab = np.asarray(batch.K, np.int32)
        idx = np.arange(S, dtype=np.int32)
        for seed in seeds:
            first, pool = engine.draw_lifetime_pool_batch(
                dist_list, n_trials, max_restarts=max_restarts, seed=seed)
            mk, fin = engine.simulate_makespan_batch(
                ptab, job_steps, first=first, pool=pool, grid_dt=grid_dt,
                delta_steps=delta_steps, restart_overhead=restart_overhead,
                max_restarts=max_restarts, unfinished="nan",
                return_finished=True)
            mk = np.asarray(mk)
            fin = np.asarray(fin)
            for policy in policies:
                if policy == "fixed":
                    chosen, m_bill, f_bill = idx, mk, fin
                    dollars = bill(g, m_bill, idx)
                elif policy == "cheapest":
                    chosen = target.astype(np.int32)
                    m_bill, f_bill = mk[chosen], fin[chosen]
                    dollars = bill(g, m_bill, chosen)
                else:   # migrate
                    chosen, m_bill, f_bill = idx, mk, fin
                    dollars = bill(g_migrate, m_bill, idx)
                    # trials still running at the crossing pay the
                    # migration overhead at the substitute's price there
                    cross_t = kc[:, None] * g.dt
                    sur = np.where(
                        m_bill > cross_t,
                        migrate_overhead_hours
                        * g.prices[target, np.minimum(kc, T - 1)][:, None],
                        0.0)
                    dollars = dollars + sur
                for s in range(S):
                    rows.append(_market_row(
                        scs[s], regime, policy, seed,
                        scs[int(chosen[s]) if policy != "migrate"
                            else int(target[s])].name,
                        launch_p[s], dollars[s], m_bill[s], f_bill[s],
                        n_trials=n_trials, job_steps=job_steps,
                        crunch=crunch_on[s]))
    return rows
