"""Online preemption-model maintenance (the paper's Discussion section:
"a long-running cloud service can continuously update the model based on
recent preemption behavior" and "detect policy and phase changes").

``OnlineModelTracker`` keeps a rolling window of observed pod/VM lifetimes,
refits Eq. 1 periodically (pure-JAX LM fitter), and raises a change-point
flag when recent observations are no longer consistent with the live model
(two-sided KS test).  The training runtime swaps the CheckpointManager's
distribution on refit, so the DP schedule tracks the fleet's actual behavior.

The change-point cut is derived from the KS sampling distribution rather
than being a fixed constant: the live model was itself fitted on ``m``
samples and is tested against ``n`` fresh ones, so under a stationary fleet
the statistic fluctuates like a *two-sample* KS,

    D_crit(alpha; m, n) = sqrt(-ln(alpha/2) / 2) * sqrt((m + n) / (m * n)),

(one-sample ``sqrt(-ln(alpha/2) / (2 n))`` when the fit count is unknown).
A fixed threshold (the old ``ks_threshold=0.15``) ignores both sample sizes
and trips on pure sampling noise for small windows — e.g. m = n = 128 puts
the alpha=0.01 critical value at ~0.20, well above 0.15.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Callable, Optional

import numpy as np

from . import distributions, fitting


def ks_critical_value(alpha: float, n_recent: int,
                      n_fit: Optional[int] = None) -> float:
    """Asymptotic two-sided KS rejection cut at significance ``alpha``.

    ``n_recent`` is the size of the sample being tested; ``n_fit`` the sample
    count behind the reference CDF (None for an exact/analytic reference,
    giving the classical one-sample form).
    """
    c = math.sqrt(-math.log(alpha / 2.0) / 2.0)
    if n_fit is None:
        return c / math.sqrt(n_recent)
    return c * math.sqrt((n_fit + n_recent) / (n_fit * n_recent))


@dataclasses.dataclass
class OnlineModelTracker:
    window: int = 512              # lifetimes kept
    refit_every: int = 64          # observations between refits
    # change-point sensitivity: None derives the cut from ``ks_alpha`` and
    # the live sample counts; a float pins the legacy fixed threshold
    ks_threshold: Optional[float] = None
    ks_alpha: float = 0.01
    min_samples: int = 64
    prior: Optional[object] = None  # distribution used before enough data
    # injectable fit (signature of fitting.fit_samples); the closed-loop
    # runtime routes refits through its fault-injection/validation envelope
    fit_fn: Optional[Callable] = None

    def __post_init__(self):
        self._obs = deque(maxlen=self.window)
        self._since_fit = 0
        self._fit_n: Optional[int] = None   # samples behind the live model
        self.model = self.prior or distributions.constrained_for()
        self.n_refits = 0
        self.change_points = 0
        self.last_ks = 0.0
        self.last_cut = float("inf")

    def observe(self, lifetime_hours: float) -> bool:
        """Record one preemption; returns True if the model was refit."""
        self._obs.append(float(lifetime_hours))
        self._since_fit += 1
        if len(self._obs) >= self.min_samples and \
                self._since_fit >= self.refit_every:
            self.refit()
            return True
        return False

    def _cut(self, n_recent: int) -> float:
        if self.ks_threshold is not None:
            return self.ks_threshold
        return ks_critical_value(self.ks_alpha, n_recent, self._fit_n)

    def defer_refit(self, n_obs: int):
        """Back off: no automatic refit for the next ``n_obs`` observations
        (the runtime's bounded retry-with-backoff after a failed refit —
        without this, a poisoned window would re-trigger the failing fit on
        every single observation)."""
        self._since_fit = self.refit_every - int(n_obs)

    def refit(self):
        """Change-point check + refit on the current window.

        On a CONFIRMED change point the rolling window is first trimmed to
        the post-change observations (the recent slice the KS test flagged),
        so the refit tracks the post-drift fleet instead of fitting a blend
        of pre- and post-drift lifetimes — the old full-window refit needed
        another ``window`` observations to wash the stale half out.

        Raises :class:`fitting.FitDiverged` when the fit returns non-finite
        parameters/loss and ``ValueError`` (from ``fit_samples``) on a
        degenerate window; in both cases the live model is left untouched
        (last-good), ``change_points`` still records the detection, and the
        caller decides the retry policy (see ``FleetRuntime``).
        """
        data = np.asarray(self._obs)
        # change-point check BEFORE refitting: is the live model still
        # consistent with the recent half of the window?
        recent = data[-max(len(data) // 2, self.min_samples // 2):]
        self.last_ks = float(fitting.ks_statistic(self.model, recent))
        self.last_cut = self._cut(len(recent))
        if self.last_ks > self.last_cut and self.n_refits > 0:
            self.change_points += 1
            # drop pre-drift lifetimes: refit on post-change observations only
            data = recent
            self._obs = deque(recent.tolist(), maxlen=self.window)
        res = (self.fit_fn or fitting.fit_samples)("constrained", data)
        theta = np.asarray(res.theta, np.float64)
        if not (np.all(np.isfinite(theta)) and np.isfinite(float(res.lse))):
            raise fitting.FitDiverged(
                f"refit on {len(data)} observations produced non-finite "
                f"theta/loss (theta={theta.tolist()})")
        self.model = res.dist
        self._fit_n = len(data)
        self.n_refits += 1
        self._since_fit = 0

    @property
    def drifted(self) -> bool:
        return self.last_ks > self.last_cut
