"""Online preemption-model maintenance (the paper's Discussion section:
"a long-running cloud service can continuously update the model based on
recent preemption behavior" and "detect policy and phase changes").

``OnlineModelTracker`` keeps a rolling window of observed pod/VM lifetimes,
refits Eq. 1 periodically (pure-JAX LM fitter), and raises a change-point
flag when recent observations are no longer consistent with the live model
(two-sided KS test at a configurable threshold).  The training runtime swaps
the CheckpointManager's distribution on refit, so the DP schedule tracks the
fleet's actual behavior.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from . import distributions, fitting


@dataclasses.dataclass
class OnlineModelTracker:
    window: int = 512              # lifetimes kept
    refit_every: int = 64          # observations between refits
    ks_threshold: float = 0.15     # change-point sensitivity
    min_samples: int = 64
    prior: Optional[object] = None  # distribution used before enough data

    def __post_init__(self):
        self._obs = deque(maxlen=self.window)
        self._since_fit = 0
        self.model = self.prior or distributions.constrained_for()
        self.n_refits = 0
        self.change_points = 0
        self.last_ks = 0.0

    def observe(self, lifetime_hours: float) -> bool:
        """Record one preemption; returns True if the model was refit."""
        self._obs.append(float(lifetime_hours))
        self._since_fit += 1
        if len(self._obs) >= self.min_samples and \
                self._since_fit >= self.refit_every:
            self.refit()
            return True
        return False

    def refit(self):
        data = np.asarray(self._obs)
        # change-point check BEFORE refitting: is the live model still
        # consistent with the recent half of the window?
        recent = data[-max(len(data) // 2, self.min_samples // 2):]
        self.last_ks = float(fitting.ks_statistic(self.model, recent))
        if self.last_ks > self.ks_threshold and self.n_refits > 0:
            self.change_points += 1
        res = fitting.fit_samples("constrained", data)
        self.model = res.dist
        self.n_refits += 1
        self._since_fit = 0

    @property
    def drifted(self) -> bool:
        return self.last_ks > self.ks_threshold
