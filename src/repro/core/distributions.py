"""Failure/preemption probability models for temporally constrained preemptions.

Implements the paper's 4-parameter constrained-preemption model (Eqs. 1-5)

    F(t) = A * (1 - exp(-t/tau1) + exp((t-b)/tau2)),   0 < t < L (~24 h)

together with the baseline families it is compared against (exponential,
Weibull, Gompertz-Makeham, uniform) and an empirical step-CDF.

All distributions are immutable dataclass pytrees, so every method is
jit/vmap/grad-compatible.  Time unit is HOURS.

Common interface (t broadcasts):
    cdf(t), pdf(t), survival(t), hazard(t)
    partial_expectation(a, b)   -> integral_a^b  x f(x) dx      (Eq. 3/7/15 kernel)
    expected_lifetime()         -> integral_0^L  x f(x) dx      (Eq. 3)
    fail_between(a, b)          -> F(b) - F(a)
    sample(key, shape)          -> lifetimes in [0, L] (inverse-CDF; residual
                                   mass above F(L) is preempted AT the deadline)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# 24-hour maximum lifetime of Google Preemptible VMs.
DEADLINE_HOURS = 24.0

# Clip for exponent arguments to keep fitting iterates finite.
_EXP_CLIP = 60.0

# 64-point Gauss-Legendre rule on [-1, 1] (static numpy; reused by all
# numeric partial expectations).
_GL_X, _GL_W = np.polynomial.legendre.leggauss(64)
_GL_X = jnp.asarray(_GL_X)
_GL_W = jnp.asarray(_GL_W)


def _dist(cls):
    """frozen dataclass + jax pytree registration."""
    cls = dataclasses.dataclass(frozen=True, eq=False)(cls)
    return jax.tree_util.register_dataclass(cls)


def _exp(x):
    return jnp.exp(jnp.clip(x, -_EXP_CLIP, _EXP_CLIP))


def _f32(t):
    return jnp.asarray(t, jnp.result_type(float))


def _gauss_legendre(fn, a, b):
    """integral_a^b fn(x) dx with a fixed 64-point GL rule (jit-friendly)."""
    a, b = _f32(a), _f32(b)
    shape = jnp.broadcast_shapes(jnp.shape(a), jnp.shape(b))
    a = jnp.broadcast_to(a, shape)
    b = jnp.broadcast_to(b, shape)
    half = 0.5 * (b - a)
    mid = 0.5 * (a + b)
    x = mid[..., None] + half[..., None] * _GL_X
    return half * jnp.sum(_GL_W * fn(x), axis=-1)


def _bisect_icdf(cdf_fn, u, lo, hi, iters: int = 64):
    """Invert a monotone CDF by bisection; fully shape-polymorphic."""
    u = _f32(u)
    lo = jnp.broadcast_to(jnp.asarray(lo, u.dtype), u.shape)
    hi = jnp.broadcast_to(jnp.asarray(hi, u.dtype), u.shape)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        below = cdf_fn(mid) < u
        return jnp.where(below, mid, lo), jnp.where(below, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return 0.5 * (lo + hi)


class _DistBase:
    """Generic (numeric) implementations; families override where a closed
    form exists."""

    # -- required primitive -------------------------------------------------
    def cdf(self, t):  # pragma: no cover - abstract
        raise NotImplementedError

    def pdf(self, t):
        # generic: elementwise autodiff of the CDF
        g = jax.grad(lambda x: jnp.sum(self.cdf(x)))
        return g(_f32(t))

    # -- derived quantities -------------------------------------------------
    def survival(self, t):
        return 1.0 - self.cdf(t)

    def hazard(self, t):
        return self.pdf(t) / jnp.maximum(self.survival(t), 1e-12)

    def fail_between(self, a, b):
        """P(a < preemption <= b) = F(b) - F(a)."""
        return self.cdf(b) - self.cdf(a)

    def partial_expectation(self, a, b):
        """integral_a^b x f(x) dx (numeric fallback)."""
        return _gauss_legendre(lambda x: x * self.pdf(x), a, b)

    def expected_lifetime(self):
        """E[L] = integral_0^L t f(t) dt (Eq. 3). Survivor mass at the deadline
        is *excluded*, exactly as in the paper's definition."""
        return self.partial_expectation(0.0, self.L)

    def mean_lifetime_capped(self):
        """E[min(T, L)] including the mass preempted AT the deadline."""
        return self.expected_lifetime() + self.survival(self.L) * self.L

    # -- sampling -----------------------------------------------------------
    def icdf(self, u):
        return _bisect_icdf(self.cdf, u, 0.0, self.L)

    def sample(self, key, shape=()):
        """Lifetimes in [0, L]. u >= F(L) means the VM survives until the hard
        cap and is preempted at exactly L (the provider's 24 h reclamation)."""
        u = jax.random.uniform(key, shape)
        fl = self.cdf(self.L)
        capped = u >= fl
        t = self.icdf(jnp.minimum(u, fl * (1.0 - 1e-6)))
        return jnp.where(capped, jnp.asarray(self.L, t.dtype), t)


@_dist
class Constrained(_DistBase):
    """The paper's constrained-preemption model (Eq. 1).

    F(t) = A * (1 - e^{-t/tau1} + e^{(t-b)/tau2}) on [0, L].

    tau1 : time scale of the initial high-preemption phase (hours)
    tau2 : time scale of the deadline reclamation wall (hours)
    b    : activation point of the deadline process (~L)
    A    : scaling constant
    """

    tau1: jnp.ndarray = 1.0
    tau2: jnp.ndarray = 0.8
    b: jnp.ndarray = 24.0
    A: jnp.ndarray = 0.475
    L: jnp.ndarray = DEADLINE_HOURS

    def cdf(self, t):
        t = _f32(t)
        raw = self.A * (1.0 - _exp(-t / self.tau1) + _exp((t - self.b) / self.tau2))
        # Eq. 1 is defined on [0, L]; clamp numerically tiny negatives at t=0.
        return jnp.clip(raw, 0.0, 1.0)

    def cdf_raw(self, t):
        """Unclipped Eq. 1 (used by the fitter)."""
        t = _f32(t)
        return self.A * (1.0 - _exp(-t / self.tau1) + _exp((t - self.b) / self.tau2))

    def pdf(self, t):
        """Eq. 2: f(t) = A * (e^{-t/tau1}/tau1 + e^{(t-b)/tau2}/tau2)."""
        t = _f32(t)
        return self.A * (_exp(-t / self.tau1) / self.tau1
                         + _exp((t - self.b) / self.tau2) / self.tau2)

    def hazard(self, t):
        """Eq. 5 with r1 = 1/tau1, r2 = 1/tau2."""
        t = _f32(t)
        r1, r2 = 1.0 / self.tau1, 1.0 / self.tau2
        num = r1 * _exp(-r1 * t) + r2 * _exp(r2 * (t - self.b))
        den = 1.0 / self.A - 1.0 + _exp(-r1 * t) - _exp(r2 * (t - self.b))
        return num / jnp.maximum(den, 1e-12)

    def _antiderivative(self, t):
        """G(t) = integral t f(t) dt = A[-(t+tau1)e^{-t/tau1} + (t-tau2)e^{(t-b)/tau2}]
        (the closed form inside Eq. 3)."""
        return self.A * (-(t + self.tau1) * _exp(-t / self.tau1)
                         + (t - self.tau2) * _exp((t - self.b) / self.tau2))

    def partial_expectation(self, a, b):
        a = _f32(a)
        return self._antiderivative(jnp.asarray(b, a.dtype)) - self._antiderivative(a)

    def phases(self):
        """Approximate phase boundaries (initial | stable | deadline): the
        initial process has decayed by ~3*tau1; the deadline process activates
        where its pdf term reaches the stable-phase floor."""
        t1 = 3.0 * self.tau1
        floor = self.pdf(t1)
        t2 = self.b + self.tau2 * jnp.log(jnp.maximum(floor * self.tau2 / self.A, 1e-12))
        return t1, jnp.clip(t2, t1, self.L)

    def icdf(self, u):
        """Invert Eq. 1 by short bracketing bisection + safeguarded Newton.

        The generic 64-iteration full-range bisection costs 64 cdf
        evaluations per quantile; Eq. 1 is smooth and strictly increasing
        with a closed-form pdf, so 12 bracketing halvings (bracket width
        ``L * 2**-12`` ~ 6e-3 h) followed by 6 quadratically-converging
        safeguarded Newton steps land past float64 precision at well under
        half the exp traffic.  Safeguards (rtsafe-style): every Newton
        iteration keeps updating the sign bracket, an overshooting proposal
        is clipped back into it (the next iteration restarts Newton from
        that endpoint), and the proposal is replaced by the bracket
        midpoint whenever the iterate sits on the clipped plateau of a
        saturating fit (raw Eq. 1 > 1 before L, where cdf is flat at 1
        while the closed-form pdf stays positive — bare Newton would stall
        there).

        Accuracy: machine precision for every proper fit (the production
        envelope — ``DiurnalConstrained.effective`` caps ``A`` precisely so
        Eq. 1 stays proper on [0, L]).  For an out-of-envelope saturating
        fit the plateau safeguard degrades gracefully to bisection rate
        around the plateau edge: worst-case ``|F(t) - u|`` ~ 1e-4 for
        quantiles at the edge (use :func:`_bisect_icdf` directly if a
        saturated tail must be inverted to full precision).  This is the
        hot path of every lifetime-pool draw; all sampling paths
        (numpy-reference and batched pools alike) share it, which keeps
        their bit-exactness contract intact.
        """
        u = _f32(u)
        lo = jnp.broadcast_to(jnp.asarray(0.0, u.dtype), u.shape)
        hi = jnp.broadcast_to(jnp.asarray(self.L, u.dtype), u.shape)

        def halve(_, carry):
            lo, hi = carry
            mid = 0.5 * (lo + hi)
            below = self.cdf(mid) < u
            return jnp.where(below, mid, lo), jnp.where(below, hi, mid)

        lo, hi = jax.lax.fori_loop(0, 12, halve, (lo, hi))

        def newton(_, carry):
            lo, hi, t = carry
            # Eq. 1 cdf and Eq. 2 pdf share their two exponentials — one
            # pair per iteration makes a Newton step cost a bisection step
            e1 = _exp(-t / self.tau1)
            e2 = _exp((t - self.b) / self.tau2)
            F_raw = self.A * (1.0 - e1 + e2)
            F = jnp.clip(F_raw, 0.0, 1.0)
            below = F < u
            lo = jnp.where(below, t, lo)
            hi = jnp.where(below, hi, t)
            pdf = self.A * (e1 / self.tau1 + e2 / self.tau2)
            tn = jnp.clip(t - (F - u) / jnp.maximum(pdf, 1e-30), lo, hi)
            # F_raw > 1 (not F == 1): the clip plateau proper, never the
            # legitimate boundary where Eq. 1 reaches exactly 1 at L
            return lo, hi, jnp.where(F_raw > 1.0, 0.5 * (lo + hi), tn)

        _, _, t = jax.lax.fori_loop(0, 6, newton, (lo, hi, 0.5 * (lo + hi)))
        return t


def capped_constrained(base, *, A_scale, tau1_scale) -> "Constrained":
    """Scale a Constrained-parameterized model's early phase (``A``, ``tau1``)
    while keeping the raw Eq. 1 CDF proper (<= 1) up to the deadline.

    This is THE modulation primitive every exogenous hazard coupling goes
    through: :meth:`DiurnalConstrained.effective` (launch-phase modulation)
    and ``market.crunch_effective`` (capacity-crunch coupling) both apply
    their scale factors here, so the properness cap — without which the
    clipped CDF would saturate before ``L`` while the closed-form pdf stayed
    positive, breaking the pdf == d(cdf)/dt contract the DP solver relies
    on — is enforced identically everywhere.  The cap never pushes ``A``
    *below* the base fit (``jnp.maximum(cap, A)``), so a boost can saturate
    but never invert.  ``base`` needs ``tau1/tau2/b/A/L`` fields; the result
    is always a plain :class:`Constrained`.
    """
    tau1 = jnp.maximum(base.tau1 * tau1_scale, 0.05)
    cap = (1.0 - 1e-3) / (1.0 - _exp(-base.L / tau1)
                          + _exp((base.L - base.b) / base.tau2))
    A = jnp.clip(base.A * A_scale, 1e-3, jnp.maximum(cap, base.A))
    return Constrained(tau1=tau1, tau2=base.tau2, b=base.b, A=A, L=base.L)


@_dist
class DiurnalConstrained(_DistBase):
    """Obs. 5 launch-phase-modulated constrained model.

    The paper observes that VMs launched during busy (daytime) hours see a
    harsher initial-preemption phase than night launches.  This family
    composes Eq. 1 with a smooth day/night modulation of ``A`` and ``tau1``
    keyed on the wall-clock hour-of-day at VM *launch*:

        m(c)        = cos(2*pi*(c - peak_clock) / 24)        in [-1, 1]
        A_eff       = A    * (1 + amp_A    * m(launch_clock))
        tau1_eff    = tau1 * (1 - amp_tau1 * m(launch_clock))

    so a launch at ``peak_clock`` preempts more (larger A, faster initial
    decay) and a launch 12 h away preempts less.  ``tau2``/``b`` (the
    provider's deadline wall) are clock-independent — the 24 h reclamation
    does not care when the VM was launched.

    The effective parameters are fixed at launch, so every method delegates
    to a plain :class:`Constrained` — the full
    ``cdf/pdf/hazard/partial_expectation/icdf`` closed-form contract (and
    with it the DP solver, ``engine.ReuseTable`` and
    ``engine.draw_lifetime_pool``) is inherited unchanged, and the class
    stays a jit/vmap-compatible pytree over all of its fields (vmap over
    ``launch_clock`` evaluates a whole diurnal profile in one call).
    """

    tau1: jnp.ndarray = 1.0
    tau2: jnp.ndarray = 0.8
    b: jnp.ndarray = 24.0
    A: jnp.ndarray = 0.475
    launch_clock: jnp.ndarray = 12.0   # wall-clock hour-of-day at VM launch
    amp_A: jnp.ndarray = 0.15          # day/night depth of the A modulation
    amp_tau1: jnp.ndarray = 0.35       # day/night depth of the tau1 modulation
    peak_clock: jnp.ndarray = 20.0     # busiest launch hour (simulator phase)
    L: jnp.ndarray = DEADLINE_HOURS

    def modulation(self):
        """m(launch_clock) in [-1, 1]; +1 at the busiest launch hour."""
        return jnp.cos(2.0 * jnp.pi
                       * (_f32(self.launch_clock) - self.peak_clock) / 24.0)

    def effective(self) -> "Constrained":
        """The launch-phase-resolved Eq. 1 model.

        The boosted day-phase ``A`` is capped so the *raw* Eq. 1 CDF stays
        proper (<= 1) up to the deadline — otherwise the clipped CDF would
        saturate before L while the closed-form pdf stayed positive,
        breaking the pdf == d(cdf)/dt contract the DP solver relies on.
        With the shipped fits (b ~ L, so F(L) ~ 2A) that cap sits near 0.5,
        which most day-phase boosts saturate — the cap never pushes ``A``
        *below* the static fit, so day >= static >= night always holds, but
        for large-A types the day-phase severity comes mostly from ``tau1``.
        """
        m = self.modulation()
        return capped_constrained(self, A_scale=1.0 + self.amp_A * m,
                                  tau1_scale=1.0 - self.amp_tau1 * m)

    def cdf(self, t):
        return self.effective().cdf(t)

    def cdf_raw(self, t):
        return self.effective().cdf_raw(t)

    def pdf(self, t):
        return self.effective().pdf(t)

    def hazard(self, t):
        return self.effective().hazard(t)

    def partial_expectation(self, a, b):
        return self.effective().partial_expectation(a, b)

    def icdf(self, u):
        return self.effective().icdf(u)

    def phases(self):
        return self.effective().phases()


@_dist
class Exponential(_DistBase):
    """Memoryless baseline: F(t) = 1 - e^{-t/mttf} (classical spot-instance model)."""

    mttf: jnp.ndarray = 6.0
    L: jnp.ndarray = DEADLINE_HOURS

    def cdf(self, t):
        return 1.0 - _exp(-_f32(t) / self.mttf)

    def pdf(self, t):
        return _exp(-_f32(t) / self.mttf) / self.mttf

    def hazard(self, t):
        return jnp.broadcast_to(1.0 / jnp.asarray(self.mttf), jnp.shape(jnp.asarray(t)))

    def partial_expectation(self, a, b):
        a = _f32(a)
        g = lambda t: -(t + self.mttf) * _exp(-t / self.mttf)
        return g(jnp.asarray(b, a.dtype)) - g(a)


@_dist
class Weibull(_DistBase):
    """F(t) = 1 - exp(-(lam*t)^k)."""

    lam: jnp.ndarray = 0.15
    k: jnp.ndarray = 0.9
    L: jnp.ndarray = DEADLINE_HOURS

    def cdf(self, t):
        z = jnp.maximum(self.lam * _f32(t), 1e-12)
        return 1.0 - _exp(-jnp.power(z, self.k))

    def pdf(self, t):
        z = jnp.maximum(self.lam * _f32(t), 1e-12)
        return self.lam * self.k * jnp.power(z, self.k - 1.0) * _exp(-jnp.power(z, self.k))

    def hazard(self, t):
        z = jnp.maximum(self.lam * _f32(t), 1e-12)
        return self.lam * self.k * jnp.power(z, self.k - 1.0)


@_dist
class GompertzMakeham(_DistBase):
    """F(t) = 1 - exp(-lam*t - (alpha/beta)(e^{beta t} - 1)); hazard lam + alpha e^{beta t}."""

    lam: jnp.ndarray = 0.08
    alpha: jnp.ndarray = 1e-4
    beta: jnp.ndarray = 0.35
    L: jnp.ndarray = DEADLINE_HOURS

    def cdf(self, t):
        t = _f32(t)
        return 1.0 - _exp(-self.lam * t - (self.alpha / self.beta) * (_exp(self.beta * t) - 1.0))

    def pdf(self, t):
        return self.hazard(t) * self.survival(t)

    def hazard(self, t):
        return self.lam + self.alpha * _exp(self.beta * _f32(t))


@_dist
class Uniform(_DistBase):
    """Uniformly distributed constrained preemptions: F(t) = t / L (the paper's
    Fig. 5 comparison; its printed 'F(t)=24-t' is read as the uniform CDF)."""

    L: jnp.ndarray = DEADLINE_HOURS

    def cdf(self, t):
        return jnp.clip(_f32(t) / self.L, 0.0, 1.0)

    def pdf(self, t):
        t = _f32(t)
        inside = (t >= 0) & (t <= self.L)
        return jnp.where(inside, 1.0 / self.L, 0.0)

    def partial_expectation(self, a, b):
        a = _f32(a)
        a_ = jnp.clip(a, 0.0, self.L)
        b_ = jnp.clip(jnp.asarray(b, a.dtype), 0.0, self.L)
        return (b_ * b_ - a_ * a_) / (2.0 * self.L)


@_dist
class Empirical(_DistBase):
    """Interpolated CDF from an observed lifetime trace.

    knots  : sorted lifetimes, shape (n,)
    values : ECDF at the knots (midpoint convention (i+0.5)/n)
    """

    knots: jnp.ndarray
    values: jnp.ndarray
    L: jnp.ndarray = DEADLINE_HOURS

    @staticmethod
    def from_samples(samples, L=DEADLINE_HOURS) -> "Empirical":
        s = jnp.sort(jnp.ravel(_f32(samples)))
        n = s.shape[0]
        v = (jnp.arange(n, dtype=s.dtype) + 0.5) / n
        return Empirical(knots=s, values=v, L=jnp.asarray(L, s.dtype))

    def cdf(self, t):
        return jnp.interp(_f32(t), self.knots, self.values, left=0.0, right=1.0)

    def pdf(self, t):
        # finite-difference density (diagnostics only)
        eps = 0.05
        return (self.cdf(_f32(t) + eps) - self.cdf(_f32(t) - eps)) / (2 * eps)

    def quantile(self, q):
        return jnp.interp(_f32(q), self.values, self.knots, left=0.0, right=self.L)


# -- Paper-calibrated reference parameter sets --------------------------------
# The paper quotes typical fits: tau1 in [0.5, 1.5] h, tau2 ~ 0.8 h, b ~ 24 h,
# A in [0.4, 0.5].  Larger VMs preempt faster (Obs. 4); nights are gentler
# (Obs. 5).  These sets parametrize the synthetic trace generator and all
# policy benchmarks; n1-highcpu-16/us-east1-b is the Fig. 1 headline config.
PAPER_FIT_N1_HIGHCPU_16 = dict(tau1=1.0, tau2=0.8, b=24.0, A=0.475)

VM_TYPE_PARAMS = {
    # name                tau1   tau2    b     A     (Obs. 4: larger => faster)
    "n1-highcpu-2": dict(tau1=1.5, tau2=0.85, b=24.0, A=0.40),
    "n1-highcpu-4": dict(tau1=1.3, tau2=0.85, b=24.0, A=0.42),
    "n1-highcpu-8": dict(tau1=1.1, tau2=0.80, b=24.0, A=0.44),
    "n1-highcpu-16": dict(tau1=1.0, tau2=0.80, b=24.0, A=0.475),
    "n1-highcpu-32": dict(tau1=0.6, tau2=0.75, b=24.0, A=0.50),
    # TPU-fleet analogue used by the training framework (pod-granular)
    "tpu-v5e-pod": dict(tau1=1.0, tau2=0.80, b=24.0, A=0.475),
}


def stack(dists):
    """Stack same-family distributions into ONE batched pytree whose
    parameter leaves carry a leading ``(S,)`` scenario axis.

    The result is still an instance of the family class, so the whole
    ``cdf/pdf/hazard/partial_expectation/icdf`` contract is preserved:
    evaluate it per scenario with ``jax.vmap`` (grid-shaped queries), or
    directly via broadcasting when each leaf lines up with the query batch
    (e.g. ``stack(ds).cdf(jnp.full(S, 3.0))``).  This is the distribution-
    layer entry point of the engine's leading-axis convention (see
    ``repro.core.engine``): ``checkpointing.solve_batch``,
    ``engine.draw_lifetime_pool_batch`` and ``engine.ReuseTable.batch``
    all consume scenario *lists* and stack internally.

    All inputs must be instances of the same registered family (mixing
    e.g. ``Constrained`` with ``Exponential`` would stack incompatible
    parameterizations leaf-by-leaf).
    """
    dists = list(dists)
    if not dists:
        raise ValueError("stack() needs at least one distribution")
    cls = type(dists[0])
    if any(type(d) is not cls for d in dists[1:]):
        raise TypeError("stack() requires one distribution family, got "
                        f"{sorted({type(d).__name__ for d in dists})}")
    dtype = jnp.result_type(float)
    return jax.tree_util.tree_map(
        lambda *leaves: jnp.stack([jnp.asarray(l, dtype) for l in leaves]),
        *dists)


def unstack(dist):
    """Invert :func:`stack`: a batched distribution -> list of per-scenario
    distributions (leaves sliced along the leading axis)."""
    leaves = jax.tree_util.tree_leaves(dist)
    if not leaves or jnp.ndim(leaves[0]) == 0:
        raise ValueError("unstack() expects a stacked distribution with a "
                         "leading scenario axis")
    n = leaves[0].shape[0]
    return [jax.tree_util.tree_map(lambda l: l[i], dist) for i in range(n)]


def constrained_for(vm_type: str = "n1-highcpu-16") -> Constrained:
    return Constrained(**VM_TYPE_PARAMS[vm_type])


def diurnal_for(vm_type: str = "n1-highcpu-16",
                launch_clock: float = 12.0, **kw) -> DiurnalConstrained:
    """Obs. 5 variant of :func:`constrained_for`: the type's paper-calibrated
    Eq. 1 fit, modulated by the wall-clock launch hour.  ``kw`` overrides
    any field, including the type's base Eq. 1 parameters."""
    return DiurnalConstrained(**{**VM_TYPE_PARAMS[vm_type],
                                 "launch_clock": launch_clock, **kw})


def registry():
    """Family name -> class, used by fitting/benchmarks."""
    return {
        "constrained": Constrained,
        "diurnal_constrained": DiurnalConstrained,
        "exponential": Exponential,
        "weibull": Weibull,
        "gompertz_makeham": GompertzMakeham,
        "uniform": Uniform,
    }
