"""Distributed checkpointing with model-driven (DP) scheduling.

Mechanics:
  * pytrees are flattened to path->array dicts and written as .npz with a
    JSON manifest carrying shapes/dtypes/CRC32s and user metadata;
  * writes are atomic (tmp dir + rename) and optionally asynchronous (the
    device->host copy happens synchronously, the disk write on a thread -
    on TPU fleets the same split hides the object-store upload);
  * ``restore_latest`` scans the directory, verifies CRCs, and returns the
    newest intact checkpoint - a half-written checkpoint from a preempted
    pod is skipped, which is exactly the failure mode the paper's 30 s
    warning window creates.

Scheduling: ``CheckpointManager`` consumes the paper's DP policy
(repro.core.policies.checkpointing).  Given the fitted preemption model, the
measured per-step time and the measured checkpoint cost delta, it computes
the optimal *non-uniform* schedule in units of steps and answers
``should_checkpoint(step)``.  A Young-Daly or fixed-interval schedule can be
selected for baselines (EXPERIMENTS.md compares them).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import tempfile
import threading
import time
import zlib
from typing import Any, Optional

import jax
import numpy as np

from ..core.policies import checkpointing as ckpt_policy
from ..core.policies import young_daly


# ---------------------------------------------------------------------------
# serialization
# ---------------------------------------------------------------------------

def _flatten(tree) -> dict:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten_like(template, flat: dict):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = flat[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(directory: str, step: int, tree, metadata: Optional[dict]
                    = None, *, blocking: bool = True) -> threading.Thread:
    """Atomic (tmp+rename) checkpoint write; returns the writer thread."""
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(jax.device_get(tree))  # host copy is synchronous
    manifest = {
        "step": int(step),
        "time": time.time(),
        "metadata": metadata or {},
        "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                       "crc32": zlib.crc32(np.ascontiguousarray(v).tobytes())}
                   for k, v in flat.items()},
    }

    def write():
        tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_")
        try:
            np.savez(os.path.join(tmp, "arrays.npz"),
                     **{k: v for k, v in flat.items()})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            final = os.path.join(directory, f"step_{int(step):010d}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise

    t = threading.Thread(target=write, daemon=True)
    t.start()
    if blocking:
        t.join()
    return t


def _verify(path: str) -> Optional[dict]:
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            for k, info in manifest["arrays"].items():
                arr = z[k]
                if zlib.crc32(np.ascontiguousarray(arr).tobytes()) \
                        != info["crc32"]:
                    return None
        return manifest
    except Exception:
        return None


def restore_latest(directory: str, template) -> Optional[tuple]:
    """Returns (tree, step, metadata) of the newest intact checkpoint."""
    if not os.path.isdir(directory):
        return None
    steps = sorted((d for d in os.listdir(directory) if d.startswith("step_")),
                   reverse=True)
    for d in steps:
        path = os.path.join(directory, d)
        manifest = _verify(path)
        if manifest is None:
            continue  # torn write (e.g. preempted mid-checkpoint) - skip
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        return (_unflatten_like(template, flat), manifest["step"],
                manifest["metadata"])
    return None


# ---------------------------------------------------------------------------
# model-driven scheduling
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CheckpointManager:
    """Owns the checkpoint schedule + IO for a training run on preemptible
    pods.

    policy: "dp" (the paper, non-uniform), "young_daly", "fixed", "none".
    Times are in hours of *pod age*; steps are mapped through the measured
    step time (EMA-updated online via ``observe_step_time``).
    """
    directory: str
    dist: Any                               # preemption model (core.distributions)
    policy: str = "dp"
    delta_hours: float = 1.0 / 60.0         # measured checkpoint write cost
    step_time_hours: float = 1.0 / 3600.0   # seed; EMA-updated
    total_steps: int = 1000
    pod_age_hours: float = 0.0              # age of the pod at run start
    grid_dt: float = 1.0 / 60.0
    async_write: bool = True
    fixed_interval_steps: int = 100

    def __post_init__(self):
        self._tables = None
        self._next_ckpt_step: Optional[int] = None
        self._last_ckpt_step = 0
        self._pod_start_step = 0   # global step at which the current pod began
        self._writer: Optional[threading.Thread] = None
        self.n_saved = 0
        self.n_emergency = 0
        self._recompute()

    # -- schedule -----------------------------------------------------------
    def _steps_per_grid(self) -> float:
        return max(self.grid_dt / max(self.step_time_hours, 1e-9), 1.0)

    def _recompute(self):
        if self.policy == "dp":
            remaining_h = (self.total_steps - self._last_ckpt_step) \
                * self.step_time_hours
            job_steps = max(int(round(remaining_h / self.grid_dt)), 1)
            # the DP table V/K covers EVERY remaining length j <= job_steps,
            # so restarts reuse it (the paper: "we precompute the
            # checkpointing schedule of jobs of different lengths") - only
            # solve when no table covers the need (e.g. step time grew)
            if self._tables is None or \
                    self._tables.V.shape[0] - 1 < job_steps:
                delta_steps = max(int(round(self.delta_hours / self.grid_dt)),
                                  1)
                self._tables = ckpt_policy.solve(
                    self.dist, job_steps, grid_dt=self.grid_dt,
                    delta_steps=delta_steps)
        self._plan_next()

    def _plan_next(self):
        step = self._last_ckpt_step
        if self.policy == "none":
            self._next_ckpt_step = None
        elif self.policy == "fixed":
            self._next_ckpt_step = step + self.fixed_interval_steps
        elif self.policy == "young_daly":
            mttf = young_daly.mttf_from_initial_rate(self.dist)
            tau_h = float(young_daly.interval(self.delta_hours, mttf))
            self._next_ckpt_step = step + max(
                int(round(tau_h / max(self.step_time_hours, 1e-9))), 1)
        else:  # dp
            # pod age counts only steps run on THIS pod (a restart resets it)
            age_h = self.pod_age_hours + \
                (step - self._pod_start_step) * self.step_time_hours
            remaining = self.total_steps - step
            rem_grid = max(int(round(remaining * self.step_time_hours
                                     / self.grid_dt)), 1)
            rem_grid = min(rem_grid, self._tables.V.shape[0] - 1)
            interval_grid = self._tables.interval_steps(
                rem_grid, int(round(age_h / self.grid_dt)))
            steps = max(int(round(interval_grid * self.grid_dt
                                  / max(self.step_time_hours, 1e-9))), 1)
            self._next_ckpt_step = step + steps

    # -- runtime hooks --------------------------------------------------------
    def observe_step_time(self, seconds: float, ema: float = 0.1):
        h = seconds / 3600.0
        self.step_time_hours = (1 - ema) * self.step_time_hours + ema * h

    def should_checkpoint(self, step: int) -> bool:
        return self._next_ckpt_step is not None and \
            step >= self._next_ckpt_step

    def save(self, step: int, tree, metadata=None, *, emergency: bool = False):
        if self._writer is not None:
            self._writer.join()  # one in-flight write at a time
        meta = dict(metadata or {})
        meta["policy"] = self.policy
        meta["emergency"] = emergency
        self._writer = save_checkpoint(
            self.directory, step, tree, meta,
            blocking=not self.async_write or emergency)
        self._last_ckpt_step = step
        self.n_saved += 1
        if emergency:
            self.n_emergency += 1
        self._plan_next()

    def on_preemption_warning(self, step: int, tree, metadata=None):
        """The provider's 30 s warning: flush an emergency checkpoint NOW."""
        self.save(step, tree, metadata, emergency=True)

    def restore(self, template):
        if self._writer is not None:
            self._writer.join()
        return restore_latest(self.directory, template)

    def on_restart(self, *, pod_age_hours: float = 0.0, resumed_step: int = 0):
        """Resume on a fresh pod: re-anchor ages and recompute the schedule
        (the paper recomputes E[M*(J_remaining, 0)] after every failure)."""
        self.pod_age_hours = pod_age_hours
        self._last_ckpt_step = resumed_step
        self._pod_start_step = resumed_step
        self._recompute()
