"""Checkpointing-DP inner recurrence (Eqs. 11-15) as a Pallas TPU kernel.

Grid = ``(S,)``: one program per scenario, with the whole per-scenario DP —
``n_sweeps`` restart-cost fixed-point sweeps x ``j_max`` rows — run inside
the program so the value table never leaves VMEM.  Layout per program:

  * the VM-age axis lives on the lanes: every row is a ``(1, T_pad)`` f32
    vector, so one candidate evaluation is a handful of W-wide VPU
    multiply-adds;
  * the j-loop's min-reduce over candidate intervals is a blocked
    sequential scan: candidates ``i = 1..j`` stream one at a time, each
    updating a running ``(1, T_pad)`` min (strict ``<`` on an ascending
    scan keeps the reference's first-match argmin for ``K``);
  * the value table is a persistent ``(j_max+1, T_pad)`` VMEM scratch whose
    tail padding holds each row's horizon value, so the reference's
    ``clip(t + w, 0, t_max)`` age gather becomes a plain shifted row load.

Unlike the XLA backend — which hoists ``(T, I)`` probability/loss grids per
scenario — this kernel recomputes ``p_fail``/``e_lost`` on the fly from the
``(1, T_pad)`` CDF rows as shifted-slice arithmetic: nothing larger than the
value table is ever materialized, which is what lets market-scale scenario
counts fit one core's VMEM.  The trade is bit-exactness: recomputation under
a different fusion schedule rounds differently at ULP scale, so this backend
is tolerance-tested against the reference, not bit-pinned (see
``docs/solver.md``).

The dollar objective adds one more ``(1, T_pad)`` row per program — the
cumulative-dollar grid ``Pc`` (``grids.price_cum_grids``) — and a per-program
scalar dollar restart overhead: segment dollars ``dP = Pc[t+w] - Pc[t]`` are
the same shifted-slice pattern as the CDF deltas.  The cumulative row is
built host-side on the extended age axis, so edge padding beyond it only
ever feeds dead lanes (whose values are overwritten with ``Rj``).

Oracle: ``solver_backends.reference``.  On CPU containers the kernel runs
with ``interpret=True`` (tests/test_solver_backends.py, marker ``pallas``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_EPS = 1e-9


def _dp_kernel(*refs, dt: float, restart_overhead: float, j_max: int,
               t_max: int, delta_steps: int, n_sweeps: int, TPL: int,
               TB: int, price: bool):
    if price:
        fc_ref, hc_ref, c0_ref, pc_ref, ro_ref, v_out, k_out, \
            v_scr, c0_scr = refs
    else:
        fc_ref, hc_ref, c0_ref, v_out, k_out, v_scr, c0_scr = refs
        pc_ref = ro_ref = None
    T = t_max + 1
    dtf = jnp.float32(dt)
    rof = jnp.float32(restart_overhead)
    fc = fc_ref[...]                                  # (1, TB)
    hc = hc_ref[...]
    Ft = fc[:, :TPL]
    Ht = hc[:, :TPL]
    St = jnp.maximum(1.0 - Ft, _EPS)
    dead = (1.0 - Ft) < 1e-6                          # padded lanes: Fc=1
    t_dt = jax.lax.broadcasted_iota(jnp.int32, (1, TPL), 1) * dtf
    if price:
        pc = pc_ref[...]                              # (1, TB) cumulative $
        Pt = pc[:, :TPL]
        rof = ro_ref[0, 0]                            # per-scenario $ overhead

    # row 0 (job done): V = 0 at every age, including the horizon padding
    v_scr[0, :] = jnp.zeros((TB,), jnp.float32)
    v_out[0, 0, :] = jnp.zeros((T,), jnp.float32)
    k_out[0, 0, :] = jnp.zeros((T,), jnp.int32)
    # restart-cost column seed (cold j*dt or the warm-start V's column 0)
    c0_scr[...] = c0_ref[...]

    def sweep(_s, carry):
        r = rof + c0_scr[...]                         # (1, j_max+1) snapshot

        def row(j, carry):
            Rj = r[0, j]
            m0 = jnp.full((1, TPL), jnp.inf, jnp.float32)
            k0 = jnp.zeros((1, TPL), jnp.int32)

            def cand(i, mk):
                m, k = mk
                w = jnp.where(i == j, i, i + delta_steps)
                Fe = jax.lax.dynamic_slice(fc, (0, w), (1, TPL))
                He = jax.lax.dynamic_slice(hc, (0, w), (1, TPL))
                p_fail = jnp.clip((Fe - Ft) / St, 0.0, 1.0)
                dF = jnp.maximum(Fe - Ft, _EPS)
                e_lost = (He - Ht) / dF - t_dt
                e_lost = jnp.clip(e_lost, 0.0, w * dtf)
                vrow = pl.load(v_scr, (pl.ds(j - i, 1), pl.ds(w, TPL)))
                if price:
                    Pe = jax.lax.dynamic_slice(pc, (0, w), (1, TPL))
                    dP = Pe - Pt
                    pb = dP / (w * dtf)
                    v_succ = dP + vrow
                    cost = (1.0 - p_fail) * v_succ \
                        + p_fail * (e_lost * pb + Rj)
                else:
                    v_succ = w * dtf + vrow
                    cost = (1.0 - p_fail) * v_succ + p_fail * (e_lost + Rj)
                upd = cost < m
                return jnp.where(upd, cost, m), jnp.where(upd, i, k)

            m, k = jax.lax.fori_loop(1, j + 1, cand, (m0, k0))
            vj = jnp.where(dead, Rj, m)
            kj = jnp.where(dead, jnp.minimum(j, j_max), k)
            # persist the row: computed lanes, then horizon padding (age >=
            # t_max means a dead VM, whose value is exactly Rj)
            pl.store(v_scr, (pl.ds(j, 1), pl.ds(0, TPL)), vj)
            pl.store(v_scr, (pl.ds(j, 1), pl.ds(TPL, TB - TPL)),
                     jnp.broadcast_to(Rj, (1, TB - TPL)))
            pl.store(c0_scr, (pl.ds(0, 1), pl.ds(j, 1)), vj[:, 0:1])
            pl.store(v_out, (pl.ds(0, 1), pl.ds(j, 1), pl.ds(0, T)),
                     vj[:, :T].reshape(1, 1, T))
            pl.store(k_out, (pl.ds(0, 1), pl.ds(j, 1), pl.ds(0, T)),
                     kj[:, :T].reshape(1, 1, T))
            return carry

        return jax.lax.fori_loop(1, j_max + 1, row, carry)

    jax.lax.fori_loop(0, n_sweeps, sweep, 0)


def dp_recurrence(Fc, Hc, col0, *, grid_dt: float, restart_overhead: float,
                  j_max: int, t_max: int, delta_steps: int, n_sweeps: int,
                  interpret: bool = False, Pc=None, Ro=None):
    """Solve the batched checkpointing DP.

    Fc, Hc: (S, t_max+1) f32 CDF / partial-expectation grids (see
    ``solver_backends.grids``); col0: (S, j_max+1) f32 seed for the
    restart-cost column (cold ``j*dt`` or a warm start's ``V[:, :, 0]``).
    Returns (V, K) of shapes (S, j_max+1, t_max+1).

    Dollar objective: ``Pc`` is the (S, t_max+1+j_max+delta_steps) f32
    cumulative-dollar grid and ``Ro`` the (S,) f32 dollar restart overhead
    (``restart_overhead`` is then ignored).  ``col0`` must be the dollar
    seed (``Pc[:, :j_max+1]`` cold, or a warm dollar table's column 0).
    """
    S, T = Fc.shape
    assert T == t_max + 1, (T, t_max)
    price = Pc is not None
    pad = j_max + delta_steps + 8        # max age shift is j_max + delta
    TPL = T + pad                        # compute width (tail lanes: dead)
    TB = TPL + pad                       # buffer width for shifted loads
    fc = jnp.pad(Fc, ((0, 0), (0, TB - T)), mode="edge")
    hc = jnp.pad(Hc, ((0, 0), (0, TB - T)), mode="edge")
    kernel = functools.partial(
        _dp_kernel, dt=float(grid_dt), restart_overhead=float(restart_overhead),
        j_max=j_max, t_max=t_max, delta_steps=delta_steps, n_sweeps=n_sweeps,
        TPL=TPL, TB=TB, price=price)
    in_specs = [
        pl.BlockSpec((1, TB), lambda s: (s, 0)),
        pl.BlockSpec((1, TB), lambda s: (s, 0)),
        pl.BlockSpec((1, j_max + 1), lambda s: (s, 0)),
    ]
    inputs = [fc, hc, col0]
    if price:
        # the extended Pc axis already covers every live-lane gather
        # (t < T, shift <= j_max + delta); edge padding past it only feeds
        # dead lanes whose values are overwritten with Rj
        assert Ro is not None, "dollar mode needs the (S,) dollar overhead"
        pc = jnp.pad(jnp.asarray(Pc, jnp.float32),
                     ((0, 0), (0, TB - Pc.shape[1])), mode="edge")
        in_specs += [pl.BlockSpec((1, TB), lambda s: (s, 0)),
                     pl.BlockSpec((1, 1), lambda s: (s, 0))]
        inputs += [pc, jnp.asarray(Ro, jnp.float32).reshape(S, 1)]
    V, K = pl.pallas_call(
        kernel,
        grid=(S,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, j_max + 1, T), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, j_max + 1, T), lambda s: (s, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, j_max + 1, T), jnp.float32),
            jax.ShapeDtypeStruct((S, j_max + 1, T), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((j_max + 1, TB), jnp.float32),
            pltpu.VMEM((1, j_max + 1), jnp.float32),
        ],
        interpret=interpret,
    )(*inputs)
    return V, K
