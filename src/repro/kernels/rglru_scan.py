"""Gated linear recurrence (RG-LRU core) as a Pallas TPU kernel.

Computes h_t = a_t * h_{t-1} + b_t along the sequence.  Tiling: grid =
(batch, S/block_s) with the sequence axis as the sequential (inner) grid
dimension, so the (1, W) f32 hidden-state scratch persists across sequence
blocks in VMEM.  Each block streams (block_s x W) coefficient tiles HBM->VMEM
and runs the recurrence with an unrolled fori over the block's rows - each
step is a W-wide VPU multiply-add (W = lru_width, 2560 for RecurrentGemma =
20 VREG lanes of 128).

The last block also emits h_last (the decode/prefill carry state).

Oracle: kernels/ref.py linear_recurrence (lax.scan); the XLA production path
is the associative scan in kernels/ops.py.  Tests sweep shapes/dtypes with
interpret=True.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h0_ref, out_ref, hlast_ref, h_ref, *,
                  block_s):
    si = pl.program_id(1)
    ns = pl.num_programs(1)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)          # (block_s, W)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        out_ref[0, t, :] = h.astype(out_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_s, step, h_ref[0])
    h_ref[...] = h[None]

    @pl.when(si == ns - 1)
    def _finish():
        hlast_ref[0, ...] = h_ref[0].astype(hlast_ref.dtype)


def linear_recurrence(a, b, h0=None, *, block_s: int = 256,
                      interpret: bool = False):
    """a, b: (B, S, W); h0: (B, W) or None.  Returns (h: (B,S,W), h_last)."""
    B, S, W = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, W), a.dtype)
    bs = min(block_s, S)
    while S % bs:
        bs //= 2
    ns = S // bs
    grid = (B, ns)
    kernel = functools.partial(_rglru_kernel, block_s=bs)
    out, hlast = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, W), lambda bi, si: (bi, si, 0)),
            pl.BlockSpec((1, bs, W), lambda bi, si: (bi, si, 0)),
            pl.BlockSpec((1, W), lambda bi, si: (bi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs, W), lambda bi, si: (bi, si, 0)),
            pl.BlockSpec((1, W), lambda bi, si: (bi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), a.dtype),
            jax.ShapeDtypeStruct((B, W), a.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, W), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return out, hlast
