"""Flash-decode: single-token GQA attention over a long KV cache as a Pallas
TPU kernel.

Tiling: grid = (batch, S/block_k) with the cache-sequence axis sequential;
the query tile (H x D) stays resident in VMEM while (block_k x KV x D) key /
value tiles stream from HBM.  Online softmax state (acc: (H, D) f32, running
max/sum: (H,)) lives in VMEM scratch; the final block normalizes and writes
(H x D).  Decode is HBM-bandwidth-bound - the kernel reads the cache exactly
once, which is the roofline optimum.

Valid-length masking uses the per-batch ``lengths`` vector (streamed as a
(1,)-block input); ring-buffer caches pass lengths == window.

Oracle: kernels/ref.py decode_attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                   l_ref, *, scale, block_k, kv_heads, q_heads):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)
    G = q_heads // kv_heads

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[0]
    run = ki * block_k < length

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale          # (H, D)
        k = k_ref[0].astype(jnp.float32)                  # (bk, KV, D)
        v = v_ref[0].astype(jnp.float32)
        # fold GQA: q (KV, G, D) x k (bk, KV, D) -> scores (KV, G, bk)
        qg = q.reshape(kv_heads, G, -1)
        s = jax.lax.dot_general(qg, k, (((2,), (2,)), ((0,), (1,))))
        # -> (KV, G, bk); mask invalid cache slots
        pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 2)
        s = jnp.where(pos < length, s, NEG_INF)
        s = s.reshape(q_heads, block_k)                    # (H, bk)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        pg = p.reshape(kv_heads, G, block_k)
        out = jax.lax.dot_general(pg, v, (((2,), (0,)), ((0,), (1,))))
        acc_ref[...] = acc_ref[...] * corr[:, None] + \
            out.reshape(q_heads, -1)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *, scale=None,
                     block_k: int = 256, interpret: bool = False):
    """q: (B, H, D); caches: (B, S, KV, D); lengths: (B,) -> (B, H, D)."""
    B, H, D = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    scale = D ** -0.5 if scale is None else scale
    bk = min(block_k, S)
    while S % bk:
        bk //= 2
    nk = S // bk
    kernel = functools.partial(_decode_kernel, scale=scale, block_k=bk,
                               kv_heads=KV, q_heads=H)
    out = pl.pallas_call(
        kernel,
        grid=(B, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda bi, ki: (bi,)),
            pl.BlockSpec((1, H, D), lambda bi, ki: (bi, 0, 0)),
            pl.BlockSpec((1, bk, KV, D), lambda bi, ki: (bi, ki, 0, 0)),
            pl.BlockSpec((1, bk, KV, D), lambda bi, ki: (bi, ki, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, D), lambda bi, ki: (bi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((H, D), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), q, k_cache, v_cache)
    return out
