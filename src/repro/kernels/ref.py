"""Pure-jnp oracles for every Pallas kernel (the correctness references).

Each function is the mathematical definition with no blocking/tiling; tests
sweep shapes and dtypes asserting the kernels (interpret=True on CPU) match
these to tolerance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_expand(k, q_heads):
    """(B, S, KV, D) -> (B, S, H, D) by repeating each kv head H/KV times."""
    b, s, kv, d = k.shape
    rep = q_heads // kv
    return jnp.repeat(k, rep, axis=2)


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              scale: float | None = None):
    """Multi-head (GQA) attention oracle.

    q: (B, Sq, H, D); k, v: (B, Sk, KV, D); returns (B, Sq, H, D).
    ``window`` > 0 restricts each query to the last ``window`` keys
    (local/sliding attention); causal offsets assume q occupies the final
    Sq positions of the Sk-long context.
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else d ** -0.5
    k = _gqa_expand(k, h)
    v = _gqa_expand(v, h)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(sq)[:, None] + (sk - sq)
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *, scale: float | None = None):
    """Single-token decode oracle.

    q: (B, H, D); k_cache, v_cache: (B, S, KV, D); lengths: (B,) valid cache
    lengths.  Returns (B, H, D).

    GQA via grouped einsums (no KV repeat): materializing the expanded
    (B,S,H,D) cache both wastes memory and - under GSPMD - invites a
    head-sharded cache layout that reshards the multi-GB cache per layer.
    """
    b, h, d = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    scale = scale if scale is not None else d ** -0.5
    from .. import sharding as _shd   # anchor only; no-op without a mesh
    qg = (q.astype(jnp.float32) * scale).reshape(b, kv, g, d)
    k32 = k_cache.astype(jnp.float32)
    v32 = v_cache.astype(jnp.float32)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k32)        # (B,KV,G,S)
    # keep the context dim sharded like the cache: otherwise GSPMD gathers
    # the f32 cache per layer rather than emitting partial logits + a small
    # softmax all-reduce (~250 GB/chip/token on yi-34b decode_32k, §Perf C3)
    logits = _shd.constrain(logits, "cache_batch", None, None, "cache_seq")
    valid = jnp.arange(s)[None, None, None, :] < lengths[:, None, None, None]
    logits = jnp.where(valid, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v32)
    return out.reshape(b, h, d).astype(q.dtype)


def linear_recurrence(a, b0, h0=None):
    """Gated linear recurrence oracle: h_t = a_t * h_{t-1} + b_t.

    a, b0: (B, S, D); h0: (B, D) initial state (zeros if None).
    Returns (h: (B, S, D), h_last: (B, D)).  This is the RG-LRU core once the
    gate algebra has produced (a_t, b_t).
    """
    if h0 is None:
        h0 = jnp.zeros(a.shape[:1] + a.shape[2:], a.dtype)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    (a_s, b_s) = (jnp.swapaxes(a, 0, 1), jnp.swapaxes(b0, 0, 1))
    h_last, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                              (a_s.astype(jnp.float32), b_s.astype(jnp.float32)))
    return jnp.swapaxes(hs, 0, 1).astype(a.dtype), h_last.astype(a.dtype)


def mlstm_chunkwise(q, k, v, log_f, log_i, *, chunk: int = 64, c0=None,
                    n0=None, m0=None, eps: float = 1e-6):
    """Chunkwise-parallel mLSTM oracle (xLSTM matrix memory, stabilized).

    q, k, v : (B, S, H, D)
    log_f   : (B, S, H) log-sigmoid forget pre-activations (log f_t)
    log_i   : (B, S, H) input-gate pre-activations (log-space i_t)
    Returns (out: (B,S,H,D), (C, n, m) final state) where C: (B,H,D,D),
    n: (B,H,D), m: (B,H).

    This is the sequential (step-by-step) definition run via scan - the
    oracle for both the chunkwise JAX implementation and any future kernel:
        m_t = max(log_f_t + m_{t-1}, log_i_t)
        C_t = exp(log_f_t + m_{t-1} - m_t) C_{t-1} + exp(log_i_t - m_t) k_t v_t^T
        n_t = exp(log_f_t + m_{t-1} - m_t) n_{t-1} + exp(log_i_t - m_t) k_t
        h_t = C_t^T q_t / max(|n_t . q_t|, exp(-m_t), eps)
    """
    b, s, h, d = q.shape
    scale = d ** -0.5
    q32, k32, v32 = (x.astype(jnp.float32) for x in (q, k, v))
    lf = log_f.astype(jnp.float32)
    li = log_i.astype(jnp.float32)
    C = jnp.zeros((b, h, d, d), jnp.float32) if c0 is None else c0.astype(jnp.float32)
    n = jnp.zeros((b, h, d), jnp.float32) if n0 is None else n0.astype(jnp.float32)
    m = jnp.full((b, h), -jnp.inf, jnp.float32) if m0 is None else m0.astype(jnp.float32)

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, lft, lit = xs          # (B,H,D), (B,H,D), (B,H,D), (B,H), (B,H)
        m_new = jnp.maximum(lft + m, lit)
        fg = jnp.exp(lft + m - m_new)[..., None]              # (B,H,1)
        ig = jnp.exp(lit - m_new)[..., None]                  # (B,H,1)
        C = fg[..., None] * C + ig[..., None] * (kt[..., :, None] * vt[..., None, :])
        n = fg * n + ig * kt
        qs = qt * scale
        num = jnp.einsum("bhij,bhi->bhj", C, qs)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhi,bhi->bh", n, qs)),
                          jnp.exp(-m_new))[..., None] + eps
        return (C, n, m_new), num / den

    xs = (jnp.moveaxis(q32, 1, 0), jnp.moveaxis(k32, 1, 0),
          jnp.moveaxis(v32, 1, 0), jnp.moveaxis(lf, 1, 0), jnp.moveaxis(li, 1, 0))
    (C, n, m), out = jax.lax.scan(step, (C, n, m), xs)
    return jnp.moveaxis(out, 0, 1).astype(q.dtype), (C, n, m)
