"""Compute hot-spot kernels.

Three implementations per op, in three modules:

  ref.py                pure-jnp oracles (ground truth for every test)
  ops.py                jit'd dispatchers + XLA production paths:
                          - flash_attention_xla: blocked online-softmax fwd
                            with a hand-written FlashAttention-2 backward
                            (custom_vjp; no O(S^2) residuals) - what the
                            dry-run lowers and CPU training runs
                          - _decode_xla: serving decode, cache consumed in
                            stored dtype, f32 softmax statistics only
                          - associative-scan linear recurrence
  flash_attention.py    Pallas TPU kernel: grid (B*H, Sq/bq, Sk/bk), VMEM
                        scratch accumulators, causal/windowed block skipping,
                        GQA via index maps
  rglru_scan.py         Pallas TPU kernel: sequence-blocked gated linear
                        recurrence with a persistent VMEM hidden state
  decode_attention.py   Pallas TPU kernel: flash-decode over a long KV cache
                        (one HBM pass - the decode roofline optimum)
  dp_recurrence.py      Pallas TPU kernel: the checkpointing-DP inner
                        recurrence (Eqs. 11-15), grid over the scenario axis,
                        rows as (1, TB) lanes with a VMEM value scratch -
                        reached via solve_batch(backend="pallas"); see
                        docs/solver.md

Pallas kernels target TPU; on this CPU container they are validated with
``interpret=True`` against ref.py over shape/dtype sweeps
(tests/test_kernels.py).
"""
