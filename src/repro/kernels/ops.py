"""Dispatching wrappers around the attention/recurrence compute hot-spots.

Three implementations per op:
  * ``ref``       - the pure-jnp oracle (kernels/ref.py), O(S^2) memory.
  * ``xla_flash`` - blockwise online-softmax attention written as XLA scans
                    with a hand-written flash *backward* (custom_vjp, no
                    O(S^2) residuals).  This is what the multi-pod dry-run
                    lowers, and what CPU training uses.
  * ``pallas``    - the TPU Pallas kernels (kernels/flash_attention.py etc.),
                    VMEM-blocked for real hardware; validated on CPU via
                    interpret=True against ``ref``.

``impl="auto"`` picks ``ref`` for short sequences (cheaper at small S) and
``xla_flash`` beyond ``_AUTO_FLASH_S``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref

_AUTO_FLASH_S = 2048
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Blockwise flash attention in pure XLA (fwd + custom bwd)
# ---------------------------------------------------------------------------

def _pick_block(s: int, want: int) -> int:
    b = min(want, s)
    while s % b:
        b //= 2
    return max(b, 1)


def _reshape_back(x, B, Sq, H, D=None):
    # x: (nq, B, KV, G, bq, [D]) -> (B, Sq, H, [D])
    nq = x.shape[0]
    bq = x.shape[4]
    kv, g = x.shape[2], x.shape[3]
    if D is None:
        x = jnp.transpose(x, (1, 0, 4, 2, 3))             # B,nq,bq,KV,G
        return x.reshape(B, Sq, H)
    x = jnp.transpose(x, (1, 0, 4, 2, 3, 5))              # B,nq,bq,KV,G,D
    return x.reshape(B, Sq, H, D)


def _flash_fwd_shaped(q, k, v, causal, window, scale, block_q, block_k):
    B, Sq, H, D = q.shape
    out, lse = _flash_fwd_raw(q, k, v, causal, window, scale, block_q, block_k)
    out = _reshape_back(out, B, Sq, H, D).astype(q.dtype)
    lse = _reshape_back(lse, B, Sq, H)
    return out, lse


def _flash_fwd_raw(q, k, v, causal, window, scale, block_q, block_k):
    """As _flash_fwd but returns the blocked (nq,B,KV,G,bq,...) layout."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    bq = _pick_block(Sq, block_q)
    bk = _pick_block(Sk, block_k)
    nq, nk = Sq // bq, Sk // bk
    off = Sk - Sq
    q32 = (q.astype(jnp.float32) * scale).reshape(B, nq, bq, KV, G, D)
    k32 = k.astype(jnp.float32).reshape(B, nk, bk, KV, D)
    v32 = v.astype(jnp.float32).reshape(B, nk, bk, KV, D)
    q_pos = jnp.arange(Sq).reshape(nq, bq) + off
    k_pos = jnp.arange(Sk).reshape(nk, bk)

    def q_block(qi):
        qb = q32[:, qi]
        qp = q_pos[qi]

        def kv_step(carry, ki):
            acc, m, l = carry
            kb, vb = k32[:, ki], v32[:, ki]
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb)
            kp = k_pos[ki]
            mask = jnp.ones((bq, bk), bool)
            if causal:
                mask = mask & (kp[None, :] <= qp[:, None])
            if window > 0:
                mask = mask & (kp[None, :] > qp[:, None] - window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum("bkgqs,bskd->bkgqd", p, vb)
            return (acc, m_new, l), None

        acc0 = jnp.zeros((B, KV, G, bq, D), jnp.float32)
        m0 = jnp.full((B, KV, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, bq), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return out, lse

    return jax.lax.map(q_block, jnp.arange(nq))


def _flash_bwd(q, k, v, out, lse, dout, causal, window, scale, block_q, block_k):
    """FlashAttention-2 backward: recompute P per block from (q,k,lse); no
    O(S^2) residuals.  All accumulation in f32."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    bq = _pick_block(Sq, block_q)
    bk = _pick_block(Sk, block_k)
    nq, nk = Sq // bq, Sk // bk
    off = Sk - Sq
    q32 = q.astype(jnp.float32).reshape(B, nq, bq, KV, G, D)
    k32 = k.astype(jnp.float32).reshape(B, nk, bk, KV, D)
    v32 = v.astype(jnp.float32).reshape(B, nk, bk, KV, D)
    do32 = dout.astype(jnp.float32).reshape(B, nq, bq, KV, G, D)
    o32 = out.astype(jnp.float32).reshape(B, nq, bq, KV, G, D)
    lse_b = lse.reshape(B, nq, bq, KV, G)
    # delta_i = rowsum(dO_i * O_i), per (nq, bq) block layout
    delta = jnp.einsum("bnqkgd,bnqkgd->bnqkg", do32, o32)    # (B,nq,bq,KV,G)
    q_pos = jnp.arange(Sq).reshape(nq, bq) + off
    k_pos = jnp.arange(Sk).reshape(nk, bk)

    def k_block(ki):
        kb, vb = k32[:, ki], v32[:, ki]
        kp = k_pos[ki]

        def q_step(carry, qi):
            dk_acc, dv_acc = carry
            qb = q32[:, qi]
            qp = q_pos[qi]
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb * scale, kb)
            mask = jnp.ones((bq, bk), bool)
            if causal:
                mask = mask & (kp[None, :] <= qp[:, None])
            if window > 0:
                mask = mask & (kp[None, :] > qp[:, None] - window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            p = jnp.exp(s - jnp.transpose(lse_b[:, qi], (0, 2, 3, 1))[..., None])
            dob = do32[:, qi]
            dp = jnp.einsum("bqkgd,bskd->bkgqs", dob, vb)
            dl = jnp.transpose(delta[:, qi], (0, 2, 3, 1))    # (B,KV,G,bq)
            ds = p * (dp - dl[..., None]) * scale
            dq_b = jnp.einsum("bkgqs,bskd->bqkgd", ds, kb)
            dk_acc = dk_acc + jnp.einsum("bkgqs,bqkgd->bskd", ds, qb)
            dv_acc = dv_acc + jnp.einsum("bkgqs,bqkgd->bskd", p, dob)
            return (dk_acc, dv_acc), dq_b

        dk0 = jnp.zeros((B, bk, KV, D), jnp.float32)
        dv0 = jnp.zeros((B, bk, KV, D), jnp.float32)
        (dk_b, dv_b), dq_parts = jax.lax.scan(q_step, (dk0, dv0), jnp.arange(nq))
        return dk_b, dv_b, dq_parts                       # dq_parts: (nq,B,bq,KV,G,D)

    dk, dv, dq = jax.lax.map(k_block, jnp.arange(nk))
    # dq: (nk, nq, B, bq, KV, G, D) -> sum over k blocks
    dq = dq.sum(axis=0)
    dq = jnp.transpose(dq, (1, 0, 2, 3, 4, 5)).reshape(B, Sq, KV, G, D) \
        .reshape(B, Sq, H, D)
    dk = jnp.moveaxis(dk, 0, 1).reshape(B, Sk, KV, D)
    dv = jnp.moveaxis(dv, 0, 1).reshape(B, Sk, KV, D)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention_xla(q, k, v, causal=True, window=0, scale=None,
                        block_q=512, block_k=512):
    """Blockwise attention, XLA-native, flash forward + flash backward."""
    scale = q.shape[-1] ** -0.5 if scale is None else scale
    out, _ = _flash_fwd_shaped(q, k, v, causal, window, scale, block_q, block_k)
    return out


def _fa_fwd(q, k, v, causal, window, scale, block_q, block_k):
    scale_v = q.shape[-1] ** -0.5 if scale is None else scale
    out, lse = _flash_fwd_shaped(q, k, v, causal, window, scale_v,
                                 block_q, block_k)
    return out, (q, k, v, out, lse)


def _fa_bwd(causal, window, scale, block_q, block_k, res, dout):
    q, k, v, out, lse = res
    scale_v = q.shape[-1] ** -0.5 if scale is None else scale
    return _flash_bwd(q, k, v, out, lse, dout, causal, window, scale_v,
                      block_q, block_k)


flash_attention_xla.defvjp(_fa_fwd, _fa_bwd)


# ---------------------------------------------------------------------------
# Public dispatchers
# ---------------------------------------------------------------------------

def attention(q, k, v, *, causal: bool = True, window: int = 0,
              scale=None, impl: str = "auto", interpret: bool = True):
    """Training/prefill attention. q: (B,Sq,H,D); k,v: (B,Sk,KV,D)."""
    if impl == "auto":
        impl = "ref" if k.shape[1] <= _AUTO_FLASH_S else "xla_flash"
    if impl == "ref":
        return ref.attention(q, k, v, causal=causal, window=window, scale=scale)
    if impl == "xla_flash":
        return flash_attention_xla(q, k, v, causal, window, scale)
    if impl == "pallas":
        from . import flash_attention as fa
        return fa.flash_attention(q, k, v, causal=causal, window=window,
                                  scale=scale, interpret=interpret)
    raise ValueError(f"unknown attention impl {impl!r}")


def _decode_xla(q, k_cache, v_cache, lengths, scale):
    """Serving-grade XLA decode: grouped GQA einsums with
    ``preferred_element_type`` so the multi-GB cache is consumed in its
    stored dtype (the oracle's f32 casts would materialize 2x-cache f32
    temporaries per layer); f32 only for softmax statistics."""
    from .. import sharding as _shd
    b, h, d = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    scale = d ** -0.5 if scale is None else scale
    qg = (q.astype(jnp.float32) * scale).astype(k_cache.dtype) \
        .reshape(b, kv, g, d)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                        preferred_element_type=jnp.float32)
    logits = _shd.constrain(logits, "cache_batch", None, None, "cache_seq")
    valid = jnp.arange(s)[None, None, None, :] < lengths[:, None, None, None]
    logits = jnp.where(valid, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, d).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *, scale=None,
                     impl: str = "xla", interpret: bool = True):
    """Single new token vs a KV cache. q: (B,H,D); caches: (B,S,KV,D)."""
    if impl == "ref":
        return ref.decode_attention(q, k_cache, v_cache, lengths, scale=scale)
    if impl in ("xla", "auto", "xla_flash"):
        return _decode_xla(q, k_cache, v_cache, lengths, scale)
    if impl == "pallas":
        from . import decode_attention as da
        return da.decode_attention(q, k_cache, v_cache, lengths, scale=scale,
                                   interpret=interpret)
    raise ValueError(f"unknown decode impl {impl!r}")


def linear_recurrence(a, b, h0=None, *, impl: str = "assoc", interpret: bool = True):
    """h_t = a_t * h_{t-1} + b_t over axis 1.  a, b: (B, S, D)."""
    if impl == "ref":
        return ref.linear_recurrence(a, b, h0)
    if impl == "assoc":
        B, S, D = a.shape
        h0v = jnp.zeros((B, D), a.dtype) if h0 is None else h0
        # fold h0 into the first step: h_1 = a_1*h0 + b_1
        b0 = b.at[:, 0].add(a[:, 0] * h0v)
        af = a.astype(jnp.float32)
        bf = b0.astype(jnp.float32)

        def op(x, y):
            a1, b1 = x
            a2, b2 = y
            return a1 * a2, a2 * b1 + b2

        aa, bb = jax.lax.associative_scan(op, (af, bf), axis=1)
        return bb.astype(a.dtype), bb[:, -1].astype(a.dtype)
    if impl == "pallas":
        from . import rglru_scan as rs
        return rs.linear_recurrence(a, b, h0, interpret=interpret)
    raise ValueError(f"unknown recurrence impl {impl!r}")
