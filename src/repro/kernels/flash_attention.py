"""FlashAttention-2 forward as a Pallas TPU kernel.

Tiling: grid = (batch*q_heads, Sq/block_q, Sk/block_k); the last grid axis is
the sequential online-softmax reduction (TPU executes the grid in row-major
order on one core, so VMEM scratch persists across the k-axis).  BlockSpecs
stream (block_q x D) query tiles and (block_k x D) key/value tiles HBM->VMEM;
the f32 accumulator (block_q x D), running max and sum live in VMEM scratch.
MXU alignment: block_q/block_k default 128 (TPU lane width 128, MXU 128x128);
D is the head dim (64..256 for the assigned archs).

GQA is handled in the index maps (q head h reads kv head h // (H/KV)) - no
materialized K/V expansion.  Causality skips fully-masked k-blocks via
pl.when predication; the final k-step normalizes and writes the output tile.

The backward pass reuses the XLA flash backward from kernels/ops.py via
custom_vjp (training on TPU would add a Pallas bwd kernel; the dry-run and
CPU training lower the XLA path anyway - see DESIGN.md).

Validated against kernels/ref.py with interpret=True (CPU) in
tests/test_kernels.py over shape/dtype/causality sweeps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               scale, causal, window, block_q, block_k, sk_off):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q + sk_off          # absolute position of q row 0
    k_start = ki * block_k

    # skip fully-masked blocks (strictly above the causal diagonal, or
    # entirely left of the local window)
    run = jnp.asarray(True)
    if causal:
        run = run & (k_start <= q_start + block_q - 1)
    if window > 0:
        run = run & (k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale        # (bq, D)
        k = k_ref[0].astype(jnp.float32)                # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q,
                                                               block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q,
                                                               block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[...]
        safe = jnp.maximum(l, 1e-30)
        o_ref[0, ...] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale=None, block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B, Sq, H, D); k, v: (B, Sk, KV, D) -> (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = D ** -0.5 if scale is None else scale
    bq = min(block_q, Sq)
    while Sq % bq:
        bq //= 2
    bk = min(block_k, Sk)
    while Sk % bk:
        bk //= 2
    nq, nk = Sq // bq, Sk // bk
    sk_off = Sk - Sq

    # layout: fold heads into the leading grid axis via (B*H) "rows"
    qr = jnp.moveaxis(q, 2, 1).reshape(B * H, Sq, D)
    kr = jnp.moveaxis(k, 2, 1).reshape(B * KV, Sk, D)
    vr = jnp.moveaxis(v, 2, 1).reshape(B * KV, Sk, D)

    grid = (B * H, nq, nk)
    kernel = functools.partial(_fa_kernel, scale=scale, causal=causal,
                               window=window, block_q=bq, block_k=bk,
                               sk_off=sk_off)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, D),
                         lambda bh, qi, ki, G=G, KV=KV:
                         ((bh // G) if G > 1 else bh, ki, 0)),
            pl.BlockSpec((1, bk, D),
                         lambda bh, qi, ki, G=G, KV=KV:
                         ((bh // G) if G > 1 else bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return jnp.moveaxis(out.reshape(B, H, Sq, D), 1, 2)
