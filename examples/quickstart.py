"""Quickstart: the paper's preemption model + policies in ten lines each.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import distributions, fitting, simulator
from repro.core.policies import checkpointing, scheduling, young_daly

# 1. A fleet study: sample preemption lifetimes for 1,516 VMs (the paper's
#    empirical scale) from the calibrated ground-truth process.
trace = simulator.trace_for(jax.random.PRNGKey(42), vm_type="n1-highcpu-16",
                            n=1516)
print(f"observed {trace.shape[0]} preemptions, "
      f"median lifetime {float(jax.numpy.median(trace)):.1f} h")

# 2. Fit the paper's constrained-preemption model (Eq. 1) and baselines.
fits = fitting.fit_all(trace)
ours = fits["constrained"]
d = ours.dist
print(f"fitted: tau1={float(d.tau1):.2f}h tau2={float(d.tau2):.2f}h "
      f"b={float(d.b):.1f}h A={float(d.A):.3f} (lse={float(ours.lse):.3f})")
print(f"  vs exponential lse={float(fits['exponential'].lse):.1f}, "
      f"weibull lse={float(fits['weibull'].lse):.1f}")

# 3. Reliability quantities (Eqs. 2-5).
print(f"expected lifetime E[L] = {float(d.expected_lifetime()):.1f} h; "
      f"hazard at 0.5h/12h/23.5h = {float(d.hazard(0.5)):.3f}/"
      f"{float(d.hazard(12.0)):.4f}/{float(d.hazard(23.5)):.2f} per h")

# 4. Job scheduling / VM-reuse policy (Eqs. 9-10, Fig. 6).
for age in (6.0, 19.0):
    keep = bool(scheduling.reuse_decision(d, 6.0, age))
    print(f"6h job on a {age:.0f}h-old VM -> "
          f"{'reuse it' if keep else 'get a fresh VM'}")

# 5. Optimal checkpoint schedule (Eqs. 11-15, Fig. 7).
tables = checkpointing.solve(d, 300, grid_dt=1 / 60, delta_steps=1)
sched = checkpointing.extract_schedule(tables, 300, 0)
print(f"5h job, 1min checkpoints: DP intervals (min) = {sched}")
tau = float(young_daly.interval(1 / 60, 1.0))
print(f"Young-Daly at MTTF=1h would checkpoint every {tau*60:.0f} min "
      f"({int(5/tau)} checkpoints vs {len(sched)-1})")
