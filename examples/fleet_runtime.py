"""Closed-loop fleet runtime demo: drift, faults, and graceful degradation.

Streams fleet lifetimes through ``runtime.FleetRuntime`` under the
deterministic default fault schedule (a regime drift, a preemption storm,
injected fit divergences and a solve timeout — see `docs/runtime.md`).  The
runtime refits Eq. 1 on a confirmed change point, re-solves the DP
(warm-started from the previous value table) and hot-swaps validated tables
into the standing sweep; every injected fault degrades to the last-good
model/tables instead of crashing.

Run: PYTHONPATH=src python examples/fleet_runtime.py [--quick]

``--quick`` shrinks the stream so the example (and the CI smoke that
executes it) finishes in seconds; the printed structure is identical.
"""
import sys

from repro import fault
from repro.core import runtime as rt

QUICK = "--quick" in sys.argv
n_obs = 320 if QUICK else 800

cfg = rt.RuntimeConfig(
    job_steps=40, grid_dt=0.25, window=128, refit_every=32, min_samples=48,
    stream_block=128, stream_vm_types=("n1-highcpu-2",),
    regret_trials=64 if QUICK else 256, retry_backoff_obs=8, max_retries=3)
schedule = fault.default_schedule(n_obs)
print(f"fault schedule ({n_obs} observations):")
for ev in schedule:
    print(f"  obs {ev.at_obs:4d}: {ev.kind:15s} duration={ev.duration}"
          + (f"  param={ev.param}" if ev.param else ""))

runtime = rt.FleetRuntime(cfg, injector=fault.FaultInjector(schedule, seed=0))
report = runtime.run(n_obs)

print("\nevent log (stream -> track -> refit -> re-solve -> swap):")
for obs, kind, detail in report.events:
    print(f"  obs {obs:4d}: {kind:22s} {detail}")

print(f"\nswaps ({len(report.swaps)}):")
for s in report.swaps:
    regret = ("" if s.regret_frac is None
              else f"  stale-K regret {s.regret_hours:+.2f}h "
                   f"({s.regret_frac:+.1%})")
    print(f"  obs {s.obs:4d}: {s.reason:12s} warm={s.warm!s:5s} "
          f"solve {s.solve_seconds:.2f}s  stale for {s.stale_obs} obs{regret}")

print(f"\nheadline: {report.change_points} change point(s), "
      f"{report.n_refits} refits, retries fit={report.retries['fit']} "
      f"solve={report.retries['solve']}, degraded={report.degraded}")
if report.adaptation_lag_obs is not None:
    print(f"adaptation lag: {report.adaptation_lag_obs} observations from "
          f"injected drift to the answering table swap")

print("\nthe fleet keeps serving: re-evaluating the standing sweep from the "
      "CURRENT live tables (no re-solve)")
rows = runtime.evaluate(n_trials=64 if QUICK else 256)
for r in rows:
    if r["scenario"] == cfg.live_name:
        print(f"  {r['scenario']:12s} {r['policy']:5s}: "
              f"mean {r['makespan_mean']:5.2f}h  p95 {r['makespan_p95']:5.2f}h")
