"""Spot-market sweep: what do the policies actually pay, in dollars?

Builds the default market over the (zone x phase x vm_type) scenario grid —
seeded OU price traces per leaf, with a capacity crunch scheduled on the
tight zone (us-central1-a) — and runs `scenarios.sweep_market` under both
regimes.  The crunch couples into the Eq. 1 early hazard (crunch-zone VMs
die younger) AND lifts the crunch zone's prices, so the fixed policy pays
roughly the crunch premium while cheapest-feasible substitution flees to
the calm zone and keeps costs flat.

Run: PYTHONPATH=src python examples/market_sweep.py [--quick]

``--quick`` shrinks trials/steps so the example (and the CI smoke that
executes it) finishes in seconds; the printed structure is identical.
"""
import sys

import numpy as np

from repro.core import market, scenarios

QUICK = "--quick" in sys.argv
job_steps = 60 if QUICK else 300
n_trials = 60 if QUICK else 400

grid = scenarios.default_grid()
mkt = market.MarketModel.for_scenarios(grid)
print("scenarios:", ", ".join(s.name for s in grid))
print(f"market: horizon {mkt.horizon:.0f}h, dt {mkt.dt:.2f}h, "
      f"crunch on us-central1-a over "
      f"[{mkt.launch_time('crunch'):.0f}h, ...)")

rows = scenarios.sweep_market(grid, market=mkt, job_steps=job_steps,
                              n_trials=n_trials)

print(f"\nexpected dollars per job ({n_trials} trials, "
      f"{job_steps} grid steps):")
for regime in ("calm", "crunch"):
    print(f"  {regime}:")
    for policy in ("fixed", "cheapest", "migrate"):
        sel = [r for r in rows
               if r["regime"] == regime and r["policy"] == policy]
        mean = float(np.nanmean([r["expected_dollars"] for r in sel]))
        n_sub = sum(1 for r in sel if r["chosen"] != r["scenario"])
        print(f"    {policy:9s}: ${mean:6.4f}  "
              f"({n_sub}/{len(sel)} leaves substituted)")

crunch_fixed = float(np.nanmean([r["expected_dollars"] for r in rows
                                 if r["regime"] == "crunch"
                                 and r["policy"] == "fixed" and r["crunch"]]))
crunch_cheap = float(np.nanmean([r["expected_dollars"] for r in rows
                                 if r["regime"] == "crunch"
                                 and r["policy"] == "cheapest"
                                 and r["crunch"]]))
print(f"\non the crunch leaves, cheapest-feasible pays "
      f"{crunch_cheap / crunch_fixed:.2f}x what fixed pays")
