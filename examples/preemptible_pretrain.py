"""End-to-end driver: pre-train a ~100M-class LM on simulated preemptible
pods for a few hundred steps, with the paper's full fault-tolerance stack -
DP checkpoint schedule, 30s-warning emergency checkpoints, restart+restore+
deterministic data replay.

The committed run uses the reduced smollm config so it finishes on CPU in a
couple of minutes; pass --full for the real 135M model (the config is
identical in structure - the framework path is the same one the multi-pod
dry-run compiles at 512 chips).

Run: PYTHONPATH=src python examples/preemptible_pretrain.py [--full]
"""
import argparse
import dataclasses
import shutil

from repro import configs
from repro.configs.base import TrainConfig
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="real smollm-135m (slow on CPU)")
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    if args.full:
        cfg = configs.get("smollm-135m")
    else:
        cfg = dataclasses.replace(configs.smoke("smollm-135m"),
                                  n_layers=4, d_model=128, d_ff=256,
                                  vocab_size=2048)
    ckpt_dir = "/tmp/repro_example_pretrain"
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    tc = TrainConfig(ckpt_dir=ckpt_dir, ckpt_policy="dp", warmup_steps=20,
                     total_steps=args.steps)

    print(f"training {cfg.name} ({cfg.param_count()/1e6:.1f}M params) for "
          f"{args.steps} steps on simulated preemptible pods...")
    res = train(cfg, tc, total_steps=args.steps, inject_preemptions=True,
                sim_hours_per_step=0.05, preemption_seed=11, log_every=50)
    print(f"\nfinal loss {res.final_loss:.4f} "
          f"(first-10 mean {sum(res.losses[:10])/10:.4f})")
    print(f"pod preemptions survived: {res.restarts}; checkpoints: "
          f"{res.checkpoints} ({res.emergency_checkpoints} emergency); "
          f"steps replayed after restarts: {res.wasted_steps}")
    assert res.final_loss < sum(res.losses[:10]) / 10, "must learn"


if __name__ == "__main__":
    main()
