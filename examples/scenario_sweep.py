"""Diurnal scenario sweep (paper Obs. 5): how much gentler are night
launches, and does the advantage survive both evaluation paths?

The default grid spans (zone x phase x vm_type) and the sweep runs the
one-kernel fold end-to-end: one DP solve, one device lifetime pool and ONE
executor dispatch cover the whole (scenario x policy x seed) grid (see
`scenarios.sweep_checkpointing(mode=...)` and the README's leading-axis
worked example).

Run: PYTHONPATH=src python examples/scenario_sweep.py [--quick]

``--quick`` shrinks the trial counts so the example (and the CI smoke that
executes it) finishes in seconds; the printed structure is identical.
"""
import sys

import numpy as np

from repro.core import scenarios

QUICK = "--quick" in sys.argv
n_trials = 120 if QUICK else 500
n_jobs = 10 if QUICK else 30

grid = scenarios.default_grid(vm_types=("n1-highcpu-16", "n1-highcpu-32"),
                              phases=("day", "night"))
print("scenarios:", ", ".join(s.name for s in grid))

print(f"\ncheckpointing executor (5h job, DP vs no-checkpoint, "
      f"{n_trials} trials, one kernel dispatch):")
rows = scenarios.sweep_checkpointing(grid, policies=("dp", "none"),
                                     job_steps=300, n_trials=n_trials)
for r in rows:
    print(f"  {r['scenario']:34s} {r['policy']:5s}: "
          f"mean {r['makespan_mean']:5.2f}h  p95 {r['makespan_p95']:5.2f}h")

print(f"\nbatch service ({n_jobs} x 2h jobs, 8 VMs):")
for r in scenarios.sweep_service(grid, policies=("model",),
                                 cluster_sizes=(8,), n_jobs=n_jobs):
    print(f"  {r['scenario']:34s}: makespan {r['makespan']:5.1f}h  "
          f"failures {r['n_job_failures']:2d}  "
          f"{r['cost_reduction']:.2f}x cheaper than on-demand")

day = [r["p_fail_fresh"] for r in rows if r["phase"] == "day"]
night = [r["p_fail_fresh"] for r in rows if r["phase"] == "night"]
print(f"\nObs. 5 headline: night/day single-attempt failure-probability "
      f"ratio {np.mean(night) / np.mean(day):.3f} (< 1: night launches "
      f"preempt less)")
