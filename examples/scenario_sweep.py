"""Diurnal scenario sweep (paper Obs. 5): how much gentler are night
launches, and does the advantage survive both evaluation paths?

The default grid now spans (zone x phase x vm_type) and the sweep runs the
batched scenario axis end-to-end: one DP solve, one device lifetime pool
and one scenario-batched executor call cover the whole grid (see
`scenarios.sweep_checkpointing(mode=...)`).

Run: PYTHONPATH=src python examples/scenario_sweep.py
"""
import numpy as np

from repro.core import scenarios

grid = scenarios.default_grid(vm_types=("n1-highcpu-16", "n1-highcpu-32"),
                              phases=("day", "night"))
print("scenarios:", ", ".join(s.name for s in grid))

print("\ncheckpointing executor (5h job, DP vs no-checkpoint, 500 trials):")
rows = scenarios.sweep_checkpointing(grid, policies=("dp", "none"),
                                     job_steps=300, n_trials=500)
for r in rows:
    print(f"  {r['scenario']:34s} {r['policy']:5s}: "
          f"mean {r['makespan_mean']:5.2f}h  p95 {r['makespan_p95']:5.2f}h")

print("\nbatch service (30 x 2h jobs, 8 VMs):")
for r in scenarios.sweep_service(grid, policies=("model",),
                                 cluster_sizes=(8,), n_jobs=30):
    print(f"  {r['scenario']:34s}: makespan {r['makespan']:5.1f}h  "
          f"failures {r['n_job_failures']:2d}  "
          f"{r['cost_reduction']:.2f}x cheaper than on-demand")

day = [r["p_fail_fresh"] for r in rows if r["phase"] == "day"]
night = [r["p_fail_fresh"] for r in rows if r["phase"] == "night"]
print(f"\nObs. 5 headline: night/day single-attempt failure-probability "
      f"ratio {np.mean(night) / np.mean(day):.3f} (< 1: night launches "
      f"preempt less)")
