"""Batched serving on preemptible pods: prefill + greedy decode with the
paper's reuse policy deciding pod rotation at admission time.

Run: PYTHONPATH=src python examples/serve_preemptible.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import distributions
from repro.fault import PreemptionSource
from repro.launch.serve import serve_batch
from repro.models import transformer as T

cfg = configs.smoke("llama3.2-1b")
params, _ = T.init(cfg, jax.random.PRNGKey(0))
dist = distributions.constrained_for()
src = PreemptionSource(dist, n_pods=1, seed=3)

rng = np.random.default_rng(0)
sim_now, rotations = 0.0, 0
for i in range(4):
    if not src.reuse_decision(0, 0.05, sim_now):
        src.replace_pod(0, sim_now)
        rotations += 1
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32)
    t0 = time.time()
    toks = serve_batch(cfg, params, prompts, n_decode=16)
    sim_now += 0.05
    print(f"batch {i}: decoded {toks.shape[1]} tokens x {toks.shape[0]} "
          f"requests in {time.time()-t0:.2f}s "
          f"(pod age {src.pod_age(0, sim_now):.2f}h)")
print(f"{rotations} pod rotations (policy-driven)")
