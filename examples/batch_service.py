"""The paper's batch computing service (Fig. 4/8): run a bag of scientific
jobs on a simulated preemptible cluster under the model-driven policies and
compare the bill against on-demand.

Run: PYTHONPATH=src python examples/batch_service.py
"""
import numpy as np

from repro.core import distributions, service

dist = distributions.constrained_for("n1-highcpu-32")

print("bag of 100 x 2h jobs on 32 preemptible n1-highcpu-32 VMs")
for policy in ("model", "memoryless"):
    r = service.run_bag(dist, n_jobs=100, job_hours=2.0, cluster_size=32,
                        policy=policy, seed=3)
    print(f"  {policy:10s}: makespan {r.makespan:5.1f}h  "
          f"preemptions {r.n_preemptions:3d}  "
          f"cost ${r.cost:6.2f} vs on-demand ${r.on_demand_cost:6.2f} "
          f"({r.cost_reduction:.2f}x cheaper)")

print("\nwith model-driven checkpointing enabled:")
r = service.run_bag(dist, n_jobs=100, job_hours=2.0, cluster_size=32,
                    policy="model", seed=3, checkpointing=True,
                    ckpt_interval=0.5)
print(f"  model+ckpt : makespan {r.makespan:5.1f}h  "
      f"preemptions {r.n_preemptions:3d}  cost ${r.cost:6.2f} "
      f"({r.cost_reduction:.2f}x cheaper)")

print("\nlong jobs (4h) - where the bathtub matters most:")
r = service.run_bag(dist, n_jobs=60, job_hours=4.0, cluster_size=32,
                    policy="model", seed=5)
print(f"  model      : makespan {r.makespan:5.1f}h  "
      f"preemptions {r.n_preemptions:3d}  ({r.cost_reduction:.2f}x cheaper)")
